//! ABLATIONS — design choices the paper motivates but does not sweep:
//!
//!   1. partitioning scheme: balanced-random (paper §3) vs iid vs
//!      contiguous — quality and capacity-violation rate;
//!   2. compressor choice: greedy vs stochastic greedy (ε sweep) vs
//!      threshold greedy (β = 1 + 2ε) — quality vs oracle-eval cost;
//!   3. lazy vs naive greedy: oracle evaluations saved by the Minoux
//!      heap (the reason the tree's O(nk) constant is small);
//!   4. best-of-all-rounds vs final-round-only solution tracking
//!      (Algorithm 1 line 11 matters).
//!
//! ```bash
//! cargo bench --bench ablations [-- --quick]
//! ```

mod common;

use std::sync::Arc;

use hss::algorithms::{Compressor, LazyGreedy, StochasticGreedy, ThresholdGreedy};
use hss::bench::{BenchArgs, Table};
use hss::coordinator::PartitionStrategy;
use hss::coordinator::TreeBuilder;
use hss::objectives::Problem;

fn main() -> hss::Result<()> {
    let bargs = BenchArgs::from_env(3);
    let engine = common::maybe_engine();
    let name = if bargs.quick { "csn-2k" } else { "csn-20k" };
    let k = 50usize;
    let mu = 200usize;
    let problem = common::problem_for(name, k, 2, &engine)?;
    let central = common::centralized_cached(&problem, name)?;
    let compressor = common::compressor(&engine);

    // ---- 1. partitioning ---------------------------------------------------
    let mut t1 = Table::new(
        "ablation: partitioning scheme (tree, mu=200)",
        &["mode", "ratio", "violations", "rounds"],
    );
    for (label, mode) in [
        ("balanced-random (paper)", PartitionStrategy::Balanced),
        ("iid multinomial", PartitionStrategy::Iid),
        ("contiguous", PartitionStrategy::Contiguous),
    ] {
        let mut viols = 0usize;
        let mut vals = hss::util::stats::Summary::new();
        let mut rounds = 0usize;
        for t in 0..bargs.trials {
            match TreeBuilder::new(mu)
                .compressor(compressor.clone())
                .partition_mode(mode)
                .build()
                .run(&problem, 31 + t as u64)
            {
                Ok(res) => {
                    vals.push(res.best.value / central.value);
                    rounds = res.rounds;
                }
                Err(hss::Error::CapacityExceeded { .. }) => viols += 1,
                Err(e) => return Err(e),
            }
        }
        t1.row(vec![
            label.into(),
            if vals.is_empty() { "-".into() } else { format!("{:.4}", vals.mean()) },
            format!("{viols}/{}", bargs.trials),
            rounds.to_string(),
        ]);
    }
    t1.print();
    t1.save_json("ablation_partitioning")?;

    // ---- 2. compressor choice ----------------------------------------------
    let mut t2 = Table::new(
        "ablation: compression subprocedure (tree, mu=200)",
        &["compressor", "beta", "ratio", "oracle_evals"],
    );
    let compressors: Vec<(String, Arc<dyn Compressor>)> = vec![
        ("greedy".into(), Arc::new(LazyGreedy::new())),
        ("stochastic eps=0.5".into(), Arc::new(StochasticGreedy::new(0.5))),
        ("stochastic eps=0.2".into(), Arc::new(StochasticGreedy::new(0.2))),
        ("stochastic eps=0.1".into(), Arc::new(StochasticGreedy::new(0.1))),
        ("threshold eps=0.2".into(), Arc::new(ThresholdGreedy::new(0.2))),
        ("threshold eps=0.05".into(), Arc::new(ThresholdGreedy::new(0.05))),
    ];
    for (label, comp) in compressors {
        let evals0 = problem.eval_count();
        let (ratio, _) = common::mean_over_trials(bargs.trials, 77, |seed| {
            Ok(TreeBuilder::new(mu)
                .compressor(comp.clone())
                .build()
                .run(&problem, seed)?
                .best
                .value
                / central.value)
        })?;
        let evals = (problem.eval_count() - evals0) / bargs.trials as u64;
        t2.row(vec![
            label,
            comp.beta().map(|b| format!("{b:.2}")).unwrap_or("-".into()),
            format!("{ratio:.4}"),
            evals.to_string(),
        ]);
        println!("{}", t2.rows.last().unwrap().join("  "));
    }
    t2.print();
    t2.save_json("ablation_compressor")?;

    // ---- 3. lazy vs naive oracle evaluations --------------------------------
    let mut t3 = Table::new(
        "ablation: lazy (Minoux) heap vs naive greedy — oracle evals per machine",
        &["mu", "naive=mu*k", "lazy", "saved"],
    );
    for mu in [200usize, 400, 800] {
        let cands: Vec<u32> = (0..mu as u32).collect();
        let p = Problem::exemplar(problem.dataset.clone(), k, 2);
        LazyGreedy::new().compress(&p, &cands, 1)?;
        let lazy = p.eval_count();
        let naive = (mu * k) as u64;
        t3.row(vec![
            mu.to_string(),
            naive.to_string(),
            lazy.to_string(),
            format!("{:.1}x", naive as f64 / lazy as f64),
        ]);
    }
    t3.print();
    t3.save_json("ablation_lazy")?;

    // ---- 4. best-of-all-rounds vs final-only ---------------------------------
    let mut t4 = Table::new(
        "ablation: Algorithm 1 line 11 (best over all machines/rounds)",
        &["mu", "best_of_all", "final_round_only", "gap_%"],
    );
    for mu in [2 * k, 200, 400] {
        let res = TreeBuilder::new(mu)
            .compressor(compressor.clone())
            .build()
            .run(&problem, 13)?;
        let final_only = res.final_round_best.value;
        let gap = 100.0 * (res.best.value - final_only) / res.best.value;
        t4.row(vec![
            mu.to_string(),
            format!("{:.5}", res.best.value),
            format!("{final_only:.5}"),
            format!("{gap:.3}"),
        ]);
    }
    t4.print();
    t4.save_json("ablation_best_tracking")?;
    Ok(())
}

//! Shared helpers for the bench binaries (each regenerates one paper
//! table/figure — see DESIGN.md §6 for the experiment index).

use std::sync::Arc;

use hss::algorithms::Compressor;
use hss::config::dataset_objective;
use hss::coordinator::baselines;
use hss::error::Result;
use hss::objectives::Problem;
use hss::runtime::accel::XlaGreedy;
use hss::runtime::{EngineHandle, XlaRuntime};

/// Start the XLA device thread if artifacts are built.
pub fn maybe_engine() -> Option<EngineHandle> {
    let dir = hss::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: artifacts/ not built — running pure-rust oracles");
        return None;
    }
    match XlaRuntime::start(&dir) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("note: engine failed to start ({e}); pure-rust oracles");
            None
        }
    }
}

/// Build the problem for a registry dataset with the Table 2 objective.
pub fn problem_for(name: &str, k: usize, seed: u64, engine: &Option<EngineHandle>) -> Result<Problem> {
    let ds = hss::data::registry::load(name, seed)?;
    let mut p = match dataset_objective(name) {
        "logdet" => Problem::logdet(ds, k, seed),
        _ => Problem::exemplar(ds, k, seed),
    };
    if let Some(e) = engine {
        p = p.with_engine(e.clone());
    }
    Ok(p)
}

/// The per-machine compressor for a problem: XLA-fused when available.
pub fn compressor(engine: &Option<EngineHandle>) -> Arc<dyn Compressor> {
    match engine {
        Some(e) => Arc::new(XlaGreedy::new(e.clone())),
        None => Arc::new(hss::algorithms::LazyGreedy::new()),
    }
}

/// Stochastic-greedy compressor (ε) for the problem.
pub fn stochastic_compressor(engine: &Option<EngineHandle>, eps: f64) -> Arc<dyn Compressor> {
    match engine {
        Some(e) => Arc::new(XlaGreedy::stochastic(e.clone(), eps)),
        None => Arc::new(hss::algorithms::StochasticGreedy::new(eps)),
    }
}

/// Centralized greedy, cached on disk per (dataset, k, seed) — it is the
/// denominator of every ratio and expensive at paper scale.
pub fn centralized_cached(problem: &Problem, name: &str) -> Result<hss::algorithms::Solution> {
    let dir = std::path::PathBuf::from("bench_results/.central_cache");
    std::fs::create_dir_all(&dir).ok();
    let key = dir.join(format!("{name}_k{}_s{}.json", problem.k, problem.seed));
    if let Ok(text) = std::fs::read_to_string(&key) {
        if let Ok(v) = hss::util::json::Json::parse(&text) {
            if let (Some(items), Some(value)) = (v.get("items"), v.get("value").and_then(|x| x.as_f64())) {
                let items: Vec<u32> = items
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_usize().map(|u| u as u32))
                    .collect();
                if !items.is_empty() {
                    return Ok(hss::algorithms::Solution { items, value });
                }
            }
        }
    }
    let sol = baselines::centralized(problem)?;
    let doc = hss::util::json::obj(vec![
        ("value", hss::util::json::num(sol.value)),
        (
            "items",
            hss::util::json::arr(sol.items.iter().map(|&i| hss::util::json::num(i as f64))),
        ),
    ]);
    std::fs::write(&key, doc.to_string()).ok();
    Ok(sol)
}

/// Mean of a closure over `trials` seeds.
pub fn mean_over_trials<F: FnMut(u64) -> Result<f64>>(trials: usize, base_seed: u64, mut f: F) -> Result<(f64, f64)> {
    let mut s = hss::util::stats::Summary::new();
    for t in 0..trials {
        s.push(f(base_seed + 1000 * t as u64)?);
    }
    Ok((s.mean(), s.stddev()))
}

//! Straggler dispatch bench — serial barrier vs pipelined event-driven
//! rounds, with an injected straggler.
//!
//! Three scenarios:
//!
//! 1. **tcp / balanced**: three in-process protocol workers, one
//!    started with a per-request `straggle_ms` delay (the `hss worker
//!    --straggle-ms` knob). The pipelined tree runner overlaps
//!    next-round planning and union-building with the straggler's
//!    tail; the serial path idles at the barrier and pays that
//!    coordinator work on the critical path afterwards.
//! 2. **tcp / contiguous**: the same fleet under `--partitioner
//!    contiguous` — the locality-aware regime where the pipelined
//!    runner additionally **speculatively dispatches**
//!    straggler-independent next-round parts into an early-opened
//!    round session: idle workers start round `t+1` while the
//!    straggler still holds round `t`, so the straggler's tail is
//!    overlapped with real compute, not just planning.
//! 3. **sim**: a deterministic virtual straggler
//!    (`straggler_prob = 1`), as a replayable reference — virtual delay
//!    is charged identically on both paths, isolating the real-time
//!    dispatch difference.
//!
//! Emits `bench_results/BENCH_dispatch.json` (diffed against the
//! committed `BENCH_dispatch.json` baseline by the CI smoke job) and
//! exits non-zero if a pipelined path regresses more than 10% behind
//! its serial barrier (non-blocking in CI).
//!
//! ```bash
//! cargo bench --bench dispatch [-- --quick] [--straggle-ms 50]
//! ```

use std::sync::Arc;

use hss::bench::{fmt_ms, BenchArgs, BenchRunner, Table};
use hss::config::RunConfig;
use hss::coordinator::{CapacityProfile, JobRunner, JobSpec, PartitionStrategy, TreeBuilder};
use hss::data::registry;
use hss::dist::worker::{self, WorkerConfig};
use hss::dist::{Backend as _, FaultPlan, SimBackend, TcpBackend};
use hss::objectives::Problem;
use hss::serve::JobScheduler;

fn main() -> hss::Result<()> {
    let bargs = BenchArgs::from_env(5);
    let runner = if bargs.quick {
        BenchRunner::quick()
    } else {
        BenchRunner { warmup: 1, samples: bargs.trials }
    };
    let straggle_ms = bargs.args.u64("straggle-ms", 50)?;
    let (k, mu, seed) = (25usize, 150usize, 42u64);
    let ds = registry::load("csn-2k", seed)?;
    let problem = Problem::exemplar(ds, k, seed);

    let mut table = Table::new(
        &format!(
            "round dispatch with 1 injected straggler \
             (csn-2k, k={k}, mu={mu}, straggle {straggle_ms}ms)"
        ),
        &[
            "backend",
            "partitioner",
            "mode",
            "wall",
            "overlap_ms",
            "requeued",
            "busy_ms",
            "queue_ms",
        ],
    );

    // ---- tcp: real protocol workers, one of them slow --------------------
    let spawn = |ms: u64| {
        worker::spawn_in_process(WorkerConfig {
            listen: "127.0.0.1:0".into(),
            capacity: mu,
            straggle_ms: ms,
            ..WorkerConfig::default()
        })
    };
    let addrs = vec![spawn(0)?, spawn(0)?, spawn(straggle_ms)?];
    let tcp = Arc::new(TcpBackend::new(mu, addrs)?);
    let tree = TreeBuilder::new(mu).backend(tcp.clone()).build();

    // protocol-v5 utilization: worker-reported execute/queue-wait time
    // accumulated by the shared backend — per-row deltas, per run
    let runs = (runner.warmup + runner.samples).max(1) as f64;
    let fleet_busy = |b: &TcpBackend| {
        b.worker_stats()
            .iter()
            .fold((0.0f64, 0.0f64), |acc, w| (acc.0 + w.busy_ms, acc.1 + w.queue_wait_ms))
    };

    let mut requeued = 0u64;
    let util0 = fleet_busy(&tcp);
    let s_serial = runner.time(|| {
        let r = tree.run_serial(&problem, seed).unwrap();
        requeued = r.requeued_parts;
    });
    let util1 = fleet_busy(&tcp);
    table.row(vec![
        "tcp".into(),
        "balanced".into(),
        "serial".into(),
        fmt_ms(&s_serial),
        "0.0".into(),
        requeued.to_string(),
        format!("{:.1}", (util1.0 - util0.0) / runs),
        format!("{:.1}", (util1.1 - util0.1) / runs),
    ]);

    let mut overlap = 0.0f64;
    let util0 = fleet_busy(&tcp);
    let s_piped = runner.time(|| {
        let r = tree.run(&problem, seed).unwrap();
        overlap = r.straggler_overlap_ms;
        requeued = r.requeued_parts;
    });
    let util1 = fleet_busy(&tcp);
    table.row(vec![
        "tcp".into(),
        "balanced".into(),
        "pipelined".into(),
        fmt_ms(&s_piped),
        format!("{overlap:.1}"),
        requeued.to_string(),
        format!("{:.1}", (util1.0 - util0.0) / runs),
        format!("{:.1}", (util1.1 - util0.1) / runs),
    ]);

    // ---- tcp + contiguous: speculative next-round dispatch ---------------
    // Locality-aware partitioning is where speculation pays: next-round
    // parts whose inputs are complete start executing on idle workers
    // while the straggler still holds the current round.
    let contig_tree = TreeBuilder::new(mu)
        .partition_mode(PartitionStrategy::Contiguous)
        .backend(tcp.clone())
        .build();
    let util0 = fleet_busy(&tcp);
    let s_contig_serial = runner.time(|| {
        let r = contig_tree.run_serial(&problem, seed).unwrap();
        requeued = r.requeued_parts;
    });
    let util1 = fleet_busy(&tcp);
    table.row(vec![
        "tcp".into(),
        "contiguous".into(),
        "serial".into(),
        fmt_ms(&s_contig_serial),
        "0.0".into(),
        requeued.to_string(),
        format!("{:.1}", (util1.0 - util0.0) / runs),
        format!("{:.1}", (util1.1 - util0.1) / runs),
    ]);
    let mut contig_overlap = 0.0f64;
    let util0 = fleet_busy(&tcp);
    let s_contig_spec = runner.time(|| {
        let r = contig_tree.run(&problem, seed).unwrap();
        contig_overlap = r.straggler_overlap_ms;
        requeued = r.requeued_parts;
    });
    let util1 = fleet_busy(&tcp);
    table.row(vec![
        "tcp".into(),
        "contiguous".into(),
        "pipelined+speculative".into(),
        fmt_ms(&s_contig_spec),
        format!("{contig_overlap:.1}"),
        requeued.to_string(),
        format!("{:.1}", (util1.0 - util0.0) / runs),
        format!("{:.1}", (util1.1 - util0.1) / runs),
    ]);

    // ---- serve: two tenant jobs over the same shared fleet ---------------
    // The `hss serve` scheduler interleaves two jobs' rounds over one
    // fleet (ticket-FIFO round admission): while one job's straggler
    // part drains, the other job's rounds keep the idle workers busy.
    // Back-to-back serial execution of the same two jobs through the
    // same JobRunner is the reference.
    let job = |dataset: &str, jk: usize, jseed: u64| {
        let mut cfg = RunConfig::default();
        cfg.dataset = dataset.to_string();
        cfg.k = jk;
        cfg.capacity = CapacityProfile::uniform(mu);
        cfg.seed = jseed;
        cfg.trials = 1;
        JobSpec::from_config(cfg)
    };
    let job_a = job("csn-2k", k, seed);
    let job_b = job("tiny-2k", 10, 7);
    let shared: Arc<dyn hss::dist::Backend> = tcp.clone();
    let job_runner = JobRunner::new(shared.clone());
    let util0 = fleet_busy(&tcp);
    let s_jobs_serial = runner.time(|| {
        job_runner.run(&job_a).unwrap();
        job_runner.run(&job_b).unwrap();
    });
    let util1 = fleet_busy(&tcp);
    table.row(vec![
        "serve".into(),
        "balanced".into(),
        "two-jobs-serial".into(),
        fmt_ms(&s_jobs_serial),
        "0.0".into(),
        "0".into(),
        format!("{:.1}", (util1.0 - util0.0) / runs),
        format!("{:.1}", (util1.1 - util0.1) / runs),
    ]);
    let scheduler = JobScheduler::new(shared, 2);
    let util0 = fleet_busy(&tcp);
    let s_jobs_conc = runner.time(|| {
        let a = scheduler.submit(job_a.clone()).unwrap();
        let b = scheduler.submit(job_b.clone()).unwrap();
        scheduler.wait_terminal(a);
        scheduler.wait_terminal(b);
    });
    let util1 = fleet_busy(&tcp);
    table.row(vec![
        "serve".into(),
        "balanced".into(),
        "two-jobs-concurrent".into(),
        fmt_ms(&s_jobs_conc),
        "0.0".into(),
        "0".into(),
        format!("{:.1}", (util1.0 - util0.0) / runs),
        format!("{:.1}", (util1.1 - util0.1) / runs),
    ]);
    tcp.shutdown_workers();

    // ---- sim: deterministic virtual straggler ----------------------------
    let faults = FaultPlan {
        straggler_prob: 1.0,
        straggler_delay_ms: straggle_ms as f64,
        ..FaultPlan::default()
    };
    let sim_tree = |f: &FaultPlan| {
        TreeBuilder::new(mu)
            .backend(Arc::new(SimBackend::new(mu).with_faults(f.clone())))
            .build()
    };
    let s_sim_serial = runner.time(|| {
        sim_tree(&faults).run_serial(&problem, seed).unwrap();
    });
    let mut sim_overlap = 0.0f64;
    let s_sim_piped = runner.time(|| {
        let r = sim_tree(&faults).run(&problem, seed).unwrap();
        sim_overlap = r.straggler_overlap_ms;
    });
    // the sim backend has no per-worker accounting — no wire, no
    // worker-reported telemetry
    table.row(vec![
        "sim".into(),
        "balanced".into(),
        "serial".into(),
        fmt_ms(&s_sim_serial),
        "0.0".into(),
        "0".into(),
        "-".into(),
        "-".into(),
    ]);
    table.row(vec![
        "sim".into(),
        "balanced".into(),
        "pipelined".into(),
        fmt_ms(&s_sim_piped),
        format!("{sim_overlap:.1}"),
        "0".into(),
        "-".into(),
        "-".into(),
    ]);

    table.print();
    table.save_json("BENCH_dispatch").map_err(hss::error::Error::Io)?;

    let speedup = s_serial.mean() / s_piped.mean();
    println!(
        "\ntcp straggler round-trip: serial {:.1} ms vs pipelined {:.1} ms ({speedup:.3}x); \
         coordinator overlapped {overlap:.1} ms of straggler tail per run",
        s_serial.mean(),
        s_piped.mean()
    );
    let contig_speedup = s_contig_serial.mean() / s_contig_spec.mean();
    println!(
        "contiguous + speculative dispatch: serial {:.1} ms vs speculative {:.1} ms \
         ({contig_speedup:.3}x); workers ran {contig_overlap:.1} ms of next-round parts \
         inside the straggler tail per run",
        s_contig_serial.mean(),
        s_contig_spec.mean()
    );
    // Smoke gates (CI runs this job non-blocking): a pipelined path
    // must never be meaningfully SLOWER than the barrier it replaces.
    // The win scales with coordinator-side round work (balanced) and
    // with the straggler tail itself (contiguous + speculative), so on
    // this small reference instance we only guard against regression.
    let mut failed = false;
    if s_piped.mean() > s_serial.mean() * 1.10 {
        eprintln!(
            "DISPATCH REGRESSION: pipelined {:.1} ms > 1.10 × serial {:.1} ms",
            s_piped.mean(),
            s_serial.mean()
        );
        failed = true;
    }
    if s_contig_spec.mean() > s_contig_serial.mean() * 1.10 {
        eprintln!(
            "DISPATCH REGRESSION (contiguous): speculative {:.1} ms > 1.10 × serial {:.1} ms",
            s_contig_spec.mean(),
            s_contig_serial.mean()
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    Ok(())
}

//! FIGURE 2 (a)-(d) — approximation ratio vs capacity, k = 50.
//!
//! Four panels (paper §4.3):
//!   (a) active-set selection, WEBSCOPE-100K   (logdet)
//!   (b) exemplar clustering,  CSN-20K         (exemplar)
//!   (c) active-set selection, PARKINSONS      (logdet)
//!   (d) exemplar clustering,  TINY-10K        (exemplar, d = 3072)
//!
//! Series: TREE, RANDGREEDI (undefined below its min capacity — printed
//! as "-"), RANDOM; all ratios vs centralized GREEDY. The vertical
//! reference is √(nk), the two-round minimum capacity.
//!
//! Expected shape: TREE ≈ 1.0 down to µ = 2k; RANDGREEDI matches TREE
//! above √(nk) and is infeasible below; RANDOM far below both.
//!
//! ```bash
//! cargo bench --bench fig2_capacity [-- --plot b] [-- --full] [-- --quick]
//! ```

mod common;

use hss::bench::{BenchArgs, Table};
use hss::coordinator::{baselines, TreeBuilder};

struct Panel {
    id: char,
    dataset: &'static str,
    quick_dataset: &'static str,
}

const PANELS: [Panel; 4] = [
    Panel { id: 'a', dataset: "webscope-100k", quick_dataset: "webscope-10k" },
    Panel { id: 'b', dataset: "csn-20k", quick_dataset: "csn-2k" },
    Panel { id: 'c', dataset: "parkinsons", quick_dataset: "parkinsons-1k" },
    Panel { id: 'd', dataset: "tiny-10k", quick_dataset: "tiny-2k" },
];

fn main() -> hss::Result<()> {
    let bargs = BenchArgs::from_env(2);
    let engine = common::maybe_engine();
    let full = bargs.args.flag("full");
    let k = bargs.args.usize("k", 50)?;
    let only = bargs.args.get("plot").map(|s| s.chars().next().unwrap());

    for panel in PANELS {
        if let Some(p) = only {
            if p != panel.id {
                continue;
            }
        }
        // default: paper-scale for the cheap panels, scaled for the two
        // expensive ones (webscope-100k centralized logdet is fine; tiny-10k
        // d=3072 is the heavy one)
        let name = if full {
            panel.dataset
        } else if bargs.quick || panel.id == 'd' || panel.id == 'a' {
            panel.quick_dataset
        } else {
            panel.dataset
        };
        let problem = common::problem_for(name, k, 3, &engine)?;
        let n = problem.n();
        let sqrt_nk = ((n * k) as f64).sqrt() as usize;
        println!(
            "\npanel ({}) {} — n = {n}, k = {k}, sqrt(nk) = {sqrt_nk}, objective = {}",
            panel.id,
            name,
            problem.objective.name()
        );

        let compressor = common::compressor(&engine);
        let central = common::centralized_cached(&problem, name)?;

        // geometric capacity sweep from 2k past 2·sqrt(nk)
        let mut capacities = vec![];
        let mut mu = 2 * k;
        while mu <= (2 * sqrt_nk).max(4 * k) && mu < n {
            capacities.push(mu);
            mu = (mu as f64 * 1.7).round() as usize;
        }

        let mut table = Table::new(
            &format!("Fig 2({}) {} k={k} (ratio vs centralized; sqrt(nk)={sqrt_nk})", panel.id, name),
            &["mu", "tree", "tree_rounds", "randgreedi", "random"],
        );

        for &mu in &capacities {
            let mut rounds = 0usize;
            let (tree_val, _) = common::mean_over_trials(bargs.trials, 101, |seed| {
                let res = TreeBuilder::new(mu)
                    .compressor(compressor.clone())
                    .build()
                    .run(&problem, seed)?;
                rounds = res.rounds;
                Ok(res.best.value)
            })?;
            let rg = match baselines::rand_greedi(&problem, mu, compressor.as_ref(), 5) {
                Ok(r) => format!("{:.4}", r.solution.value / central.value),
                Err(hss::Error::CapacityExceeded { .. }) => "-".into(),
                Err(e) => return Err(e),
            };
            let (rand_val, _) = common::mean_over_trials(bargs.trials, 303, |seed| {
                Ok(baselines::random_subset(&problem, seed)?.value)
            })?;
            table.row(vec![
                mu.to_string(),
                format!("{:.4}", tree_val / central.value),
                rounds.to_string(),
                rg,
                format!("{:.4}", rand_val / central.value),
            ]);
            println!("{}", table.rows.last().unwrap().join("  "));
        }
        table.print();
        table.save_json(&format!("fig2{}_capacity_{name}", panel.id))?;
    }
    Ok(())
}

//! FIGURE 2 (e)-(f) — large-scale experiments with GREEDY and
//! STOCHASTIC GREEDY as pruning subprocedures (paper §4.4).
//!
//!   (e) active-set selection, WEBSCOPE (45M in the paper; scaled
//!       surrogate here — see DESIGN.md §4)
//!   (f) exemplar clustering, TINY (1M in the paper; scaled surrogate)
//!
//! Capacity is a small *percentage* of the ground set (0.05% / 0.1%);
//! series: TREE@0.05%, TREE@0.1%, STOCHASTIC-TREE(ε=0.5)@0.05%,
//! STOCHASTIC-TREE(ε=0.2)@0.05%, RANDOM — ratio vs centralized greedy,
//! swept over k.
//!
//! Expected shape (paper Fig 2e/f): all TREE variants ≈ 1.0 on logdet;
//! a slight stochastic-greedy quality dip on exemplar clustering.
//!
//! ```bash
//! cargo bench --bench fig2_largescale [-- --plot e] [-- --quick]
//! ```

mod common;

use hss::bench::{BenchArgs, Table};
use hss::coordinator::{baselines, TreeBuilder};

fn main() -> hss::Result<()> {
    let bargs = BenchArgs::from_env(1);
    let engine = common::maybe_engine();
    let only = bargs.args.get("plot").map(|s| s.chars().next().unwrap());

    let panels: Vec<(char, &str)> = vec![
        ('e', if bargs.quick { "webscope-10k" } else { "webscope-large" }),
        ('f', if bargs.quick { "tiny-2k-d64" } else { "tiny-large" }),
    ];
    let ks: Vec<usize> = if bargs.args.flag("full") {
        vec![25, 50, 100]
    } else if bargs.quick {
        vec![25]
    } else {
        vec![25, 50]
    };

    for (id, name) in panels {
        if let Some(p) = only {
            if p != id {
                continue;
            }
        }
        let spec_n = hss::data::registry::spec(name)?.n();
        // capacity tiers as percentage of n; must exceed max k
        let pct_small = ((spec_n as f64) * 0.0005) as usize;
        let pct_big = ((spec_n as f64) * 0.001) as usize;
        let kmax = *ks.iter().max().unwrap();
        let cap_small = pct_small.max(2 * kmax);
        let cap_big = pct_big.max(4 * kmax);
        println!(
            "\npanel ({id}) {name}: n = {spec_n}, capacities {cap_small} (~0.05%) / {cap_big} (~0.1%)"
        );

        let mut table = Table::new(
            &format!("Fig 2({id}) {name} — ratio vs centralized greedy"),
            &["k", "tree@0.05%", "tree@0.1%", "stoch(0.5)@0.05%", "stoch(0.2)@0.05%", "random"],
        );

        // centralized once at kmax; greedy prefixes give every smaller k
        let p_max = common::problem_for(name, kmax, 3, &engine)?;
        let central_full = common::centralized_cached(&p_max, name)?;

        for &k in &ks {
            let problem = common::problem_for(name, k, 3, &engine)?;
            let prefix: Vec<u32> = central_full.items.iter().copied().take(k).collect();
            let central_k = problem.value(&prefix);

            let greedy = common::compressor(&engine);
            let st05 = common::stochastic_compressor(&engine, 0.5);
            let st02 = common::stochastic_compressor(&engine, 0.2);

            let run = |cap: usize, c: std::sync::Arc<dyn hss::algorithms::Compressor>| -> hss::Result<f64> {
                let res = TreeBuilder::new(cap).compressor(c).build().run(&problem, 17)?;
                Ok(res.best.value / central_k)
            };

            let row = vec![
                k.to_string(),
                format!("{:.4}", run(cap_small, greedy.clone())?),
                format!("{:.4}", run(cap_big, greedy.clone())?),
                format!("{:.4}", run(cap_small, st05)?),
                format!("{:.4}", run(cap_small, st02)?),
                format!("{:.4}", baselines::random_subset(&problem, 5)?.value / central_k),
            ];
            table.row(row);
            println!("{}", table.rows.last().unwrap().join("  "));
        }
        table.print();
        table.save_json(&format!("fig2{id}_largescale_{name}"))?;
    }
    Ok(())
}

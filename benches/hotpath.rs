//! HOT-PATH microbenchmarks — the §Perf instrumentation.
//!
//! Measures each layer of the stack in isolation:
//!   1. per-machine compression latency: pure lazy greedy vs fused XLA
//!      greedy vs per-step XLA, across µ tiers;
//!   2. artifact variants: pallas vs jnp distance kernel inside PJRT;
//!   3. engine overhead: upload + dispatch vs device compute
//!      (roofline context: the dist matmul's FLOP count / time);
//!   4. end-to-end tree wall time at several capacities.
//!
//! ```bash
//! cargo bench --bench hotpath [-- --quick]
//! ```

mod common;

use std::sync::Arc;

use hss::algorithms::{Compressor, LazyGreedy};
use hss::bench::{fmt_ms, BenchArgs, BenchRunner, Table};
use hss::coordinator::TreeBuilder;
use hss::objectives::Problem;
use hss::runtime::accel::XlaGreedy;
use hss::runtime::manifest::Query;

fn main() -> hss::Result<()> {
    let bargs = BenchArgs::from_env(3);
    let runner = if bargs.quick { BenchRunner::quick() } else { BenchRunner { warmup: 1, samples: bargs.trials } };
    let Some(engine) = common::maybe_engine() else {
        eprintln!("hotpath bench requires artifacts (make artifacts)");
        return Ok(());
    };

    let k = 50usize;
    let ds = hss::data::registry::load("csn-20k", 1)?;
    let problem = Problem::exemplar(ds.clone(), k, 1).with_engine(engine.clone());

    // ---- 1. per-machine compression latency ------------------------------
    let mut t1 = Table::new(
        "per-machine compression (csn-20k, k=50): pure vs fused XLA",
        &["mu", "pure_greedy", "xla_fused", "speedup"],
    );
    for mu in [128usize, 256, 512, 1024, 2048] {
        let cands: Vec<u32> = (0..mu as u32).collect();
        let pure = LazyGreedy::new();
        let xla = XlaGreedy::new(engine.clone());
        let sp = runner.time(|| {
            pure.compress(&problem, &cands, 1).unwrap();
        });
        let sx = runner.time(|| {
            xla.compress(&problem, &cands, 1).unwrap();
        });
        t1.row(vec![
            mu.to_string(),
            fmt_ms(&sp),
            fmt_ms(&sx),
            format!("{:.2}x", sp.mean() / sx.mean()),
        ]);
        println!("{}", t1.rows.last().unwrap().join("  "));
    }
    t1.print();
    t1.save_json("hotpath_machine")?;

    // ---- 2. pallas vs jnp artifact inside PJRT ---------------------------
    let mut t2 = Table::new(
        "artifact variants: pallas vs jnp (same computation, same PJRT client)",
        &["kind", "shape", "jnp", "pallas", "jnp/pallas"],
    );
    for (kind, min_mu, d) in [("dist", 1024usize, 17usize), ("rbf", 1024, 22), ("exgreedy", 1024, 17)] {
        let q = |pallas| Query {
            kind,
            min_m: if kind == "rbf" { 1024 } else { 2048 },
            min_mu,
            min_d: d,
            min_k: if kind == "exgreedy" { k } else { 0 },
            pallas: Some(pallas),
        };
        let (Ok(art_j), Ok(art_p)) = (engine.select(&q(false)), engine.select(&q(true))) else {
            continue; // variant not in the artifact set
        };
        let cands: Vec<u32> = (0..min_mu as u32).collect();
        let run_art = |art: &hss::runtime::Artifact| -> hss::Result<f64> {
            let x = ds.gather_padded(&cands, art.mu, art.d);
            let t0 = std::time::Instant::now();
            match kind {
                "dist" => {
                    let w = ds.gather_padded(&problem.eval_ids, art.m, art.d);
                    engine.dist(art, 0xbe9c, &w, x)?;
                }
                "rbf" => {
                    let a = ds.gather_padded(&cands, art.m, art.d);
                    engine.rbf(art, a, x)?;
                }
                _ => {
                    let w = ds.gather_padded(&problem.eval_ids, art.m, art.d);
                    let mut sm = vec![0.0f32; art.k * art.mu];
                    for t in 0..art.k {
                        sm[t * art.mu..t * art.mu + min_mu].fill(1.0);
                    }
                    engine.exgreedy(art, 0xbe9d, &w, x, sm)?;
                }
            }
            Ok(t0.elapsed().as_secs_f64() * 1e3)
        };
        // warm both once (compile), then time
        run_art(&art_j)?;
        run_art(&art_p)?;
        let mut sj = hss::util::stats::Summary::new();
        let mut sp = hss::util::stats::Summary::new();
        for _ in 0..runner.samples {
            sj.push(run_art(&art_j)?);
            sp.push(run_art(&art_p)?);
        }
        t2.row(vec![
            kind.into(),
            format!("m{}xu{}xd{}", art_j.m, art_j.mu, art_j.d),
            fmt_ms(&sj),
            fmt_ms(&sp),
            format!("{:.2}x", sp.mean() / sj.mean()),
        ]);
        println!("{}", t2.rows.last().unwrap().join("  "));
    }
    t2.print();
    t2.save_json("hotpath_variants")?;

    // ---- 3. roofline context for the dist matmul -------------------------
    let art = engine.select(&Query {
        kind: "dist", min_m: 2048, min_mu: 2048, min_d: 17, min_k: 0, pallas: Some(false),
    })?;
    let w = ds.gather_padded(&problem.eval_ids, art.m, art.d);
    let cands: Vec<u32> = (0..2048).collect();
    let x = ds.gather_padded(&cands, art.mu, art.d);
    engine.dist(&art, 0xf00f, &w, x.clone())?; // warm
    let s = runner.time(|| {
        engine.dist(&art, 0xf00f, &w, x.clone()).unwrap();
    });
    let flops = 2.0 * art.m as f64 * art.mu as f64 * art.d as f64;
    println!(
        "\ndist m{}xu{}xd{}: {:.2} ms -> {:.2} GFLOP/s (cross-term matmul only)",
        art.m, art.mu, art.d,
        s.median(),
        flops / (s.median() / 1e3) / 1e9
    );

    // ---- 4. end-to-end tree wall time -------------------------------------
    let mut t4 = Table::new(
        "end-to-end tree (csn-20k, k=50): wall time by capacity and substrate",
        &["mu", "pure_s", "xla_s", "speedup"],
    );
    let caps: &[usize] = if bargs.quick { &[400] } else { &[200, 400, 800] };
    for &mu in caps {
        let pure_p = Problem::exemplar(ds.clone(), k, 1);
        let t0 = std::time::Instant::now();
        TreeBuilder::new(mu).build().run(&pure_p, 3)?;
        let pure_s = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        TreeBuilder::new(mu)
            .compressor(Arc::new(XlaGreedy::new(engine.clone())))
            .build()
            .run(&problem, 3)?;
        let xla_s = t0.elapsed().as_secs_f64();
        t4.row(vec![
            mu.to_string(),
            format!("{pure_s:.2}"),
            format!("{xla_s:.2}"),
            format!("{:.2}x", pure_s / xla_s),
        ]);
        println!("{}", t4.rows.last().unwrap().join("  "));
    }
    t4.print();
    t4.save_json("hotpath_tree")?;

    let (calls, compiles, exec_ns, upload, hits) = engine.stats().snapshot();
    println!(
        "\nengine totals: {calls} calls, {compiles} compiles, {:.2} s device, {:.0} MB uploaded, {hits} cache hits",
        exec_ns as f64 / 1e9,
        upload as f64 / 1e6
    );
    Ok(())
}

//! Oracle-kernel bench — the batched engine gain path vs the scalar
//! one-at-a-time loop, per objective × engine × machine capacity µ.
//!
//! This is the per-machine hot loop the pluggable-engine refactor
//! targets: `lazy_greedy_over` refreshes stale heap entries in blocks
//! through `Oracle::gains_for`, which lands in the engine's blocked
//! kernels (`linalg/block.rs`) as one call instead of µ virtual
//! dispatches + eval-counter atomics. Both paths compute bit-identical
//! gains (the differential tests in `objectives/` enforce it); this
//! bench measures what the batching buys.
//!
//! For each objective (exemplar, logdet) × engine (native, xla) ×
//! µ ∈ {128, 512, 2048}, a µ-candidate oracle with a warm selection
//! state serves one full sweep of gains, scalar (`gain(j)` µ times)
//! and batched (`bulk_gains()`), reporting wall-ms and oracle-evals/sec.
//!
//! Emits `bench_results/BENCH_oracle.json` (diffed against the
//! committed `BENCH_oracle.json` baseline by the advisory CI job) and
//! exits non-zero if the NativeEngine batched path falls under the
//! issue's acceptance floor of 2× the scalar evals/sec on logdet at
//! µ = 2048.
//!
//! ```bash
//! cargo bench --bench oracle [-- --quick] [--eval-rows 512]
//! ```

use std::hint::black_box;
use std::sync::Arc;

use hss::bench::{fmt_ms, BenchArgs, BenchRunner, Table};
use hss::data::{synthetic, DatasetRef};
use hss::objectives::Problem;
use hss::runtime::EngineChoice;

fn main() -> hss::Result<()> {
    let bargs = BenchArgs::from_env(5);
    let runner = if bargs.quick {
        BenchRunner::quick()
    } else {
        BenchRunner { warmup: 1, samples: bargs.trials }
    };
    // exemplar evaluation-subsample size: fixed so per-candidate work is
    // constant while µ scales (the paper's high-d setting uses 512)
    let eval_m = bargs.args.usize("eval-rows", 512)?;
    let mus = [128usize, 512, 2048];

    let mut table = Table::new(
        &format!(
            "oracle gain kernels, batched engine path vs scalar loop \
             (exemplar over {eval_m} eval rows)"
        ),
        &["objective", "engine", "mu", "path", "wall", "evals_s"],
    );

    // "<objective>/<engine>/<mu>/<path>" -> evals/sec, for the gate
    let mut rates: Vec<(String, f64)> = Vec::new();

    for &mu in &mus {
        let ds: DatasetRef = Arc::new(synthetic::csn_like(mu, 11));
        for engine in [EngineChoice::Native, EngineChoice::Xla] {
            let problems = [
                ("exemplar", Problem::exemplar_with_eval(ds.clone(), 8, 11, eval_m)),
                ("logdet", Problem::logdet(ds.clone(), 8, 11)),
            ];
            for (name, p) in problems {
                let p = p.with_compute(engine.build());
                let cands: Vec<u32> = (0..mu as u32).collect();
                // warm selection state: a few committed items so gains
                // take the mid-run path, not the empty-set shortcut
                let mut oracle = p.oracle(&cands);
                for j in [0usize, mu / 2, mu - 1] {
                    oracle.commit(j);
                }
                let js: Vec<usize> = (0..mu).collect();
                let s_scalar = runner.time(|| {
                    for &j in &js {
                        black_box(oracle.gain(j));
                    }
                });
                let s_batched = runner.time(|| {
                    black_box(oracle.bulk_gains());
                });
                for (path, summary) in [("scalar", s_scalar), ("batched", s_batched)] {
                    let evals_s = mu as f64 / (summary.mean() / 1e3).max(1e-12);
                    table.row(vec![
                        name.into(),
                        engine.wire_name().into(),
                        mu.to_string(),
                        path.into(),
                        fmt_ms(&summary),
                        format!("{evals_s:.0}"),
                    ]);
                    rates.push((
                        format!("{name}/{}/{mu}/{path}", engine.wire_name()),
                        evals_s,
                    ));
                }
            }
        }
    }

    table.print();
    table.save_json("BENCH_oracle").map_err(hss::error::Error::Io)?;

    // Smoke gate (CI runs this job non-blocking). The issue's acceptance
    // floor: NativeEngine batched ≥ 2× scalar evals/sec on logdet at
    // µ = 2048.
    let rate = |key: &str| {
        rates
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, r)| *r)
            .unwrap_or(0.0)
    };
    let scalar = rate("logdet/native/2048/scalar");
    let batched = rate("logdet/native/2048/batched");
    let speedup = batched / scalar.max(1e-12);
    println!("logdet mu=2048 native: batched path {speedup:.2}x the scalar evals/sec");
    if speedup < 2.0 {
        eprintln!(
            "ORACLE REGRESSION: logdet mu=2048 batched gains are only {speedup:.2}x \
             the scalar path (issue floor: 2x)"
        );
        std::process::exit(1);
    }
    Ok(())
}

//! Wire-serialization bench — protocol-v6 binary row payloads vs pure
//! JSON, and lazy byte-scanner vs full-tree control-frame reads.
//!
//! Measures the two hot frames end to end (`compress` requests carrying
//! a row-id block, `solution` responses carrying an item block):
//!
//! * **encode**: message → frame payload, per encoding;
//! * **decode**: frame payload → message, per encoding — the binary
//!   path reads the id block zero-copy from the blob section, the JSON
//!   path goes through the `parse_u32_array` fast path;
//! * **control reads**: the same JSON `solution` frame decoded via the
//!   lazy scanner ([`Response::decode`]) vs the full-tree parser
//!   ([`Json::parse`] + [`Response::from_json`]).
//!
//! Emits `bench_results/BENCH_serialization.json` (diffed against the
//! committed `BENCH_serialization.json` baseline by the advisory CI
//! job) and exits non-zero if binary row-block decode falls under the
//! issue's acceptance floor of 2× the JSON decode throughput.
//!
//! ```bash
//! cargo bench --bench serialization [-- --quick] [--rows 200000]
//! ```

use std::hint::black_box;

use hss::bench::{fmt_ms, BenchArgs, BenchRunner, Table};
use hss::dist::protocol::{PayloadMode, Request, Response, Telemetry};
use hss::util::json::Json;

/// One throughput measurement: mean wall ms → rows/sec and MB/sec over
/// the fixed `rows`-id block.
fn throughput(mean_ms: f64, rows: usize, bytes: usize) -> (f64, f64) {
    let secs = (mean_ms / 1e3).max(1e-12);
    (rows as f64 / secs, bytes as f64 / secs / (1024.0 * 1024.0))
}

fn main() -> hss::Result<()> {
    let bargs = BenchArgs::from_env(5);
    let runner = if bargs.quick {
        BenchRunner::quick()
    } else {
        BenchRunner { warmup: 1, samples: bargs.trials }
    };
    let rows = bargs.args.usize("rows", if bargs.quick { 20_000 } else { 200_000 })?;

    let ids: Vec<u32> = (0..rows as u32).map(|i| i.wrapping_mul(2_654_435_761) >> 8).collect();
    let request = Request::Compress {
        problem_id: 3,
        compressor: "greedy".into(),
        part: ids.clone(),
        cap: rows,
        seed: 42,
    };
    let response = Response::Solution {
        items: ids,
        value: 1234.5678,
        evals: 987_654_321,
        wall_ms: 12.5,
        telemetry: Telemetry { queue_wait_ms: 0.25, ..Telemetry::default() },
    };

    let mut table = Table::new(
        &format!("wire serialization, {rows}-id row blocks (protocol v6)"),
        &["frame", "op", "encoding", "wall", "Mrows_s", "MB_s", "bytes"],
    );

    /// Bench one (frame, mode) pair: encode and decode rows, returning
    /// the decode throughput in rows/sec for the acceptance gate.
    fn bench_frame<E, D>(
        table: &mut Table,
        runner: &BenchRunner,
        rows: usize,
        frame_name: &str,
        mode: PayloadMode,
        encode: E,
        decode: D,
    ) -> f64
    where
        E: Fn() -> Vec<u8>,
        D: Fn(&[u8]),
    {
        let payload = encode();
        let bytes = payload.len();

        let s_enc = runner.time(|| {
            black_box(encode());
        });
        let (rs, mbs) = throughput(s_enc.mean(), rows, bytes);
        table.row(vec![
            frame_name.into(),
            "encode".into(),
            mode.wire_name().into(),
            fmt_ms(&s_enc),
            format!("{:.2}", rs / 1e6),
            format!("{mbs:.1}"),
            bytes.to_string(),
        ]);

        let s_dec = runner.time(|| decode(&payload));
        let (rs, mbs) = throughput(s_dec.mean(), rows, bytes);
        table.row(vec![
            frame_name.into(),
            "decode".into(),
            mode.wire_name().into(),
            fmt_ms(&s_dec),
            format!("{:.2}", rs / 1e6),
            format!("{mbs:.1}"),
            bytes.to_string(),
        ]);
        rs
    }

    // decode throughputs the acceptance gate reads back, keyed below
    let mut decode_rows_per_sec: Vec<(&'static str, PayloadMode, f64)> = Vec::new();
    for mode in [PayloadMode::Json, PayloadMode::Binary] {
        let rs = bench_frame(
            &mut table,
            &runner,
            rows,
            "compress-request",
            mode,
            || request.encode(mode),
            |payload| {
                black_box(Request::decode(black_box(payload), mode).unwrap());
            },
        );
        decode_rows_per_sec.push(("compress-request", mode, rs));
        let rs = bench_frame(
            &mut table,
            &runner,
            rows,
            "solution-response",
            mode,
            || response.encode(mode),
            |payload| {
                black_box(Response::decode(black_box(payload), mode).unwrap());
            },
        );
        decode_rows_per_sec.push(("solution-response", mode, rs));
    }

    // ---- lazy scanner vs full-tree parse on the same JSON frame ----------
    let json_payload = response.encode(PayloadMode::Json);
    let s_lazy = runner.time(|| {
        black_box(Response::decode(black_box(&json_payload), PayloadMode::Json).unwrap());
    });
    let s_full = runner.time(|| {
        let text = std::str::from_utf8(black_box(&json_payload)).unwrap();
        black_box(Response::from_json(&Json::parse(text).unwrap()).unwrap());
    });
    for (name, summary) in [("lazy-scan", &s_lazy), ("full-tree", &s_full)] {
        let (rs, mbs) = throughput(summary.mean(), rows, json_payload.len());
        table.row(vec![
            "solution-response".into(),
            "decode".into(),
            name.into(),
            fmt_ms(summary),
            format!("{:.2}", rs / 1e6),
            format!("{mbs:.1}"),
            json_payload.len().to_string(),
        ]);
    }

    table.print();
    table.save_json("BENCH_serialization").map_err(hss::error::Error::Io)?;

    // Smoke gates (CI runs this job non-blocking). The issue's
    // acceptance floor: binary row-block decode ≥ 2× JSON decode.
    let rate = |frame: &str, mode: PayloadMode| {
        decode_rows_per_sec
            .iter()
            .find(|(f, m, _)| *f == frame && *m == mode)
            .map(|(_, _, r)| *r)
            .unwrap_or(0.0)
    };
    let mut failed = false;
    for frame in ["compress-request", "solution-response"] {
        let (bin, json) = (rate(frame, PayloadMode::Binary), rate(frame, PayloadMode::Json));
        let speedup = bin / json.max(1e-12);
        println!("{frame}: binary decode {speedup:.2}x the JSON decode throughput");
        if speedup < 2.0 {
            eprintln!(
                "SERIALIZATION REGRESSION: {frame} binary decode is only {speedup:.2}x \
                 JSON (issue floor: 2x)"
            );
            failed = true;
        }
    }
    let lazy_speedup = (rows as f64 / (s_lazy.mean() / 1e3)) / (rows as f64 / (s_full.mean() / 1e3));
    println!("solution-response JSON: lazy scan {lazy_speedup:.2}x the full-tree decode");
    if s_lazy.mean() > s_full.mean() * 1.10 {
        eprintln!(
            "SERIALIZATION REGRESSION: lazy scan {:.2} ms is slower than the full-tree \
             parse {:.2} ms it replaces",
            s_lazy.mean(),
            s_full.mean()
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    Ok(())
}

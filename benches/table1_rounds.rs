//! TABLE 1 — distributed-algorithm cost profile: minimum capacity,
//! rounds, oracle evaluations, machines.
//!
//! Regenerates the paper's comparison empirically on this testbed:
//! * measured rounds vs the Prop 3.1 formula across a capacity sweep;
//! * oracle evaluations vs the O(nk) claim;
//! * machines provisioned vs the O(n/µ) claim;
//! * the two-round baselines' minimum-capacity wall (√(nk)): RANDGREEDI
//!   hard-fails below it, TREE keeps working down to µ = 2k.
//!
//! ```bash
//! cargo bench --bench table1_rounds [-- --quick]
//! ```

mod common;

use hss::bench::{BenchArgs, Table};
use hss::coordinator::{baselines, planner, TreeBuilder};

fn main() -> hss::Result<()> {
    let bargs = BenchArgs::from_env(3);
    let engine = common::maybe_engine();
    let default_ds = if bargs.quick { "csn-2k" } else { "csn-20k" };
    let dataset = bargs.args.get_or("dataset", default_ds).to_string();
    let k = bargs.args.usize("k", 50)?;
    let seed = 1u64;

    let problem = common::problem_for(&dataset, k, seed, &engine)?;
    let n = problem.n();
    let sqrt_nk = ((n * k) as f64).sqrt();
    let min_two_round = baselines::two_round_min_capacity(n, k);
    println!(
        "dataset {dataset}: n = {n}, k = {k}, sqrt(nk) = {sqrt_nk:.0}, \
         two-round min capacity = {min_two_round}"
    );

    let mut table = Table::new(
        "Table 1 (empirical): capacity / rounds / oracle evals / machines",
        &[
            "mu", "algo", "feasible", "rounds", "bound", "evals", "evals/nk",
            "machines", "n/mu", "ratio",
        ],
    );

    let mut default_mus: Vec<usize> = [2 * k, 4 * k, 200, 400, 800, 1600, 3200]
        .into_iter()
        .filter(|&mu| mu < 2 * n)
        .collect();
    default_mus.sort_unstable();
    default_mus.dedup();
    let capacities = bargs.args.usize_list("mus", &default_mus)?;
    let compressor = common::compressor(&engine);
    let central = common::centralized_cached(&problem, &dataset)?;

    for &mu in &capacities {
        if mu <= k {
            continue;
        }
        // TREE
        let evals0 = problem.eval_count();
        let res = TreeBuilder::new(mu)
            .compressor(compressor.clone())
            .build()
            .run(&problem, seed)?;
        let evals = problem.eval_count() - evals0;
        table.row(vec![
            mu.to_string(),
            "tree".into(),
            "yes".into(),
            res.rounds.to_string(),
            planner::round_bound(n, k, mu).to_string(),
            res.oracle_evals.to_string(),
            format!("{:.2}", evals as f64 / (n * k) as f64),
            res.total_machines.to_string(),
            n.div_ceil(mu).to_string(),
            format!("{:.4}", res.best.value / central.value),
        ]);

        // RANDGREEDI at the same capacity
        match baselines::rand_greedi(&problem, mu, compressor.as_ref(), seed) {
            Ok(rg) => table.row(vec![
                mu.to_string(),
                "randgreedi".into(),
                "yes".into(),
                "2".into(),
                "2".into(),
                "-".into(),
                "-".into(),
                rg.machines.to_string(),
                n.div_ceil(mu).to_string(),
                format!("{:.4}", rg.solution.value / central.value),
            ]),
            Err(hss::Error::CapacityExceeded { got, .. }) => table.row(vec![
                mu.to_string(),
                "randgreedi".into(),
                format!("NO ({got}>{mu})"),
                "-".into(),
                "2".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]),
            Err(e) => return Err(e),
        }
    }

    table.print();
    table.save_json("table1_rounds")?;

    // O(nk) check across n at fixed µ (scaling columns of Table 1)
    let mut scale = Table::new(
        "Table 1 (scaling): oracle evaluations are O(nk) for TREE",
        &["n", "evals", "evals/nk", "machines", "rounds"],
    );
    let ns: &[usize] = if bargs.quick {
        &[1_000, 2_000, 4_000]
    } else {
        &[2_000, 4_000, 8_000, 16_000]
    };
    for &n in ns {
        let ds = std::sync::Arc::new(hss::data::synthetic::csn_like(n, 9));
        let mut p = hss::objectives::Problem::exemplar(ds, k, 9);
        if let Some(e) = &engine {
            p = p.with_engine(e.clone());
        }
        let res = TreeBuilder::new(200)
            .compressor(compressor.clone())
            .build()
            .run(&p, 2)?;
        scale.row(vec![
            n.to_string(),
            res.oracle_evals.to_string(),
            format!("{:.3}", res.oracle_evals as f64 / (n * k) as f64),
            res.total_machines.to_string(),
            res.rounds.to_string(),
        ]);
    }
    scale.print();
    scale.save_json("table1_scaling")?;
    Ok(())
}

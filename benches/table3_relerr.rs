//! TABLE 3 — relative error (%) w.r.t. centralized GREEDY for three
//! fixed capacities µ ∈ {200, 400, 800} and k ∈ {50, 100}, plus the
//! RANDOM column, across the four evaluation datasets.
//!
//! Paper shape to reproduce: TREE ≤ ~0.4% error everywhere; RANDOM
//! 20-60%.
//!
//! ```bash
//! cargo bench --bench table3_relerr            # scaled datasets
//! cargo bench --bench table3_relerr -- --full  # paper-scale datasets
//! cargo bench --bench table3_relerr -- --quick # smallest/fastest
//! ```

mod common;

use hss::bench::{BenchArgs, Table};
use hss::coordinator::{baselines, TreeBuilder};

fn main() -> hss::Result<()> {
    let bargs = BenchArgs::from_env(2);
    let engine = common::maybe_engine();
    let full = bargs.args.flag("full");

    // Paper datasets (Table 2). Default trims the two expensive ones for
    // the single-core budget; --full restores the paper grid.
    let datasets: Vec<&str> = if full {
        vec!["webscope-100k", "csn-20k", "parkinsons", "tiny-10k"]
    } else if bargs.quick {
        vec!["webscope-10k", "csn-2k", "parkinsons-1k", "tiny-2k"]
    } else {
        vec!["webscope-10k", "csn-20k", "parkinsons", "tiny-2k"]
    };
    let ks: Vec<usize> = if bargs.quick { vec![50] } else { vec![50, 100] };
    let mus = [200usize, 400, 800];
    let trials = bargs.trials;

    let mut table = Table::new(
        "Table 3: relative error (%) vs centralized GREEDY",
        &["dataset", "k", "mu200", "mu400", "mu800", "random"],
    );

    for name in &datasets {
        for &k in &ks {
            let problem = common::problem_for(name, k, 7, &engine)?;
            let compressor = common::compressor(&engine);
            let central = common::centralized_cached(&problem, name)?;
            let mut cells = vec![name.to_string(), k.to_string()];
            for &mu in &mus {
                if mu <= k {
                    cells.push("-".into());
                    continue;
                }
                let (mean_val, _) = common::mean_over_trials(trials, 11, |seed| {
                    Ok(TreeBuilder::new(mu)
                        .compressor(compressor.clone())
                        .build()
                        .run(&problem, seed)?
                        .best
                        .value)
                })?;
                let rel_err = 100.0 * (1.0 - mean_val / central.value);
                cells.push(format!("{rel_err:.3}"));
            }
            let (rand_val, _) = common::mean_over_trials(trials, 23, |seed| {
                Ok(baselines::random_subset(&problem, seed)?.value)
            })?;
            cells.push(format!("{:.2}", 100.0 * (1.0 - rand_val / central.value)));
            table.row(cells);
            // stream rows as they land (long bench)
            println!("{}", table.rows.last().unwrap().join("  "));
        }
    }

    table.print();
    table.save_json("table3_relerr")?;
    Ok(())
}

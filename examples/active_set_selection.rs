//! Active-set selection for sparse GP inference (paper §4.2): maximize
//! the Informative Vector Machine objective
//! `f(S) = 1/2 logdet(I + σ⁻² K_SS)` over a Webscope-like click-feature
//! dataset, distributed under fixed capacity.
//!
//! ```bash
//! cargo run --release --example active_set_selection \
//!     [-- --dataset webscope-10k --k 50 --capacity 400]
//! ```

use std::sync::Arc;

use hss::coordinator::baselines;
use hss::prelude::*;
use hss::runtime::accel::XlaGreedy;

fn main() -> Result<()> {
    let args = hss::util::cli::Args::from_env()?;
    let name = args.get_or("dataset", "webscope-10k");
    let k = args.usize("k", 50)?;
    let capacity = args.usize("capacity", 400)?;
    let seed = args.u64("seed", 5)?;

    let dataset = hss::data::registry::load(name, seed)?;
    println!("dataset {name}: n = {}, d = {} (user click features)", dataset.n, dataset.d);
    let mut problem = Problem::logdet(dataset, k, seed);

    let engine = if args.flag("no-engine") {
        None
    } else {
        XlaRuntime::start_default().ok()
    };
    if let Some(e) = &engine {
        problem = problem.with_engine(e.clone());
    }

    let tree = match &engine {
        Some(e) => TreeBuilder::new(capacity)
            .compressor(Arc::new(XlaGreedy::new(e.clone())))
            .build(),
        None => TreeBuilder::new(capacity).build(),
    };
    let t0 = std::time::Instant::now();
    let result = tree.run(&problem, seed)?;
    println!(
        "tree        f(S) = {:.5} nats  ({} rounds, {} machines, {:.0} ms)",
        result.best.value,
        result.rounds,
        result.total_machines,
        t0.elapsed().as_secs_f64() * 1e3
    );

    let central = baselines::centralized(&problem)?;
    println!("centralized f(S) = {:.5} nats", central.value);
    println!("random      f(S) = {:.5} nats", baselines::random_subset(&problem, 1)?.value);
    println!(
        "information captured vs centralized: {:.2}%",
        100.0 * result.best.value / central.value
    );

    // Interpretation: the active set supports O(k²) GP inference instead
    // of O(n²); report the compression factor.
    println!(
        "active set: {} of {} points ({}x kernel-matrix compression)",
        result.best.items.len(),
        problem.n(),
        problem.n() / result.best.items.len().max(1)
    );
    Ok(())
}

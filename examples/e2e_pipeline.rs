//! END-TO-END DRIVER — proves all three layers compose on a real
//! workload (recorded in EXPERIMENTS.md §E2E).
//!
//! Pipeline exercised:
//!   L1/L2  Pallas distance kernel + JAX greedy graph, AOT-lowered to
//!          artifacts/*.hlo.txt by `make artifacts` (build time, python)
//!   L3     rust coordinator: balanced random partitioner → simulated
//!          fixed-capacity cluster → fused XLA greedy per machine →
//!          multi-round tree compression
//!
//! Workload: paper-scale CSN (n = 20 000, d = 17, exemplar objective,
//! k = 50) — the paper's Figure 2(b)/Table 3 setting — at three
//! capacities including the extreme µ = 2k. Headline metric: relative
//! error vs centralized GREEDY (paper reports < 1%).
//!
//! ```bash
//! cargo run --release --example e2e_pipeline [-- --quick]
//! ```

use std::sync::Arc;

use hss::coordinator::{baselines, TreeBuilder};
use hss::prelude::*;
use hss::runtime::accel::XlaGreedy;

fn main() -> Result<()> {
    let args = hss::util::cli::Args::from_env()?;
    let quick = args.flag("quick");
    let name = if quick { "csn-2k" } else { "csn-20k" };
    let k = args.usize("k", 50)?;
    let seed = 2016; // ICML 2016 :)

    println!("=== hss end-to-end pipeline ===");
    let t_load = std::time::Instant::now();
    let dataset = hss::data::registry::load(name, seed)?;
    println!(
        "[data]    {name}: n = {}, d = {} ({} MB) in {:.0} ms",
        dataset.n,
        dataset.d,
        dataset.raw().len() * 4 / 1_000_000,
        t_load.elapsed().as_secs_f64() * 1e3
    );

    let t_eng = std::time::Instant::now();
    let engine = XlaRuntime::start_default()?;
    println!(
        "[runtime] PJRT engine up with {} AOT artifacts ({:.0} ms)",
        engine.manifest().artifacts.len(),
        t_eng.elapsed().as_secs_f64() * 1e3
    );

    let problem = Problem::exemplar(dataset, k, seed).with_engine(engine.clone());
    println!(
        "[problem] exemplar clustering, k = {k}, eval subsample m = {}",
        problem.eval_ids.len()
    );

    // Centralized greedy reference (XLA bulk pass + lazy heap).
    let t_c = std::time::Instant::now();
    let central = baselines::centralized(&problem)?;
    println!(
        "[central] f(S*) = {:.6} in {:.1} s ({} oracle evals)",
        central.value,
        t_c.elapsed().as_secs_f64(),
        problem.eval_count()
    );

    let n = problem.n();
    let mut table = hss::bench::Table::new(
        "e2e: tree compression vs centralized greedy (csn, k=50)",
        &["capacity", "rounds", "machines", "f(S)", "rel_err_%", "floor", "wall_s"],
    );
    let capacities = if quick {
        vec![2 * k, 8 * k]
    } else {
        vec![2 * k, 200, 800]
    };
    for capacity in capacities {
        let tree = TreeBuilder::new(capacity)
            .compressor(Arc::new(XlaGreedy::new(engine.clone())))
            .build();
        let t0 = std::time::Instant::now();
        let res = tree.run(&problem, seed)?;
        let wall = t0.elapsed().as_secs_f64();
        let rel_err = 100.0 * (1.0 - res.best.value / central.value);
        let floor = bounds::thm33_greedy(n, k, capacity);
        assert!(
            res.best.value / central.value >= floor,
            "Theorem 3.3 floor violated"
        );
        assert!(res.rounds <= res.round_bound + 2);
        println!(
            "[tree µ={capacity:>4}] f(S) = {:.6}  rel-err {rel_err:.3}%  \
             {} rounds  {} machines  {:.2} s",
            res.best.value, res.rounds, res.total_machines, wall
        );
        table.row(vec![
            capacity.to_string(),
            res.rounds.to_string(),
            res.total_machines.to_string(),
            format!("{:.6}", res.best.value),
            format!("{rel_err:.3}"),
            format!("{floor:.3}"),
            format!("{wall:.2}"),
        ]);
    }

    table.print();
    table.save_json("e2e_pipeline").ok();

    let (calls, compiles, exec_ns, upload, hits) = engine.stats().snapshot();
    println!(
        "\n[engine]  {calls} executions, {compiles} XLA compiles, {:.1} s device time, \
         {:.0} MB uploaded, {hits} buffer-cache hits",
        exec_ns as f64 / 1e9,
        upload as f64 / 1e6
    );
    println!("[ok]      all layers composed: artifacts -> PJRT -> coordinator -> results");
    Ok(())
}

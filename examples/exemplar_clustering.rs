//! Exemplar-based clustering (paper §4.2) on the Tiny-Images surrogate:
//! pick k exemplars that minimize quantization error, distributed over
//! fixed-capacity machines with the XLA-accelerated oracle.
//!
//! ```bash
//! cargo run --release --example exemplar_clustering \
//!     [-- --dataset tiny-2k --k 50 --capacity 200 --no-engine]
//! ```

use std::sync::Arc;

use hss::coordinator::baselines;
use hss::prelude::*;
use hss::runtime::accel::XlaGreedy;

fn main() -> Result<()> {
    let args = hss::util::cli::Args::from_env()?;
    let name = args.get_or("dataset", "tiny-2k-d64");
    let k = args.usize("k", 50)?;
    let capacity = args.usize("capacity", 200)?;
    let seed = args.u64("seed", 11)?;

    let dataset = hss::data::registry::load(name, seed)?;
    println!(
        "dataset {name}: n = {}, d = {} (unit-norm image-like vectors)",
        dataset.n, dataset.d
    );
    let mut problem = Problem::exemplar(dataset.clone(), k, seed);

    // Attach the XLA engine (AOT artifacts) unless --no-engine.
    let engine = if args.flag("no-engine") {
        None
    } else {
        match XlaRuntime::start_default() {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("engine unavailable ({e}); using the pure-rust oracle");
                None
            }
        }
    };
    if let Some(e) = &engine {
        problem = problem.with_engine(e.clone());
    }

    let tree = match &engine {
        Some(e) => TreeBuilder::new(capacity)
            .compressor(Arc::new(XlaGreedy::new(e.clone())))
            .build(),
        None => TreeBuilder::new(capacity).build(),
    };

    let t0 = std::time::Instant::now();
    let result = tree.run(&problem, seed)?;
    let tree_ms = t0.elapsed().as_secs_f64() * 1e3;

    let central = baselines::centralized(&problem)?;

    println!("\nselected {} exemplars (ids): {:?}", result.best.items.len(),
             &result.best.items[..result.best.items.len().min(10)]);
    println!("tree        f(S) = {:.5}  in {:.0} ms, {} rounds, {} machines",
             result.best.value, tree_ms, result.rounds, result.total_machines);
    println!("centralized f(S) = {:.5}", central.value);
    println!("relative error: {:.3}%",
             100.0 * (1.0 - result.best.value / central.value));

    // Quantization-error view (the k-medoid objective the reduction came
    // from): L(S) = L(e0) − f(S).
    let l_e0 = problem
        .eval_ids
        .iter()
        .map(|&i| hss::linalg::sq_norm(dataset.row(i)))
        .sum::<f64>()
        / problem.eval_ids.len() as f64;
    println!(
        "quantization error: {:.5} -> {:.5} (baseline e0 only -> with exemplars)",
        l_e0,
        l_e0 - result.best.value
    );
    if let Some(e) = &engine {
        let (calls, compiles, exec_ns, upload, hits) = e.stats().snapshot();
        println!(
            "engine: {calls} executions, {compiles} XLA compiles, {:.0} ms device time, \
             {:.1} MB uploaded, {hits} buffer-cache hits",
            exec_ns as f64 / 1e6,
            upload as f64 / 1e6
        );
    }
    Ok(())
}

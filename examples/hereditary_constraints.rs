//! Hereditary constraints beyond cardinality (paper §3.2 / Theorem 3.5):
//! distributed summarization under a knapsack budget, a partition
//! matroid (diversity across groups), and their intersection.
//!
//! ```bash
//! cargo run --release --example hereditary_constraints [-- --n 2000 --capacity 120]
//! ```

use std::sync::Arc;

use hss::constraints::{Constraint, Intersection};
use hss::coordinator::{baselines, TreeBuilder};
use hss::prelude::*;

fn main() -> Result<()> {
    let args = hss::util::cli::Args::from_env()?;
    let n = args.usize("n", 2_000)?;
    let capacity = args.usize("capacity", 120)?;
    let k = 20;

    let ds = Arc::new(hss::data::synthetic::csn_like(n, 3));

    // Knapsack: each item costs its squared norm ("transmission energy");
    // budget caps the total.
    let budget = 400.0;
    let knapsack: Arc<dyn Constraint> =
        Arc::new(Knapsack::from_row_norms(&ds, budget, k));

    // Partition matroid: items belong to 8 "sensor groups" (id mod 8);
    // at most 3 exemplars per group for coverage diversity.
    let matroid: Arc<dyn Constraint> =
        Arc::new(PartitionMatroid::round_robin(n, 8, 3, k));

    let both: Arc<dyn Constraint> = Arc::new(Intersection::new(vec![
        Arc::new(Knapsack::from_row_norms(&ds, budget, k)),
        Arc::new(PartitionMatroid::round_robin(n, 8, 3, k)),
    ]));

    println!("n = {n}, k = {k}, µ = {capacity} — Thm 3.5: E[f(S)] ≥ (α/r)·f(OPT)\n");
    for (label, cons) in [
        ("cardinality only", None),
        ("knapsack(b=400)", Some(knapsack)),
        ("partition-matroid(8×3)", Some(matroid)),
        ("knapsack ∩ matroid", Some(both)),
    ] {
        let mut p = Problem::exemplar(ds.clone(), k, 3);
        if let Some(c) = cons {
            p = p.with_constraint(c);
        }
        let central = baselines::centralized(&p)?;
        let tree = TreeBuilder::new(capacity).build().run(&p, 9)?;
        assert!(p.constraint.is_feasible(&tree.best.items, &p.dataset));
        println!(
            "{label:<24} tree f(S) = {:.4} ({} items, {} rounds) | centralized {:.4} | ratio {:.3}",
            tree.best.value,
            tree.best.items.len(),
            tree.rounds,
            central.value,
            tree.best.value / central.value
        );
    }
    Ok(())
}

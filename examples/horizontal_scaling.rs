//! Horizontal-scaling demo — the paper's central claim made concrete.
//!
//! Holds machine capacity µ FIXED and grows the dataset. The two-round
//! RANDGREEDI baseline breaks down once its union of partial solutions
//! (m·k items) no longer fits on one machine (µ < √(nk)); the tree
//! framework keeps working by adding rounds, at a mild quality cost
//! bounded by Theorem 3.3.
//!
//! ```bash
//! cargo run --release --example horizontal_scaling [-- --capacity 150 --k 25]
//! ```

use hss::coordinator::{baselines, TreeBuilder};
use hss::prelude::*;

fn main() -> Result<()> {
    let args = hss::util::cli::Args::from_env()?;
    let capacity = args.usize("capacity", 150)?;
    let k = args.usize("k", 25)?;

    println!("fixed machine capacity µ = {capacity}, k = {k}\n");
    println!(
        "{:>8}  {:>9}  {:>12}  {:>22}  {:>7}",
        "n", "sqrt(nk)", "randgreedi", "tree", "ratio"
    );

    let mut table = hss::bench::Table::new(
        "horizontal scaling at fixed capacity",
        &["n", "sqrt_nk", "randgreedi", "tree_rounds", "tree_ratio"],
    );

    for n in [500usize, 1_000, 2_000, 4_000, 8_000, 16_000] {
        let ds = std::sync::Arc::new(hss::data::synthetic::csn_like(n, 42));
        let problem = Problem::exemplar(ds, k, 42);
        let central = baselines::centralized(&problem)?;

        let rg = match baselines::rand_greedi_default(&problem, capacity, 1) {
            Ok(res) => format!("ok ({:.3})", res.solution.value / central.value),
            Err(Error::CapacityExceeded { got, .. }) => {
                format!("BREAKS ({got}>{capacity})")
            }
            Err(e) => return Err(e),
        };

        let tree = TreeBuilder::new(capacity).build().run(&problem, 1)?;
        let ratio = tree.best.value / central.value;
        let sqrt_nk = ((n * k) as f64).sqrt() as usize;
        println!(
            "{n:>8}  {sqrt_nk:>9}  {rg:>12}  {:>15} rounds  {ratio:>6.3}",
            tree.rounds
        );
        table.row(vec![
            n.to_string(),
            sqrt_nk.to_string(),
            rg.clone(),
            tree.rounds.to_string(),
            format!("{ratio:.4}"),
        ]);
    }
    println!(
        "\nRANDGREEDI requires µ ≥ ~√(nk); TREE only requires µ > k and adds rounds instead."
    );
    table.save_json("horizontal_scaling_example").ok();
    Ok(())
}

//! Quickstart: distributed submodular maximization in ~20 lines.
//!
//! Selects k representative points from a synthetic sensor dataset with
//! machines of fixed capacity µ, and compares against centralized greedy
//! and a random subset.
//!
//! ```bash
//! cargo run --release --example quickstart [-- --n 4000 --k 20 --capacity 100]
//! ```

use std::sync::Arc;

use hss::coordinator::baselines;
use hss::prelude::*;

fn main() -> Result<()> {
    let args = hss::util::cli::Args::from_env()?;
    let n = args.usize("n", 4_000)?;
    let k = args.usize("k", 20)?;
    let capacity = args.usize("capacity", 100)?;

    // 1. A dataset: 17-dim accelerometer-like features (CSN surrogate).
    let dataset = Arc::new(hss::data::synthetic::csn_like(n, 7));

    // 2. A problem: exemplar-based clustering (k-medoid reduction),
    //    cardinality constraint k.
    let problem = Problem::exemplar(dataset, k, 7);

    // 3. The paper's tree-based compression over fixed-capacity machines.
    let tree = TreeBuilder::new(capacity).build();
    let result = tree.run(&problem, 1)?;

    // 4. Baselines.
    let central = baselines::centralized(&problem)?;
    let random = baselines::random_subset(&problem, 1)?;

    println!("n = {n}, k = {k}, machine capacity µ = {capacity}");
    println!(
        "tree-compression : f(S) = {:.4}  ({} rounds ≤ bound {}, {} machines, {} oracle evals)",
        result.best.value, result.rounds, result.round_bound,
        result.total_machines, result.oracle_evals
    );
    println!("centralized      : f(S) = {:.4}", central.value);
    println!("random subset    : f(S) = {:.4}", random.value);
    println!(
        "approximation ratio vs centralized: {:.4} (theoretical floor {:.4})",
        result.best.value / central.value,
        bounds::thm33_greedy(n, k, capacity)
    );
    Ok(())
}

"""AOT compiler: lower every configured L2 graph to HLO text + manifest.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The emitted ``manifest.json`` is the contract with the rust runtime
(rust/src/runtime/manifest.rs): artifact name, kind, fixed shapes and
input/output specs. The runtime selects the smallest artifact whose
shapes dominate a request and pads accordingly.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model
from .model import ArtifactConfig

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[str(dt)]


def lower_config(cfg: ArtifactConfig, out_dir: str) -> dict:
    """Lower one artifact; returns its manifest entry."""
    fn, args = model.build(cfg)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = cfg.name + ".hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(fn, *args)
    return {
        "name": cfg.name,
        "kind": cfg.kind,
        "file": fname,
        "m": cfg.m,
        "mu": cfg.mu,
        "d": cfg.d,
        "k": cfg.k,
        "h2": cfg.h2,
        "use_pallas": cfg.use_pallas,
        "inputs": [
            {"shape": list(a.shape), "dtype": _dtype_tag(a.dtype)} for a in args
        ],
        "outputs": [
            {"shape": list(o.shape), "dtype": _dtype_tag(o.dtype)}
            for o in out_shapes
        ],
    }


def default_configs() -> list[ArtifactConfig]:
    """The artifact set covering every experiment in DESIGN.md §6.

    mu tiers are powers of two; the runtime pads each machine's partition
    up to the next tier. m = 2048 is the evaluation-subsample size used
    throughout (paper uses 10000; scaled for the single-core CPU testbed,
    same Chernoff-bound argument — see EXPERIMENTS.md).
    """
    cfgs: list[ArtifactConfig] = []
    M = 2048
    mu_tiers = [128, 256, 512, 1024, 2048]

    def jnp_cfg(**kw):
        cfgs.append(ArtifactConfig(use_pallas=False, **kw))

    def pallas_cfg(**kw):
        cfgs.append(ArtifactConfig(use_pallas=True, **kw))

    # --- exemplar fused greedy (the workhorse) --------------------------
    for u in mu_tiers:
        for k in (50, 100):
            jnp_cfg(kind="exgreedy", m=M, mu=u, d=32, k=k)  # csn-like
    for u in mu_tiers:
        jnp_cfg(kind="exgreedy", m=M, mu=u, d=3072, k=50)  # tiny-10k
    for u in (256, 512, 1024):
        jnp_cfg(kind="exgreedy", m=M, mu=u, d=3072, k=100)
    # m=512 eval subsample for very high-dimensional data (Problem::exemplar
    # drops to 512 eval rows when d >= 1024 — 4x less padded compute)
    for u in mu_tiers:
        for k in (50, 100):
            jnp_cfg(kind="exgreedy", m=512, mu=u, d=3072, k=k)
    jnp_cfg(kind="dist", m=512, mu=2048, d=3072)
    for u in (512, 1024):
        jnp_cfg(kind="exgreedy", m=M, mu=u, d=64, k=50)  # tiny-1m
    pallas_cfg(kind="exgreedy", m=M, mu=1024, d=32, k=50)  # ablation twin

    # --- distance matrix + per-step artifacts (hereditary / flexible) ---
    for u in mu_tiers:
        jnp_cfg(kind="dist", m=M, mu=u, d=32)
        jnp_cfg(kind="exstep", m=M, mu=u)
        jnp_cfg(kind="exupd", m=M, mu=u)
    jnp_cfg(kind="dist", m=M, mu=2048, d=3072)
    jnp_cfg(kind="dist", m=M, mu=1024, d=64)
    pallas_cfg(kind="dist", m=M, mu=1024, d=32)
    pallas_cfg(kind="dist", m=M, mu=2048, d=3072)
    pallas_cfg(kind="dist", m=M, mu=1024, d=64)

    # --- RBF Gram blocks (log-det / active-set path) ---------------------
    for u in mu_tiers + [4096]:  # 4096: webscope-100k sweep beyond sqrt(nk)
        jnp_cfg(kind="rbf", m=u, mu=u, d=32)
    pallas_cfg(kind="rbf", m=1024, mu=1024, d=32)
    return cfgs


def smoke_configs() -> list[ArtifactConfig]:
    """Tiny shapes for CI / pytest round-trip tests."""
    return [
        ArtifactConfig(kind="dist", m=64, mu=32, d=16, use_pallas=True,
                       block_m=32, block_n=16, block_d=8),
        ArtifactConfig(kind="dist", m=64, mu=32, d=16, use_pallas=False),
        ArtifactConfig(kind="rbf", m=32, mu=32, d=16, use_pallas=True,
                       block_m=16, block_n=16, block_d=8),
        ArtifactConfig(kind="exstep", m=64, mu=32, use_pallas=False),
        ArtifactConfig(kind="exupd", m=64, mu=32, use_pallas=False),
        ArtifactConfig(kind="exgreedy", m=64, mu=32, d=16, k=4,
                       use_pallas=False),
        ArtifactConfig(kind="exgreedy", m=64, mu=32, d=16, k=4,
                       use_pallas=True, block_m=32, block_n=16, block_d=8),
    ]


CONFIG_SETS = {"default": default_configs, "smoke": smoke_configs}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--set", dest="cfg_set", default="default",
                   choices=sorted(CONFIG_SETS))
    p.add_argument("--only", default=None,
                   help="comma-separated artifact-name substrings to build")
    args = p.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    cfgs = CONFIG_SETS[args.cfg_set]()
    if args.only:
        keys = args.only.split(",")
        cfgs = [c for c in cfgs if any(s in c.name for s in keys)]

    entries = []
    for i, cfg in enumerate(cfgs):
        entry = lower_config(cfg, args.out_dir)
        entries.append(entry)
        print(f"[{i + 1}/{len(cfgs)}] {cfg.name}", file=sys.stderr)

    manifest = {"version": MANIFEST_VERSION, "set": args.cfg_set,
                "eval_m": 2048 if args.cfg_set == "default" else 64,
                "artifacts": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out_dir}",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

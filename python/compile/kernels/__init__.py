"""Layer-1 Pallas kernels for the oracle-evaluation hot spot.

The paper's framework performs O(nk) oracle evaluations; for the two
objective families used in its evaluation (exemplar-based clustering and
log-det active-set selection) the hot spot is a pairwise
distance / kernel-matrix block. Both kernels tile that block for the MXU
(matmul path) and accumulate over the feature dimension.

All kernels are lowered with ``interpret=True`` — the CPU PJRT client
cannot execute Mosaic custom-calls. See DESIGN.md §Hardware-Adaptation.
"""

from . import exemplar, rbf, ref

__all__ = ["exemplar", "rbf", "ref"]

"""Pallas kernel: tiled squared-euclidean distance matrix.

The exemplar-based clustering objective (paper §4.2) needs, once per
(machine, round), the full distance matrix between the evaluation
subsample ``W [m, d]`` and the machine's partition ``X [mu, d]``::

    D2[i, j] = ||w_i - x_j||^2 = ||w_i||^2 + ||x_j||^2 - 2 <w_i, x_j>

The inner-product term is a matmul — the MXU hot path. The kernel tiles
(m, mu, d) into (block_m, block_n, block_d) VMEM blocks; the grid iterates
the d-axis innermost so each output block is revisited and used as the
accumulator (standard Pallas matmul schedule — no scratch needed, which
also keeps interpret-mode lowering simple).

VMEM footprint per grid step (see EXPERIMENTS.md §Perf for the sweep):
    block_m*block_d + block_n*block_d + block_m + block_n + block_m*block_n
floats. With the default 256/256/512 blocks: 1.25 MiB — comfortably under
the ~16 MiB VMEM of a TPU core, leaving room for double-buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(nsteps: int, w_ref, x_ref, wn_ref, xn_ref, o_ref):
    """One (block_m, block_n) output tile; d-axis is grid axis 2.

    Schedule per output tile:
      step 0:        o  = ||w||^2[:, None] + ||x||^2[None, :]
      every step:    o -= 2 * w_blk @ x_blk^T        (MXU)
    Norms are precomputed in the L2 graph (one fused pass over the data)
    so the kernel reduces only the cross term.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = wn_ref[...][:, None] + xn_ref[...][None, :]

    w = w_ref[...]
    x = x_ref[...]
    o_ref[...] -= 2.0 * jax.lax.dot_general(
        w,
        x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    del nsteps  # part of the signature for symmetry with rbf kernel


def dist_matrix(
    w: jax.Array,
    x: jax.Array,
    wn: jax.Array,
    xn: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_d: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Squared-euclidean distance matrix ``[m, mu]`` via the Pallas kernel.

    Args:
      w:  evaluation subsample, ``[m, d]`` float32.
      x:  candidate items,      ``[mu, d]`` float32.
      wn: precomputed row norms ``||w_i||^2``, ``[m]``.
      xn: precomputed row norms ``||x_j||^2``, ``[mu]``.
      block_*: VMEM tile sizes; every dimension must be divisible by its
        block (the AOT layer pads to the artifact's fixed shapes).
      interpret: must stay True for CPU-PJRT execution.
    """
    m, d = w.shape
    mu, d2 = x.shape
    if d != d2:
        raise ValueError(f"feature dims differ: {d} vs {d2}")
    block_m = min(block_m, m)
    block_n = min(block_n, mu)
    block_d = min(block_d, d)
    if m % block_m or mu % block_n or d % block_d:
        raise ValueError(
            f"shapes ({m},{mu},{d}) not divisible by blocks "
            f"({block_m},{block_n},{block_d})"
        )
    nsteps = d // block_d
    grid = (m // block_m, mu // block_n, nsteps)
    return pl.pallas_call(
        functools.partial(_dist_kernel, nsteps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_d), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_n, block_d), lambda i, j, s: (j, s)),
            pl.BlockSpec((block_m,), lambda i, j, s: (i,)),
            pl.BlockSpec((block_n,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, mu), jnp.float32),
        interpret=interpret,
    )(w, x, wn, xn)

"""Pallas kernel: tiled RBF (squared-exponential) kernel-matrix block.

The log-det / active-set-selection objective (paper §4.2, Informative
Vector Machine) is driven by the Gram matrix of the candidate partition:

    K[i, j] = exp(-||a_i - b_j||^2 / h^2)

The rust coordinator computes ``K(T_i, T_i)`` once per (machine, round)
and then runs the incremental-Cholesky greedy entirely on top of it
(O(k*mu) per step), so this kernel is the whole compute cost of the
log-det path.

Same schedule as :mod:`exemplar`: the d-axis is the innermost grid axis,
the output tile doubles as the cross-term accumulator, and the exp() is
applied on the final d-step only (the tile is revisited sequentially, so
the transform sees the fully-accumulated distance).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_kernel(nsteps: int, inv_h2: float, a_ref, b_ref, an_ref, bn_ref, o_ref):
    """One (block_p, block_q) tile of the RBF Gram matrix."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = an_ref[...][:, None] + bn_ref[...][None, :]

    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] -= 2.0 * jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _finish():
        # Clamp tiny negative distances from float cancellation before exp.
        d2 = jnp.maximum(o_ref[...], 0.0)
        o_ref[...] = jnp.exp(-d2 * inv_h2)


def rbf_matrix(
    a: jax.Array,
    b: jax.Array,
    an: jax.Array,
    bn: jax.Array,
    *,
    h2: float = 0.25,
    block_p: int = 256,
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """RBF Gram matrix ``[p, q]`` with bandwidth ``h^2`` (paper: h=0.5).

    ``an``/``bn`` are precomputed squared row norms, as in
    :func:`exemplar.dist_matrix`.
    """
    p, d = a.shape
    q, d2 = b.shape
    if d != d2:
        raise ValueError(f"feature dims differ: {d} vs {d2}")
    block_p = min(block_p, p)
    block_q = min(block_q, q)
    block_d = min(block_d, d)
    if p % block_p or q % block_q or d % block_d:
        raise ValueError(
            f"shapes ({p},{q},{d}) not divisible by blocks "
            f"({block_p},{block_q},{block_d})"
        )
    nsteps = d // block_d
    grid = (p // block_p, q // block_q, nsteps)
    return pl.pallas_call(
        functools.partial(_rbf_kernel, nsteps, 1.0 / h2),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, block_d), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_q, block_d), lambda i, j, s: (j, s)),
            pl.BlockSpec((block_p,), lambda i, j, s: (i,)),
            pl.BlockSpec((block_q,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((block_p, block_q), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, q), jnp.float32),
        interpret=interpret,
    )(a, b, an, bn)

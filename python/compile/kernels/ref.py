"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis, python/tests/test_kernels.py) and double as the ``jnp``
artifact variants emitted by aot.py — the XLA-fused formulation a
downstream user would write without Pallas. Keeping both lets the rust
benches ablate pallas-vs-jnp on identical inputs.
"""

import jax
import jax.numpy as jnp


def dist_matrix_ref(w: jax.Array, x: jax.Array) -> jax.Array:
    """Squared-euclidean distance matrix ``[m, mu]`` (float32)."""
    wn = jnp.sum(w * w, axis=-1)
    xn = jnp.sum(x * x, axis=-1)
    cross = jax.lax.dot_general(
        w, x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return wn[:, None] + xn[None, :] - 2.0 * cross


def rbf_matrix_ref(a: jax.Array, b: jax.Array, h2: float = 0.25) -> jax.Array:
    """RBF Gram matrix ``exp(-d2/h2)``, ``[p, q]`` (float32)."""
    d2 = jnp.maximum(dist_matrix_ref(a, b), 0.0)
    return jnp.exp(-d2 / h2)


def exemplar_gains_ref(d2: jax.Array, curmin: jax.Array, mask: jax.Array) -> jax.Array:
    """Marginal gains (unnormalized sums) of every candidate.

    gain_j = sum_i max(0, curmin_i - d2[i, j]); masked-out candidates get
    -inf so argmax never picks padding / already-selected items.
    """
    gains = jnp.sum(jnp.maximum(curmin[:, None] - d2, 0.0), axis=0)
    return jnp.where(mask > 0, gains, -jnp.inf)

"""Layer-2 JAX compute graphs for the oracle hot path.

Each public ``make_*`` returns a function with *fixed* shapes (taken from
an :class:`ArtifactConfig`) suitable for ``jax.jit(...).lower()`` — the
AOT layer (aot.py) lowers every configured variant to HLO text once, and
the rust coordinator executes them via PJRT forever after. Python never
runs on the request path.

Shape/padding contract with the rust side (runtime/manifest.rs):
  * all tensors are float32 (indices int32);
  * the evaluation subsample ``w`` is padded with zero rows — a zero row
    has curmin == ||w||^2 == 0 so it never contributes gain (this is
    exactly "a point already covered by the auxiliary element e0");
  * candidate partitions ``x`` are padded with zero rows and ``mask`` /
    ``stepmask`` entries 0; masked candidates read gain -inf;
  * gains are *sums* over eval rows — the rust side normalizes by the
    true eval-set size;
  * argmax uses jnp.argmax first-max tie-breaking, matching the rust
    pure-path (strictly-greater scan) so both are the same 1-nice GREEDY.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import exemplar as k_exemplar
from .kernels import rbf as k_rbf
from .kernels import ref as k_ref

NEG_INF = jnp.float32(-3.0e38)  # sentinel for masked gains (finite: survives arithmetic)


@dataclasses.dataclass(frozen=True)
class ArtifactConfig:
    """Fixed-shape configuration for one AOT artifact."""

    kind: str  # dist | rbf | exstep | exupd | exgreedy
    m: int = 0  # eval-subsample rows (exemplar family)
    mu: int = 0  # machine capacity / candidate rows
    d: int = 0  # feature dimension
    k: int = 0  # greedy budget (exgreedy only)
    h2: float = 0.25  # RBF bandwidth^2 (paper: h = 0.5)
    use_pallas: bool = True
    block_m: int = 256
    block_n: int = 256
    block_d: int = 512

    @property
    def name(self) -> str:
        v = "pallas" if self.use_pallas else "jnp"
        base = f"{self.kind}_{v}"
        if self.kind in ("dist", "exgreedy"):
            base += f"_m{self.m}_u{self.mu}_d{self.d}"
        elif self.kind == "rbf":
            base += f"_p{self.m}_q{self.mu}_d{self.d}"
        else:  # exstep / exupd operate on a precomputed d2
            base += f"_m{self.m}_u{self.mu}"
        if self.kind == "exgreedy":
            base += f"_k{self.k}"
        return base


def _dist(cfg: ArtifactConfig, w, x):
    if cfg.use_pallas:
        wn = jnp.sum(w * w, axis=-1)
        xn = jnp.sum(x * x, axis=-1)
        return k_exemplar.dist_matrix(
            w, x, wn, xn,
            block_m=cfg.block_m, block_n=cfg.block_n, block_d=cfg.block_d,
        )
    return k_ref.dist_matrix_ref(w, x)


def make_dist(cfg: ArtifactConfig) -> tuple[Callable, list]:
    """(w[m,d], x[mu,d]) -> (d2[m,mu],)"""

    def fn(w, x):
        return (_dist(cfg, w, x),)

    args = [
        jax.ShapeDtypeStruct((cfg.m, cfg.d), jnp.float32),
        jax.ShapeDtypeStruct((cfg.mu, cfg.d), jnp.float32),
    ]
    return fn, args


def make_rbf(cfg: ArtifactConfig) -> tuple[Callable, list]:
    """(a[p,d], b[q,d]) -> (K[p,q],) — RBF Gram block for the log-det path."""

    def fn(a, b):
        if cfg.use_pallas:
            an = jnp.sum(a * a, axis=-1)
            bn = jnp.sum(b * b, axis=-1)
            k = k_rbf.rbf_matrix(
                a, b, an, bn, h2=cfg.h2,
                block_p=cfg.block_m, block_q=cfg.block_n, block_d=cfg.block_d,
            )
        else:
            k = k_ref.rbf_matrix_ref(a, b, cfg.h2)
        return (k,)

    args = [
        jax.ShapeDtypeStruct((cfg.m, cfg.d), jnp.float32),
        jax.ShapeDtypeStruct((cfg.mu, cfg.d), jnp.float32),
    ]
    return fn, args


def _masked_gains(d2, curmin, mask):
    gains = jnp.sum(jnp.maximum(curmin[:, None] - d2, 0.0), axis=0)
    return jnp.where(mask > 0, gains, NEG_INF)


def make_exstep(cfg: ArtifactConfig) -> tuple[Callable, list]:
    """One greedy step on a precomputed distance matrix.

    (d2[m,mu], curmin[m], mask[mu]) ->
        (gains[mu], best[], best_gain[], new_curmin[m])

    The rust coordinator may override the argmax choice (hereditary
    constraints) — it then calls the ``exupd`` artifact instead of using
    ``new_curmin``.
    """

    def fn(d2, curmin, mask):
        gains = _masked_gains(d2, curmin, mask)
        best = jnp.argmax(gains).astype(jnp.int32)
        best_gain = gains[best]
        new_curmin = jnp.minimum(curmin, d2[:, best])
        return gains, best, best_gain, new_curmin

    args = [
        jax.ShapeDtypeStruct((cfg.m, cfg.mu), jnp.float32),
        jax.ShapeDtypeStruct((cfg.m,), jnp.float32),
        jax.ShapeDtypeStruct((cfg.mu,), jnp.float32),
    ]
    return fn, args


def make_exupd(cfg: ArtifactConfig) -> tuple[Callable, list]:
    """(d2[m,mu], curmin[m], idx[]) -> (new_curmin[m],) — commit item idx."""

    def fn(d2, curmin, idx):
        col = jax.lax.dynamic_slice_in_dim(d2, idx, 1, axis=1)[:, 0]
        return (jnp.minimum(curmin, col),)

    args = [
        jax.ShapeDtypeStruct((cfg.m, cfg.mu), jnp.float32),
        jax.ShapeDtypeStruct((cfg.m,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ]
    return fn, args


def make_exgreedy(cfg: ArtifactConfig) -> tuple[Callable, list]:
    """Whole-machine greedy: k steps fused into one executable.

    (w[m,d], x[mu,d], stepmask[k,mu]) ->
        (idxs[k] int32, step_gains[k], curmin[m])

    ``stepmask`` row t restricts the candidates available at step t: all
    ones (plain GREEDY), or a random subset per step (STOCHASTIC GREEDY,
    Mirzasoleiman et al. 2015 — the rust side draws the subsets). The
    availability mask (no re-selection) is maintained inside the scan.
    A step whose best gain is the masked sentinel is a no-op: the rust
    side truncates the solution at the first sentinel gain.
    """

    def fn(w, x, stepmask):
        d2 = _dist(cfg, w, x)
        curmin0 = jnp.sum(w * w, axis=-1)  # distance to auxiliary e0 = 0
        avail0 = jnp.ones((cfg.mu,), jnp.float32)

        def step(carry, smask):
            curmin, avail = carry
            gains = _masked_gains(d2, curmin, smask * avail)
            best = jnp.argmax(gains).astype(jnp.int32)
            best_gain = gains[best]
            ok = best_gain > NEG_INF / 2
            new_curmin = jnp.where(
                ok, jnp.minimum(curmin, d2[:, best]), curmin
            )
            new_avail = jnp.where(
                ok, avail.at[best].set(0.0), avail
            )
            return (new_curmin, new_avail), (best, best_gain)

        (curmin, _), (idxs, gains) = jax.lax.scan(
            step, (curmin0, avail0), stepmask
        )
        return idxs, gains, curmin

    args = [
        jax.ShapeDtypeStruct((cfg.m, cfg.d), jnp.float32),
        jax.ShapeDtypeStruct((cfg.mu, cfg.d), jnp.float32),
        jax.ShapeDtypeStruct((cfg.k, cfg.mu), jnp.float32),
    ]
    return fn, args


MAKERS = {
    "dist": make_dist,
    "rbf": make_rbf,
    "exstep": make_exstep,
    "exupd": make_exupd,
    "exgreedy": make_exgreedy,
}


def build(cfg: ArtifactConfig) -> tuple[Callable, list]:
    """Resolve a config to (traceable_fn, example_args)."""
    return MAKERS[cfg.kind](cfg)

"""L1 performance model: VMEM footprint and MXU-utilization estimates.

Pallas interpret mode gives CPU-numpy timings only — not a TPU proxy —
so the kernel is optimized *structurally*: we budget VMEM per grid step
and estimate the fraction of work landing on the MXU, per DESIGN.md §9.
Run as a module to print the table recorded in EXPERIMENTS.md §Perf:

    cd python && python -m compile.perf
"""

from __future__ import annotations

import dataclasses

# TPU-v4-ish budget figures (per core), used for *ratio* reporting only.
VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128  # systolic array edge


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    name: str
    block_m: int
    block_n: int
    block_d: int
    m: int
    mu: int
    d: int

    def vmem_bytes(self) -> int:
        """f32 VMEM resident per grid step (double-buffered inputs).

        Blocks live in VMEM while the MXU consumes them; Pallas
        double-buffers the HBM→VMEM pipeline, hence the 2x on inputs.
        """
        inputs = self.block_m * self.block_d + self.block_n * self.block_d
        norms = self.block_m + self.block_n
        out = self.block_m * self.block_n
        return 4 * (2 * (inputs + norms) + out)

    def mxu_alignment(self) -> float:
        """Fraction of each dot's operands filling the 128x128 MXU tiles."""
        fill_m = min(self.block_m, MXU_DIM) / MXU_DIM
        fill_n = min(self.block_n, MXU_DIM) / MXU_DIM
        fill_d = min(self.block_d, MXU_DIM) / MXU_DIM
        return fill_m * fill_n * fill_d

    def mxu_flop_fraction(self) -> float:
        """Share of kernel FLOPs on the MXU (dot) vs the VPU (norms,
        scale-add, exp). Per output tile: dot = 2·bm·bn·bd; VPU ≈ 3·bm·bn
        per d-step amortized."""
        dot = 2.0 * self.block_m * self.block_n * self.block_d
        vpu = 3.0 * self.block_m * self.block_n
        return dot / (dot + vpu)

    def grid(self) -> tuple[int, int, int]:
        return (
            self.m // self.block_m,
            self.mu // self.block_n,
            self.d // self.block_d,
        )

    def hbm_traffic_bytes(self) -> int:
        """Bytes moved HBM→VMEM for one kernel invocation: every (i,j)
        output tile re-reads its W and X blocks for each d-step."""
        gi, gj, gd = self.grid()
        w_reads = gi * gj * gd * self.block_m * self.block_d
        x_reads = gi * gj * gd * self.block_n * self.block_d
        out = self.m * self.mu
        return 4 * (w_reads + x_reads + out)

    def arithmetic_intensity(self) -> float:
        flops = 2.0 * self.m * self.mu * self.d
        return flops / self.hbm_traffic_bytes()


def default_configs() -> list[BlockConfig]:
    return [
        BlockConfig("dist d32 (csn/webscope)", 256, 256, 32, 2048, 1024, 32),
        BlockConfig("dist d64 (tiny-large)", 256, 256, 64, 2048, 1024, 64),
        BlockConfig("dist d3072 (tiny)", 256, 256, 512, 512, 2048, 3072),
        BlockConfig("rbf d32 (logdet gram)", 256, 256, 32, 1024, 1024, 32),
        # block-size ablation on the heavy shape
        BlockConfig("dist d3072 bm128", 128, 128, 512, 512, 2048, 3072),
        BlockConfig("dist d3072 bd1024", 256, 256, 1024, 512, 2048, 3072),
        BlockConfig("dist d3072 bm512", 512, 512, 512, 512, 2048, 3072),
    ]


def report(cfgs: list[BlockConfig] | None = None) -> str:
    cfgs = cfgs or default_configs()
    lines = [
        f"{'config':<26} {'VMEM/step':>10} {'of 16MiB':>9} {'MXU-fill':>9} "
        f"{'MXU-flops':>10} {'AI flop/B':>10} {'grid':>14}"
    ]
    for c in cfgs:
        v = c.vmem_bytes()
        lines.append(
            f"{c.name:<26} {v / 1024:>8.0f}KB {v / VMEM_BYTES:>8.1%} "
            f"{c.mxu_alignment():>8.1%} {c.mxu_flop_fraction():>9.1%} "
            f"{c.arithmetic_intensity():>10.1f} {str(c.grid()):>14}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())

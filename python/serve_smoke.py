"""CI smoke for the `hss serve` job service (stdlib only).

Boots the daemon on an ephemeral port against the sim backend, drives
the documented HTTP API end to end (docs/SERVE.md) and validates the
result document schema plus every error path:

* ``GET /healthz``  — 200, ``status: serving`` before drain
* ``POST /jobs``    — 400 on malformed JSON, 400 on backend-selection
  keys (the service owns the fleet), 201 on a valid spec
* ``GET /jobs/:id`` — 404 on unknown ids, then polled to ``completed``
* ``GET /jobs/:id/result`` — full schema check incl. per-trial
  ``value_bits`` (lossless f64 bit pattern, must round-trip to the
  reported ``value``)
* ``POST /jobs/:id/cancel`` — 409 once the job is terminal
* ``POST /shutdown`` — 202, in-flight job still finishes, new
  submissions get 503, process exits 0 once drained

Usage::

    python3 python/serve_smoke.py [path/to/hss]

Exit status 0 on success; any assertion failure or timeout is non-zero
(the CI job is blocking).
"""

import json
import http.client
import struct
import subprocess
import sys
import time

JOB_TIMEOUT_S = 120
POLL_S = 0.2


def request(addr, method, path, body=None):
    """One request against the daemon; returns (status_code, json_doc)."""
    host, port = addr.rsplit(":", 1)
    conn = http.client.HTTPConnection(host, int(port), timeout=15)
    try:
        conn.request(method, path, body=body)
        resp = conn.getresponse()
        raw = resp.read().decode("utf-8", "replace")
        try:
            doc = json.loads(raw)
        except ValueError:
            raise AssertionError(f"{method} {path}: non-JSON body {raw!r}")
        return resp.status, doc
    finally:
        conn.close()


def check(cond, what):
    if not cond:
        raise AssertionError(what)
    print(f"  ok: {what}")


def validate_result_doc(doc, job_id):
    check(doc.get("id") == job_id, f"result.id == {job_id}")
    check(doc.get("state") == "completed", "result.state == completed")
    check(isinstance(doc.get("mean"), (int, float)), "result.mean is a number")
    check(isinstance(doc.get("wall_ms"), (int, float)), "result.wall_ms is a number")
    check("header" in doc, "result.header present")
    trials = doc.get("trials")
    check(isinstance(trials, list) and trials, "result.trials is a non-empty list")
    for t in trials:
        check(isinstance(t.get("trial"), int), "trial index is an int")
        check(isinstance(t.get("value"), (int, float)), "trial value is a number")
        bits = t.get("value_bits")
        check(isinstance(bits, str) and bits.isdigit(), "value_bits is a decimal string")
        # value_bits is the lossless channel: the f64 bit pattern must
        # decode to (approximately — the JSON float is the lossy copy)
        # the reported value
        exact = struct.unpack("<d", struct.pack("<Q", int(bits)))[0]
        check(
            abs(exact - t["value"]) <= 1e-6 * max(1.0, abs(exact)),
            "value_bits round-trips to the reported value",
        )
        check(isinstance(t.get("wall_ms"), (int, float)), "trial wall_ms is a number")
    check(isinstance(doc.get("workers"), list), "result.workers is a list")


def main():
    binary = sys.argv[1] if len(sys.argv) > 1 else "target/release/hss"
    proc = subprocess.Popen(
        [
            binary, "serve",
            "--backend", "sim",
            "--listen", "127.0.0.1:0",
            "--capacity", "150",
            "--max-jobs", "2",
        ],
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        text=True,
    )
    try:
        run(proc)
    except BaseException:
        proc.kill()
        out, err = proc.communicate()
        print(f"--- daemon stdout ---\n{out}\n--- daemon stderr ---\n{err}")
        raise
    print("serve smoke OK")


def run(proc):
    # discovery line: "hss-serve listening on <addr> backend=..." —
    # readline returns "" if the daemon dies before announcing
    line = proc.stdout.readline()
    check(line and "listening on" in line, f"boot announcement on stdout: {line!r}")
    addr = line.split("listening on", 1)[1].split()[0]
    print(f"daemon up at {addr}")

    code, doc = request(addr, "GET", "/healthz")
    check(code == 200 and doc.get("status") == "serving", "healthz reports serving")
    check(isinstance(doc.get("jobs"), dict), "healthz carries job counts")

    # error paths first: malformed body, fleet-owned keys, unknown ids
    code, doc = request(addr, "POST", "/jobs", "{not json")
    check(code == 400 and "error" in doc, "malformed spec is a 400")
    code, doc = request(addr, "POST", "/jobs", json.dumps({"dataset": "csn-2k", "backend": "tcp"}))
    check(code == 400, "backend-selection key is a 400")
    check("service owns the backend" in doc.get("error", ""), "400 names the fleet-ownership rule")
    code, doc = request(addr, "POST", "/jobs", json.dumps({"dataset": "no-such-dataset"}))
    check(code == 400, "unknown dataset is a 400")
    code, _ = request(addr, "GET", "/no/such/route")
    check(code == 404, "unknown route is a 404")
    code, _ = request(addr, "GET", "/jobs/999999")
    check(code == 404, "unknown job id is a 404")

    # a real job: submit, poll to completion, validate the result doc
    spec = {"dataset": "csn-2k", "algo": "tree", "k": 10, "capacity": 150,
            "trials": 1, "seed": 42}
    code, doc = request(addr, "POST", "/jobs", json.dumps(spec))
    check(code == 201, "valid spec is a 201")
    job_id = doc.get("id")
    check(isinstance(job_id, int), "201 body carries the job id")
    check(doc.get("state") in ("queued", "running"), "fresh job is queued or running")

    deadline = time.monotonic() + JOB_TIMEOUT_S
    while True:
        code, doc = request(addr, "GET", f"/jobs/{job_id}")
        check(code == 200, f"status poll for job {job_id} is a 200")
        if doc.get("state") in ("completed", "failed", "cancelled"):
            break
        if time.monotonic() > deadline:
            raise AssertionError(f"job {job_id} did not finish: {doc}")
        time.sleep(POLL_S)
    check(doc.get("state") == "completed", f"job {job_id} completed: {doc}")

    code, result = request(addr, "GET", f"/jobs/{job_id}/result")
    check(code == 200, "result fetch is a 200")
    validate_result_doc(result, job_id)

    code, doc = request(addr, "GET", "/jobs")
    check(code == 200 and any(j.get("id") == job_id for j in doc.get("jobs", [])),
          "job listing includes the finished job")
    code, _ = request(addr, "POST", f"/jobs/{job_id}/cancel")
    check(code == 409, "cancelling a terminal job is a 409")
    code, doc = request(addr, "GET", "/metrics")
    check(code == 200 and doc.get("fleet", {}).get("backend") == "sim",
          "metrics report the sim fleet")

    # drain: keep one job in flight so the daemon stays up long enough
    # to observe the draining state, then verify 503 + clean exit
    slow = {"dataset": "csn-2k", "algo": "tree", "k": 10, "capacity": 150,
            "trials": 3, "seed": 7}
    code, doc = request(addr, "POST", "/jobs", json.dumps(slow))
    check(code == 201, "pre-drain job admitted")
    inflight = doc["id"]
    code, doc = request(addr, "POST", "/shutdown")
    check(code == 202 and doc.get("status") == "draining", "shutdown is a 202 draining")
    try:
        code, doc = request(addr, "POST", "/jobs", json.dumps(spec))
        check(code == 503, "post-drain submission is a 503")
    except (ConnectionError, OSError):
        # the in-flight job finished first and the daemon already left —
        # acceptable, the 503 window is only as wide as the job
        print("  ok: daemon already drained before the 503 probe (in-flight job was fast)")

    proc.wait(timeout=JOB_TIMEOUT_S)
    check(proc.returncode == 0, f"daemon exited 0 after drain (got {proc.returncode})")
    out = proc.stdout.read()
    check("drained" in out, "daemon announced the drain on stdout")
    print(f"in-flight job {inflight} finished under drain; daemon exited cleanly")


if __name__ == "__main__":
    main()

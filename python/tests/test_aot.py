"""AOT pipeline round-trip: lower smoke configs, validate HLO text and
manifest schema (the contract consumed by rust/src/runtime/manifest.rs)."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot
from compile.model import ArtifactConfig

REPO_PY = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def smoke_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts_smoke")
    rc = aot.main(["--out-dir", str(out), "--set", "smoke"])
    assert rc == 0
    return out


def _manifest(smoke_dir):
    with open(smoke_dir / "manifest.json") as f:
        return json.load(f)


def test_manifest_exists_and_versioned(smoke_dir):
    man = _manifest(smoke_dir)
    assert man["version"] == aot.MANIFEST_VERSION
    assert man["set"] == "smoke"
    assert len(man["artifacts"]) == len(aot.smoke_configs())


def test_every_artifact_file_written(smoke_dir):
    man = _manifest(smoke_dir)
    for e in man["artifacts"]:
        path = smoke_dir / e["file"]
        assert path.exists(), e["name"]
        text = path.read_text()
        assert text.startswith("HloModule"), e["name"]
        assert "ROOT" in text


def test_manifest_entries_match_configs(smoke_dir):
    man = _manifest(smoke_dir)
    by_name = {e["name"]: e for e in man["artifacts"]}
    for cfg in aot.smoke_configs():
        e = by_name[cfg.name]
        assert e["kind"] == cfg.kind
        assert e["mu"] == cfg.mu
        assert e["use_pallas"] == cfg.use_pallas


def test_manifest_io_specs_are_complete(smoke_dir):
    man = _manifest(smoke_dir)
    for e in man["artifacts"]:
        assert len(e["inputs"]) >= 2
        assert len(e["outputs"]) >= 1
        for spec in e["inputs"] + e["outputs"]:
            assert spec["dtype"] in ("f32", "i32")
            assert all(isinstance(s, int) and s >= 0 for s in spec["shape"])


def test_exgreedy_manifest_shapes(smoke_dir):
    man = _manifest(smoke_dir)
    e = next(x for x in man["artifacts"]
             if x["kind"] == "exgreedy" and not x["use_pallas"])
    m, mu, d, k = e["m"], e["mu"], e["d"], e["k"]
    assert e["inputs"][0]["shape"] == [m, d]
    assert e["inputs"][1]["shape"] == [mu, d]
    assert e["inputs"][2]["shape"] == [k, mu]
    assert e["outputs"][0] == {"shape": [k], "dtype": "i32"}
    assert e["outputs"][1] == {"shape": [k], "dtype": "f32"}
    assert e["outputs"][2] == {"shape": [m], "dtype": "f32"}


def test_only_filter_limits_build(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--set", "smoke",
                   "--only", "rbf"])
    assert rc == 0
    man = json.load(open(tmp_path / "manifest.json"))
    assert all("rbf" in e["name"] for e in man["artifacts"])
    assert len(man["artifacts"]) >= 1


def test_cli_module_invocation(tmp_path):
    """`python -m compile.aot` works from the python/ directory."""
    rc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--set", "smoke", "--only", "exupd"],
        cwd=REPO_PY, capture_output=True, text=True)
    assert rc.returncode == 0, rc.stderr
    assert (tmp_path / "manifest.json").exists()


def test_pallas_and_jnp_dist_artifacts_differ_but_same_interface(smoke_dir):
    man = _manifest(smoke_dir)
    dists = [e for e in man["artifacts"] if e["kind"] == "dist"]
    assert len(dists) == 2
    a, b = dists
    assert a["inputs"] == b["inputs"]
    assert a["outputs"] == b["outputs"]
    ta = (smoke_dir / a["file"]).read_text()
    tb = (smoke_dir / b["file"]).read_text()
    assert ta != tb  # pallas emits the grid loop; jnp the fused form

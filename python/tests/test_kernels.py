"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compute layer — hypothesis
sweeps shapes and block configurations; every case asserts allclose
against the reference implementation.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import exemplar, rbf, ref

RTOL, ATOL = 1e-4, 1e-4


def _rand(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


def _norms(a):
    return jnp.sum(jnp.asarray(a) ** 2, axis=-1)


# ---------------------------------------------------------------------------
# dist_matrix
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16]),
    bd=st.sampled_from([4, 8, 16]),
    gm=st.integers(1, 3),
    gn=st.integers(1, 3),
    gd=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_dist_matches_ref_shapes(bm, bn, bd, gm, gn, gd, seed):
    rng = np.random.default_rng(seed)
    m, mu, d = bm * gm, bn * gn, bd * gd
    w, x = _rand(rng, m, d), _rand(rng, mu, d)
    got = exemplar.dist_matrix(
        jnp.asarray(w), jnp.asarray(x), _norms(w), _norms(x),
        block_m=bm, block_n=bn, block_d=bd,
    )
    want = ref.dist_matrix_ref(jnp.asarray(w), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


def test_dist_zero_rows_give_row_norms():
    """Padding contract: a zero eval row has d2[i, j] == ||x_j||^2."""
    rng = np.random.default_rng(0)
    w = _rand(rng, 16, 8)
    w[3] = 0.0
    x = _rand(rng, 8, 8)
    got = np.asarray(
        exemplar.dist_matrix(jnp.asarray(w), jnp.asarray(x),
                             _norms(w), _norms(x),
                             block_m=8, block_n=8, block_d=8)
    )
    np.testing.assert_allclose(got[3], np.sum(x * x, -1), rtol=1e-5, atol=1e-5)


def test_dist_self_distance_near_zero():
    rng = np.random.default_rng(1)
    x = _rand(rng, 16, 32)
    got = np.asarray(
        exemplar.dist_matrix(jnp.asarray(x), jnp.asarray(x),
                             _norms(x), _norms(x),
                             block_m=8, block_n=8, block_d=16)
    )
    assert np.all(np.abs(np.diag(got)) < 1e-4)


def test_dist_rejects_indivisible_blocks():
    rng = np.random.default_rng(2)
    w, x = _rand(rng, 10, 8), _rand(rng, 8, 8)
    with pytest.raises(ValueError):
        exemplar.dist_matrix(jnp.asarray(w), jnp.asarray(x),
                             _norms(w), _norms(x),
                             block_m=4, block_n=4, block_d=8)


def test_dist_rejects_dim_mismatch():
    rng = np.random.default_rng(3)
    with pytest.raises(ValueError):
        exemplar.dist_matrix(
            jnp.asarray(_rand(rng, 8, 8)), jnp.asarray(_rand(rng, 8, 4)),
            jnp.zeros(8), jnp.zeros(8))


# ---------------------------------------------------------------------------
# rbf_matrix
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    bp=st.sampled_from([8, 16]),
    bq=st.sampled_from([8, 16]),
    bd=st.sampled_from([4, 8]),
    gp=st.integers(1, 3),
    gq=st.integers(1, 3),
    gd=st.integers(1, 4),
    h2=st.sampled_from([0.25, 1.0, 4.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rbf_matches_ref_shapes(bp, bq, bd, gp, gq, gd, h2, seed):
    rng = np.random.default_rng(seed)
    p, q, d = bp * gp, bq * gq, bd * gd
    a, b = _rand(rng, p, d), _rand(rng, q, d)
    got = rbf.rbf_matrix(
        jnp.asarray(a), jnp.asarray(b), _norms(a), _norms(b),
        h2=h2, block_p=bp, block_q=bq, block_d=bd,
    )
    want = ref.rbf_matrix_ref(jnp.asarray(a), jnp.asarray(b), h2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


def test_rbf_diagonal_is_one():
    rng = np.random.default_rng(4)
    a = _rand(rng, 16, 8)
    got = np.asarray(
        rbf.rbf_matrix(jnp.asarray(a), jnp.asarray(a), _norms(a), _norms(a),
                       h2=0.25, block_p=8, block_q=8, block_d=8)
    )
    np.testing.assert_allclose(np.diag(got), np.ones(16), rtol=1e-5, atol=1e-5)


def test_rbf_values_in_unit_interval():
    rng = np.random.default_rng(5)
    a, b = _rand(rng, 16, 8), _rand(rng, 8, 8)
    got = np.asarray(
        rbf.rbf_matrix(jnp.asarray(a), jnp.asarray(b), _norms(a), _norms(b),
                       h2=0.25, block_p=8, block_q=8, block_d=8)
    )
    assert np.all(got >= 0.0) and np.all(got <= 1.0 + 1e-6)


def test_rbf_symmetry():
    rng = np.random.default_rng(6)
    a = _rand(rng, 16, 8)
    got = np.asarray(
        rbf.rbf_matrix(jnp.asarray(a), jnp.asarray(a), _norms(a), _norms(a),
                       h2=0.25, block_p=8, block_q=8, block_d=4)
    )
    np.testing.assert_allclose(got, got.T, rtol=1e-5, atol=1e-5)

"""L2 correctness: the AOT-able graphs vs straightforward numpy loops.

Verifies the exact semantics the rust coordinator relies on: padding
contract, masking sentinel, first-max tie-breaking, fused-greedy ==
step-by-step greedy == naive python greedy.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.model import ArtifactConfig


def _data(seed, m, mu, d):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m, d)).astype(np.float32)
    x = rng.normal(size=(mu, d)).astype(np.float32)
    return w, x


def _naive_greedy(w, x, k, avail=None):
    """Reference greedy: first-max tie-break, curmin starts at ||w||^2."""
    d2 = ((w[:, None, :] - x[None, :, :]) ** 2).sum(-1).astype(np.float64)
    cm = (w.astype(np.float64) ** 2).sum(-1)
    sel, gains = [], []
    avail = np.ones(len(x), bool) if avail is None else avail.copy()
    for _ in range(k):
        g = np.maximum(cm[:, None] - d2, 0).sum(0)
        g[~avail] = -np.inf
        j = int(np.argmax(g))
        sel.append(j)
        gains.append(g[j])
        avail[j] = False
        cm = np.minimum(cm, d2[:, j])
    return sel, gains, cm


# ---------------------------------------------------------------------------
# exstep
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.sampled_from([16, 64]),
       mu=st.sampled_from([8, 32]), d=st.sampled_from([4, 16]))
def test_exstep_first_pick_matches_naive(seed, m, mu, d):
    w, x = _data(seed, m, mu, d)
    cfg = ArtifactConfig(kind="exstep", m=m, mu=mu)
    fn, _ = model.build(cfg)
    d2 = ((w[:, None, :] - x[None, :, :]) ** 2).sum(-1).astype(np.float32)
    cm = (w * w).sum(-1)
    mask = np.ones(mu, np.float32)
    gains, best, best_gain, newcm = jax.jit(fn)(d2, cm, mask)
    sel, ref_gains, _ = _naive_greedy(w, x, 1)
    assert int(best) == sel[0]
    np.testing.assert_allclose(float(best_gain), ref_gains[0], rtol=1e-3)
    np.testing.assert_allclose(
        np.asarray(newcm), np.minimum(cm, d2[:, sel[0]]), rtol=1e-5)


def test_exstep_mask_excludes_candidates():
    w, x = _data(7, 32, 16, 8)
    cfg = ArtifactConfig(kind="exstep", m=32, mu=16)
    fn, _ = model.build(cfg)
    d2 = ((w[:, None, :] - x[None, :, :]) ** 2).sum(-1).astype(np.float32)
    cm = (w * w).sum(-1)
    mask = np.ones(16, np.float32)
    _, best_all, _, _ = jax.jit(fn)(d2, cm, mask)
    mask[int(best_all)] = 0.0
    gains, best2, _, _ = jax.jit(fn)(d2, cm, mask)
    assert int(best2) != int(best_all)
    assert float(np.asarray(gains)[int(best_all)]) <= float(model.NEG_INF)


def test_exstep_tie_break_is_first_max():
    """Duplicate candidates must resolve to the lower index (1-nice)."""
    w = np.ones((8, 4), np.float32)
    x = np.zeros((6, 4), np.float32)
    x[2] = 1.0
    x[5] = 1.0  # same item as index 2
    cfg = ArtifactConfig(kind="exstep", m=8, mu=6)
    fn, _ = model.build(cfg)
    d2 = ((w[:, None, :] - x[None, :, :]) ** 2).sum(-1).astype(np.float32)
    cm = (w * w).sum(-1)
    _, best, _, _ = jax.jit(fn)(d2, cm, np.ones(6, np.float32))
    assert int(best) == 2


# ---------------------------------------------------------------------------
# exupd
# ---------------------------------------------------------------------------


def test_exupd_commits_chosen_column():
    w, x = _data(11, 32, 16, 8)
    cfg = ArtifactConfig(kind="exupd", m=32, mu=16)
    fn, _ = model.build(cfg)
    d2 = ((w[:, None, :] - x[None, :, :]) ** 2).sum(-1).astype(np.float32)
    cm = (w * w).sum(-1)
    for idx in (0, 7, 15):
        (newcm,) = jax.jit(fn)(d2, cm, np.int32(idx))
        np.testing.assert_allclose(
            np.asarray(newcm), np.minimum(cm, d2[:, idx]), rtol=1e-6)


# ---------------------------------------------------------------------------
# exgreedy (fused whole-machine greedy)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_exgreedy_matches_naive(seed):
    m, mu, d, k = 64, 32, 16, 6
    w, x = _data(seed, m, mu, d)
    cfg = ArtifactConfig(kind="exgreedy", m=m, mu=mu, d=d, k=k,
                         use_pallas=False)
    fn, _ = model.build(cfg)
    sm = np.ones((k, mu), np.float32)
    idxs, gains, curmin = jax.jit(fn)(w, x, sm)
    sel, ref_gains, ref_cm = _naive_greedy(w, x, k)
    assert list(np.asarray(idxs)) == sel
    np.testing.assert_allclose(np.asarray(gains), ref_gains, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(curmin), ref_cm, rtol=1e-4,
                               atol=1e-5)


def test_exgreedy_pallas_matches_jnp():
    m, mu, d, k = 64, 32, 16, 5
    w, x = _data(3, m, mu, d)
    sm = np.ones((k, mu), np.float32)
    outs = []
    for use_pallas in (False, True):
        cfg = ArtifactConfig(kind="exgreedy", m=m, mu=mu, d=d, k=k,
                             use_pallas=use_pallas,
                             block_m=32, block_n=16, block_d=8)
        fn, _ = model.build(cfg)
        outs.append(jax.jit(fn)(w, x, sm))
    assert list(np.asarray(outs[0][0])) == list(np.asarray(outs[1][0]))
    np.testing.assert_allclose(np.asarray(outs[0][1]), np.asarray(outs[1][1]),
                               rtol=1e-4)


def test_exgreedy_padding_rows_never_selected():
    """Zero-padded candidates with mask 0 must not appear in the solution."""
    m, mu, d, k = 32, 16, 8, 5
    w, x = _data(9, m, mu, d)
    x[10:] = 0.0  # padding
    sm = np.ones((k, mu), np.float32)
    sm[:, 10:] = 0.0
    cfg = ArtifactConfig(kind="exgreedy", m=m, mu=mu, d=d, k=k,
                         use_pallas=False)
    fn, _ = model.build(cfg)
    idxs, gains, _ = jax.jit(fn)(w, x, sm)
    assert all(int(i) < 10 for i in np.asarray(idxs))


def test_exgreedy_exhausted_candidates_yield_sentinel():
    """k > #available: surplus steps report the NEG_INF sentinel gain."""
    m, mu, d, k = 32, 8, 8, 6
    w, x = _data(13, m, mu, d)
    sm = np.ones((k, mu), np.float32)
    sm[:, 4:] = 0.0  # only 4 real candidates
    cfg = ArtifactConfig(kind="exgreedy", m=m, mu=mu, d=d, k=k,
                         use_pallas=False)
    fn, _ = model.build(cfg)
    idxs, gains, _ = jax.jit(fn)(w, x, sm)
    gains = np.asarray(gains)
    assert np.all(gains[:4] > float(model.NEG_INF) / 2)
    assert np.all(gains[4:] <= float(model.NEG_INF) / 2)


def test_exgreedy_stepmask_restricts_candidates():
    """Stochastic-greedy contract: step t can only pick from stepmask[t]."""
    m, mu, d, k = 32, 16, 8, 4
    w, x = _data(17, m, mu, d)
    rng = np.random.default_rng(17)
    sm = np.zeros((k, mu), np.float32)
    allowed = []
    for t in range(k):
        pick = rng.choice(mu, size=6, replace=False)
        sm[t, pick] = 1.0
        allowed.append(set(int(p) for p in pick))
    cfg = ArtifactConfig(kind="exgreedy", m=m, mu=mu, d=d, k=k,
                         use_pallas=False)
    fn, _ = model.build(cfg)
    idxs, gains, _ = jax.jit(fn)(w, x, sm)
    for t, i in enumerate(np.asarray(idxs)):
        assert int(i) in allowed[t]


def test_exgreedy_monotone_objective():
    """f(S_t) is non-decreasing: all step gains >= 0."""
    m, mu, d, k = 64, 32, 8, 10
    w, x = _data(21, m, mu, d)
    cfg = ArtifactConfig(kind="exgreedy", m=m, mu=mu, d=d, k=k,
                         use_pallas=False)
    fn, _ = model.build(cfg)
    _, gains, _ = jax.jit(fn)(w, x, np.ones((k, mu), np.float32))
    assert np.all(np.asarray(gains) >= 0.0)


def test_exgreedy_gains_diminish():
    """Greedy step gains are non-increasing (submodularity signature)."""
    m, mu, d, k = 64, 32, 8, 10
    w, x = _data(23, m, mu, d)
    cfg = ArtifactConfig(kind="exgreedy", m=m, mu=mu, d=d, k=k,
                         use_pallas=False)
    fn, _ = model.build(cfg)
    _, gains, _ = jax.jit(fn)(w, x, np.ones((k, mu), np.float32))
    g = np.asarray(gains)
    assert np.all(g[:-1] >= g[1:] - 1e-3)


# ---------------------------------------------------------------------------
# config naming
# ---------------------------------------------------------------------------


def test_config_names_unique_across_default_set():
    from compile import aot
    names = [c.name for c in aot.default_configs()]
    assert len(names) == len(set(names))


def test_config_name_encodes_variant():
    a = ArtifactConfig(kind="dist", m=8, mu=8, d=4, use_pallas=True)
    b = ArtifactConfig(kind="dist", m=8, mu=8, d=4, use_pallas=False)
    assert a.name != b.name
    assert "pallas" in a.name and "jnp" in b.name

"""Sanity checks on the L1 performance model (compile/perf.py)."""

from compile import perf


def test_all_default_configs_fit_vmem():
    for c in perf.default_configs():
        assert c.vmem_bytes() < perf.VMEM_BYTES, c.name


def test_blocks_divide_shapes():
    for c in perf.default_configs():
        assert c.m % c.block_m == 0, c.name
        assert c.mu % c.block_n == 0, c.name
        assert c.d % c.block_d == 0, c.name


def test_mxu_alignment_full_for_128_multiples():
    c = perf.BlockConfig("t", 256, 256, 128, 512, 512, 128)
    assert c.mxu_alignment() == 1.0
    small = perf.BlockConfig("s", 64, 64, 16, 64, 64, 16)
    assert small.mxu_alignment() < 0.1


def test_mxu_flop_fraction_grows_with_depth():
    shallow = perf.BlockConfig("s", 256, 256, 32, 512, 512, 32)
    deep = perf.BlockConfig("d", 256, 256, 512, 512, 512, 512)
    assert deep.mxu_flop_fraction() > shallow.mxu_flop_fraction()
    assert deep.mxu_flop_fraction() > 0.99


def test_arithmetic_intensity_increases_with_block_size():
    small = perf.BlockConfig("s", 128, 128, 512, 512, 2048, 3072)
    big = perf.BlockConfig("b", 512, 512, 512, 512, 2048, 3072)
    assert big.arithmetic_intensity() > small.arithmetic_intensity()


def test_report_renders_all_rows():
    r = perf.report()
    assert len(r.splitlines()) == len(perf.default_configs()) + 1
    assert "MXU" in r

//! Lazy GREEDY (Minoux 1978) — the paper's default β-nice compressor
//! (β = 1 with consistent tie-breaking).

use crate::algorithms::{lazy_greedy_core, Compressor, Solution};
use crate::error::Result;
use crate::objectives::Problem;

/// Classic greedy with the lazy-evaluation priority queue. Supports any
/// objective and any hereditary constraint; tie-breaking is by lowest
/// candidate index (consistency property (1) of Definition 3.2).
#[derive(Debug, Default, Clone)]
pub struct LazyGreedy;

impl LazyGreedy {
    pub fn new() -> Self {
        LazyGreedy
    }
}

impl Compressor for LazyGreedy {
    fn name(&self) -> String {
        "greedy".into()
    }

    fn beta(&self) -> Option<f64> {
        Some(1.0)
    }

    fn compress(&self, problem: &Problem, candidates: &[u32], _seed: u64) -> Result<Solution> {
        lazy_greedy_core(problem, candidates, None)
    }

    fn boxed_clone(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }

    fn full_k(&self) -> bool {
        // greedy fills to k unless gains saturate to ≤ 0 early
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::{Knapsack, PartitionMatroid};
    use crate::data::synthetic;
    use crate::objectives::coverage::CoverageData;
    use std::sync::Arc;

    #[test]
    fn selects_top_k_on_modular() {
        let w: Vec<f64> = vec![5.0, 1.0, 9.0, 3.0, 7.0, 2.0];
        let p = Problem::modular(w, 3, 0);
        let sol = LazyGreedy::new()
            .compress(&p, &[0, 1, 2, 3, 4, 5], 0)
            .unwrap();
        let mut items = sol.items.clone();
        items.sort_unstable();
        assert_eq!(items, vec![0, 2, 4]);
        assert_eq!(sol.value, 21.0);
    }

    #[test]
    fn respects_cardinality() {
        let ds = Arc::new(synthetic::csn_like(200, 1));
        let p = Problem::exemplar(ds, 7, 1);
        let cands: Vec<u32> = (0..200).collect();
        let sol = LazyGreedy::new().compress(&p, &cands, 0).unwrap();
        assert_eq!(sol.items.len(), 7);
        // no duplicates
        let set: std::collections::HashSet<_> = sol.items.iter().collect();
        assert_eq!(set.len(), 7);
    }

    #[test]
    fn solution_value_matches_problem_value() {
        let ds = Arc::new(synthetic::csn_like(150, 2));
        let p = Problem::exemplar(ds, 5, 2);
        let cands: Vec<u32> = (0..150).collect();
        let sol = LazyGreedy::new().compress(&p, &cands, 0).unwrap();
        let v = p.value(&sol.items);
        assert!((sol.value - v).abs() < 1e-9, "{} vs {v}", sol.value);
    }

    #[test]
    fn respects_knapsack() {
        let ds = Arc::new(synthetic::csn_like(60, 3));
        let weights: Vec<f64> = (0..60).map(|i| 1.0 + (i % 4) as f64).collect();
        let knap = Arc::new(Knapsack::new(weights.clone(), 6.0, 10));
        let p = Problem::exemplar(ds, 10, 3).with_constraint(knap);
        let cands: Vec<u32> = (0..60).collect();
        let sol = LazyGreedy::new().compress(&p, &cands, 0).unwrap();
        let used: f64 = sol.items.iter().map(|&i| weights[i as usize]).sum();
        assert!(used <= 6.0 + 1e-9, "knapsack violated: {used}");
        assert!(!sol.items.is_empty());
    }

    #[test]
    fn respects_partition_matroid() {
        let ds = Arc::new(synthetic::csn_like(60, 4));
        let matroid = Arc::new(PartitionMatroid::round_robin(60, 3, 1, 10));
        let p = Problem::exemplar(ds, 10, 4).with_constraint(matroid.clone());
        let cands: Vec<u32> = (0..60).collect();
        let sol = LazyGreedy::new().compress(&p, &cands, 0).unwrap();
        assert!(sol.items.len() <= 3); // 3 groups × cap 1
        let groups: std::collections::HashSet<u32> =
            sol.items.iter().map(|&i| matroid.group(i)).collect();
        assert_eq!(groups.len(), sol.items.len());
    }

    #[test]
    fn greedy_is_optimal_on_modular_coverage() {
        // disjoint covers: greedy == optimum
        let data = CoverageData {
            covers: (0..8).map(|i| vec![i as u32]).collect(),
            weights: vec![1.0, 5.0, 3.0, 8.0, 2.0, 9.0, 4.0, 7.0],
        };
        let p = Problem::coverage(data, 3, 0);
        let sol = LazyGreedy::new()
            .compress(&p, &(0..8).collect::<Vec<_>>(), 0)
            .unwrap();
        assert_eq!(sol.value, 9.0 + 8.0 + 7.0);
    }

    #[test]
    fn achieves_1_minus_1_over_e_on_random_coverage() {
        use crate::util::check::{forall, gens};
        // exhaustive OPT on small instances, greedy ≥ (1-1/e)·OPT
        forall(17, 25, |rng| gens::coverage(rng, 10, 8), |inst| {
            let data = CoverageData {
                covers: inst.covers.clone(),
                weights: inst.weights.clone(),
            };
            let k = 3.min(inst.n);
            let p = Problem::coverage(data.clone(), k, 0);
            let cands: Vec<u32> = (0..inst.n as u32).collect();
            let sol = LazyGreedy::new().compress(&p, &cands, 0).unwrap();
            // brute-force OPT over all k-subsets
            let mut opt = 0.0f64;
            let n = inst.n;
            let idx: Vec<u32> = (0..n as u32).collect();
            fn rec(
                idx: &[u32],
                k: usize,
                start: usize,
                cur: &mut Vec<u32>,
                data: &CoverageData,
                opt: &mut f64,
            ) {
                if cur.len() == k || start == idx.len() {
                    let v = crate::objectives::coverage::coverage_value(data, cur);
                    if v > *opt {
                        *opt = v;
                    }
                    if cur.len() == k {
                        return;
                    }
                }
                for i in start..idx.len() {
                    cur.push(idx[i]);
                    rec(idx, k, i + 1, cur, data, opt);
                    cur.pop();
                }
            }
            rec(&idx, k, 0, &mut Vec::new(), &data, &mut opt);
            let bound = (1.0 - (-1.0f64).exp()) * opt;
            if sol.value + 1e-9 < bound {
                return Err(format!("greedy {} < (1-1/e)OPT {}", sol.value, bound));
            }
            Ok(())
        });
    }
}

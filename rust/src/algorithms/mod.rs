//! Single-machine β-nice compression algorithms (Definition 3.2).
//!
//! These are the `A` plugged into Algorithm 1: given a machine's
//! partition they return at most `k` feasible items. GREEDY (lazy
//! variant, Minoux 1978) is 1-nice; THRESHOLD GREEDY (Badanidiyuru &
//! Vondrák 2014) is (1+2ε)-nice; STOCHASTIC GREEDY (Mirzasoleiman et
//! al. 2015) has no proven β but performs well empirically (paper §4.4).

mod greedy;
mod random_sel;
mod stochastic;
mod threshold;

pub use greedy::LazyGreedy;
pub use random_sel::RandomCompressor;
pub use stochastic::StochasticGreedy;
pub use threshold::ThresholdGreedy;

use crate::error::Result;
use crate::objectives::Problem;

/// A feasible solution with its (f64, recomputable) objective value.
#[derive(Debug, Clone, Default)]
pub struct Solution {
    pub items: Vec<u32>,
    pub value: f64,
}

impl Solution {
    pub fn empty() -> Self {
        Solution::default()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// A single-machine compression algorithm: selects a feasible subset of
/// `candidates` with at most `problem.k` items.
pub trait Compressor: Send + Sync {
    fn name(&self) -> String;

    /// β-niceness parameter, if proven (GREEDY: 1; threshold: 1+2ε).
    /// Used by [`crate::analysis::bounds`] to instantiate Theorem 3.3.
    fn beta(&self) -> Option<f64>;

    /// Compress `candidates` (global ids) down to ≤ k feasible items.
    /// `seed` derandomizes any internal randomness per machine.
    fn compress(&self, problem: &Problem, candidates: &[u32], seed: u64) -> Result<Solution>;

    /// Clone into an owned trait object. Event-driven backends
    /// ([`crate::dist::Backend::submit_round`]) run rounds on background
    /// threads that outlive the caller's borrow, so they need an owned
    /// copy of the compressor.
    fn boxed_clone(&self) -> Box<dyn Compressor>;

    /// `true` if, under a plain cardinality constraint, this compressor
    /// *usually* returns exactly `min(k, candidates.len())` items (it
    /// may still stop early when every remaining marginal gain is
    /// non-positive). The pipelined tree runner uses this as a
    /// size-prediction hint to pre-compute the next round's partition
    /// while stragglers finish; a wrong prediction is detected and the
    /// partition recomputed, so this is a performance hint, never a
    /// correctness input.
    fn full_k(&self) -> bool {
        false
    }
}

/// Shared helper: run plain greedy with a lazy (Minoux) priority queue
/// over an oracle, respecting the problem's hereditary constraint.
/// `step_filter(step) -> Option<allowed>`: if Some, only candidate local
/// indices in `allowed` may be selected at that step (stochastic greedy's
/// per-step subsample); if None all candidates are eligible.
pub(crate) fn lazy_greedy_core(
    problem: &Problem,
    candidates: &[u32],
    step_filter: Option<&mut dyn FnMut(usize) -> Vec<usize>>,
) -> Result<Solution> {
    let mut oracle = problem.oracle(candidates);
    lazy_greedy_over(oracle.as_mut(), problem, candidates, step_filter)
}

/// Same as [`lazy_greedy_core`] but over an externally-constructed oracle
/// (the XLA-accelerated paths build their own).
pub(crate) fn lazy_greedy_over(
    oracle: &mut dyn crate::objectives::Oracle,
    problem: &Problem,
    candidates: &[u32],
    mut step_filter: Option<&mut dyn FnMut(usize) -> Vec<usize>>,
) -> Result<Solution> {
    use std::cmp::Ordering as CmpOrd;
    use std::collections::BinaryHeap;

    /// Heap entry ordered by upper bound (max-heap); ties by lower index
    /// for the consistent tie-breaking that makes GREEDY 1-nice.
    struct Entry {
        ub: f64,
        j: usize,
        stamp: usize,
    }
    impl PartialEq for Entry {
        fn eq(&self, other: &Self) -> bool {
            // consistent with Ord below (== on f64 would disagree with
            // total_cmp on NaN and signed zero)
            self.cmp(other) == CmpOrd::Equal
        }
    }
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, other: &Self) -> Option<CmpOrd> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, other: &Self) -> CmpOrd {
            // max-heap on ub, then min on index (first-max tie-break).
            // total_cmp keeps the heap order coherent even if an oracle
            // returns NaN: the old partial_cmp().unwrap_or(Equal) made
            // NaN compare equal to *everything*, which violates
            // transitivity and silently scrambles the heap.
            self.ub
                .total_cmp(&other.ub)
                .then_with(|| other.j.cmp(&self.j))
        }
    }

    let k = problem.k.min(problem.constraint.max_cardinality());
    let mut selected_local: Vec<usize> = Vec::with_capacity(k);
    let mut selected: Vec<u32> = Vec::with_capacity(k);

    if let Some(filter) = step_filter.as_mut() {
        // Restricted mode (stochastic greedy): exactly k sampling steps,
        // each scanning only that step's subsample — no lazy heap, since
        // the eligible set changes every step.
        for step in 0..k {
            let allowed = filter(step);
            let mut best: Option<(f64, usize)> = None;
            for j in allowed {
                if selected_local.contains(&j)
                    || !problem
                        .constraint
                        .can_add(&selected, candidates[j], &problem.dataset)
                {
                    continue;
                }
                let g = oracle.gain(j);
                let better = match best {
                    None => true,
                    Some((bg, bj)) => g > bg || (g == bg && j < bj),
                };
                if better {
                    best = Some((g, j));
                }
            }
            if let Some((g, j)) = best {
                if g > 0.0 {
                    oracle.commit(j);
                    selected_local.push(j);
                    selected.push(candidates[j]);
                }
            }
        }
        return Ok(Solution { value: oracle.value(), items: selected });
    }

    // Lazy (Minoux) greedy: initial bulk pass builds the heap of upper
    // bounds; thereafter stale bounds are refreshed in blocks through
    // the batched oracle path (`gains_for`).
    //
    // Block refresh is selection-identical to the one-at-a-time Minoux
    // refresh: gains are *exact* (not estimates), no commit happens
    // mid-block, and every refreshed entry re-enters the heap with its
    // exact gain at the current selection state — so the committed
    // argmax (and the smaller-index tie-break, and the `ub <= 0` stop
    // condition) are unchanged; the block merely front-loads refreshes
    // the scalar queue would have performed later. The differential
    // tests in this module hold the two byte-identical.
    const REFRESH_BLOCK: usize = 32;

    let gains = oracle.bulk_gains();
    let mut heap: BinaryHeap<Entry> = gains
        .into_iter()
        .enumerate()
        .map(|(j, ub)| Entry { ub, j, stamp: 0 })
        .collect();

    while selected.len() < k {
        let Some(top) = heap.pop() else { break };
        if !problem
            .constraint
            .can_add(&selected, candidates[top.j], &problem.dataset)
        {
            // infeasible now; with accretive hereditary constraints it
            // stays infeasible, so drop it
            continue;
        }
        let stamp = selected.len();
        if top.stamp == stamp {
            // fresh bound: this is the true argmax
            if top.ub <= 0.0 {
                break; // no positive marginal gain anywhere
            }
            oracle.commit(top.j);
            selected_local.push(top.j);
            selected.push(candidates[top.j]);
        } else {
            // gather up to REFRESH_BLOCK stale entries off the top of
            // the heap (dropping infeasible ones — hereditary
            // constraints keep them infeasible forever) and refresh
            // them in one batched call
            let mut js = Vec::with_capacity(REFRESH_BLOCK);
            js.push(top.j);
            while js.len() < REFRESH_BLOCK {
                if !matches!(heap.peek(), Some(e) if e.stamp != stamp) {
                    break;
                }
                let Some(e) = heap.pop() else { break };
                if !problem
                    .constraint
                    .can_add(&selected, candidates[e.j], &problem.dataset)
                {
                    continue;
                }
                js.push(e.j);
            }
            let refreshed = oracle.gains_for(&js);
            for (&j, ub) in js.iter().zip(refreshed) {
                heap.push(Entry { ub, j, stamp });
            }
        }
    }

    Ok(Solution { value: oracle.value(), items: selected })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objectives::coverage::CoverageData;

    /// Naive reference greedy used to validate the lazy implementation.
    pub(crate) fn naive_greedy(problem: &Problem, candidates: &[u32]) -> Solution {
        let mut oracle = problem.oracle(candidates);
        let mut selected: Vec<u32> = Vec::new();
        let mut taken = vec![false; candidates.len()];
        while selected.len() < problem.k {
            let mut best: Option<(f64, usize)> = None;
            for j in 0..candidates.len() {
                if taken[j]
                    || !problem.constraint.can_add(&selected, candidates[j], &problem.dataset)
                {
                    continue;
                }
                let g = oracle.gain(j);
                if best.map(|(bg, _)| g > bg).unwrap_or(true) {
                    best = Some((g, j));
                }
            }
            match best {
                Some((g, j)) if g > 0.0 => {
                    oracle.commit(j);
                    taken[j] = true;
                    selected.push(candidates[j]);
                }
                _ => break,
            }
        }
        Solution { value: oracle.value(), items: selected }
    }

    #[test]
    fn nan_gain_is_deterministic_and_surfaces_in_the_value() {
        // Regression for the heap comparator (the bug class re-fixed in
        // PRs 2/4/5): partial-comparison fallbacks made a NaN gain
        // compare "equal" to everything, which breaks transitivity and
        // scrambles the heap nondeterministically. Under total_cmp a
        // positive NaN outranks every finite gain, so the poisoned item
        // is selected deterministically and the NaN *surfaces* in the
        // solution value instead of silently reordering unrelated items.
        let weights = vec![1.0, f64::NAN, 3.0, 2.0];
        let p = Problem::modular(weights, 2, 0);
        let cands: Vec<u32> = (0..4).collect();
        let a = lazy_greedy_core(&p, &cands, None).unwrap();
        let b = lazy_greedy_core(&p, &cands, None).unwrap();
        assert_eq!(a.items, b.items, "NaN gains must not make selection nondeterministic");
        assert_eq!(a.items, vec![1, 2], "NaN-gain item pops first, then the best finite gain");
        assert!(a.value.is_nan(), "the poisoned objective must surface, got {}", a.value);
    }

    /// The seed's one-at-a-time Minoux queue, kept verbatim as the
    /// reference the block-refresh implementation must match bitwise.
    fn scalar_minoux(problem: &Problem, candidates: &[u32]) -> Solution {
        use std::cmp::Ordering as CmpOrd;
        use std::collections::BinaryHeap;
        struct Entry {
            ub: f64,
            j: usize,
            stamp: usize,
        }
        impl PartialEq for Entry {
            fn eq(&self, other: &Self) -> bool {
                self.cmp(other) == CmpOrd::Equal
            }
        }
        impl Eq for Entry {}
        impl PartialOrd for Entry {
            fn partial_cmp(&self, other: &Self) -> Option<CmpOrd> {
                Some(self.cmp(other))
            }
        }
        impl Ord for Entry {
            fn cmp(&self, other: &Self) -> CmpOrd {
                self.ub.total_cmp(&other.ub).then_with(|| other.j.cmp(&self.j))
            }
        }
        let mut oracle = problem.oracle(candidates);
        let k = problem.k.min(problem.constraint.max_cardinality());
        let mut selected: Vec<u32> = Vec::with_capacity(k);
        let gains = oracle.bulk_gains();
        let mut heap: BinaryHeap<Entry> = gains
            .into_iter()
            .enumerate()
            .map(|(j, ub)| Entry { ub, j, stamp: 0 })
            .collect();
        while selected.len() < k {
            let Some(top) = heap.pop() else { break };
            if !problem
                .constraint
                .can_add(&selected, candidates[top.j], &problem.dataset)
            {
                continue;
            }
            if top.stamp == selected.len() {
                if top.ub <= 0.0 {
                    break;
                }
                oracle.commit(top.j);
                selected.push(candidates[top.j]);
            } else {
                let g = oracle.gain(top.j);
                heap.push(Entry { ub: g, j: top.j, stamp: selected.len() });
            }
        }
        Solution { value: oracle.value(), items: selected }
    }

    #[test]
    fn block_refresh_is_byte_identical_to_scalar_minoux() {
        use crate::constraints::{Cardinality, Constraint, Intersection, Knapsack, PartitionMatroid};
        use crate::data::{synthetic, DatasetRef};
        use std::sync::Arc;

        let n: usize = 120;
        let k = 9;
        let ds: DatasetRef = Arc::new(synthetic::csn_like(n, 9));
        let mut rng = crate::util::rng::Rng::seed_from(42);
        let covers: Vec<Vec<u32>> = (0..n)
            .map(|_| (0..6).map(|_| rng.below(40) as u32).collect())
            .collect();
        let weights: Vec<f64> = (0..40).map(|_| rng.f64() + 0.1).collect();
        let modular_w: Vec<f64> = (0..n).map(|_| rng.f64() * 5.0).collect();
        let problems: Vec<Problem> = vec![
            Problem::exemplar(ds.clone(), k, 1),
            Problem::logdet(ds.clone(), k, 1),
            Problem::coverage(CoverageData { covers, weights }, k, 1),
            Problem::modular(modular_w, k, 1),
        ];
        let knap_w: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let groups: Vec<u32> = (0..n).map(|i| (i % 5) as u32).collect();
        let constraints: Vec<Arc<dyn Constraint>> = vec![
            Arc::new(Cardinality::new(k)),
            Arc::new(Knapsack::new(knap_w.clone(), 25.0, k)),
            Arc::new(PartitionMatroid::new(groups.clone(), vec![2; 5], k)),
            Arc::new(Intersection::new(vec![
                Arc::new(Knapsack::new(knap_w, 30.0, k)),
                Arc::new(PartitionMatroid::new(groups, vec![3; 5], k)),
            ])),
        ];
        let cands: Vec<u32> = (0..n as u32).collect();
        for p0 in &problems {
            for c in &constraints {
                let p = p0.clone().with_constraint(c.clone());
                let blocked = lazy_greedy_core(&p, &cands, None).unwrap();
                let scalar = scalar_minoux(&p, &cands);
                assert_eq!(
                    blocked.items, scalar.items,
                    "selection diverged: {} under {}",
                    p.objective.name(),
                    c.name()
                );
                assert_eq!(
                    blocked.value.to_bits(),
                    scalar.value.to_bits(),
                    "value not bit-identical: {} under {}",
                    p.objective.name(),
                    c.name()
                );
            }
        }
    }

    #[test]
    fn all_compressors_are_byte_identical_across_engines() {
        // the Engine bit-identity contract, observed end to end: every
        // compressor must produce the same Solution whether the problem
        // computes on the native engine or the xla engine (whose oracle
        // kernels run the same blocked code; a device, when one starts,
        // only serves the fused compressor paths, which are not in play
        // here)
        use crate::data::{synthetic, DatasetRef};
        use crate::runtime::EngineChoice;
        use std::sync::Arc;
        let ds: DatasetRef = Arc::new(synthetic::csn_like(80, 5));
        let cands: Vec<u32> = (0..80).collect();
        let compressors: Vec<Box<dyn Compressor>> = vec![
            Box::new(LazyGreedy::new()),
            Box::new(ThresholdGreedy::new(0.2)),
            Box::new(StochasticGreedy::new(0.5)),
            Box::new(RandomCompressor::new()),
        ];
        for base in [Problem::exemplar(ds.clone(), 6, 3), Problem::logdet(ds.clone(), 6, 3)] {
            for c in &compressors {
                let native = base.clone().with_compute(EngineChoice::Native.build());
                let xla = base.clone().with_compute(EngineChoice::Xla.build());
                let a = c.compress(&native, &cands, 7).unwrap();
                let b = c.compress(&xla, &cands, 7).unwrap();
                assert_eq!(a.items, b.items, "{} selection diverged", c.name());
                assert_eq!(
                    a.value.to_bits(),
                    b.value.to_bits(),
                    "{} value not bit-identical",
                    c.name()
                );
            }
        }
    }

    #[test]
    fn lazy_matches_naive_on_coverage() {
        use crate::util::check::{forall, gens};
        forall(31, 30, |rng| gens::coverage(rng, 14, 12), |inst| {
            let data = CoverageData {
                covers: inst.covers.clone(),
                weights: inst.weights.clone(),
            };
            let p = Problem::coverage(data, 4, 1);
            let cands: Vec<u32> = (0..inst.n as u32).collect();
            let lazy = lazy_greedy_core(&p, &cands, None).unwrap();
            let naive = naive_greedy(&p, &cands);
            if lazy.items != naive.items {
                return Err(format!("{:?} vs {:?}", lazy.items, naive.items));
            }
            if (lazy.value - naive.value).abs() > 1e-9 {
                return Err("value mismatch".into());
            }
            Ok(())
        });
    }
}

//! RANDOM baseline: a uniformly random feasible subset of size ≤ k
//! (the paper's Table 3 "RANDOM" column and Figure 2 baseline).

use crate::algorithms::{Compressor, Solution};
use crate::error::Result;
use crate::objectives::Problem;
use crate::util::rng::Rng;

#[derive(Debug, Default, Clone)]
pub struct RandomCompressor;

impl RandomCompressor {
    pub fn new() -> Self {
        RandomCompressor
    }
}

impl Compressor for RandomCompressor {
    fn name(&self) -> String {
        "random".into()
    }

    fn beta(&self) -> Option<f64> {
        None
    }

    fn compress(&self, problem: &Problem, candidates: &[u32], seed: u64) -> Result<Solution> {
        let mut rng = Rng::seed_from(seed ^ 0xBA5E11E5);
        let mut order: Vec<u32> = candidates.to_vec();
        rng.shuffle(&mut order);
        let k = problem.k.min(problem.constraint.max_cardinality());
        let mut items = Vec::with_capacity(k);
        for &c in &order {
            if items.len() >= k {
                break;
            }
            if problem.constraint.can_add(&items, c, &problem.dataset) {
                items.push(c);
            }
        }
        let value = problem.value(&items);
        Ok(Solution { items, value })
    }

    fn boxed_clone(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }

    fn full_k(&self) -> bool {
        // under a plain cardinality constraint every candidate is
        // addable, so random selection always fills to min(k, n)
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::data::synthetic;
    use std::sync::Arc;

    #[test]
    fn picks_k_distinct_feasible_items() {
        let ds = Arc::new(synthetic::csn_like(100, 12));
        let p = Problem::exemplar(ds, 10, 12);
        let cands: Vec<u32> = (0..100).collect();
        let sol = RandomCompressor::new().compress(&p, &cands, 5).unwrap();
        assert_eq!(sol.items.len(), 10);
        let set: std::collections::HashSet<_> = sol.items.iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn deterministic_per_seed_and_worse_than_greedy() {
        let ds = Arc::new(synthetic::csn_like(500, 13));
        let p = Problem::exemplar(ds, 10, 13);
        let cands: Vec<u32> = (0..500).collect();
        let r1 = RandomCompressor::new().compress(&p, &cands, 1).unwrap();
        let r2 = RandomCompressor::new().compress(&p, &cands, 1).unwrap();
        assert_eq!(r1.items, r2.items);
        let g = LazyGreedy::new().compress(&p, &cands, 0).unwrap();
        assert!(g.value >= r1.value, "greedy {} < random {}", g.value, r1.value);
    }
}

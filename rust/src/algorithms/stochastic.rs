//! STOCHASTIC GREEDY (Mirzasoleiman et al. 2015, "Lazier than lazy
//! greedy"): at each of the k steps, scan a uniform random subsample of
//! `s = ⌈(n/k)·ln(1/ε)⌉` remaining candidates and take the best. Expected
//! approximation `1 − 1/e − ε` centralized; used by the paper (§4.4) as a
//! pruning subprocedure without a proven β.

use crate::algorithms::{lazy_greedy_core, Compressor, Solution};
use crate::error::Result;
use crate::objectives::Problem;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct StochasticGreedy {
    pub epsilon: f64,
}

impl StochasticGreedy {
    pub fn new(epsilon: f64) -> Self {
        assert!(epsilon > 0.0 && epsilon < 1.0, "epsilon in (0,1)");
        StochasticGreedy { epsilon }
    }

    /// Per-step sample size for `n` candidates and budget `k`.
    pub fn sample_size(&self, n: usize, k: usize) -> usize {
        if n == 0 || k == 0 {
            return 0;
        }
        let s = ((n as f64 / k as f64) * (1.0 / self.epsilon).ln()).ceil() as usize;
        s.clamp(1, n)
    }
}

impl Compressor for StochasticGreedy {
    fn name(&self) -> String {
        format!("stochastic-greedy(eps={})", self.epsilon)
    }

    fn beta(&self) -> Option<f64> {
        None // not proven β-nice (paper §3)
    }

    fn compress(&self, problem: &Problem, candidates: &[u32], seed: u64) -> Result<Solution> {
        let n = candidates.len();
        let s = self.sample_size(n, problem.k);
        let mut rng = Rng::seed_from(seed ^ 0x570C4_A57C);
        let mut filter = move |_step: usize| -> Vec<usize> {
            rng.sample_indices(n, s).into_iter().map(|i| i as usize).collect()
        };
        lazy_greedy_core(problem, candidates, Some(&mut filter))
    }

    fn boxed_clone(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::data::synthetic;
    use std::sync::Arc;

    #[test]
    fn sample_size_formula() {
        let sg = StochasticGreedy::new(0.5);
        // (100/10)·ln2 ≈ 6.93 -> 7
        assert_eq!(sg.sample_size(100, 10), 7);
        let sg = StochasticGreedy::new(0.2);
        assert_eq!(sg.sample_size(100, 10), 17); // 10·ln5 ≈ 16.09 -> 17
        assert_eq!(sg.sample_size(5, 10), 1); // ⌈(5/10)·ln5⌉ = ⌈0.81⌉ = 1
        assert_eq!(sg.sample_size(0, 10), 0);
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = Arc::new(synthetic::csn_like(300, 5));
        let p = Problem::exemplar(ds, 8, 5);
        let cands: Vec<u32> = (0..300).collect();
        let sg = StochasticGreedy::new(0.5);
        let a = sg.compress(&p, &cands, 42).unwrap();
        let b = sg.compress(&p, &cands, 42).unwrap();
        assert_eq!(a.items, b.items);
        let c = sg.compress(&p, &cands, 43).unwrap();
        // different sample — almost surely a different trajectory
        assert!(a.items != c.items || a.value == c.value);
    }

    #[test]
    fn close_to_full_greedy_in_value() {
        let ds = Arc::new(synthetic::csn_like(400, 6));
        let p = Problem::exemplar(ds, 10, 6);
        let cands: Vec<u32> = (0..400).collect();
        let full = LazyGreedy::new().compress(&p, &cands, 0).unwrap();
        let sg = StochasticGreedy::new(0.2).compress(&p, &cands, 1).unwrap();
        assert!(
            sg.value >= 0.8 * full.value,
            "stochastic {} vs greedy {}",
            sg.value,
            full.value
        );
    }

    #[test]
    fn uses_fewer_oracle_evals_than_full_greedy() {
        let ds = Arc::new(synthetic::csn_like(500, 7));
        let cands: Vec<u32> = (0..500).collect();

        let p1 = Problem::exemplar(ds.clone(), 10, 7);
        LazyGreedy::new().compress(&p1, &cands, 0).unwrap();
        let full_evals = p1.eval_count();

        let p2 = Problem::exemplar(ds, 10, 7);
        StochasticGreedy::new(0.5).compress(&p2, &cands, 0).unwrap();
        let sg_evals = p2.eval_count();

        assert!(
            sg_evals < full_evals,
            "stochastic {sg_evals} >= full {full_evals}"
        );
    }

    #[test]
    fn respects_k() {
        let ds = Arc::new(synthetic::csn_like(100, 8));
        let p = Problem::exemplar(ds, 5, 8);
        let cands: Vec<u32> = (0..100).collect();
        let sol = StochasticGreedy::new(0.5).compress(&p, &cands, 3).unwrap();
        assert!(sol.items.len() <= 5);
        let set: std::collections::HashSet<_> = sol.items.iter().collect();
        assert_eq!(set.len(), sol.items.len());
    }
}

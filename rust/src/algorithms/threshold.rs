//! THRESHOLD GREEDY (Badanidiyuru & Vondrák 2014): descending-threshold
//! passes. `(1 + 2ε)`-nice (paper §3), `O(n/ε · log(n/ε))` oracle calls.

use crate::algorithms::{Compressor, Solution};
use crate::error::Result;
use crate::objectives::Problem;

#[derive(Debug, Clone)]
pub struct ThresholdGreedy {
    pub epsilon: f64,
}

impl ThresholdGreedy {
    pub fn new(epsilon: f64) -> Self {
        assert!(epsilon > 0.0 && epsilon < 1.0);
        ThresholdGreedy { epsilon }
    }
}

impl Compressor for ThresholdGreedy {
    fn name(&self) -> String {
        format!("threshold-greedy(eps={})", self.epsilon)
    }

    fn beta(&self) -> Option<f64> {
        Some(1.0 + 2.0 * self.epsilon)
    }

    fn compress(&self, problem: &Problem, candidates: &[u32], _seed: u64) -> Result<Solution> {
        let mut oracle = problem.oracle(candidates);
        let k = problem.k.min(problem.constraint.max_cardinality());
        let n = candidates.len();
        let mut selected: Vec<u32> = Vec::with_capacity(k);
        let mut taken = vec![false; n];
        if n == 0 || k == 0 {
            return Ok(Solution::empty());
        }

        // d = max singleton gain over *constraint-addable* candidates.
        // An infeasible top singleton (e.g. a knapsack item over budget
        // on its own) can never be selected, but counting it would
        // inflate both the initial threshold and the ε·d/n floor —
        // potentially above every feasible gain, selecting nothing.
        let singleton = oracle.bulk_gains();
        let mut d = 0.0f64;
        for (j, &g) in singleton.iter().enumerate() {
            if problem
                .constraint
                .can_add(&selected, candidates[j], &problem.dataset)
            {
                d = d.max(g);
            }
        }
        if d <= 0.0 {
            return Ok(Solution::empty());
        }
        let floor = (self.epsilon / n as f64) * d;
        let mut tau = d;
        while tau >= floor && selected.len() < k {
            for j in 0..n {
                if selected.len() >= k {
                    break;
                }
                if taken[j]
                    || !problem
                        .constraint
                        .can_add(&selected, candidates[j], &problem.dataset)
                {
                    continue;
                }
                let g = oracle.gain(j);
                if g >= tau {
                    oracle.commit(j);
                    taken[j] = true;
                    selected.push(candidates[j]);
                }
            }
            tau *= 1.0 - self.epsilon;
        }
        Ok(Solution { value: oracle.value(), items: selected })
    }

    fn boxed_clone(&self) -> Box<dyn Compressor> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::data::synthetic;
    use std::sync::Arc;

    #[test]
    fn within_eps_of_greedy_value() {
        let ds = Arc::new(synthetic::csn_like(300, 9));
        let p = Problem::exemplar(ds, 10, 9);
        let cands: Vec<u32> = (0..300).collect();
        let greedy = LazyGreedy::new().compress(&p, &cands, 0).unwrap();
        let th = ThresholdGreedy::new(0.1).compress(&p, &cands, 0).unwrap();
        assert!(
            th.value >= (1.0 - 0.15) * greedy.value,
            "threshold {} vs greedy {}",
            th.value,
            greedy.value
        );
    }

    #[test]
    fn beta_reflects_epsilon() {
        assert_eq!(ThresholdGreedy::new(0.25).beta(), Some(1.5));
    }

    #[test]
    fn respects_k_and_feasibility() {
        let ds = Arc::new(synthetic::csn_like(120, 10));
        let p = Problem::exemplar(ds, 4, 10);
        let cands: Vec<u32> = (0..120).collect();
        let sol = ThresholdGreedy::new(0.2).compress(&p, &cands, 0).unwrap();
        assert!(sol.items.len() <= 4);
        assert!(p.constraint.is_feasible(&sol.items, &p.dataset));
    }

    #[test]
    fn infeasible_top_singleton_does_not_inflate_threshold() {
        use crate::constraints::Knapsack;

        // item 0 has by far the largest gain but violates the knapsack
        // budget on its own; with d over *all* singletons the floor
        // ε·d/n = 5 would exceed every feasible gain (1.0) and the
        // algorithm would return empty
        let mut gains = vec![1.0; 10];
        gains[0] = 100.0;
        let mut weights = vec![1.0; 10];
        weights[0] = 10.0; // > budget alone
        let p = Problem::modular(gains, 5, 0)
            .with_constraint(Arc::new(Knapsack::new(weights, 5.0, 5)));
        let cands: Vec<u32> = (0..10).collect();
        let sol = ThresholdGreedy::new(0.5).compress(&p, &cands, 0).unwrap();
        assert!(!sol.items.contains(&0), "selected the over-budget item");
        assert_eq!(sol.items.len(), 5, "feasible items were skipped: {:?}", sol.items);
        assert_eq!(sol.value, 5.0);
        assert!(p.constraint.is_feasible(&sol.items, &p.dataset));
    }

    #[test]
    fn empty_input_gives_empty_solution() {
        let ds = Arc::new(synthetic::csn_like(50, 11));
        let p = Problem::exemplar(ds, 5, 11);
        let sol = ThresholdGreedy::new(0.2).compress(&p, &[], 0).unwrap();
        assert!(sol.items.is_empty());
        assert_eq!(sol.value, 0.0);
    }
}

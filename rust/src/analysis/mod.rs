//! Theoretical bounds from the paper, used by tests and benches to
//! sanity-check every measured ratio.

pub mod bounds {
    use crate::coordinator::planner::round_bound;

    /// Theorem 3.3 lower bound on `E[f(S)]/f(OPT)` for a β-nice
    /// compressor with capacity µ:
    /// * µ ≥ n      → 1/(1+β)
    /// * µ ≥ √(nk)  → 1/(2(1+β))
    /// * otherwise  → 1/(r(1+β)), r = ⌈log_{µ/k}(n/µ)⌉ + 1
    pub fn thm33(n: usize, k: usize, capacity: usize, beta: f64) -> f64 {
        if capacity >= n {
            1.0 / (1.0 + beta)
        } else if (capacity * capacity) as f64 >= (n * k) as f64 {
            1.0 / (2.0 * (1.0 + beta))
        } else {
            let r = round_bound(n, k, capacity) as f64;
            1.0 / (r * (1.0 + beta))
        }
    }

    /// Theorem 3.3 specialized to GREEDY (the paper's statement):
    /// (1−1/e) centralized, (1−1/e)/2 two-round, 1/(2r) multi-round.
    pub fn thm33_greedy(n: usize, k: usize, capacity: usize) -> f64 {
        let e = std::f64::consts::E;
        if capacity >= n {
            1.0 - 1.0 / e
        } else if (capacity * capacity) as f64 >= (n * k) as f64 {
            (1.0 - 1.0 / e) / 2.0
        } else {
            let r = round_bound(n, k, capacity) as f64;
            1.0 / (2.0 * r)
        }
    }

    /// Theorem 3.5: `E[f(S)] ≥ (α/r)·f(OPT)` for GREEDY under any
    /// hereditary constraint, where α is centralized GREEDY's factor for
    /// that constraint (e.g. 1/2 for matroids, 1−1/e for cardinality).
    pub fn thm35(n: usize, k: usize, capacity: usize, alpha: f64) -> f64 {
        let r = round_bound(n, k, capacity).max(1) as f64;
        alpha / r
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn regimes_of_thm33() {
            let e = std::f64::consts::E;
            // centralized regime
            assert!((thm33_greedy(100, 10, 100) - (1.0 - 1.0 / e)).abs() < 1e-12);
            // two-round regime: µ² ≥ nk
            assert!((thm33_greedy(10_000, 25, 500) - (1.0 - 1.0 / e) / 2.0).abs() < 1e-12);
            // multi-round: strictly positive, decreasing with r
            let deep = thm33_greedy(1_000_000, 50, 200);
            let shallow = thm33_greedy(10_000, 50, 200);
            assert!(deep > 0.0 && deep < shallow);
        }

        #[test]
        fn beta_degrades_bound() {
            let b1 = thm33(10_000, 25, 100, 1.0);
            let b2 = thm33(10_000, 25, 100, 1.5);
            assert!(b2 < b1);
        }

        #[test]
        fn thm35_matches_cardinality_special_case() {
            // α = 1−1/e under cardinality: thm35 = (1−1/e)/r vs thm33's 1/(2r):
            // thm35 is the tighter statement for greedy
            let n = 100_000;
            let (k, mu) = (50, 200);
            let t35 = thm35(n, k, mu, 1.0 - 1.0 / std::f64::consts::E);
            let t33 = thm33_greedy(n, k, mu);
            assert!(t35 >= t33);
        }
    }
}

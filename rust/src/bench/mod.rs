//! Bench harness (criterion substitute for the offline build): timing
//! runner with warmup + sampling, aligned table printing, and JSON
//! result persistence under `bench_results/`.

use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};
use crate::util::stats::Summary;

/// Timed measurement of a closure.
pub struct BenchRunner {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup: 1, samples: 5 }
    }
}

impl BenchRunner {
    pub fn quick() -> Self {
        BenchRunner { warmup: 0, samples: 2 }
    }

    /// Run `f` with warmup, collect per-sample wall times (ms).
    pub fn time<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut summary = Summary::new();
        for _ in 0..self.samples.max(1) {
            let t = Instant::now();
            f();
            summary.push(t.elapsed().as_secs_f64() * 1e3);
        }
        summary
    }
}

/// A column-aligned text table (what the bench binaries print — the
/// same rows the paper's tables report).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        // lint:allow(logging): bench tables are the harness's primary stdout artifact (CI diffs them), not diagnostics for the leveled logger
        print!("{}", self.render());
    }

    /// Persist as JSON under `bench_results/<name>.json`.
    pub fn save_json(&self, name: &str) -> std::io::Result<()> {
        let dir = std::path::Path::new("bench_results");
        std::fs::create_dir_all(dir)?;
        let doc = obj(vec![
            ("title", s(&self.title)),
            ("headers", arr(self.headers.iter().map(|h| s(h)))),
            (
                "rows",
                arr(self.rows.iter().map(|r| arr(r.iter().map(|c| s(c))))),
            ),
            ("unix_ms", num(now_ms())),
        ]);
        std::fs::write(dir.join(format!("{name}.json")), doc.to_string_pretty())
    }
}

fn now_ms() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as f64)
        .unwrap_or(0.0)
}

/// Format helper: mean ± stddev in ms.
pub fn fmt_ms(s: &Summary) -> String {
    format!("{:.1}±{:.1}ms", s.mean(), s.stddev())
}

/// Parse common bench CLI flags: `--quick` (fewer trials), `--trials N`,
/// `--out NAME`.
pub struct BenchArgs {
    pub args: crate::util::cli::Args,
    pub quick: bool,
    pub trials: usize,
}

impl BenchArgs {
    pub fn from_env(default_trials: usize) -> Self {
        let args = crate::util::cli::Args::from_env().unwrap_or_default();
        let quick = args.flag("quick");
        let trials = args
            .usize("trials", if quick { 2 } else { default_trials })
            .unwrap_or(default_trials);
        BenchArgs { args, quick, trials }
    }
}

/// Check Json import is exercised (keeps the module honest under
/// `--no-default-features`-style pruning).
pub fn _json_type_witness() -> Json {
    Json::Null
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "column"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("column"));
        let lines: Vec<&str> = r.lines().filter(|l| !l.is_empty()).collect();
        // header + separator + 2 rows + title
        assert!(lines.len() >= 5);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn row_arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn runner_collects_samples() {
        let r = BenchRunner { warmup: 1, samples: 3 };
        let mut count = 0;
        let s = r.time(|| count += 1);
        assert_eq!(count, 4);
        assert_eq!(s.len(), 3);
        assert!(s.mean() >= 0.0);
    }
}

//! Experiment configuration: JSON-loadable run specs used by the CLI
//! (`hss run --config <file>`) and defaults matching the paper's
//! experimental grid.

use std::path::Path;

use crate::data::registry;
use crate::error::{Error, Result};
use crate::objectives::Problem;
use crate::util::json::Json;

/// Which algorithm a run executes.
#[derive(Debug, Clone, PartialEq)]
pub enum Algo {
    Tree,
    StochasticTree { epsilon: f64 },
    RandGreedi,
    Greedi,
    Centralized,
    Random,
}

impl Algo {
    pub fn parse(name: &str, epsilon: f64) -> Result<Algo> {
        Ok(match name {
            "tree" => Algo::Tree,
            "stochastic-tree" => Algo::StochasticTree { epsilon },
            "randgreedi" => Algo::RandGreedi,
            "greedi" => Algo::Greedi,
            "centralized" | "greedy" => Algo::Centralized,
            "random" => Algo::Random,
            other => return Err(Error::Config(format!("unknown algorithm '{other}'"))),
        })
    }

    pub fn name(&self) -> String {
        match self {
            Algo::Tree => "tree".into(),
            Algo::StochasticTree { epsilon } => format!("stochastic-tree(eps={epsilon})"),
            Algo::RandGreedi => "randgreedi".into(),
            Algo::Greedi => "greedi".into(),
            Algo::Centralized => "centralized".into(),
            Algo::Random => "random".into(),
        }
    }
}

/// One experiment run specification.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub dataset: String,
    pub algo: Algo,
    pub k: usize,
    pub capacity: usize,
    pub seed: u64,
    pub trials: usize,
    pub use_engine: bool,
    pub threads: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "csn-2k".into(),
            algo: Algo::Tree,
            k: 50,
            capacity: 200,
            seed: 42,
            trials: 1,
            use_engine: true,
            threads: 2,
        }
    }
}

impl RunConfig {
    /// Load from a JSON file, e.g.
    /// `{"dataset":"csn-20k","algo":"tree","k":50,"capacity":400}`.
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<RunConfig> {
        let v = Json::parse(text)?;
        let mut cfg = RunConfig::default();
        if let Some(d) = v.get("dataset").and_then(Json::as_str) {
            cfg.dataset = d.to_string();
        }
        let eps = v.get("epsilon").and_then(Json::as_f64).unwrap_or(0.5);
        if let Some(a) = v.get("algo").and_then(Json::as_str) {
            cfg.algo = Algo::parse(a, eps)?;
        }
        if let Some(x) = v.get("k").and_then(Json::as_usize) {
            cfg.k = x;
        }
        if let Some(x) = v.get("capacity").and_then(Json::as_usize) {
            cfg.capacity = x;
        }
        if let Some(x) = v.get("seed").and_then(Json::as_f64) {
            cfg.seed = x as u64;
        }
        if let Some(x) = v.get("trials").and_then(Json::as_usize) {
            cfg.trials = x.max(1);
        }
        if let Some(x) = v.get("use_engine").and_then(Json::as_bool) {
            cfg.use_engine = x;
        }
        if let Some(x) = v.get("threads").and_then(Json::as_usize) {
            cfg.threads = x.max(1);
        }
        // dataset names validate eagerly
        registry::spec(&cfg.dataset)?;
        Ok(cfg)
    }

    /// Materialize the problem this config describes (objective follows
    /// the paper's Table 2 dataset→objective mapping).
    pub fn problem(&self) -> Result<Problem> {
        let ds = registry::load(&self.dataset, self.seed)?;
        let p = match dataset_objective(&self.dataset) {
            "logdet" => Problem::logdet(ds, self.k, self.seed),
            _ => Problem::exemplar(ds, self.k, self.seed),
        };
        Ok(p)
    }

    /// Attach the XLA engine if requested and available.
    pub fn problem_with_engine(&self) -> Result<(Problem, Option<crate::runtime::EngineHandle>)> {
        let mut p = self.problem()?;
        let engine = if self.use_engine {
            match crate::runtime::Engine::start_default() {
                Ok(e) => {
                    p = p.with_engine(e.clone());
                    Some(e)
                }
                Err(_) => None, // artifacts not built: pure path
            }
        } else {
            None
        };
        Ok((p, engine))
    }
}

/// Paper Table 2 dataset → objective mapping.
pub fn dataset_objective(dataset: &str) -> &'static str {
    if dataset.starts_with("parkinsons") || dataset.starts_with("webscope") {
        "logdet"
    } else {
        "exemplar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_json_text(
            r#"{"dataset":"csn-2k","algo":"stochastic-tree","epsilon":0.2,
                "k":20,"capacity":100,"seed":7,"trials":3,"use_engine":false}"#,
        )
        .unwrap();
        assert_eq!(cfg.k, 20);
        assert_eq!(cfg.capacity, 100);
        assert_eq!(cfg.algo, Algo::StochasticTree { epsilon: 0.2 });
        assert!(!cfg.use_engine);
        assert_eq!(cfg.trials, 3);
    }

    #[test]
    fn rejects_unknown_dataset_and_algo() {
        assert!(RunConfig::from_json_text(r#"{"dataset":"nope"}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"algo":"nope"}"#).is_err());
    }

    #[test]
    fn objective_mapping_matches_table2() {
        assert_eq!(dataset_objective("csn-20k"), "exemplar");
        assert_eq!(dataset_objective("tiny-10k"), "exemplar");
        assert_eq!(dataset_objective("parkinsons"), "logdet");
        assert_eq!(dataset_objective("webscope-100k"), "logdet");
    }

    #[test]
    fn default_is_valid() {
        let cfg = RunConfig::default();
        assert!(registry::spec(&cfg.dataset).is_ok());
    }
}

//! Experiment configuration: JSON-loadable run specs used by the CLI
//! (`hss run --config <file>`) and defaults matching the paper's
//! experimental grid.

use std::path::Path;
use std::sync::Arc;

use crate::constraints::spec::ConstraintSpec;
use crate::coordinator::capacity::CapacityProfile;
use crate::coordinator::PartitionStrategy;
use crate::data::registry;
use crate::dist::{Backend, BackendChoice, FaultPlan};
use crate::error::{Error, Result};
use crate::objectives::Problem;
use crate::runtime::EngineChoice;
use crate::util::json::Json;

/// Which algorithm a run executes.
#[derive(Debug, Clone, PartialEq)]
pub enum Algo {
    Tree,
    StochasticTree { epsilon: f64 },
    RandGreedi,
    Greedi,
    Centralized,
    Random,
}

impl Algo {
    pub fn parse(name: &str, epsilon: f64) -> Result<Algo> {
        Ok(match name {
            "tree" => Algo::Tree,
            "stochastic-tree" => Algo::StochasticTree { epsilon },
            "randgreedi" => Algo::RandGreedi,
            "greedi" => Algo::Greedi,
            "centralized" | "greedy" => Algo::Centralized,
            "random" => Algo::Random,
            other => return Err(Error::Config(format!("unknown algorithm '{other}'"))),
        })
    }

    pub fn name(&self) -> String {
        match self {
            Algo::Tree => "tree".into(),
            Algo::StochasticTree { epsilon } => format!("stochastic-tree(eps={epsilon})"),
            Algo::RandGreedi => "randgreedi".into(),
            Algo::Greedi => "greedi".into(),
            Algo::Centralized => "centralized".into(),
            Algo::Random => "random".into(),
        }
    }
}

/// One experiment run specification.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub dataset: String,
    pub algo: Algo,
    pub k: usize,
    /// Fleet capacity profile: a scalar µ (`200`), an explicit class
    /// list (`500,200,200` / `[500, 200, 200]` in JSON), or a repeated
    /// class (`200x8`) — see [`CapacityProfile::parse`].
    pub capacity: CapacityProfile,
    pub seed: u64,
    pub trials: usize,
    /// Legacy device-offload gate: `false` (`--no-engine`) pins the run
    /// to [`EngineChoice::Native`] regardless of `engine`, exactly like
    /// the pre-engine "pure rust" mode.
    pub use_engine: bool,
    /// Compute engine for oracles and kernels (`engine` config key,
    /// `--engine` flag): `native` (default) is the dependency-free
    /// batched CPU backend; `xla` adds the device thread when artifacts
    /// are built, falling back to the native kernels otherwise. Under a
    /// tcp backend the choice is also requested from every worker at
    /// handshake.
    pub engine: EngineChoice,
    pub threads: usize,
    /// Execution backend for compression rounds (local | tcp | sim).
    pub backend: BackendChoice,
    /// Round partition strategy (`balanced` — the paper's §3 default —
    /// or `contiguous`, the GreeDI-style locality-aware partitioner
    /// that unlocks speculative next-round dispatch).
    pub partitioner: PartitionStrategy,
    /// Hereditary constraint in the [`ConstraintSpec::parse`] grammar
    /// (e.g. `knapsack:b=30,w=rownorm2+pmatroid:groups=5,cap=2`);
    /// `None` means the plain cardinality constraint `card(k)`. Kept as
    /// text because `k` may still be overridden by later CLI flags —
    /// the spec is resolved against the final `k` in [`RunConfig::problem`].
    pub constraint: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "csn-2k".into(),
            algo: Algo::Tree,
            k: 50,
            capacity: CapacityProfile::uniform(200),
            seed: 42,
            trials: 1,
            use_engine: true,
            engine: EngineChoice::Native,
            threads: 2,
            backend: BackendChoice::Local,
            partitioner: PartitionStrategy::Balanced,
            constraint: None,
        }
    }
}

impl RunConfig {
    /// Load from a JSON file, e.g.
    /// `{"dataset":"csn-20k","algo":"tree","k":50,"capacity":400}`.
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<RunConfig> {
        let v = Json::parse(text)?;
        let mut cfg = RunConfig::default();
        if let Some(d) = v.get("dataset").and_then(Json::as_str) {
            cfg.dataset = d.to_string();
        }
        let eps = v.get("epsilon").and_then(Json::as_f64).unwrap_or(0.5);
        if let Some(a) = v.get("algo").and_then(Json::as_str) {
            cfg.algo = Algo::parse(a, eps)?;
        }
        if let Some(x) = v.get("k").and_then(Json::as_usize) {
            cfg.k = x;
        }
        if let Some(x) = v.get("capacity") {
            cfg.capacity = capacity_from_json(x)?;
        }
        if let Some(x) = v.get("seed") {
            cfg.seed = json_u64(x, "seed")?;
        }
        if let Some(x) = v.get("trials").and_then(Json::as_usize) {
            cfg.trials = x.max(1);
        }
        if let Some(x) = v.get("use_engine").and_then(Json::as_bool) {
            cfg.use_engine = x;
        }
        if let Some(e) = v.get("engine").and_then(Json::as_str) {
            cfg.engine = EngineChoice::parse(e)?;
        }
        if let Some(x) = v.get("threads").and_then(Json::as_usize) {
            cfg.threads = x.max(1);
        }
        if let Some(c) = v.get("constraint").and_then(Json::as_str) {
            // validate the grammar eagerly; the spec is re-resolved
            // against the final k when the problem is built
            ConstraintSpec::parse(c, cfg.k)?;
            cfg.constraint = Some(c.to_string());
        }
        if let Some(p) = v.get("partitioner").and_then(Json::as_str) {
            cfg.partitioner = PartitionStrategy::parse(p)?;
        }
        if let Some(b) = v.get("backend").and_then(Json::as_str) {
            cfg.backend = BackendChoice::parse(b)?;
        }
        if let BackendChoice::Tcp { workers } = &mut cfg.backend {
            if let Some(list) = v.get("workers").and_then(Json::as_arr) {
                *workers = list
                    .iter()
                    .map(|w| {
                        w.as_str().map(str::to_string).ok_or_else(|| {
                            Error::Config("'workers' must be an array of host:port strings".into())
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            // An empty list here is not an error: the CLI may supply
            // --workers after the config file loads ("config file first,
            // CLI flags override"). TcpBackend::new rejects an empty
            // list at build time.
        }
        if let BackendChoice::Sim { faults, schedule } = &mut cfg.backend {
            if let Some(sim) = v.get("sim") {
                *faults = parse_fault_plan(sim)?;
                if let Some(entry) = sim.get("capacity_schedule") {
                    *schedule = parse_capacity_schedule(entry)?;
                }
            }
        }
        // dataset names validate eagerly
        registry::spec(&cfg.dataset)?;
        Ok(cfg)
    }

    /// Build the concrete execution backend this config selects. Tcp
    /// backends request this config's engine from every worker at
    /// handshake; local and sim execution follow the problem's own
    /// engine.
    pub fn build_backend(&self) -> Result<Arc<dyn Backend>> {
        self.backend
            .build_with_engine(&self.capacity, Some(self.threads), self.engine_choice())
    }

    /// The effective engine choice: `engine`, unless the legacy
    /// `--no-engine` gate pins the run to native.
    pub fn engine_choice(&self) -> EngineChoice {
        if self.use_engine {
            self.engine
        } else {
            EngineChoice::Native
        }
    }

    /// Materialize the problem this config describes (objective follows
    /// the paper's Table 2 dataset→objective mapping; the constraint
    /// spec, if any, is built against the loaded dataset).
    pub fn problem(&self) -> Result<Problem> {
        let ds = registry::load(&self.dataset, self.seed)?;
        let mut p = match dataset_objective(&self.dataset) {
            "logdet" => Problem::logdet(ds, self.k, self.seed),
            _ => Problem::exemplar(ds, self.k, self.seed),
        };
        if let Some(text) = &self.constraint {
            let spec = ConstraintSpec::parse(text, self.k)?;
            let constraint = spec.build(&p.dataset)?;
            p = p.with_constraint(constraint);
        }
        Ok(p)
    }

    /// Materialize the problem with this config's compute engine
    /// attached. The returned handle is the XLA device thread when the
    /// engine is `xla` *and* its artifacts are built — `None` otherwise
    /// (the engine then serves the same batched native kernels, so
    /// results are bit-identical either way).
    pub fn problem_with_engine(&self) -> Result<(Problem, Option<crate::runtime::EngineHandle>)> {
        let engine = self.engine_choice().build();
        let handle = engine.xla_handle().cloned();
        let p = self.problem()?.with_compute(engine);
        Ok((p, handle))
    }
}

/// Parse a capacity profile from a config value: a plain number
/// (uniform µ), a string in the [`CapacityProfile::parse`] grammar
/// (`"500,200,200"`, `"200x8"`), or an array of per-class numbers.
fn capacity_from_json(v: &Json) -> Result<CapacityProfile> {
    if let Some(mu) = v.as_usize() {
        if mu == 0 {
            return Err(Error::Config("capacity must be positive".into()));
        }
        return Ok(CapacityProfile::uniform(mu));
    }
    if let Some(text) = v.as_str() {
        return CapacityProfile::parse(text);
    }
    if let Some(arr) = v.as_arr() {
        let caps: Vec<usize> = arr
            .iter()
            .map(|x| {
                x.as_usize().ok_or_else(|| {
                    Error::Config("'capacity' array entries must be positive integers".into())
                })
            })
            .collect::<Result<_>>()?;
        return CapacityProfile::new(caps).map_err(|e| Error::Config(e.to_string()));
    }
    Err(Error::Config(
        "'capacity' must be a number, a profile string (e.g. \"500,200,200\" or \
         \"200x8\"), or an array of numbers"
            .into(),
    ))
}

/// Parse a `sim.capacity_schedule` config value: an array of per-round
/// profiles (each in any [`capacity_from_json`] form) or a single
/// string in the CLI's `--sim-capacity-schedule` grammar
/// (`PROFILE[;PROFILE…]`). Wrong types are an error, never silently a
/// static fleet.
fn parse_capacity_schedule(v: &Json) -> Result<Vec<CapacityProfile>> {
    let schedule: Vec<CapacityProfile> = if let Some(entries) = v.as_arr() {
        entries.iter().map(capacity_from_json).collect::<Result<Vec<_>>>()?
    } else if let Some(text) = v.as_str() {
        text.split(';')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(CapacityProfile::parse)
            .collect::<Result<Vec<_>>>()?
    } else {
        return Err(Error::Config(
            "'sim.capacity_schedule' must be an array of capacity profiles or a \
             'PROFILE[;PROFILE...]' string"
                .into(),
        ));
    };
    if schedule.is_empty() {
        return Err(Error::Config(
            "'sim.capacity_schedule' needs at least one profile".into(),
        ));
    }
    Ok(schedule)
}

/// Parse a u64 config field losslessly (decimal string above 2^53 —
/// same convention as the dist wire protocol; see
/// [`crate::util::json::as_lossless_u64`]).
fn json_u64(v: &Json, what: &str) -> Result<u64> {
    crate::util::json::as_lossless_u64(v).ok_or_else(|| {
        Error::Config(format!(
            "{what}: expected a non-negative integer (use a decimal string above 2^53)"
        ))
    })
}

/// Parse a fault-injection plan from a config `"sim"` object, e.g.
/// `{"loss_per_round":1,"straggler_prob":0.1,"straggler_delay_ms":50}`.
fn parse_fault_plan(v: &Json) -> Result<FaultPlan> {
    let mut f = FaultPlan::default();
    if let Some(x) = v.get("seed") {
        f.seed = json_u64(x, "sim.seed")?;
    }
    if let Some(x) = v.get("loss_per_round").and_then(Json::as_usize) {
        f.machine_loss_per_round = x;
    }
    if let Some(x) = v.get("loss_prob").and_then(Json::as_f64) {
        if !(0.0..=1.0).contains(&x) {
            return Err(Error::Config(format!("sim.loss_prob {x} out of [0,1]")));
        }
        f.loss_prob = x;
    }
    if let Some(x) = v.get("max_retries").and_then(Json::as_usize) {
        f.max_retries = x;
    }
    if let Some(x) = v.get("straggler_prob").and_then(Json::as_f64) {
        if !(0.0..=1.0).contains(&x) {
            return Err(Error::Config(format!("sim.straggler_prob {x} out of [0,1]")));
        }
        f.straggler_prob = x;
    }
    if let Some(x) = v.get("straggler_delay_ms").and_then(Json::as_f64) {
        f.straggler_delay_ms = x;
    }
    Ok(f)
}

/// Paper Table 2 dataset → objective mapping.
pub fn dataset_objective(dataset: &str) -> &'static str {
    if dataset.starts_with("parkinsons") || dataset.starts_with("webscope") {
        "logdet"
    } else {
        "exemplar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_json_text(
            r#"{"dataset":"csn-2k","algo":"stochastic-tree","epsilon":0.2,
                "k":20,"capacity":100,"seed":7,"trials":3,"use_engine":false}"#,
        )
        .unwrap();
        assert_eq!(cfg.k, 20);
        assert_eq!(cfg.capacity, CapacityProfile::uniform(100));
        assert_eq!(cfg.algo, Algo::StochasticTree { epsilon: 0.2 });
        assert!(!cfg.use_engine);
        assert_eq!(cfg.trials, 3);
    }

    #[test]
    fn parses_capacity_profiles_in_all_three_json_forms() {
        let num = RunConfig::from_json_text(r#"{"capacity":400}"#).unwrap();
        assert_eq!(num.capacity, CapacityProfile::uniform(400));
        let text = RunConfig::from_json_text(r#"{"capacity":"500,200x2"}"#).unwrap();
        assert_eq!(text.capacity.caps(), &[500, 200, 200]);
        let arr = RunConfig::from_json_text(r#"{"capacity":[200,500,200]}"#).unwrap();
        assert_eq!(arr.capacity.caps(), &[500, 200, 200], "arrays sort descending");
        for bad in [
            r#"{"capacity":0}"#,
            r#"{"capacity":"zebra"}"#,
            r#"{"capacity":[100,0]}"#,
            r#"{"capacity":true}"#,
        ] {
            assert!(RunConfig::from_json_text(bad).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn rejects_unknown_dataset_and_algo() {
        assert!(RunConfig::from_json_text(r#"{"dataset":"nope"}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"algo":"nope"}"#).is_err());
    }

    #[test]
    fn parses_engine_choice_and_no_engine_pins_native() {
        let cfg = RunConfig::from_json_text(r#"{"engine":"xla"}"#).unwrap();
        assert_eq!(cfg.engine, EngineChoice::Xla);
        assert_eq!(cfg.engine_choice(), EngineChoice::Xla);
        // the legacy gate wins over the engine name
        let pinned =
            RunConfig::from_json_text(r#"{"engine":"xla","use_engine":false}"#).unwrap();
        assert_eq!(pinned.engine_choice(), EngineChoice::Native);
        // default runs native
        assert_eq!(RunConfig::default().engine_choice(), EngineChoice::Native);
        assert!(RunConfig::from_json_text(r#"{"engine":"gpu9000"}"#).is_err());
    }

    #[test]
    fn objective_mapping_matches_table2() {
        assert_eq!(dataset_objective("csn-20k"), "exemplar");
        assert_eq!(dataset_objective("tiny-10k"), "exemplar");
        assert_eq!(dataset_objective("parkinsons"), "logdet");
        assert_eq!(dataset_objective("webscope-100k"), "logdet");
    }

    #[test]
    fn default_is_valid() {
        let cfg = RunConfig::default();
        assert!(registry::spec(&cfg.dataset).is_ok());
        assert_eq!(cfg.backend, BackendChoice::Local);
        assert_eq!(cfg.partitioner, PartitionStrategy::Balanced);
    }

    #[test]
    fn parses_partitioner_strategies() {
        let cfg = RunConfig::from_json_text(r#"{"partitioner":"contiguous"}"#).unwrap();
        assert_eq!(cfg.partitioner, PartitionStrategy::Contiguous);
        let cfg = RunConfig::from_json_text(r#"{"partitioner":"balanced"}"#).unwrap();
        assert_eq!(cfg.partitioner, PartitionStrategy::Balanced);
        // the iid strawman is ablation-only, not a run path
        assert!(RunConfig::from_json_text(r#"{"partitioner":"iid"}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"partitioner":"zebra"}"#).is_err());
    }

    #[test]
    fn parses_tcp_backend_with_workers() {
        let cfg = RunConfig::from_json_text(
            r#"{"backend":"tcp","workers":["127.0.0.1:7070","127.0.0.1:7071"]}"#,
        )
        .unwrap();
        match &cfg.backend {
            BackendChoice::Tcp { workers } => {
                assert_eq!(workers, &["127.0.0.1:7070", "127.0.0.1:7071"]);
            }
            other => panic!("wrong backend {other:?}"),
        }
        assert!(cfg.build_backend().is_ok());
    }

    #[test]
    fn tcp_backend_without_workers_parses_but_does_not_build() {
        // parsing succeeds — the CLI may add --workers after the config
        // file loads — but building the backend without any rejects
        let cfg = RunConfig::from_json_text(r#"{"backend":"tcp"}"#).unwrap();
        assert!(cfg.build_backend().is_err());
        // malformed entries and unknown backends still fail at parse time
        assert!(RunConfig::from_json_text(r#"{"backend":"tcp","workers":[7]}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"backend":"warp"}"#).is_err());
    }

    #[test]
    fn parses_sim_capacity_schedule_in_all_profile_forms() {
        // round-indexed fleet script: numbers, profile strings and
        // arrays are all accepted (the --capacity forms)
        let cfg = RunConfig::from_json_text(
            r#"{"backend":"sim","sim":{"capacity_schedule":[400,"200x2",[100,50]]}}"#,
        )
        .unwrap();
        match &cfg.backend {
            BackendChoice::Sim { schedule, .. } => {
                assert_eq!(schedule.len(), 3);
                assert_eq!(schedule[0], CapacityProfile::uniform(400));
                assert_eq!(schedule[1].caps(), &[200, 200]);
                assert_eq!(schedule[2].caps(), &[100, 50]);
            }
            other => panic!("wrong backend {other:?}"),
        }
        // the built backend replays the script round by round
        let backend = cfg.build_backend().unwrap();
        assert_eq!(backend.profile(), CapacityProfile::uniform(400));
        // the CLI's PROFILE[;PROFILE…] grammar works as a string too
        let cli_form = RunConfig::from_json_text(
            r#"{"backend":"sim","sim":{"capacity_schedule":"400;200x2;100,50"}}"#,
        )
        .unwrap();
        match &cli_form.backend {
            BackendChoice::Sim { schedule, .. } => {
                assert_eq!(schedule.len(), 3);
                assert_eq!(schedule[2].caps(), &[100, 50]);
            }
            other => panic!("wrong backend {other:?}"),
        }
        // malformed entries and wrong types are rejected at parse time,
        // never silently a static fleet
        for bad in [
            r#"{"backend":"sim","sim":{"capacity_schedule":["zebra"]}}"#,
            r#"{"backend":"sim","sim":{"capacity_schedule":[0]}}"#,
            r#"{"backend":"sim","sim":{"capacity_schedule":true}}"#,
            r#"{"backend":"sim","sim":{"capacity_schedule":[]}}"#,
            r#"{"backend":"sim","sim":{"capacity_schedule":";"}}"#,
        ] {
            assert!(RunConfig::from_json_text(bad).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn parses_sim_backend_faults() {
        let cfg = RunConfig::from_json_text(
            r#"{"backend":"sim","sim":{"loss_per_round":1,"loss_prob":0.1,
                "straggler_prob":0.2,"straggler_delay_ms":40,"max_retries":5,"seed":9}}"#,
        )
        .unwrap();
        match &cfg.backend {
            BackendChoice::Sim { faults, .. } => {
                assert_eq!(faults.machine_loss_per_round, 1);
                assert_eq!(faults.loss_prob, 0.1);
                assert_eq!(faults.straggler_prob, 0.2);
                assert_eq!(faults.straggler_delay_ms, 40.0);
                assert_eq!(faults.max_retries, 5);
                assert_eq!(faults.seed, 9);
            }
            other => panic!("wrong backend {other:?}"),
        }
        // out-of-range probabilities rejected
        assert!(
            RunConfig::from_json_text(r#"{"backend":"sim","sim":{"loss_prob":1.5}}"#).is_err()
        );
    }

    #[test]
    fn parses_constraint_spec_and_applies_it() {
        let cfg = RunConfig::from_json_text(
            r#"{"k":10,"constraint":"knapsack:b=25,w=unit+pmatroid:groups=5,cap=2"}"#,
        )
        .unwrap();
        assert_eq!(
            cfg.constraint.as_deref(),
            Some("knapsack:b=25,w=unit+pmatroid:groups=5,cap=2")
        );
        let p = cfg.problem().unwrap();
        let name = p.constraint.name();
        assert!(name.contains("knapsack"), "{name}");
        assert!(name.contains("partition"), "{name}");
        // the built constraint is wire-representable end to end
        assert!(p.constraint.wire_spec().is_some());
        // malformed constraint specs fail at parse time
        assert!(RunConfig::from_json_text(r#"{"constraint":"mystery"}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"constraint":"knapsack:b=zebra"}"#).is_err());
    }

    #[test]
    fn u64_seeds_parse_losslessly_from_strings() {
        // above 2^53 a JSON number would silently lose low bits; the
        // string form is exact (mirrors the dist wire convention)
        let cfg = RunConfig::from_json_text(
            r#"{"seed":"18446744073709551615",
                "backend":"sim","sim":{"seed":"18446744073709551614"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.seed, u64::MAX);
        match &cfg.backend {
            BackendChoice::Sim { faults, .. } => assert_eq!(faults.seed, u64::MAX - 1),
            other => panic!("wrong backend {other:?}"),
        }
        assert!(RunConfig::from_json_text(r#"{"seed":-3}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"seed":"zebra"}"#).is_err());
    }
}

//! Hereditary constraints (paper §3.2).
//!
//! A constraint family `I ⊆ 2^V` is *hereditary* if `S ∈ I` implies every
//! subset of `S` is in `I`. All implementations here are defined through
//! a `can_add(current, item)` predicate that is oblivious to insertion
//! order, which guarantees heredity by construction (removing items never
//! invalidates the remaining prefix checks) — property-tested below.

pub mod spec;

use crate::data::Dataset;
use spec::{ConstraintSpec, GroupSpec, WeightSpec};

/// A hereditary constraint over dataset items.
pub trait Constraint: Send + Sync {
    fn name(&self) -> String;

    /// May `item` be added to the feasible set `current`?
    fn can_add(&self, current: &[u32], item: u32, dataset: &Dataset) -> bool;

    /// Is the whole set feasible? Default: incremental check (valid for
    /// order-oblivious `can_add`).
    fn is_feasible(&self, items: &[u32], dataset: &Dataset) -> bool {
        let mut cur: Vec<u32> = Vec::with_capacity(items.len());
        for &i in items {
            if !self.can_add(&cur, i, dataset) {
                return false;
            }
            cur.push(i);
        }
        true
    }

    /// An upper bound on the size of any feasible set (used for buffer
    /// sizing; the cardinality component of composite constraints).
    fn max_cardinality(&self) -> usize;

    /// Wire spec this constraint can be rebuilt from on a remote worker
    /// ([`ConstraintSpec`], wire spec v2). `None` for constraints with
    /// no recorded construction recipe (ad-hoc test constraints).
    fn wire_spec(&self) -> Option<ConstraintSpec> {
        None
    }
}

/// `|S| ≤ k`.
#[derive(Debug, Clone)]
pub struct Cardinality {
    pub k: usize,
}

impl Cardinality {
    pub fn new(k: usize) -> Self {
        Cardinality { k }
    }
}

impl Constraint for Cardinality {
    fn name(&self) -> String {
        format!("card({})", self.k)
    }

    fn can_add(&self, current: &[u32], _item: u32, _dataset: &Dataset) -> bool {
        current.len() < self.k
    }

    fn max_cardinality(&self) -> usize {
        self.k
    }

    fn wire_spec(&self) -> Option<ConstraintSpec> {
        Some(ConstraintSpec::Cardinality { k: self.k })
    }
}

/// Knapsack: `Σ_{i∈S} w_i ≤ b` with per-item weights supplied by a
/// closure of the dataset (e.g. row norm) or an explicit table, plus a
/// cardinality cap `k` (the paper's framework always selects ≤ k items).
pub struct Knapsack {
    pub budget: f64,
    pub k: usize,
    weights: Vec<f64>,
    /// Wire provenance: how `weights` can be regenerated remotely.
    /// `None` means "explicit table" — the [`WeightSpec::Explicit`] form
    /// is derived from `weights` on demand rather than stored as a
    /// second permanent copy.
    weight_spec: Option<WeightSpec>,
}

impl Knapsack {
    pub fn new(weights: Vec<f64>, budget: f64, k: usize) -> Self {
        Self::with_weight_spec(weights, None, budget, k)
    }

    pub(crate) fn with_weight_spec(
        weights: Vec<f64>,
        weight_spec: Option<WeightSpec>,
        budget: f64,
        k: usize,
    ) -> Self {
        assert!(weights.iter().all(|&w| w >= 0.0), "negative knapsack weight");
        Knapsack { budget, k, weights, weight_spec }
    }

    /// Weights = squared row norms (a natural "cost" for data summaries).
    pub fn from_row_norms(dataset: &Dataset, budget: f64, k: usize) -> Self {
        // one definition of the table, shared with worker-side spec
        // rebuilding — coordinator and worker must agree bit-for-bit
        let weights = WeightSpec::RowNorm2
            .materialize(dataset)
            .expect("rownorm2 weights are infallible");
        Self::with_weight_spec(weights, Some(WeightSpec::RowNorm2), budget, k)
    }

    /// Seeded uniform weights in `[lo, hi)` — an ad-hoc instance any
    /// worker regenerates from the spec alone.
    pub fn seeded(n: usize, seed: u64, lo: f64, hi: f64, budget: f64, k: usize) -> Self {
        // one definition of range validity, shared with the CLI/wire path
        WeightSpec::check_range(lo, hi).expect("invalid seeded weight range");
        let weights = spec::seeded_weights(n, seed, lo, hi);
        Self::with_weight_spec(weights, Some(WeightSpec::Seeded { seed, lo, hi }), budget, k)
    }

    pub fn weight(&self, item: u32) -> f64 {
        self.weights[item as usize]
    }
}

impl Constraint for Knapsack {
    fn name(&self) -> String {
        format!("knapsack(b={}, k={})", self.budget, self.k)
    }

    fn can_add(&self, current: &[u32], item: u32, _dataset: &Dataset) -> bool {
        if current.len() >= self.k {
            return false;
        }
        let used: f64 = current.iter().map(|&i| self.weights[i as usize]).sum();
        used + self.weights[item as usize] <= self.budget + 1e-12
    }

    fn max_cardinality(&self) -> usize {
        self.k
    }

    fn wire_spec(&self) -> Option<ConstraintSpec> {
        let weights = self
            .weight_spec
            .clone()
            .unwrap_or_else(|| WeightSpec::Explicit(self.weights.clone()));
        Some(ConstraintSpec::Knapsack { budget: self.budget, k: self.k, weights })
    }
}

/// Partition matroid: the ground set is split into groups; at most
/// `cap[g]` items may be chosen from group `g` (plus a global cap `k`).
pub struct PartitionMatroid {
    pub k: usize,
    group_of: Vec<u32>,
    caps: Vec<usize>,
    /// Wire provenance: how `group_of` can be regenerated remotely.
    /// `None` means "explicit table", derived on demand like
    /// [`Knapsack`]'s weight spec.
    group_spec: Option<GroupSpec>,
}

impl PartitionMatroid {
    pub fn new(group_of: Vec<u32>, caps: Vec<usize>, k: usize) -> Self {
        Self::with_group_spec(group_of, None, caps, k)
    }

    pub(crate) fn with_group_spec(
        group_of: Vec<u32>,
        group_spec: Option<GroupSpec>,
        caps: Vec<usize>,
        k: usize,
    ) -> Self {
        assert!(group_of.iter().all(|&g| (g as usize) < caps.len()));
        PartitionMatroid { k, group_of, caps, group_spec }
    }

    /// Assign groups round-robin by item id (deterministic; also the
    /// wire-friendly form — only the group count crosses the network).
    pub fn round_robin(n: usize, groups: usize, per_group: usize, k: usize) -> Self {
        // shared with worker-side spec rebuilding (see from_row_norms)
        let spec = GroupSpec::RoundRobin { groups };
        let group_of = spec
            .materialize(n, groups)
            .expect("round-robin needs groups ≥ 1");
        Self::with_group_spec(group_of, Some(spec), vec![per_group; groups], k)
    }

    pub fn group(&self, item: u32) -> u32 {
        self.group_of[item as usize]
    }
}

impl Constraint for PartitionMatroid {
    fn name(&self) -> String {
        format!("partition({} groups, k={})", self.caps.len(), self.k)
    }

    fn can_add(&self, current: &[u32], item: u32, _dataset: &Dataset) -> bool {
        if current.len() >= self.k {
            return false;
        }
        let g = self.group_of[item as usize] as usize;
        let used = current
            .iter()
            .filter(|&&i| self.group_of[i as usize] as usize == g)
            .count();
        used < self.caps[g]
    }

    fn max_cardinality(&self) -> usize {
        self.k.min(self.caps.iter().sum())
    }

    fn wire_spec(&self) -> Option<ConstraintSpec> {
        let groups = self
            .group_spec
            .clone()
            .unwrap_or_else(|| GroupSpec::Explicit(self.group_of.clone()));
        Some(ConstraintSpec::PartitionMatroid {
            k: self.k,
            caps: self.caps.clone(),
            groups,
        })
    }
}

/// Intersection of hereditary constraints (itself hereditary).
pub struct Intersection {
    parts: Vec<std::sync::Arc<dyn Constraint>>,
}

impl Intersection {
    pub fn new(parts: Vec<std::sync::Arc<dyn Constraint>>) -> Self {
        assert!(!parts.is_empty());
        Intersection { parts }
    }
}

impl Constraint for Intersection {
    fn name(&self) -> String {
        let names: Vec<String> = self.parts.iter().map(|p| p.name()).collect();
        format!("∩[{}]", names.join(", "))
    }

    fn can_add(&self, current: &[u32], item: u32, dataset: &Dataset) -> bool {
        self.parts.iter().all(|p| p.can_add(current, item, dataset))
    }

    fn max_cardinality(&self) -> usize {
        self.parts.iter().map(|p| p.max_cardinality()).min().unwrap()
    }

    fn wire_spec(&self) -> Option<ConstraintSpec> {
        self.parts
            .iter()
            .map(|p| p.wire_spec())
            .collect::<Option<Vec<_>>>()
            .map(ConstraintSpec::Intersection)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use std::sync::Arc;

    fn ds(n: usize) -> Dataset {
        Dataset::new("t", n, 1, (0..n).map(|i| i as f32).collect())
    }

    #[test]
    fn cardinality_caps_size() {
        let c = Cardinality::new(2);
        let d = ds(5);
        assert!(c.can_add(&[], 0, &d));
        assert!(c.can_add(&[0], 1, &d));
        assert!(!c.can_add(&[0, 1], 2, &d));
        assert!(c.is_feasible(&[0, 1], &d));
        assert!(!c.is_feasible(&[0, 1, 2], &d));
    }

    #[test]
    fn knapsack_budget() {
        let c = Knapsack::new(vec![1.0, 2.0, 3.0, 10.0], 5.0, 10);
        let d = ds(4);
        assert!(c.can_add(&[0], 2, &d)); // 1+3 = 4 ≤ 5
        assert!(!c.can_add(&[0, 1], 2, &d)); // 1+2+3 = 6 > 5
        assert!(!c.can_add(&[], 3, &d)); // 10 > 5 alone
        assert!(c.is_feasible(&[0, 1], &d)); // 3 ≤ 5
        assert!(!c.is_feasible(&[3], &d));
    }

    #[test]
    fn knapsack_respects_cardinality_cap() {
        let c = Knapsack::new(vec![0.0; 10], 100.0, 2);
        let d = ds(10);
        assert!(!c.can_add(&[0, 1], 2, &d));
    }

    #[test]
    fn partition_matroid_group_caps() {
        // items 0..6, groups {0,1} alternating, cap 1 per group, k=4
        let c = PartitionMatroid::round_robin(6, 2, 1, 4);
        let d = ds(6);
        assert!(c.can_add(&[], 0, &d));
        assert!(!c.can_add(&[0], 2, &d)); // group 0 full
        assert!(c.can_add(&[0], 1, &d)); // group 1 free
        assert!(c.is_feasible(&[0, 1], &d));
        assert!(!c.is_feasible(&[0, 2], &d));
        assert_eq!(c.max_cardinality(), 2);
    }

    #[test]
    fn intersection_requires_all() {
        let d = ds(6);
        let c = Intersection::new(vec![
            Arc::new(Cardinality::new(3)),
            Arc::new(PartitionMatroid::round_robin(6, 2, 1, 10)),
        ]);
        assert!(c.can_add(&[], 0, &d));
        assert!(!c.can_add(&[0], 2, &d)); // matroid blocks
        assert_eq!(c.max_cardinality(), 2); // min(3, 2)
        assert!(c.name().contains("card(3)"));
    }

    /// Heredity property: if S is feasible then every subset is.
    #[test]
    fn heredity_property_random_instances() {
        use crate::util::check::forall;
        let d = ds(16);
        let constraints: Vec<Arc<dyn Constraint>> = vec![
            Arc::new(Cardinality::new(4)),
            Arc::new(Knapsack::new((0..16).map(|i| (i % 5) as f64).collect(), 7.0, 6)),
            Arc::new(PartitionMatroid::round_robin(16, 4, 2, 5)),
        ];
        for c in constraints {
            forall(7, 60, |rng| {
                // grow a feasible set greedily from a random order
                let mut order: Vec<u32> = (0..16).collect();
                rng.shuffle(&mut order);
                let mut set = Vec::new();
                for &i in &order {
                    if c.can_add(&set, i, &d) {
                        set.push(i);
                    }
                    if set.len() >= 5 {
                        break;
                    }
                }
                let drop = if set.is_empty() { 0 } else { rng.below(set.len()) };
                (set, drop)
            }, |(set, drop)| {
                if !c.is_feasible(set, &d) {
                    return Err(format!("{} grew infeasible set", c.name()));
                }
                // remove one element: must stay feasible
                let mut sub = set.clone();
                if !sub.is_empty() {
                    sub.remove(*drop);
                }
                if !c.is_feasible(&sub, &d) {
                    return Err(format!("{} violated heredity", c.name()));
                }
                Ok(())
            });
        }
    }
}

//! Wire-serializable constraint specifications (wire spec v2).
//!
//! A [`ConstraintSpec`] describes a hereditary constraint *by
//! construction*, not by value: knapsack weights and matroid group
//! assignments are carried as generator specs (`unit`, `rownorm2`,
//! `seeded`, `round-robin`, …) that every process materializes
//! identically from the dataset and a seed, so a few bytes of JSON
//! rebuild the exact same constraint on a remote worker. Explicit
//! per-item tables remain representable for constraints that were built
//! from arbitrary data.
//!
//! The same grammar backs the CLI (`--constraint
//! knapsack:b=30,w=rownorm2+pmatroid:groups=5,cap=2`), config files and
//! the dist wire protocol, so a constraint that runs locally runs — and
//! means the same thing — on every backend.

use std::sync::Arc;

use crate::constraints::{Cardinality, Constraint, Intersection, Knapsack, PartitionMatroid};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::util::json::{self, wire_f64, wire_str, wire_u64, wire_usize, Json};
use crate::util::rng::Rng;

/// Stream tag for seeded knapsack weights ("KNAPSACK" in ASCII), keeping
/// the weight stream independent of every algorithmic seed stream.
const WEIGHT_STREAM_TAG: u64 = 0x4B4E_4150_5341_434B;

/// Deterministic seeded uniform weights in `[lo, hi)` — the single
/// definition shared by [`Knapsack::seeded`] and spec materialization.
pub(crate) fn seeded_weights(n: usize, seed: u64, lo: f64, hi: f64) -> Vec<f64> {
    let mut rng = Rng::seed_from(seed ^ WEIGHT_STREAM_TAG);
    (0..n).map(|_| lo + rng.f64() * (hi - lo)).collect()
}

/// How per-item knapsack weights are produced.
#[derive(Debug, Clone, PartialEq)]
pub enum WeightSpec {
    /// `w_i = 1` (cardinality-flavoured knapsack).
    Unit,
    /// `w_i = ‖x_i‖²` — squared row norm, a natural cost for summaries.
    RowNorm2,
    /// `w_i ~ U[lo, hi)` from a seeded stream (ad-hoc instances).
    Seeded { seed: u64, lo: f64, hi: f64 },
    /// Explicit per-item table (shipped by value).
    Explicit(Vec<f64>),
}

impl WeightSpec {
    pub(crate) fn check_range(lo: f64, hi: f64) -> Result<()> {
        if !lo.is_finite() || !hi.is_finite() || lo < 0.0 || hi < lo {
            return Err(Error::invalid(format!(
                "seeded weight range [{lo}, {hi}) must be finite, non-negative and ordered"
            )));
        }
        Ok(())
    }

    fn check_table(w: &[f64]) -> Result<()> {
        if w.iter().any(|x| !x.is_finite() || *x < 0.0) {
            return Err(Error::invalid(
                "explicit knapsack weights must be finite and non-negative",
            ));
        }
        Ok(())
    }

    /// Materialize the per-item weight table for `ds`.
    pub fn materialize(&self, ds: &Dataset) -> Result<Vec<f64>> {
        match self {
            WeightSpec::Unit => Ok(vec![1.0; ds.n]),
            WeightSpec::RowNorm2 => Ok((0..ds.n)
                .map(|i| crate::linalg::sq_norm(ds.row(i as u32)))
                .collect()),
            WeightSpec::Seeded { seed, lo, hi } => {
                Self::check_range(*lo, *hi)?;
                Ok(seeded_weights(ds.n, *seed, *lo, *hi))
            }
            WeightSpec::Explicit(w) => {
                if w.len() != ds.n {
                    return Err(Error::invalid(format!(
                        "explicit weight table has {} entries for a ground set of {}",
                        w.len(),
                        ds.n
                    )));
                }
                Self::check_table(w)?;
                Ok(w.clone())
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            WeightSpec::Unit => json::obj(vec![("gen", json::s("unit"))]),
            WeightSpec::RowNorm2 => json::obj(vec![("gen", json::s("rownorm2"))]),
            WeightSpec::Seeded { seed, lo, hi } => json::obj(vec![
                ("gen", json::s("seeded")),
                ("seed", Json::Str(seed.to_string())),
                ("lo", json::num(*lo)),
                ("hi", json::num(*hi)),
            ]),
            WeightSpec::Explicit(w) => json::obj(vec![
                ("gen", json::s("explicit")),
                ("w", Json::Arr(w.iter().map(|&x| Json::Num(x)).collect())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<WeightSpec> {
        match wire_str(v, "gen")? {
            "unit" => Ok(WeightSpec::Unit),
            "rownorm2" => Ok(WeightSpec::RowNorm2),
            "seeded" => {
                let seed = wire_u64(v, "seed")?;
                let lo = wire_f64(v, "lo")?;
                let hi = wire_f64(v, "hi")?;
                Self::check_range(lo, hi)?;
                Ok(WeightSpec::Seeded { seed, lo, hi })
            }
            "explicit" => {
                let arr = v.get("w").and_then(Json::as_arr).ok_or_else(|| {
                    Error::Protocol("explicit weight spec is missing array field 'w'".into())
                })?;
                let w: Vec<f64> = arr
                    .iter()
                    .map(|x| {
                        x.as_f64().ok_or_else(|| {
                            Error::Protocol("'w' contains a non-number entry".into())
                        })
                    })
                    .collect::<Result<_>>()?;
                Self::check_table(&w)?;
                Ok(WeightSpec::Explicit(w))
            }
            other => Err(Error::Protocol(format!(
                "unknown weight generator '{other}'"
            ))),
        }
    }

    /// Parse the CLI form: `unit`, `rownorm2` or `seeded:SEED:LO:HI`.
    pub fn parse(text: &str) -> Result<WeightSpec> {
        match text {
            "unit" => return Ok(WeightSpec::Unit),
            "rownorm2" => return Ok(WeightSpec::RowNorm2),
            _ => {}
        }
        let parts: Vec<&str> = text.split(':').collect();
        if parts.len() == 4 && parts[0] == "seeded" {
            let seed = parts[1]
                .parse::<u64>()
                .map_err(|_| Error::Config(format!("bad seeded weight seed '{}'", parts[1])))?;
            let lo = parts[2]
                .parse::<f64>()
                .map_err(|_| Error::Config(format!("bad seeded weight lo '{}'", parts[2])))?;
            let hi = parts[3]
                .parse::<f64>()
                .map_err(|_| Error::Config(format!("bad seeded weight hi '{}'", parts[3])))?;
            Self::check_range(lo, hi)
                .map_err(|e| Error::Config(e.to_string()))?;
            return Ok(WeightSpec::Seeded { seed, lo, hi });
        }
        Err(Error::Config(format!(
            "unknown weight spec '{text}' (known: unit, rownorm2, seeded:SEED:LO:HI)"
        )))
    }
}

/// How items are assigned to partition-matroid groups.
#[derive(Debug, Clone, PartialEq)]
pub enum GroupSpec {
    /// Item `i` belongs to group `i mod groups`.
    RoundRobin { groups: usize },
    /// Explicit per-item group table (shipped by value).
    Explicit(Vec<u32>),
}

impl GroupSpec {
    /// Materialize the per-item group table for a ground set of `n`
    /// items over `num_groups` groups.
    pub fn materialize(&self, n: usize, num_groups: usize) -> Result<Vec<u32>> {
        match self {
            GroupSpec::RoundRobin { groups } => {
                if *groups == 0 || *groups != num_groups {
                    return Err(Error::invalid(format!(
                        "round-robin group count {groups} does not match {num_groups} caps"
                    )));
                }
                Ok((0..n as u32).map(|i| i % *groups as u32).collect())
            }
            GroupSpec::Explicit(of) => {
                if of.len() != n {
                    return Err(Error::invalid(format!(
                        "explicit group table has {} entries for a ground set of {n}",
                        of.len()
                    )));
                }
                if of.iter().any(|&g| g as usize >= num_groups) {
                    return Err(Error::invalid(format!(
                        "explicit group table references a group ≥ {num_groups}"
                    )));
                }
                Ok(of.clone())
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            GroupSpec::RoundRobin { groups } => json::obj(vec![
                ("gen", json::s("round-robin")),
                ("groups", json::num(*groups as f64)),
            ]),
            GroupSpec::Explicit(of) => json::obj(vec![
                ("gen", json::s("explicit")),
                ("of", Json::Arr(of.iter().map(|&g| Json::Num(g as f64)).collect())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<GroupSpec> {
        match wire_str(v, "gen")? {
            "round-robin" => Ok(GroupSpec::RoundRobin { groups: wire_usize(v, "groups")? }),
            "explicit" => {
                let arr = v.get("of").and_then(Json::as_arr).ok_or_else(|| {
                    Error::Protocol("explicit group spec is missing array field 'of'".into())
                })?;
                let of: Vec<u32> = arr
                    .iter()
                    .map(|x| {
                        x.as_f64()
                            .filter(|v| *v >= 0.0 && v.fract() == 0.0 && *v <= u32::MAX as f64)
                            .map(|v| v as u32)
                            .ok_or_else(|| {
                                Error::Protocol("'of' contains a non-u32 entry".into())
                            })
                    })
                    .collect::<Result<_>>()?;
                Ok(GroupSpec::Explicit(of))
            }
            other => Err(Error::Protocol(format!(
                "unknown group generator '{other}'"
            ))),
        }
    }
}

/// A wire-serializable hereditary constraint (paper §3.2): cardinality,
/// knapsack, partition matroid, or an intersection of those.
#[derive(Debug, Clone, PartialEq)]
pub enum ConstraintSpec {
    Cardinality { k: usize },
    Knapsack { budget: f64, k: usize, weights: WeightSpec },
    PartitionMatroid { k: usize, caps: Vec<usize>, groups: GroupSpec },
    Intersection(Vec<ConstraintSpec>),
}

impl ConstraintSpec {
    fn check_budget(budget: f64) -> Result<()> {
        if !budget.is_finite() || budget < 0.0 {
            return Err(Error::invalid(format!(
                "knapsack budget {budget} must be finite and non-negative"
            )));
        }
        Ok(())
    }

    /// Build the concrete constraint for `ds`. Deterministic: the same
    /// spec over the same dataset materializes the identical constraint
    /// in every process.
    pub fn build(&self, ds: &Dataset) -> Result<Arc<dyn Constraint>> {
        Ok(match self {
            ConstraintSpec::Cardinality { k } => Arc::new(Cardinality::new(*k)),
            ConstraintSpec::Knapsack { budget, k, weights } => {
                Self::check_budget(*budget)?;
                let w = weights.materialize(ds)?;
                // explicit tables carry no generator recipe: the built
                // constraint derives its wire form from the table itself
                let provenance = match weights {
                    WeightSpec::Explicit(_) => None,
                    other => Some(other.clone()),
                };
                Arc::new(Knapsack::with_weight_spec(w, provenance, *budget, *k))
            }
            ConstraintSpec::PartitionMatroid { k, caps, groups } => {
                if caps.is_empty() {
                    return Err(Error::invalid("partition matroid needs at least one group"));
                }
                let group_of = groups.materialize(ds.n, caps.len())?;
                let provenance = match groups {
                    GroupSpec::Explicit(_) => None,
                    other => Some(other.clone()),
                };
                Arc::new(PartitionMatroid::with_group_spec(
                    group_of,
                    provenance,
                    caps.clone(),
                    *k,
                ))
            }
            ConstraintSpec::Intersection(parts) => {
                if parts.is_empty() {
                    return Err(Error::invalid("empty constraint intersection"));
                }
                let built = parts
                    .iter()
                    .map(|p| p.build(ds))
                    .collect::<Result<Vec<_>>>()?;
                Arc::new(Intersection::new(built))
            }
        })
    }

    pub fn to_json(&self) -> Json {
        match self {
            ConstraintSpec::Cardinality { k } => json::obj(vec![
                ("type", json::s("card")),
                ("k", json::num(*k as f64)),
            ]),
            ConstraintSpec::Knapsack { budget, k, weights } => json::obj(vec![
                ("type", json::s("knapsack")),
                ("budget", json::num(*budget)),
                ("k", json::num(*k as f64)),
                ("weights", weights.to_json()),
            ]),
            ConstraintSpec::PartitionMatroid { k, caps, groups } => json::obj(vec![
                ("type", json::s("pmatroid")),
                ("k", json::num(*k as f64)),
                ("caps", Json::Arr(caps.iter().map(|&c| Json::Num(c as f64)).collect())),
                ("groups", groups.to_json()),
            ]),
            ConstraintSpec::Intersection(parts) => json::obj(vec![
                ("type", json::s("intersection")),
                ("parts", Json::Arr(parts.iter().map(|p| p.to_json()).collect())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<ConstraintSpec> {
        match wire_str(v, "type")? {
            "card" => Ok(ConstraintSpec::Cardinality { k: wire_usize(v, "k")? }),
            "knapsack" => {
                let budget = wire_f64(v, "budget")?;
                Self::check_budget(budget)
                    .map_err(|e| Error::Protocol(e.to_string()))?;
                let weights = WeightSpec::from_json(v.get("weights").ok_or_else(|| {
                    Error::Protocol("knapsack spec is missing field 'weights'".into())
                })?)?;
                Ok(ConstraintSpec::Knapsack { budget, k: wire_usize(v, "k")?, weights })
            }
            "pmatroid" => {
                let caps_arr = v.get("caps").and_then(Json::as_arr).ok_or_else(|| {
                    Error::Protocol("pmatroid spec is missing array field 'caps'".into())
                })?;
                let caps: Vec<usize> = caps_arr
                    .iter()
                    .map(|x| {
                        x.as_usize().ok_or_else(|| {
                            Error::Protocol("'caps' contains a non-integer entry".into())
                        })
                    })
                    .collect::<Result<_>>()?;
                if caps.is_empty() {
                    return Err(Error::Protocol(
                        "pmatroid spec needs at least one group cap".into(),
                    ));
                }
                let groups = GroupSpec::from_json(v.get("groups").ok_or_else(|| {
                    Error::Protocol("pmatroid spec is missing field 'groups'".into())
                })?)?;
                Ok(ConstraintSpec::PartitionMatroid { k: wire_usize(v, "k")?, caps, groups })
            }
            "intersection" => {
                let arr = v.get("parts").and_then(Json::as_arr).ok_or_else(|| {
                    Error::Protocol("intersection spec is missing array field 'parts'".into())
                })?;
                if arr.is_empty() {
                    return Err(Error::Protocol("empty constraint intersection".into()));
                }
                let parts = arr
                    .iter()
                    .map(ConstraintSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                Ok(ConstraintSpec::Intersection(parts))
            }
            other => Err(Error::Protocol(format!("unknown constraint type '{other}'"))),
        }
    }

    /// Does this spec carry an O(n) explicit table (by-value weights or
    /// group assignments)? Such specs are large on the wire and cheap to
    /// rebuild, so worker-side memoization skips them.
    pub fn has_explicit_table(&self) -> bool {
        match self {
            ConstraintSpec::Cardinality { .. } => false,
            ConstraintSpec::Knapsack { weights, .. } => {
                matches!(weights, WeightSpec::Explicit(_))
            }
            ConstraintSpec::PartitionMatroid { groups, .. } => {
                matches!(groups, GroupSpec::Explicit(_))
            }
            ConstraintSpec::Intersection(parts) => {
                parts.iter().any(|p| p.has_explicit_table())
            }
        }
    }

    /// Parse the CLI / config grammar with budget `k` supplied by the
    /// run: `card`, `knapsack:b=30[,w=unit|rownorm2|seeded:S:LO:HI]`,
    /// `pmatroid:groups=G,cap=C`, joined with `+` for intersections.
    ///
    /// ```
    /// use hss::constraints::spec::{ConstraintSpec, WeightSpec};
    ///
    /// // a single constraint; k is supplied by the run
    /// let card = ConstraintSpec::parse("card", 10).unwrap();
    /// assert_eq!(card, ConstraintSpec::Cardinality { k: 10 });
    ///
    /// // '+' joins constraints into an intersection
    /// let both = ConstraintSpec::parse("knapsack:b=30,w=rownorm2+pmatroid:groups=5,cap=2", 10);
    /// assert!(matches!(both, Ok(ConstraintSpec::Intersection(parts)) if parts.len() == 2));
    ///
    /// // '+' inside an f64 exponent is NOT a separator
    /// let big = ConstraintSpec::parse("knapsack:b=1e+3", 10).unwrap();
    /// assert_eq!(
    ///     big,
    ///     ConstraintSpec::Knapsack { budget: 1000.0, k: 10, weights: WeightSpec::Unit }
    /// );
    ///
    /// // unknown constraint names are rejected
    /// assert!(ConstraintSpec::parse("mystery", 10).is_err());
    /// ```
    pub fn parse(text: &str, k: usize) -> Result<ConstraintSpec> {
        // A '+' separates constraints only when it starts a new
        // constraint name (next char alphabetic) — so f64 exponents
        // like `b=1e+3` pass through intact.
        let mut pieces: Vec<&str> = Vec::new();
        let bytes = text.as_bytes();
        let mut start = 0;
        for (i, &b) in bytes.iter().enumerate() {
            if b == b'+' && bytes.get(i + 1).is_some_and(u8::is_ascii_alphabetic) {
                pieces.push(&text[start..i]);
                start = i + 1;
            }
        }
        pieces.push(&text[start..]);
        let pieces: Vec<&str> = pieces
            .into_iter()
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect();
        if pieces.is_empty() {
            return Err(Error::Config("empty constraint spec".into()));
        }
        let mut specs = pieces
            .iter()
            .map(|p| Self::parse_one(p, k))
            .collect::<Result<Vec<_>>>()?;
        if specs.len() == 1 {
            Ok(specs.remove(0))
        } else {
            Ok(ConstraintSpec::Intersection(specs))
        }
    }

    fn parse_one(text: &str, k: usize) -> Result<ConstraintSpec> {
        let (head, rest) = text.split_once(':').unwrap_or((text, ""));
        match head {
            "card" => {
                if !rest.is_empty() {
                    return Err(Error::Config(format!(
                        "'card' takes no options (got '{rest}'); k comes from --k"
                    )));
                }
                Ok(ConstraintSpec::Cardinality { k })
            }
            "knapsack" => {
                let mut budget = None;
                let mut weights = WeightSpec::Unit;
                for kv in rest.split(',').filter(|s| !s.is_empty()) {
                    let (key, val) = kv.split_once('=').ok_or_else(|| {
                        Error::Config(format!("knapsack option '{kv}' is not key=value"))
                    })?;
                    match key {
                        "b" | "budget" => {
                            let b = val.parse::<f64>().map_err(|_| {
                                Error::Config(format!("bad knapsack budget '{val}'"))
                            })?;
                            Self::check_budget(b).map_err(|e| Error::Config(e.to_string()))?;
                            budget = Some(b);
                        }
                        "w" | "weights" => weights = WeightSpec::parse(val)?,
                        other => {
                            return Err(Error::Config(format!(
                                "unknown knapsack option '{other}' (known: b, w)"
                            )))
                        }
                    }
                }
                let budget = budget.ok_or_else(|| {
                    Error::Config("knapsack needs b=<budget> (e.g. knapsack:b=30)".into())
                })?;
                Ok(ConstraintSpec::Knapsack { budget, k, weights })
            }
            "pmatroid" => {
                let mut groups = None;
                let mut cap = None;
                for kv in rest.split(',').filter(|s| !s.is_empty()) {
                    let (key, val) = kv.split_once('=').ok_or_else(|| {
                        Error::Config(format!("pmatroid option '{kv}' is not key=value"))
                    })?;
                    let parsed = val.parse::<usize>().map_err(|_| {
                        Error::Config(format!("bad pmatroid option '{key}={val}'"))
                    })?;
                    match key {
                        "groups" => groups = Some(parsed),
                        "cap" => cap = Some(parsed),
                        other => {
                            return Err(Error::Config(format!(
                                "unknown pmatroid option '{other}' (known: groups, cap)"
                            )))
                        }
                    }
                }
                let (groups, cap) = match (groups, cap) {
                    (Some(g), Some(c)) if g > 0 => (g, c),
                    _ => {
                        return Err(Error::Config(
                            "pmatroid needs groups=<G≥1>,cap=<C> (e.g. pmatroid:groups=5,cap=2)"
                                .into(),
                        ))
                    }
                };
                Ok(ConstraintSpec::PartitionMatroid {
                    k,
                    caps: vec![cap; groups],
                    groups: GroupSpec::RoundRobin { groups },
                })
            }
            other => Err(Error::Config(format!(
                "unknown constraint '{other}' (known: card, knapsack:b=..[,w=..], \
                 pmatroid:groups=..,cap=..; combine with '+')"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn ds(n: usize) -> Dataset {
        Dataset::new("t", n, 2, (0..2 * n).map(|i| i as f32).collect())
    }

    fn roundtrip(spec: &ConstraintSpec) -> ConstraintSpec {
        let text = spec.to_json().to_string();
        ConstraintSpec::from_json(&Json::parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn json_roundtrips_all_variants() {
        let specs = vec![
            ConstraintSpec::Cardinality { k: 7 },
            ConstraintSpec::Knapsack { budget: 12.5, k: 4, weights: WeightSpec::Unit },
            ConstraintSpec::Knapsack { budget: 3.0, k: 4, weights: WeightSpec::RowNorm2 },
            ConstraintSpec::Knapsack {
                budget: 0.125,
                k: 9,
                weights: WeightSpec::Seeded { seed: u64::MAX - 3, lo: 0.5, hi: 2.5 },
            },
            ConstraintSpec::Knapsack {
                budget: 1.0,
                k: 2,
                weights: WeightSpec::Explicit(vec![0.1, 0.2, 123.456_789_012_345_67 / 3.0]),
            },
            ConstraintSpec::PartitionMatroid {
                k: 6,
                caps: vec![2, 2, 1],
                groups: GroupSpec::RoundRobin { groups: 3 },
            },
            ConstraintSpec::PartitionMatroid {
                k: 6,
                caps: vec![1, 3],
                groups: GroupSpec::Explicit(vec![0, 1, 1, 0]),
            },
            ConstraintSpec::Intersection(vec![
                ConstraintSpec::Cardinality { k: 3 },
                ConstraintSpec::Knapsack { budget: 5.0, k: 3, weights: WeightSpec::Unit },
            ]),
        ];
        for spec in &specs {
            assert_eq!(&roundtrip(spec), spec, "{spec:?}");
        }
    }

    #[test]
    fn json_roundtrip_property_random_specs() {
        fn random_weights(rng: &mut Rng) -> WeightSpec {
            match rng.below(4) {
                0 => WeightSpec::Unit,
                1 => WeightSpec::RowNorm2,
                2 => WeightSpec::Seeded {
                    seed: rng.next_u64(),
                    lo: rng.f64(),
                    hi: 1.0 + rng.f64(),
                },
                _ => WeightSpec::Explicit(
                    (0..rng.range(1, 9)).map(|_| rng.f64() * 10.0).collect(),
                ),
            }
        }
        fn random_leaf(rng: &mut Rng) -> ConstraintSpec {
            match rng.below(3) {
                0 => ConstraintSpec::Cardinality { k: rng.below(100) },
                1 => ConstraintSpec::Knapsack {
                    budget: rng.f64() * 50.0,
                    k: rng.below(20),
                    weights: random_weights(rng),
                },
                _ => {
                    let groups = rng.range(1, 6);
                    ConstraintSpec::PartitionMatroid {
                        k: rng.below(20),
                        caps: (0..groups).map(|_| rng.below(4)).collect(),
                        groups: if rng.bool(0.5) {
                            GroupSpec::RoundRobin { groups }
                        } else {
                            GroupSpec::Explicit(
                                (0..rng.range(1, 12))
                                    .map(|_| rng.below(groups) as u32)
                                    .collect(),
                            )
                        },
                    }
                }
            }
        }
        forall(
            0x5EC5_77E5,
            80,
            |rng| {
                if rng.bool(0.25) {
                    ConstraintSpec::Intersection(
                        (0..rng.range(1, 4)).map(|_| random_leaf(rng)).collect(),
                    )
                } else {
                    random_leaf(rng)
                }
            },
            |spec| {
                let back = roundtrip(spec);
                if &back != spec {
                    return Err(format!("{back:?} != {spec:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn malformed_frames_are_rejected() {
        for bad in [
            r#"{"k":3}"#,                                          // missing type
            r#"{"type":"blob","k":3}"#,                            // unknown type
            r#"{"type":"card"}"#,                                  // missing k
            r#"{"type":"knapsack","k":3}"#,                        // missing budget
            r#"{"type":"knapsack","budget":1e999,"k":3,"weights":{"gen":"unit"}}"#, // inf budget
            r#"{"type":"knapsack","budget":-2,"k":3,"weights":{"gen":"unit"}}"#,    // negative
            r#"{"type":"knapsack","budget":5,"k":3,"weights":{"gen":"warp"}}"#,     // bad gen
            r#"{"type":"knapsack","budget":5,"k":3,"weights":{"gen":"explicit","w":[-1]}}"#,
            r#"{"type":"knapsack","budget":5,"k":3,"weights":{"gen":"seeded","seed":"1","lo":2,"hi":1}}"#,
            r#"{"type":"pmatroid","k":3,"groups":{"gen":"round-robin","groups":2}}"#, // no caps
            r#"{"type":"pmatroid","k":3,"caps":[],"groups":{"gen":"round-robin","groups":0}}"#,
            r#"{"type":"pmatroid","k":3,"caps":[1],"groups":{"gen":"explicit","of":[1.5]}}"#,
            r#"{"type":"intersection","parts":[]}"#,               // empty intersection
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(ConstraintSpec::from_json(&v).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn build_materializes_equivalent_constraints() {
        let d = ds(8);
        let spec = ConstraintSpec::Knapsack {
            budget: 10.0,
            k: 3,
            weights: WeightSpec::Seeded { seed: 5, lo: 1.0, hi: 2.0 },
        };
        let a = spec.build(&d).unwrap();
        let b = spec.build(&d).unwrap();
        // the direct constructor and the spec path share one weight
        // stream — coordinator-built and worker-rebuilt constraints
        // must make identical feasibility decisions
        let direct = Knapsack::seeded(8, 5, 1.0, 2.0, 10.0, 3);
        for item in 0..8u32 {
            assert_eq!(a.can_add(&[], item, &d), b.can_add(&[], item, &d));
            assert_eq!(a.can_add(&[], item, &d), direct.can_add(&[], item, &d));
        }
        // and the built constraint's own wire spec is the input spec
        assert_eq!(direct.wire_spec(), Some(spec.clone()));
        assert_eq!(a.wire_spec(), Some(spec));

        let pm = ConstraintSpec::PartitionMatroid {
            k: 4,
            caps: vec![1, 1],
            groups: GroupSpec::RoundRobin { groups: 2 },
        };
        let c = pm.build(&d).unwrap();
        assert!(c.can_add(&[], 0, &d));
        assert!(!c.can_add(&[0], 2, &d)); // group 0 full
        assert_eq!(c.wire_spec(), Some(pm));
    }

    #[test]
    fn build_validates_against_dataset() {
        let d = ds(4);
        // explicit table of the wrong length
        let spec = ConstraintSpec::Knapsack {
            budget: 1.0,
            k: 2,
            weights: WeightSpec::Explicit(vec![1.0; 3]),
        };
        assert!(spec.build(&d).is_err());
        // explicit groups of the wrong length
        let spec = ConstraintSpec::PartitionMatroid {
            k: 2,
            caps: vec![1, 1],
            groups: GroupSpec::Explicit(vec![0, 1]),
        };
        assert!(spec.build(&d).is_err());
        // round-robin group count disagreeing with caps
        let spec = ConstraintSpec::PartitionMatroid {
            k: 2,
            caps: vec![1, 1],
            groups: GroupSpec::RoundRobin { groups: 3 },
        };
        assert!(spec.build(&d).is_err());
    }

    #[test]
    fn cli_grammar_parses() {
        let c = ConstraintSpec::parse("card", 9).unwrap();
        assert_eq!(c, ConstraintSpec::Cardinality { k: 9 });

        let c = ConstraintSpec::parse("knapsack:b=30", 5).unwrap();
        assert_eq!(
            c,
            ConstraintSpec::Knapsack { budget: 30.0, k: 5, weights: WeightSpec::Unit }
        );

        let c = ConstraintSpec::parse("knapsack:b=2.5,w=seeded:7:0.5:1.5", 5).unwrap();
        assert_eq!(
            c,
            ConstraintSpec::Knapsack {
                budget: 2.5,
                k: 5,
                weights: WeightSpec::Seeded { seed: 7, lo: 0.5, hi: 1.5 },
            }
        );

        let c = ConstraintSpec::parse("pmatroid:groups=4,cap=2", 8).unwrap();
        assert_eq!(
            c,
            ConstraintSpec::PartitionMatroid {
                k: 8,
                caps: vec![2; 4],
                groups: GroupSpec::RoundRobin { groups: 4 },
            }
        );

        let c = ConstraintSpec::parse("knapsack:b=30,w=rownorm2+pmatroid:groups=5,cap=2", 10)
            .unwrap();
        match c {
            ConstraintSpec::Intersection(parts) => assert_eq!(parts.len(), 2),
            other => panic!("expected intersection, got {other:?}"),
        }

        // '+' inside an f64 exponent is not an intersection separator
        let c = ConstraintSpec::parse("knapsack:b=1e+3", 5).unwrap();
        assert_eq!(
            c,
            ConstraintSpec::Knapsack { budget: 1000.0, k: 5, weights: WeightSpec::Unit }
        );
        let c = ConstraintSpec::parse("knapsack:b=2.5e+1+pmatroid:groups=2,cap=1", 5).unwrap();
        match c {
            ConstraintSpec::Intersection(parts) => {
                assert_eq!(parts.len(), 2);
                assert_eq!(
                    parts[0],
                    ConstraintSpec::Knapsack { budget: 25.0, k: 5, weights: WeightSpec::Unit }
                );
            }
            other => panic!("expected intersection, got {other:?}"),
        }

        for bad in [
            "",
            "warp",
            "card:k=3",
            "knapsack",
            "knapsack:b=zebra",
            "knapsack:b=5,w=warp",
            "knapsack:b=5,x=1",
            "pmatroid:groups=0,cap=2",
            "pmatroid:groups=2",
        ] {
            assert!(ConstraintSpec::parse(bad, 5).is_err(), "accepted '{bad}'");
        }
    }
}

//! Baseline algorithms the paper compares against (§4.3):
//! centralized GREEDY, the two-round GREEDI / RANDGREEDI, and RANDOM.

use std::sync::Arc;

use crate::algorithms::{Compressor, LazyGreedy, RandomCompressor, Solution};
use crate::coordinator::partitioner;
use crate::dist::{Backend, LocalBackend};
use crate::error::{Error, Result};
use crate::objectives::Problem;
use crate::util::rng::Rng;

/// Centralized GREEDY over the full ground set — the quality reference
/// all ratios are reported against. Uses the XLA-accelerated oracle when
/// the problem carries an engine (bulk initial pass), the pure oracle
/// otherwise.
pub fn centralized(problem: &Problem) -> Result<Solution> {
    let all: Vec<u32> = (0..problem.n() as u32).collect();
    centralized_on(problem, &all)
}

/// Centralized GREEDY restricted to a subset (shared helper).
pub fn centralized_on(problem: &Problem, items: &[u32]) -> Result<Solution> {
    if let (Some(engine), crate::objectives::Objective::Exemplar) =
        (problem.compute.xla_handle(), &problem.objective)
    {
        let mut oracle =
            crate::runtime::accel::XlaExemplarOracle::new(engine.clone(), problem, items)?;
        return crate::algorithms::lazy_greedy_over(&mut oracle, problem, items, None);
    }
    LazyGreedy::new().compress(problem, items, 0)
}

/// Result of a two-round baseline run.
#[derive(Debug)]
pub struct TwoRoundResult {
    pub solution: Solution,
    pub machines: usize,
    /// Size of the union of partial solutions (must fit in µ).
    pub union_size: usize,
}

/// RANDGREEDI (Barbosa et al. 2015a): random partition to m = ⌈n/µ⌉
/// machines, greedy each, then greedy over the union on ONE machine.
/// **Fails with [`Error::CapacityExceeded`] when the union exceeds µ** —
/// the horizontal-scaling failure mode motivating the paper (Table 1).
pub fn rand_greedi(
    problem: &Problem,
    capacity: usize,
    compressor: &dyn Compressor,
    seed: u64,
) -> Result<TwoRoundResult> {
    two_round(problem, compressor, seed, true, &LocalBackend::new(capacity))
}

/// GREEDI (Mirzasoleiman et al. 2013): same two-round scheme but with an
/// arbitrary (contiguous) partition.
pub fn greedi(
    problem: &Problem,
    capacity: usize,
    compressor: &dyn Compressor,
    seed: u64,
) -> Result<TwoRoundResult> {
    two_round(problem, compressor, seed, false, &LocalBackend::new(capacity))
}

/// RANDGREEDI on an explicit execution backend (tcp workers, fault
/// simulator); µ comes from the backend.
pub fn rand_greedi_on(
    problem: &Problem,
    backend: &dyn Backend,
    compressor: &dyn Compressor,
    seed: u64,
) -> Result<TwoRoundResult> {
    two_round(problem, compressor, seed, true, backend)
}

/// GREEDI on an explicit execution backend; µ comes from the backend.
pub fn greedi_on(
    problem: &Problem,
    backend: &dyn Backend,
    compressor: &dyn Compressor,
    seed: u64,
) -> Result<TwoRoundResult> {
    two_round(problem, compressor, seed, false, backend)
}

fn two_round(
    problem: &Problem,
    compressor: &dyn Compressor,
    seed: u64,
    random_partition: bool,
    backend: &dyn Backend,
) -> Result<TwoRoundResult> {
    let n = problem.n();
    let profile = backend.profile();
    // round 2 runs on ONE machine — the largest class must exceed k
    let capacity = profile.max_capacity();
    if capacity <= problem.k {
        return Err(Error::invalid(format!(
            "capacity {capacity} must exceed k={}",
            problem.k
        )));
    }
    let m = profile.machines_for(n);
    let caps = profile.round_caps(m);
    let all: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::seed_from(seed ^ 0x6EED_1D1D);
    let parts = if random_partition {
        partitioner::weighted_balanced_random_partition(&all, &caps, &mut rng)?
    } else {
        partitioner::weighted_contiguous_partition(&all, &caps)?
    };
    let sols = backend
        .run_round(problem, compressor, &parts, rng.next_u64())?
        .solutions;

    let mut union: Vec<u32> = sols.iter().flat_map(|s| s.items.iter().copied()).collect();
    union.sort_unstable();
    let union_size = union.len();
    // The defining limitation: round 2 runs on ONE machine — at best the
    // fleet's largest, capacity µ_max.
    if union_size > capacity {
        return Err(Error::CapacityExceeded {
            capacity,
            got: union_size,
            ctx: format!(" (two-round union of {m} machines × k={})", problem.k),
        });
    }
    // Round 2 also runs on the backend (ONE machine of capacity µ), so
    // under the tcp backend every oracle call happens on a worker.
    let final_sol = backend
        .run_round(problem, compressor, std::slice::from_ref(&union), rng.next_u64())?
        .solutions
        .into_iter()
        .next()
        .ok_or_else(|| {
            Error::Worker(format!(
                "backend '{}' returned no solution for the two-round final merge",
                backend.name()
            ))
        })?;
    // NaN-safe, first-max selection shared with the tree runner — a
    // worker-returned NaN value must surface, not panic the coordinator
    let best_partial = crate::coordinator::tree::round_best_of(&sols);
    let solution = if final_sol.value >= best_partial.value {
        final_sol
    } else {
        best_partial
    };
    Ok(TwoRoundResult { solution, machines: m, union_size })
}

/// RANDOM baseline: uniformly random feasible k-subset of the ground set.
pub fn random_subset(problem: &Problem, seed: u64) -> Result<Solution> {
    let all: Vec<u32> = (0..problem.n() as u32).collect();
    RandomCompressor::new().compress(problem, &all, seed)
}

/// Convenience wrapper: default-compressor (pure greedy) RANDGREEDI.
pub fn rand_greedi_default(
    problem: &Problem,
    capacity: usize,
    seed: u64,
) -> Result<TwoRoundResult> {
    rand_greedi(problem, capacity, &LazyGreedy::new(), seed)
}

/// The minimum capacity at which the two-round baselines are feasible:
/// `max(⌈n/m⌉, m·k)` minimized over m — i.e. ≈ √(nk) (paper §2).
pub fn two_round_min_capacity(n: usize, k: usize) -> usize {
    let mut best = usize::MAX;
    let mut m = 1usize;
    while m * m <= n.max(1) * 4 {
        let cap = (n.div_ceil(m)).max(m * k);
        best = best.min(cap);
        m += 1;
    }
    best
}

/// Trivially wraps centralized greedy in an Arc-compressor shape for the
/// bench tables.
pub fn centralized_as_compressor() -> Arc<dyn Compressor> {
    Arc::new(LazyGreedy::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn centralized_beats_random() {
        let ds = Arc::new(synthetic::csn_like(400, 1));
        let p = Problem::exemplar(ds, 10, 1);
        let c = centralized(&p).unwrap();
        let r = random_subset(&p, 3).unwrap();
        assert!(c.value > r.value);
        assert_eq!(c.items.len(), 10);
    }

    #[test]
    fn randgreedi_breaks_down_below_sqrt_nk() {
        // n=900, k=30: min two-round capacity ≈ √(nk) ≈ 164.
        // At µ=60 the union (m=15 machines × 30) = 450 > 60 → must fail.
        let ds = Arc::new(synthetic::csn_like(900, 2));
        let p = Problem::exemplar(ds, 30, 2);
        let err = rand_greedi_default(&p, 60, 1).unwrap_err();
        assert!(matches!(err, Error::CapacityExceeded { .. }), "{err}");
    }

    #[test]
    fn randgreedi_succeeds_above_min_capacity() {
        let ds = Arc::new(synthetic::csn_like(900, 3));
        let p = Problem::exemplar(ds, 10, 3);
        let mu = two_round_min_capacity(900, 10); // ≈ √9000 ≈ 95
        let res = rand_greedi_default(&p, mu + 5, 1).unwrap();
        assert!(res.union_size <= mu + 5);
        assert_eq!(res.solution.items.len(), 10);
        // close to centralized on easy data
        let c = centralized(&p).unwrap();
        assert!(res.solution.value >= 0.9 * c.value);
    }

    #[test]
    fn greedi_contiguous_partition_runs() {
        let ds = Arc::new(synthetic::csn_like(300, 4));
        let p = Problem::exemplar(ds, 5, 4);
        let res = greedi(&p, 120, &LazyGreedy::new(), 2).unwrap();
        assert_eq!(res.machines, 3);
        assert_eq!(res.solution.items.len(), 5);
    }

    #[test]
    fn min_capacity_formula_order_sqrt_nk() {
        let n = 10_000;
        let k = 25;
        let mc = two_round_min_capacity(n, k);
        let sqrt_nk = ((n * k) as f64).sqrt();
        assert!(
            (mc as f64) >= 0.8 * sqrt_nk && (mc as f64) <= 2.5 * sqrt_nk,
            "min capacity {mc} vs sqrt(nk) {sqrt_nk}"
        );
    }
}

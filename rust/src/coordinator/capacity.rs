//! Per-worker capacity profiles — the heterogeneous generalization of
//! the paper's single scalar µ.
//!
//! The paper assumes every machine holds exactly µ items. Real fleets
//! are never uniform: provisioned machines differ in memory, and the
//! framework's guarantees degrade gracefully when each part is sized to
//! the machine that executes it instead of to the smallest machine in
//! the fleet. A [`CapacityProfile`] describes the fleet as a **cyclic
//! pattern of capacity classes**, sorted descending:
//!
//! * a uniform profile `[µ]` reproduces the paper exactly — virtual
//!   machine `j` has capacity µ and a round over `N` items uses
//!   `⌈N/µ⌉` machines;
//! * a heterogeneous profile `[µ_0 ≥ µ_1 ≥ …]` assigns virtual machine
//!   `j` the capacity `µ_{j mod L}` and a round uses the smallest
//!   prefix of that cyclic sequence whose total capacity covers `N`.
//!
//! Because the pattern cycles, the fleet stays *elastic* (the paper's
//! machine count `m_t` is unbounded; physical workers host several
//! virtual machines per round, exactly as the TCP backend's
//! work-stealing dispatch already does) while every part is still sized
//! to a machine class that exists.
//!
//! The profile grammar accepted by `--capacity`, config files and
//! [`CapacityProfile::parse`]:
//!
//! ```text
//! MU            one capacity class          --capacity 200
//! MU1,MU2,…     explicit class list         --capacity 500,200,200
//! MUxCOUNT      repeated class (and mixes)  --capacity 200x8  /  500,200x4
//! ```
//!
//! ```
//! use hss::coordinator::capacity::CapacityProfile;
//!
//! let p = CapacityProfile::parse("500,200x2").unwrap();
//! assert_eq!(p.caps(), &[500, 200, 200]);
//! // virtual machines cycle through the classes, largest first
//! assert_eq!(p.virtual_capacity(0), 500);
//! assert_eq!(p.virtual_capacity(4), 200);
//! // smallest prefix of [500, 200, 200, 500, …] covering 1000 items
//! assert_eq!(p.machines_for(1000), 4);
//! // a uniform profile is the paper's ⌈N/µ⌉
//! let u = CapacityProfile::uniform(200);
//! assert_eq!(u.machines_for(1000), 5);
//! ```

use std::fmt;

use crate::error::{Error, Result};

/// A fleet capacity profile: per-machine-class capacities, sorted
/// descending, interpreted as a cyclic pattern of virtual machines.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CapacityProfile {
    /// Capacity classes, non-increasing, all positive.
    caps: Vec<usize>,
}

impl CapacityProfile {
    /// The paper's homogeneous fleet: every machine holds µ items.
    pub fn uniform(capacity: usize) -> CapacityProfile {
        CapacityProfile { caps: vec![capacity.max(1)] }
    }

    /// Build a profile from explicit per-class capacities. The list is
    /// sorted descending (the canonical order: rounds fill the largest
    /// machines first, and uniform prefixes then have the largest
    /// possible average capacity). Rejects empty lists and zero
    /// capacities.
    pub fn new(mut caps: Vec<usize>) -> Result<CapacityProfile> {
        if caps.is_empty() {
            return Err(Error::invalid("capacity profile must name at least one machine"));
        }
        if caps.iter().any(|&c| c == 0) {
            return Err(Error::invalid("capacity profile entries must be positive"));
        }
        caps.sort_unstable_by_key(|&c| std::cmp::Reverse(c));
        Ok(CapacityProfile { caps })
    }

    /// Parse the `--capacity` grammar: `MU`, `MU1,MU2,…`, with any
    /// entry optionally repeated as `MUxCOUNT` (e.g. `500,200,200`,
    /// `200x8`, `500,200x4`).
    pub fn parse(text: &str) -> Result<CapacityProfile> {
        let mut caps = Vec::new();
        for piece in text.split(',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            let (cap_text, count) = match piece.split_once('x') {
                Some((c, reps)) => {
                    let reps: usize = reps.trim().parse().map_err(|_| {
                        Error::Config(format!(
                            "capacity profile: bad repeat count in '{piece}' \
                             (grammar: MU | MU1,MU2,… | MUxCOUNT)"
                        ))
                    })?;
                    if reps == 0 {
                        return Err(Error::Config(format!(
                            "capacity profile: repeat count in '{piece}' must be positive"
                        )));
                    }
                    (c.trim(), reps)
                }
                None => (piece, 1),
            };
            let cap: usize = cap_text.parse().map_err(|_| {
                Error::Config(format!(
                    "capacity profile: bad capacity '{cap_text}' in '{text}' \
                     (grammar: MU | MU1,MU2,… | MUxCOUNT)"
                ))
            })?;
            caps.extend(std::iter::repeat(cap).take(count));
        }
        if caps.is_empty() {
            return Err(Error::Config(format!("empty capacity profile '{text}'")));
        }
        CapacityProfile::new(caps).map_err(|e| Error::Config(e.to_string()))
    }

    /// The capacity classes, non-increasing.
    pub fn caps(&self) -> &[usize] {
        &self.caps
    }

    /// Number of capacity classes in one cycle of the pattern.
    pub fn classes(&self) -> usize {
        self.caps.len()
    }

    /// True when the profile has a single class (the paper's setting).
    pub fn is_uniform(&self) -> bool {
        self.caps.iter().all(|&c| c == self.caps[0])
    }

    /// Largest machine capacity (the first class).
    pub fn max_capacity(&self) -> usize {
        self.caps[0]
    }

    /// Smallest machine capacity (the last class).
    pub fn min_capacity(&self) -> usize {
        // invariant: construction rejects empty profiles, so caps is
        // never empty
        *self.caps.last().unwrap()
    }

    /// Total capacity of one cycle `Σ µ_p`.
    pub fn cycle_total(&self) -> usize {
        self.caps.iter().sum()
    }

    /// Effective per-machine capacity for round-bound purposes: the
    /// mean class capacity `⌊Σµ_p / L⌋`. Any prefix of the
    /// descending-sorted cyclic pattern has at least this average, so
    /// `m_t ≤ ⌈|A_t| / µ_eff⌉` and the Prop 3.1 bound computed at
    /// µ_eff upper-bounds the heterogeneous round count. For a uniform
    /// profile this is µ itself.
    pub fn effective_capacity(&self) -> usize {
        self.cycle_total() / self.caps.len()
    }

    /// Capacity of virtual machine `j`: the cyclic pattern `µ_{j mod L}`.
    pub fn virtual_capacity(&self, j: usize) -> usize {
        self.caps[j % self.caps.len()]
    }

    /// Number of virtual machines a round over `n` items uses: the
    /// smallest `m ≥ 1` whose first `m` virtual capacities sum to at
    /// least `n`. Reduces to the paper's `⌈n/µ⌉` for uniform profiles.
    pub fn machines_for(&self, n: usize) -> usize {
        if n <= self.caps[0] {
            return 1;
        }
        let total = self.cycle_total();
        let full_cycles = n / total;
        let mut m = full_cycles * self.caps.len();
        let mut covered = full_cycles * total;
        while covered < n {
            covered += self.caps[m % self.caps.len()];
            m += 1;
        }
        m.max(1)
    }

    /// The per-machine capacity vector of a round that uses `machines`
    /// virtual machines: `[µ_{0 mod L}, …, µ_{(machines-1) mod L}]`.
    pub fn round_caps(&self, machines: usize) -> Vec<usize> {
        (0..machines).map(|j| self.virtual_capacity(j)).collect()
    }

    /// Validate the framework's standing assumption per machine class:
    /// every µ_p must exceed k (a machine must hold one solution's
    /// worth of items plus a candidate).
    pub fn require_exceeds_k(&self, k: usize) -> Result<()> {
        if self.min_capacity() <= k {
            return Err(Error::invalid(format!(
                "capacity profile {self}: every machine capacity must exceed k={k} \
                 (paper assumption µ > k; smallest class is {})",
                self.min_capacity()
            )));
        }
        Ok(())
    }
}

/// Canonical display form, run-length compressed back into the parse
/// grammar: `[200]` → `200`, `[500, 200, 200]` → `500,200x2`.
impl fmt::Display for CapacityProfile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        let mut i = 0;
        while i < self.caps.len() {
            let cap = self.caps[i];
            let mut run = 1;
            while i + run < self.caps.len() && self.caps[i + run] == cap {
                run += 1;
            }
            if !first {
                write!(f, ",")?;
            }
            first = false;
            if run == 1 {
                write!(f, "{cap}")?;
            } else {
                write!(f, "{cap}x{run}")?;
            }
            i += run;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_grammar_forms() {
        assert_eq!(CapacityProfile::parse("200").unwrap().caps(), &[200]);
        assert_eq!(
            CapacityProfile::parse("500,200,200").unwrap().caps(),
            &[500, 200, 200]
        );
        assert_eq!(CapacityProfile::parse("200x4").unwrap().caps(), &[200; 4]);
        assert_eq!(
            CapacityProfile::parse("200x2, 500").unwrap().caps(),
            &[500, 200, 200],
            "entries sort descending regardless of input order"
        );
        for bad in ["", "zebra", "200x", "200x0", "0", "100,0", "x3"] {
            assert!(CapacityProfile::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn display_roundtrips_through_parse() {
        for text in ["200", "500,200x2", "300x3", "7,5,3"] {
            let p = CapacityProfile::parse(text).unwrap();
            assert_eq!(p.to_string(), text);
            assert_eq!(CapacityProfile::parse(&p.to_string()).unwrap(), p);
        }
    }

    #[test]
    fn uniform_machines_match_paper_ceiling() {
        let p = CapacityProfile::uniform(200);
        for n in [0usize, 1, 199, 200, 201, 999, 1000, 1001] {
            let want = if n == 0 { 1 } else { n.div_ceil(200) };
            assert_eq!(p.machines_for(n), want, "n={n}");
        }
        assert!(p.is_uniform());
        assert_eq!(p.effective_capacity(), 200);
    }

    #[test]
    fn heterogeneous_machines_use_smallest_covering_prefix() {
        let p = CapacityProfile::parse("500,200,200").unwrap();
        // prefix sums of the cycle 500,200,200,500,…: 500, 700, 900, 1400
        assert_eq!(p.machines_for(400), 1);
        assert_eq!(p.machines_for(500), 1);
        assert_eq!(p.machines_for(501), 2);
        assert_eq!(p.machines_for(900), 3);
        assert_eq!(p.machines_for(901), 4);
        assert_eq!(p.machines_for(1400), 4);
        // exactly one full cycle
        let q = CapacityProfile::parse("100,50").unwrap();
        assert_eq!(q.machines_for(150), 2);
        assert_eq!(q.machines_for(151), 3);
        assert_eq!(q.round_caps(5), vec![100, 50, 100, 50, 100]);
    }

    #[test]
    fn machines_for_is_minimal_cover() {
        use crate::util::check::forall;
        forall(41, 80, |rng| {
            let classes = rng.range(1, 6);
            let caps: Vec<usize> = (0..classes).map(|_| rng.range(1, 300)).collect();
            let n = rng.range(0, 5000);
            (caps, n)
        }, |(caps, n)| {
            let p = CapacityProfile::new(caps.clone()).map_err(|e| e.to_string())?;
            let m = p.machines_for(*n);
            let sum: usize = p.round_caps(m).iter().sum();
            if sum < *n {
                return Err(format!("m={m} covers only {sum} < {n}"));
            }
            if m > 1 {
                let prev: usize = p.round_caps(m - 1).iter().sum();
                if prev >= *n {
                    return Err(format!("m={m} not minimal: {} machines suffice", m - 1));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn effective_capacity_lower_bounds_every_prefix_average() {
        let p = CapacityProfile::parse("1000,10,10").unwrap();
        let eff = p.effective_capacity();
        assert_eq!(eff, 340);
        for m in 1..=9 {
            let caps = p.round_caps(m);
            let avg = caps.iter().sum::<usize>() / m;
            assert!(avg >= eff, "prefix {m} average {avg} < effective {eff}");
        }
    }

    #[test]
    fn exceeds_k_checks_the_smallest_class() {
        let p = CapacityProfile::parse("500,20").unwrap();
        assert!(p.require_exceeds_k(10).is_ok());
        assert!(p.require_exceeds_k(20).is_err());
        assert!(p.require_exceeds_k(400).is_err());
    }
}

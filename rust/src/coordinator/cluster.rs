//! Simulated cluster: a pool of fixed-capacity machines.
//!
//! Each *machine* is a logical worker with a hard item capacity µ —
//! dispatching more than µ items to one machine is a
//! [`Error::CapacityExceeded`], not a soft warning: fixed capacity is the
//! paper's entire premise, and the Table 1 benches rely on the two-round
//! baselines *failing* here once `m·k > µ`.
//!
//! Machines execute on a small pool of OS threads (the testbed is a
//! single host); XLA work funnels through the engine's device thread.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::algorithms::{Compressor, Solution};
use crate::error::{Error, Result};
use crate::objectives::Problem;
use crate::util::rng::Rng;

/// Fixed-capacity machine pool.
pub struct Cluster {
    pub capacity: usize,
    pub threads: usize,
}

impl Cluster {
    pub fn new(capacity: usize) -> Self {
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .clamp(1, 8);
        Cluster { capacity, threads }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Execute one round: run `compressor` on every part in parallel.
    /// Returns one solution per part (order preserved).
    pub fn run_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<Vec<Solution>> {
        // capacity enforcement before any work starts
        for (i, p) in parts.iter().enumerate() {
            if p.len() > self.capacity {
                return Err(Error::CapacityExceeded {
                    capacity: self.capacity,
                    got: p.len(),
                    ctx: format!(" (machine {i} of {})", parts.len()),
                });
            }
        }

        // per-machine deterministic seeds
        let mut seed_rng = Rng::seed_from(round_seed);
        let seeds: Vec<u64> = (0..parts.len()).map(|_| seed_rng.next_u64()).collect();

        let next = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<Result<Solution>>>> =
            Mutex::new((0..parts.len()).map(|_| None).collect());

        let workers = self.threads.min(parts.len()).max(1);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= parts.len() {
                        break;
                    }
                    let sol = compressor.compress(problem, &parts[i], seeds[i]);
                    results.lock().unwrap()[i] = Some(sol);
                });
            }
        });

        let results = results.into_inner().unwrap();
        let mut out = Vec::with_capacity(parts.len());
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Some(Ok(sol)) => out.push(sol),
                Some(Err(e)) => return Err(e),
                None => return Err(Error::Worker(format!("machine {i} never ran"))),
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::data::synthetic;
    use std::sync::Arc;

    #[test]
    fn rejects_overloaded_machine() {
        let ds = Arc::new(synthetic::csn_like(100, 1));
        let p = Problem::exemplar(ds, 5, 1);
        let cluster = Cluster::new(10);
        let parts = vec![(0..11).collect::<Vec<u32>>()];
        let err = cluster
            .run_round(&p, &LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        assert!(matches!(err, Error::CapacityExceeded { capacity: 10, got: 11, .. }));
    }

    #[test]
    fn runs_all_parts_and_preserves_order() {
        let ds = Arc::new(synthetic::csn_like(120, 2));
        let p = Problem::exemplar(ds, 3, 2);
        let cluster = Cluster::new(40).with_threads(3);
        let parts: Vec<Vec<u32>> = (0..4).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let sols = cluster.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        assert_eq!(sols.len(), 4);
        for (i, s) in sols.iter().enumerate() {
            assert_eq!(s.items.len(), 3);
            // each solution's items come from its own part
            for &item in &s.items {
                assert!(parts[i].contains(&item), "machine {i} leaked items");
            }
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // Machine seeds are positional, so results must not depend on the
        // number of worker threads (scheduling nondeterminism).
        let ds = Arc::new(synthetic::csn_like(200, 3));
        let p = Problem::exemplar(ds, 4, 3);
        let parts: Vec<Vec<u32>> = (0..5).map(|i| (i * 40..(i + 1) * 40).collect()).collect();
        let a = Cluster::new(64)
            .with_threads(1)
            .run_round(&p, &LazyGreedy::new(), &parts, 7)
            .unwrap();
        let b = Cluster::new(64)
            .with_threads(4)
            .run_round(&p, &LazyGreedy::new(), &parts, 7)
            .unwrap();
        let items_a: Vec<_> = a.iter().map(|s| s.items.clone()).collect();
        let items_b: Vec<_> = b.iter().map(|s| s.items.clone()).collect();
        assert_eq!(items_a, items_b);
    }

    #[test]
    fn empty_parts_are_fine() {
        let ds = Arc::new(synthetic::csn_like(50, 4));
        let p = Problem::exemplar(ds, 3, 4);
        let cluster = Cluster::new(20);
        let parts = vec![vec![], (0..10).collect::<Vec<u32>>()];
        let sols = cluster.run_round(&p, &LazyGreedy::new(), &parts, 0).unwrap();
        assert!(sols[0].items.is_empty());
        assert_eq!(sols[1].items.len(), 3);
    }
}

//! Simulated cluster: a pool of fixed-capacity machines.
//!
//! Each *machine* is a logical worker with a hard item capacity µ —
//! dispatching more than µ items to one machine is a
//! [`Error::CapacityExceeded`], not a soft warning: fixed capacity is the
//! paper's entire premise, and the Table 1 benches rely on the two-round
//! baselines *failing* here once `m·k > µ`.
//!
//! The thread-pool execution itself now lives in
//! [`crate::dist::LocalBackend`] behind the [`Backend`] trait (so rounds
//! can also run on real `hss worker` processes or the fault simulator —
//! see [`crate::dist`]). Internal call sites (tree, baselines) use
//! `Backend` directly; `Cluster` remains as the crate's stable
//! *single-round* public entry point (re-exported from
//! [`crate::coordinator`]) for downstream users who just want "compress
//! these parts on a capacity-µ pool" without choosing a backend.

use crate::algorithms::{Compressor, Solution};
use crate::coordinator::capacity::CapacityProfile;
use crate::dist::{Backend, LocalBackend};
use crate::error::Result;
use crate::objectives::Problem;

/// Fixed-capacity machine pool (facade over [`LocalBackend`]).
pub struct Cluster {
    /// Largest machine capacity (the profile's first class).
    pub capacity: usize,
    pub threads: usize,
    profile: CapacityProfile,
}

impl Cluster {
    /// Uniform pool: every machine holds µ items.
    pub fn new(capacity: usize) -> Self {
        Self::with_profile(CapacityProfile::uniform(capacity))
    }

    /// Heterogeneous pool: machine `j` holds `µ_{j mod L}` items.
    pub fn with_profile(profile: CapacityProfile) -> Self {
        Cluster {
            capacity: profile.max_capacity(),
            threads: LocalBackend::default_threads(),
            profile,
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// The pool's capacity profile.
    pub fn profile(&self) -> &CapacityProfile {
        &self.profile
    }

    /// Execute one round: run `compressor` on every part in parallel.
    /// Returns one solution per part (order preserved).
    pub fn run_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<Vec<Solution>> {
        let backend =
            LocalBackend::with_profile(self.profile.clone()).with_threads(self.threads);
        backend
            .run_round(problem, compressor, parts, round_seed)
            .map(|outcome| outcome.solutions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::data::synthetic;
    use crate::error::Error;
    use std::sync::Arc;

    #[test]
    fn rejects_overloaded_machine() {
        let ds = Arc::new(synthetic::csn_like(100, 1));
        let p = Problem::exemplar(ds, 5, 1);
        let cluster = Cluster::new(10);
        let parts = vec![(0..11).collect::<Vec<u32>>()];
        let err = cluster
            .run_round(&p, &LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        assert!(matches!(err, Error::CapacityExceeded { capacity: 10, got: 11, .. }));
    }

    #[test]
    fn capacity_error_context_names_the_machine_index() {
        let ds = Arc::new(synthetic::csn_like(100, 1));
        let p = Problem::exemplar(ds, 5, 1);
        let cluster = Cluster::new(10);
        // machine 2 of 3 is the overloaded one
        let parts = vec![
            (0..5).collect::<Vec<u32>>(),
            (5..10).collect::<Vec<u32>>(),
            (10..25).collect::<Vec<u32>>(),
        ];
        let err = cluster
            .run_round(&p, &LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        match err {
            Error::CapacityExceeded { capacity, got, ctx } => {
                assert_eq!((capacity, got), (10, 15));
                assert!(ctx.contains("machine 2 of 3"), "ctx: {ctx}");
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn runs_all_parts_and_preserves_order() {
        let ds = Arc::new(synthetic::csn_like(120, 2));
        let p = Problem::exemplar(ds, 3, 2);
        let cluster = Cluster::new(40).with_threads(3);
        let parts: Vec<Vec<u32>> = (0..4).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let sols = cluster.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        assert_eq!(sols.len(), 4);
        for (i, s) in sols.iter().enumerate() {
            assert_eq!(s.items.len(), 3);
            // each solution's items come from its own part
            for &item in &s.items {
                assert!(parts[i].contains(&item), "machine {i} leaked items");
            }
        }
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // Machine seeds are positional, so results must not depend on the
        // number of worker threads (scheduling nondeterminism).
        let ds = Arc::new(synthetic::csn_like(200, 3));
        let p = Problem::exemplar(ds, 4, 3);
        let parts: Vec<Vec<u32>> = (0..5).map(|i| (i * 40..(i + 1) * 40).collect()).collect();
        let a = Cluster::new(64)
            .with_threads(1)
            .run_round(&p, &LazyGreedy::new(), &parts, 7)
            .unwrap();
        let b = Cluster::new(64)
            .with_threads(4)
            .run_round(&p, &LazyGreedy::new(), &parts, 7)
            .unwrap();
        let items_a: Vec<_> = a.iter().map(|s| s.items.clone()).collect();
        let items_b: Vec<_> = b.iter().map(|s| s.items.clone()).collect();
        assert_eq!(items_a, items_b);
    }

    #[test]
    fn heterogeneous_pool_sizes_machines_per_class() {
        let ds = Arc::new(synthetic::csn_like(90, 5));
        let p = Problem::exemplar(ds, 3, 5);
        let cluster = Cluster::with_profile(CapacityProfile::parse("40,25,25").unwrap());
        assert_eq!(cluster.capacity, 40);
        // machine classes cycle 40, 25, 25
        let fits = vec![
            (0..40).collect::<Vec<u32>>(),
            (40..65).collect::<Vec<u32>>(),
            (65..90).collect::<Vec<u32>>(),
        ];
        let sols = cluster.run_round(&p, &LazyGreedy::new(), &fits, 1).unwrap();
        assert_eq!(sols.len(), 3);
        // a large part on a small class machine is rejected
        let overloaded = vec![(0..40).collect::<Vec<u32>>(), (40..80).collect::<Vec<u32>>()];
        let err = cluster.run_round(&p, &LazyGreedy::new(), &overloaded, 1).unwrap_err();
        assert!(matches!(err, Error::CapacityExceeded { capacity: 25, got: 40, .. }), "{err}");
    }

    #[test]
    fn empty_parts_are_fine() {
        let ds = Arc::new(synthetic::csn_like(50, 4));
        let p = Problem::exemplar(ds, 3, 4);
        let cluster = Cluster::new(20);
        let parts = vec![vec![], (0..10).collect::<Vec<u32>>()];
        let sols = cluster.run_round(&p, &LazyGreedy::new(), &parts, 0).unwrap();
        assert!(sols[0].items.is_empty());
        assert_eq!(sols[1].items.len(), 3);
    }
}

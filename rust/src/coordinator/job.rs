//! A tree run as a first-class value: [`JobSpec`] → [`JobRunner`] →
//! [`JobOutput`].
//!
//! Historically "a run" *was* the program — `hss run` built a backend,
//! executed one experiment, printed lines, and exited. This module
//! extracts the run's setup/metrics plumbing into a reusable layer so
//! the same experiment can be executed by the one-shot CLI *or*
//! submitted to a long-lived multi-tenant service (`hss serve`,
//! [`crate::serve`]) over a shared fleet:
//!
//! * [`JobSpec`] — what to run: a [`RunConfig`] (the existing config
//!   file schema). The service path ([`JobSpec::from_service_json`])
//!   rejects backend-selection keys, because a service's jobs share
//!   *its* fleet.
//! * [`JobRunner`] — executes a spec against an injected
//!   [`Backend`], streaming [`JobEvent`]s (header resolved, trial
//!   finished) so the CLI can print progressively while the service
//!   records state transitions.
//! * [`JobOutput`] — everything the run produced: per-trial values and
//!   detail strings, the mean/stddev summary, and the per-worker
//!   [`WorkerStats`] **delta over the job's own interval** (via
//!   [`stats_delta`]), so concurrent tenants never see each other's
//!   utilization.
//!
//! Determinism: the runner is a verbatim extraction of the old
//! `cmd_run` trial loop — compressor selection, seed derivation
//! (`cfg.seed + trial`), and the formatted output lines
//! ([`JobHeader::to_line`], [`TrialOutcome::to_line`]) are
//! bit-identical to the pre-refactor CLI on every backend.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::algorithms::{Compressor, LazyGreedy, StochasticGreedy};
use crate::config::{Algo, RunConfig};
use crate::coordinator::{baselines, TreeBuilder};
use crate::dist::{stats_delta, Backend, WorkerStats};
use crate::error::{Error, Result};
use crate::runtime::accel::XlaGreedy;
use crate::runtime::EngineHandle;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// What to run: the existing run-config schema, reused verbatim so a
/// config file, a CLI invocation and a service submission all describe
/// experiments the same way.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub config: RunConfig,
}

/// Keys a *service* submission may not set: the daemon owns the fleet,
/// so a job has no say in where it executes.
const SERVICE_REJECTED_KEYS: &[&str] = &["backend", "workers", "sim"];

impl JobSpec {
    /// Wrap an already-resolved config (the `hss run` path — the
    /// config's own backend selection was used to build the backend the
    /// runner receives).
    pub fn from_config(config: RunConfig) -> JobSpec {
        JobSpec { config }
    }

    /// Parse a job submitted to the service (`POST /jobs` body): the
    /// run-config JSON schema, minus backend selection — the service
    /// owns the fleet, so `backend`, `workers` and `sim` are rejected
    /// with a clear error instead of silently ignored.
    pub fn from_service_json(text: &str) -> Result<JobSpec> {
        let doc = Json::parse(text)?;
        if let Json::Obj(fields) = &doc {
            for (key, _) in fields {
                if SERVICE_REJECTED_KEYS.contains(&key.as_str()) {
                    return Err(Error::invalid(format!(
                        "job spec field '{key}' is not allowed: the service owns the \
                         backend — submit only problem/algorithm fields \
                         (dataset, algo, k, capacity, seed, trials, constraint, \
                         partitioner, engine, threads, epsilon)"
                    )));
                }
            }
        } else {
            return Err(Error::invalid("job spec must be a JSON object"));
        }
        Ok(JobSpec { config: RunConfig::from_json_text(text)? })
    }

    /// One-line description for logs and job listings.
    pub fn summary(&self) -> String {
        format!(
            "dataset={} algo={} k={} trials={}",
            self.config.dataset,
            self.config.algo.name(),
            self.config.k,
            self.config.trials
        )
    }
}

/// The resolved experiment header — everything the classic
/// `dataset=… n=… …` banner line reports, kept as a value so services
/// can serve it as JSON while the CLI prints it.
#[derive(Debug, Clone)]
pub struct JobHeader {
    pub dataset: String,
    pub n: usize,
    pub d: usize,
    pub objective: String,
    pub constraint: String,
    pub k: usize,
    pub capacity: String,
    pub algo: String,
    pub backend: String,
    pub partitioner: String,
    pub engine: String,
}

impl JobHeader {
    /// The exact banner line `hss run` has always printed.
    pub fn to_line(&self) -> String {
        format!(
            "dataset={} n={} d={} objective={} constraint={} k={} capacity={} algo={} backend={} partitioner={} engine={}",
            self.dataset,
            self.n,
            self.d,
            self.objective,
            self.constraint,
            self.k,
            self.capacity,
            self.algo,
            self.backend,
            self.partitioner,
            self.engine,
        )
    }
}

/// One finished trial: the objective value, the algorithm-specific
/// detail string (rounds, machines, shuffle bytes, …), and wall time.
#[derive(Debug, Clone)]
pub struct TrialOutcome {
    pub trial: usize,
    pub value: f64,
    pub detail: String,
    pub wall_ms: f64,
}

impl TrialOutcome {
    /// The exact per-trial line `hss run` has always printed.
    pub fn to_line(&self) -> String {
        format!(
            "trial {}: f(S) = {:.6}  [{}]  ({:.0} ms)",
            self.trial, self.value, self.detail, self.wall_ms
        )
    }
}

/// Everything one executed job produced.
pub struct JobOutput {
    pub header: JobHeader,
    pub trials: Vec<TrialOutcome>,
    /// Mean/stddev of the trial values (the `mean f(S) = …` summary).
    pub mean: f64,
    pub stddev: f64,
    /// Per-worker utilization over **this job's interval only**: the
    /// scoped slice when the backend attributes per scope, otherwise
    /// the delta between lifetime snapshots taken around the job.
    pub worker_stats: Vec<WorkerStats>,
    /// Job wall time (header resolution to last trial), milliseconds.
    pub wall_ms: f64,
    /// The XLA device handle the job ran with, if any — the CLI prints
    /// its stats; services on non-local backends never get one.
    pub engine: Option<EngineHandle>,
}

impl JobOutput {
    /// The exact multi-trial summary line `hss run` has always printed
    /// (callers print it only when more than one trial ran).
    pub fn mean_line(&self) -> String {
        format!(
            "mean f(S) = {:.6} ± {:.6} over {} trials",
            self.mean,
            self.stddev,
            self.trials.len()
        )
    }
}

/// Progress notifications streamed while a job runs, so the CLI prints
/// lines the moment they happen and the service timestamps state
/// transitions.
pub enum JobEvent<'a> {
    /// The problem is loaded and the experiment banner is resolved.
    Started(&'a JobHeader),
    /// One trial finished.
    Trial(&'a TrialOutcome),
}

/// Executes [`JobSpec`]s against an injected backend. Stateless across
/// jobs — one runner may execute many specs, sequentially or from
/// several threads (the backend is the shared resource, the runner just
/// drives it).
pub struct JobRunner {
    backend: Arc<dyn Backend>,
    cancel: Option<Arc<AtomicBool>>,
}

impl JobRunner {
    pub fn new(backend: Arc<dyn Backend>) -> JobRunner {
        JobRunner { backend, cancel: None }
    }

    /// Attach a cancellation flag: checked between trials (and, via a
    /// scope-aware backend wrapper, at round boundaries inside one).
    /// A set flag surfaces as [`Error::Cancelled`].
    pub fn with_cancel(mut self, cancel: Arc<AtomicBool>) -> JobRunner {
        self.cancel = Some(cancel);
        self
    }

    /// Run to completion, discarding progress events.
    pub fn run(&self, spec: &JobSpec) -> Result<JobOutput> {
        self.run_with(spec, &mut |_| {})
    }

    /// Run to completion, streaming [`JobEvent`]s to `observe`.
    pub fn run_with(
        &self,
        spec: &JobSpec,
        observe: &mut dyn FnMut(JobEvent<'_>),
    ) -> Result<JobOutput> {
        let cfg = &spec.config;
        let backend = &self.backend;
        let (problem, engine) = cfg.problem_with_engine()?;
        // XLA device compressors are not wire-representable; on
        // non-local backends the device handle stays out of compressor
        // dispatch and the engine choice instead rides the hello
        // handshake to each worker
        let engine = if backend.name() == "local" { engine } else { None };
        let header = JobHeader {
            dataset: cfg.dataset.clone(),
            n: problem.n(),
            d: problem.dataset.d,
            objective: problem.objective.name().to_string(),
            constraint: problem.constraint.name(),
            k: cfg.k,
            capacity: cfg.capacity.to_string(),
            algo: cfg.algo.name().to_string(),
            backend: backend.name().to_string(),
            partitioner: cfg.partitioner.name().to_string(),
            engine: problem.compute.name().to_string(),
        };
        observe(JobEvent::Started(&header));

        let stats_before = backend.worker_stats();
        let run_start = Instant::now();
        let mut values = Summary::new();
        let mut trials: Vec<TrialOutcome> = Vec::new();
        for trial in 0..cfg.trials {
            self.check_cancelled(trial)?;
            let seed = cfg.seed + trial as u64;
            let t0 = Instant::now();
            let (value, detail) = match &cfg.algo {
                Algo::Centralized => {
                    let s = baselines::centralized(&problem)?;
                    (s.value, format!("|S|={}", s.items.len()))
                }
                Algo::Random => {
                    let s = baselines::random_subset(&problem, seed)?;
                    (s.value, format!("|S|={}", s.items.len()))
                }
                Algo::RandGreedi | Algo::Greedi => {
                    let run = |p: &_, c: &dyn Compressor| match cfg.algo {
                        Algo::RandGreedi => {
                            baselines::rand_greedi_on(p, backend.as_ref(), c, seed)
                        }
                        _ => baselines::greedi_on(p, backend.as_ref(), c, seed),
                    };
                    let res = match &engine {
                        Some(e) => run(&problem, &XlaGreedy::new(e.clone()))?,
                        None => run(&problem, &LazyGreedy::new())?,
                    };
                    (
                        res.solution.value,
                        format!("machines={} union={}", res.machines, res.union_size),
                    )
                }
                Algo::Tree | Algo::StochasticTree { .. } => {
                    let compressor: Arc<dyn Compressor> = match (&cfg.algo, &engine) {
                        (Algo::Tree, Some(e)) => Arc::new(XlaGreedy::new(e.clone())),
                        (Algo::Tree, None) => Arc::new(LazyGreedy::new()),
                        (Algo::StochasticTree { epsilon }, Some(e)) => {
                            Arc::new(XlaGreedy::stochastic(e.clone(), *epsilon))
                        }
                        (Algo::StochasticTree { epsilon }, None) => {
                            Arc::new(StochasticGreedy::new(*epsilon))
                        }
                        // the outer arm admits only tree algorithms, so
                        // this is unreachable; defaulting (rather than
                        // panicking) keeps the coordinator panic-free
                        _ => Arc::new(LazyGreedy::new()),
                    };
                    let res = TreeBuilder::for_profile(cfg.capacity.clone())
                        .compressor(compressor)
                        .partition_mode(cfg.partitioner)
                        .threads(cfg.threads)
                        .backend(backend.clone())
                        .build()
                        .run(&problem, seed)?;
                    let requeue = if res.requeued_parts > 0 {
                        format!(" requeued={}", res.requeued_parts)
                    } else {
                        String::new()
                    };
                    let overlap = if res.straggler_overlap_ms > 0.0 {
                        format!(" overlapMs={:.1}", res.straggler_overlap_ms)
                    } else {
                        String::new()
                    };
                    // interning telemetry: after round 0 this stays
                    // flat — compress requests ship an O(1) problem id,
                    // not the spec
                    let spec = if res.spec_bytes > 0 {
                        format!(" specKB={:.1}", res.spec_bytes as f64 / 1e3)
                    } else {
                        String::new()
                    };
                    (
                        res.best.value,
                        format!(
                            "rounds={}/{} machines={} evals={} shuffleKB={:.1} residentMB={:.1}{spec}{requeue}{overlap}",
                            res.rounds,
                            res.round_bound,
                            res.total_machines,
                            res.oracle_evals,
                            res.bytes_shuffled as f64 / 1e3,
                            res.rows_resident_bytes as f64 / 1e6
                        ),
                    )
                }
            };
            values.push(value);
            let outcome = TrialOutcome {
                trial,
                value,
                detail,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            };
            observe(JobEvent::Trial(&outcome));
            trials.push(outcome);
        }
        let wall_ms = run_start.elapsed().as_secs_f64() * 1e3;
        // the job's own interval: scoped backends report from zero, so
        // the delta is the identity; lifetime-only backends subtract
        // the snapshot taken before the first trial
        let worker_stats = stats_delta(&backend.worker_stats(), &stats_before);
        Ok(JobOutput {
            header,
            trials,
            mean: values.mean(),
            stddev: values.stddev(),
            worker_stats,
            wall_ms,
            engine,
        })
    }

    fn check_cancelled(&self, trial: usize) -> Result<()> {
        if let Some(flag) = &self.cancel {
            if flag.load(Ordering::SeqCst) {
                return Err(Error::Cancelled(format!(
                    "job cancelled before trial {trial}"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::LocalBackend;

    fn small_spec() -> JobSpec {
        let mut cfg = RunConfig::default();
        cfg.dataset = "tiny-2k".into();
        cfg.k = 5;
        cfg.capacity = crate::coordinator::capacity::CapacityProfile::uniform(200);
        cfg.trials = 2;
        JobSpec::from_config(cfg)
    }

    #[test]
    fn runner_output_lines_match_the_classic_cli_format() {
        let backend: Arc<dyn Backend> = Arc::new(LocalBackend::new(200));
        let spec = small_spec();
        let out = JobRunner::new(backend).run(&spec).unwrap();
        let banner = out.header.to_line();
        assert!(banner.starts_with("dataset=tiny-2k n="), "{banner}");
        assert!(banner.contains(" backend=local "), "{banner}");
        assert_eq!(out.trials.len(), 2);
        let line = out.trials[0].to_line();
        assert!(line.starts_with("trial 0: f(S) = "), "{line}");
        assert!(line.contains("[rounds="), "{line}");
        assert!(out.mean_line().contains("over 2 trials"), "{}", out.mean_line());
        // two trials with different seeds: the mean is defined
        assert!(out.mean.is_finite());
    }

    #[test]
    fn runner_is_deterministic_for_a_fixed_spec() {
        let backend: Arc<dyn Backend> = Arc::new(LocalBackend::new(200));
        let runner = JobRunner::new(backend);
        let spec = small_spec();
        let a = runner.run(&spec).unwrap();
        let b = runner.run(&spec).unwrap();
        assert_eq!(a.trials[0].value.to_bits(), b.trials[0].value.to_bits());
        assert_eq!(a.trials[0].detail, b.trials[0].detail);
    }

    #[test]
    fn service_spec_rejects_backend_selection_keys() {
        for body in [
            r#"{"dataset":"tiny-2k","backend":"tcp"}"#,
            r#"{"dataset":"tiny-2k","workers":["w:1"]}"#,
            r#"{"dataset":"tiny-2k","sim":{}}"#,
        ] {
            let err = JobSpec::from_service_json(body).unwrap_err().to_string();
            assert!(err.contains("service owns the backend"), "{err}");
        }
        assert!(JobSpec::from_service_json(r#"{"dataset":"tiny-2k","k":5}"#).is_ok());
        assert!(JobSpec::from_service_json("[1,2]").is_err());
    }

    #[test]
    fn a_pre_set_cancel_flag_stops_the_job_before_any_trial() {
        let backend: Arc<dyn Backend> = Arc::new(LocalBackend::new(200));
        let flag = Arc::new(AtomicBool::new(true));
        let runner = JobRunner::new(backend).with_cancel(flag);
        let err = match runner.run(&small_spec()) {
            Err(e) => e,
            Ok(_) => panic!("expected Cancelled, got a completed job"),
        };
        assert!(
            matches!(err, Error::Cancelled(_)),
            "expected Cancelled, got: {err}"
        );
    }
}

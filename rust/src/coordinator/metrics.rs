//! Run telemetry: per-round and aggregate cost accounting.
//!
//! These counters back Table 1's cost columns (machines, rounds, oracle
//! evaluations) and the shuffle/bytes accounting a real deployment would
//! watch.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Per-round record.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundMetrics {
    pub round: usize,
    pub input_items: usize,
    pub machines: usize,
    pub max_machine_load: usize,
    pub output_items: usize,
    /// Parts re-executed after a machine loss (backend fault tolerance;
    /// always 0 on a healthy backend).
    pub requeued_parts: usize,
    /// Item-id bytes that crossed the coordinator↔machine boundary this
    /// round (part ids shipped out, re-shipments after machine loss,
    /// and solution ids returned). The wire protocol ships ids, never
    /// feature rows.
    pub bytes_shuffled: u64,
    /// Feature-row bytes resident across the round's machines — what a
    /// shared-nothing deployment holds in RAM, *not* wire traffic.
    pub rows_resident_bytes: u64,
    pub wall_ms: f64,
    /// Straggler tail the pipelined coordinator overlapped: wall-clock
    /// between the round's *first* and *last* part completion, during
    /// which the event-driven tree runner builds the surviving set and
    /// pre-computes the next round's plan/partition instead of idling
    /// at a barrier. 0 on the serial (`run_round`) path, which observes
    /// nothing until the whole round is done.
    pub straggler_overlap_ms: f64,
    /// Problem-spec bytes shipped over the wire this round (protocol v4
    /// interning: the spec crosses once per (worker connection, problem
    /// identity), so after round 0 every compress request carries an
    /// O(1) problem id and this is 0). Always 0 on wire-less backends.
    pub spec_bytes: u64,
    /// Oracle evaluations charged to this round: the delta of the
    /// problem's shared counter between the round starting and its
    /// last part reporting (remote workers fold their evals in before
    /// announcing completion, so the delta covers every backend).
    /// Under contiguous speculative dispatch, a next-round part that
    /// executes early is charged to the round whose window it
    /// completes in — totals stay exact, per-round attribution is
    /// approximate.
    pub oracle_evals: u64,
    pub best_value: f64,
}

/// Aggregate metrics for one coordinator run.
#[derive(Debug, Default)]
pub struct Metrics {
    pub bytes_shuffled: AtomicU64,
    pub rows_resident_bytes: AtomicU64,
    pub machines_provisioned: AtomicU64,
    pub parts_requeued: AtomicU64,
    pub spec_bytes: AtomicU64,
    rounds: Mutex<Vec<RoundMetrics>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_round(&self, r: RoundMetrics) {
        // relaxed: monotone statistics counters — no other memory is
        // published through them, and the totals are only read after the
        // run's rounds have completed (round completion itself
        // synchronizes via the rounds mutex below / backend joins)
        self.bytes_shuffled.fetch_add(r.bytes_shuffled, Ordering::Relaxed);
        self.rows_resident_bytes
            .fetch_add(r.rows_resident_bytes, Ordering::Relaxed); // relaxed: see above
        self.machines_provisioned
            .fetch_add(r.machines as u64, Ordering::Relaxed); // relaxed: see above
        self.parts_requeued
            .fetch_add(r.requeued_parts as u64, Ordering::Relaxed); // relaxed: see above
        // relaxed: see above — independent monotone counter
        self.spec_bytes.fetch_add(r.spec_bytes, Ordering::Relaxed);
        // invariant: the rounds mutex cannot be poisoned — the only
        // critical sections are push/clone/len, none of which panic
        self.rounds.lock().unwrap().push(r);
    }

    pub fn rounds(&self) -> Vec<RoundMetrics> {
        // invariant: push/clone/len critical sections never panic
        self.rounds.lock().unwrap().clone()
    }

    pub fn num_rounds(&self) -> usize {
        // invariant: push/clone/len critical sections never panic
        self.rounds.lock().unwrap().len()
    }

    pub fn total_bytes_shuffled(&self) -> u64 {
        // relaxed: monotone counter read after the recording rounds end
        self.bytes_shuffled.load(Ordering::Relaxed)
    }

    pub fn total_rows_resident_bytes(&self) -> u64 {
        // relaxed: monotone counter read after the recording rounds end
        self.rows_resident_bytes.load(Ordering::Relaxed)
    }

    pub fn total_machines(&self) -> u64 {
        // relaxed: monotone counter read after the recording rounds end
        self.machines_provisioned.load(Ordering::Relaxed)
    }

    pub fn total_requeued(&self) -> u64 {
        // relaxed: monotone counter read after the recording rounds end
        self.parts_requeued.load(Ordering::Relaxed)
    }

    pub fn total_spec_bytes(&self) -> u64 {
        // relaxed: monotone counter read after the recording rounds end
        self.spec_bytes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::new();
        m.record_round(RoundMetrics {
            round: 0,
            input_items: 100,
            machines: 4,
            max_machine_load: 25,
            output_items: 20,
            requeued_parts: 1,
            bytes_shuffled: 400,
            rows_resident_bytes: 6_800,
            wall_ms: 1.0,
            straggler_overlap_ms: 0.4,
            spec_bytes: 300,
            oracle_evals: 1_000,
            best_value: 5.0,
        });
        m.record_round(RoundMetrics {
            round: 1,
            input_items: 20,
            machines: 1,
            max_machine_load: 20,
            output_items: 5,
            requeued_parts: 2,
            bytes_shuffled: 80,
            rows_resident_bytes: 1_360,
            wall_ms: 0.5,
            straggler_overlap_ms: 0.0,
            spec_bytes: 0,
            oracle_evals: 250,
            best_value: 6.0,
        });
        assert_eq!(m.num_rounds(), 2);
        assert_eq!(m.total_bytes_shuffled(), 480);
        assert_eq!(m.total_rows_resident_bytes(), 8_160);
        assert_eq!(m.total_machines(), 5);
        assert_eq!(m.total_requeued(), 3);
        assert_eq!(m.total_spec_bytes(), 300);
        assert_eq!(m.rounds()[1].best_value, 6.0);
    }
}

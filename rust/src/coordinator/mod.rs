//! The distributed coordinator — the paper's system contribution.
//!
//! * [`capacity`] — per-worker capacity profiles (`µ_p` per machine
//!   class, cyclic weighted sharding) generalizing the paper's scalar µ;
//! * [`partitioner`] — the balanced random partition of §3 ("virtual
//!   free locations") and its capacity-weighted generalization;
//! * [`planner`] — round planning: `m_t = ⌈|A_t|/µ⌉` (smallest covering
//!   prefix for heterogeneous fleets) and the Prop 3.1 round bound
//!   `r = ⌈log_{µ/k}(n/µ)⌉ + 1`;
//! * [`cluster`] — fixed-capacity machine-pool facade (hard capacity
//!   enforcement; execution now lives behind [`crate::dist::Backend`],
//!   so rounds also run on real `hss worker` processes or the fault
//!   simulator);
//! * [`tree`] — Algorithm 1 TREE-BASED COMPRESSION;
//! * [`baselines`] — centralized GREEDY, GREEDI, RANDGREEDI, RANDOM;
//! * [`job`] — a run as a first-class value: [`JobSpec`] → [`JobRunner`]
//!   → [`JobOutput`], the layer both the one-shot CLI and the
//!   multi-tenant `hss serve` daemon ([`crate::serve`]) execute through.

pub mod baselines;
pub mod capacity;
pub mod cluster;
pub mod job;
pub mod metrics;
pub mod partitioner;
pub mod planner;
pub mod tree;

pub use capacity::CapacityProfile;
pub use cluster::Cluster;
pub use job::{JobEvent, JobHeader, JobOutput, JobRunner, JobSpec, TrialOutcome};
pub use metrics::{Metrics, RoundMetrics};
pub use partitioner::{
    balanced_random_partition, weighted_balanced_random_partition, PartitionStrategy,
};
pub use planner::RoundPlan;
pub use tree::{TreeBuilder, TreeResult, TreeRunner};

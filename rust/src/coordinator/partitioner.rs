//! The paper's balanced random partitioner (§3, "Framework"):
//!
//! > To partition N items to L parts, we assign each of the L parts
//! > ⌈N/L⌉ virtual free locations. We pick items one by one, and for each
//! > one we find a location uniformly at random among the available
//! > locations in all machines, and assign the item to the chosen
//! > location.
//!
//! Equivalent implementation: build the multiset of `L·⌈N/L⌉` location
//! labels, draw a uniform random N-subset *arrangement* of it via a
//! partial Fisher–Yates shuffle, and read off each item's part. This is
//! exactly the paper's process (every injective map from items to free
//! locations is equally likely) and guarantees `max − min ≤ ⌈N/L⌉ −
//! ⌊N/L⌋ ≤ 1` part-size imbalance... strictly: every part ≤ ⌈N/L⌉.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// How items are spread across machines each round — a first-class run
/// path selected by `--partitioner` / config `partitioner` (the paper's
/// algorithm uses [`PartitionStrategy::Balanced`]; the contiguous
/// strategy is GreeDI-style locality-aware partitioning, the regime
/// where speculative next-round dispatch pays off because each next
/// part's inputs come from a small window of current parts).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionStrategy {
    /// Paper §3: balanced random via virtual free locations.
    #[default]
    Balanced,
    /// Contiguous chunks in surviving-set order (GreeDI's arbitrary,
    /// locality-aware partitioning).
    Contiguous,
    /// Each item independently uniform (unbalanced strawman; ablation
    /// only — not reachable from the CLI).
    Iid,
}

impl PartitionStrategy {
    /// Parse the `--partitioner` grammar: `balanced` | `contiguous`.
    pub fn parse(name: &str) -> Result<PartitionStrategy> {
        Ok(match name {
            "balanced" => PartitionStrategy::Balanced,
            "contiguous" => PartitionStrategy::Contiguous,
            other => {
                return Err(Error::Config(format!(
                    "unknown partitioner '{other}' (known: balanced, contiguous)"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionStrategy::Balanced => "balanced",
            PartitionStrategy::Contiguous => "contiguous",
            PartitionStrategy::Iid => "iid",
        }
    }

    /// Partition `items` over machines with per-machine capacities
    /// `caps`, consuming `rng` exactly as the strategy's underlying
    /// partitioner does (contiguous consumes nothing — which is what
    /// makes its next-round parts computable, and dispatchable, the
    /// moment their input items are known).
    pub fn partition(
        &self,
        items: &[u32],
        caps: &[usize],
        rng: &mut Rng,
    ) -> Result<Vec<Vec<u32>>> {
        match self {
            PartitionStrategy::Balanced => {
                weighted_balanced_random_partition(items, caps, rng)
            }
            PartitionStrategy::Contiguous => weighted_contiguous_partition(items, caps),
            PartitionStrategy::Iid => Ok(iid_partition(items, caps.len(), rng)),
        }
    }
}

/// Partition `items` into `parts` balanced random parts.
/// Every returned part has size ≤ ⌈N/L⌉; parts may be empty only when
/// N < L. The union of parts is exactly `items` (as a multiset).
pub fn balanced_random_partition(
    items: &[u32],
    parts: usize,
    rng: &mut Rng,
) -> Vec<Vec<u32>> {
    assert!(parts > 0, "parts must be positive");
    let n = items.len();
    let cap = if n == 0 { 0 } else { n.div_ceil(parts) };
    // multiset of location labels: part p appears cap times
    let mut labels: Vec<u32> = (0..parts as u32)
        .flat_map(|p| std::iter::repeat(p).take(cap))
        .collect();
    // partial Fisher–Yates: the first n entries become a uniform random
    // n-arrangement of the label multiset
    for i in 0..n {
        let j = rng.range(i, labels.len());
        labels.swap(i, j);
    }
    let mut out: Vec<Vec<u32>> = vec![Vec::with_capacity(cap); parts];
    for (idx, &item) in items.iter().enumerate() {
        out[labels[idx] as usize].push(item);
    }
    out
}

/// Weighted balanced random partition for heterogeneous machine
/// capacities: part `p` gets `⌈N·µ_p/Σµ⌉` virtual free locations, so
/// larger machines receive proportionally larger parts while the
/// assignment stays a uniform random injective map from items to
/// locations — the paper's §3 process, with the location multiset
/// weighted by capacity instead of uniform.
///
/// Guarantees, for `caps = [µ_0, …, µ_{L-1}]` with `Σµ ≥ N`:
///
/// * every part `p` has size ≤ `⌈N·µ_p/Σµ⌉ ≤ µ_p` (no machine is ever
///   overloaded: `N ≤ Σµ` makes the budget at most the integer µ_p);
/// * the union of parts is exactly `items` as a multiset;
/// * deterministic per rng state;
/// * a **uniform** capacity vector reduces *bit-identically* to
///   [`balanced_random_partition`]: the budgets collapse to `⌈N/L⌉`,
///   the location multiset is the same, and the Fisher–Yates draws
///   consume the identical rng stream.
pub fn weighted_balanced_random_partition(
    items: &[u32],
    caps: &[usize],
    rng: &mut Rng,
) -> Result<Vec<Vec<u32>>> {
    let labels = weighted_balanced_labels(items.len(), caps, rng)?;
    Ok(apply_labels(items, &labels, caps.len()))
}

/// The label assignment underlying
/// [`weighted_balanced_random_partition`]: input position `i` goes to
/// part `labels[i]`. The assignment depends only on `(n, caps, rng)` —
/// never on the item *values* — which is what lets the pipelined tree
/// runner draw the next round's partition the moment the surviving-set
/// **size** is known, while the items themselves are still being
/// compressed by stragglers. Consumes the identical rng stream as the
/// full partition call.
///
/// A capacity vector that cannot hold `n` items is a structured
/// [`Error::CapacityExceeded`], not a panic: a fleet that shrinks below
/// `|A_t|` mid-run (scripted sim schedules, mass worker loss) must fail
/// the round, never abort the coordinator process.
pub fn weighted_balanced_labels(n: usize, caps: &[usize], rng: &mut Rng) -> Result<Vec<u32>> {
    let total = check_caps_hold(n, caps, "weighted balanced partition")?;
    // per-part location budgets ⌈N·µ_p/Σµ⌉ (0 when n == 0)
    let budgets: Vec<usize> = caps
        .iter()
        .map(|&c| if n == 0 { 0 } else { (n * c).div_ceil(total) })
        .collect();
    // multiset of location labels: part p appears budgets[p] times
    let mut labels: Vec<u32> = budgets
        .iter()
        .enumerate()
        .flat_map(|(p, &b)| std::iter::repeat(p as u32).take(b))
        .collect();
    debug_assert!(labels.len() >= n);
    // partial Fisher–Yates: the first n entries become a uniform random
    // n-arrangement of the weighted label multiset
    for i in 0..n {
        let j = rng.range(i, labels.len());
        labels.swap(i, j);
    }
    labels.truncate(n);
    Ok(labels)
}

/// Shared precondition of the weighted partitioners: the fleet's round
/// capacities must hold all `n` items. Returns the total on success.
fn check_caps_hold(n: usize, caps: &[usize], what: &str) -> Result<usize> {
    if caps.is_empty() {
        return Err(Error::invalid(format!(
            "{what}: capacity vector must be non-empty"
        )));
    }
    let total: usize = caps.iter().sum();
    if total < n {
        return Err(Error::CapacityExceeded {
            capacity: total,
            got: n,
            ctx: format!(" ({what}: the fleet's {} machines cannot hold the surviving set)", caps.len()),
        });
    }
    Ok(total)
}

/// Materialize a label assignment: item `i` goes to part `labels[i]`,
/// preserving input order within every part (the order machines see —
/// and greedy tie-breaking depends on — so it is part of the
/// deterministic contract).
pub fn apply_labels(items: &[u32], labels: &[u32], parts: usize) -> Vec<Vec<u32>> {
    debug_assert_eq!(items.len(), labels.len());
    // one counts pass so every part allocates exactly once
    let mut sizes = vec![0usize; parts];
    for &l in labels {
        sizes[l as usize] += 1;
    }
    let mut out: Vec<Vec<u32>> = sizes.into_iter().map(Vec::with_capacity).collect();
    for (idx, &item) in items.iter().enumerate() {
        out[labels[idx] as usize].push(item);
    }
    out
}

/// Contiguous (arbitrary, non-random) partition — the GREEDI baseline's
/// assumption, used by the partitioning ablation.
pub fn contiguous_partition(items: &[u32], parts: usize) -> Vec<Vec<u32>> {
    assert!(parts > 0);
    let n = items.len();
    let cap = if n == 0 { 0 } else { n.div_ceil(parts) };
    let mut out = Vec::with_capacity(parts);
    for p in 0..parts {
        let lo = (p * cap).min(n);
        let hi = ((p + 1) * cap).min(n);
        out.push(items[lo..hi].to_vec());
    }
    out
}

/// Weighted contiguous partition: chunk `items` in order, part `p`
/// taking up to its `⌈N·µ_p/Σµ⌉` budget. The heterogeneous analogue of
/// [`contiguous_partition`]; reduces to it exactly for uniform `caps`.
pub fn weighted_contiguous_partition(items: &[u32], caps: &[usize]) -> Result<Vec<Vec<u32>>> {
    let n = items.len();
    let bounds = weighted_contiguous_bounds(n, caps)?;
    Ok(bounds
        .into_iter()
        .map(|(lo, hi)| items[lo..hi].to_vec())
        .collect())
}

/// The index ranges underlying [`weighted_contiguous_partition`]: part
/// `p` holds input positions `lo..hi`. Like
/// [`weighted_balanced_labels`], the assignment depends only on `(n,
/// caps)` — never on item values — and for the contiguous strategy it
/// consumes no randomness at all, so the pipelined tree runner knows
/// exactly which current-round parts feed each next-round part the
/// moment the surviving-set size is predicted. That is the data
/// dependency speculative dispatch exploits.
pub fn weighted_contiguous_bounds(n: usize, caps: &[usize]) -> Result<Vec<(usize, usize)>> {
    let total = check_caps_hold(n, caps, "weighted contiguous partition")?;
    let mut out = Vec::with_capacity(caps.len());
    let mut lo = 0usize;
    for &c in caps {
        let budget = if n == 0 { 0 } else { (n * c).div_ceil(total) };
        let hi = (lo + budget).min(n);
        out.push((lo, hi));
        lo = hi;
    }
    Ok(out)
}

/// IID multinomial partition (each item independently uniform over
/// parts) — the *unbalanced* strawman for the partitioning ablation:
/// part sizes fluctuate and can exceed capacity.
pub fn iid_partition(items: &[u32], parts: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    assert!(parts > 0);
    let mut out: Vec<Vec<u32>> = vec![Vec::new(); parts];
    for &item in items {
        out[rng.below(parts)].push(item);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flatten_sorted(parts: &[Vec<u32>]) -> Vec<u32> {
        let mut all: Vec<u32> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        all
    }

    #[test]
    fn labels_plus_apply_reproduce_the_partition_bit_exactly() {
        // the pipelined tree runner draws labels from the item COUNT
        // alone, then scatters items in as their parts complete — that
        // is only sound if (labels, apply) is the partition, same rng
        // stream included
        let caps = vec![50usize, 20, 20];
        let items: Vec<u32> = (0..80).map(|i| i * 3 + 1).collect();
        let mut rng_a = Rng::seed_from(9);
        let mut rng_b = rng_a.clone();
        let direct = weighted_balanced_random_partition(&items, &caps, &mut rng_a).unwrap();
        let labels = weighted_balanced_labels(items.len(), &caps, &mut rng_b).unwrap();
        assert_eq!(labels.len(), items.len());
        let applied = apply_labels(&items, &labels, caps.len());
        assert_eq!(direct, applied);
        // the streams stay aligned after the call
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    fn partition_is_exact_cover() {
        let mut rng = Rng::seed_from(1);
        let items: Vec<u32> = (0..103).collect();
        let parts = balanced_random_partition(&items, 7, &mut rng);
        assert_eq!(parts.len(), 7);
        assert_eq!(flatten_sorted(&parts), items);
    }

    #[test]
    fn parts_never_exceed_ceiling() {
        let mut rng = Rng::seed_from(2);
        for &(n, l) in &[(100usize, 7usize), (5, 10), (64, 8), (1, 3), (0, 4), (1000, 13)] {
            let items: Vec<u32> = (0..n as u32).collect();
            let parts = balanced_random_partition(&items, l, &mut rng);
            let cap = if n == 0 { 0 } else { n.div_ceil(l) };
            for p in &parts {
                assert!(p.len() <= cap.max(1), "n={n} l={l}: part {} > cap {cap}", p.len());
            }
            assert_eq!(flatten_sorted(&parts), items);
        }
    }

    #[test]
    fn balance_property_random_instances() {
        use crate::util::check::forall;
        forall(3, 50, |rng| {
            let n = rng.range(1, 500);
            let l = rng.range(1, 20);
            (n, l, rng.next_u64())
        }, |&(n, l, seed)| {
            let items: Vec<u32> = (0..n as u32).collect();
            let mut rng = Rng::seed_from(seed);
            let parts = balanced_random_partition(&items, l, &mut rng);
            let cap = n.div_ceil(l);
            let max = parts.iter().map(Vec::len).max().unwrap();
            let total: usize = parts.iter().map(Vec::len).sum();
            if max > cap {
                return Err(format!("max part {max} > cap {cap}"));
            }
            if total != n {
                return Err(format!("lost items: {total} != {n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn assignment_is_roughly_uniform() {
        // item 0 should land in each of 4 parts ~equally often
        let items: Vec<u32> = (0..16).collect();
        let mut counts = [0usize; 4];
        for seed in 0..4000 {
            let mut rng = Rng::seed_from(seed);
            let parts = balanced_random_partition(&items, 4, &mut rng);
            for (p, part) in parts.iter().enumerate() {
                if part.contains(&0) {
                    counts[p] += 1;
                }
            }
        }
        for &c in &counts {
            assert!((800..1200).contains(&c), "skewed: {counts:?}");
        }
    }

    #[test]
    fn contiguous_covers_in_order() {
        let items: Vec<u32> = (0..10).collect();
        let parts = contiguous_partition(&items, 3);
        assert_eq!(parts[0], vec![0, 1, 2, 3]);
        assert_eq!(parts[1], vec![4, 5, 6, 7]);
        assert_eq!(parts[2], vec![8, 9]);
    }

    #[test]
    fn iid_partition_covers_but_unbalanced() {
        let mut rng = Rng::seed_from(9);
        let items: Vec<u32> = (0..1000).collect();
        let parts = iid_partition(&items, 10, &mut rng);
        assert_eq!(flatten_sorted(&parts), items);
        // with 1000 items/10 parts, some fluctuation beyond ±1 is
        // essentially certain — that's the point of the ablation
        let sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
        let spread = sizes.iter().max().unwrap() - sizes.iter().min().unwrap();
        assert!(spread > 1, "iid partition suspiciously balanced: {sizes:?}");
    }

    #[test]
    fn deterministic_per_seed() {
        let items: Vec<u32> = (0..50).collect();
        let a = balanced_random_partition(&items, 5, &mut Rng::seed_from(7));
        let b = balanced_random_partition(&items, 5, &mut Rng::seed_from(7));
        assert_eq!(a, b);
    }

    #[test]
    fn union_is_the_input_multiset_even_with_duplicates() {
        // The documented contract is multiset equality — items may repeat
        // (e.g. an A_t assembled from overlapping partial solutions) and
        // every occurrence must land on exactly one machine.
        let mut rng = Rng::seed_from(17);
        let items: Vec<u32> = (0..90).map(|i| i % 30).collect(); // each id 3×
        let parts = balanced_random_partition(&items, 7, &mut rng);
        let mut expected = items.clone();
        expected.sort_unstable();
        assert_eq!(flatten_sorted(&parts), expected);
        let cap = items.len().div_ceil(7);
        for p in &parts {
            assert!(p.len() <= cap, "part {} exceeds ceiling {cap}", p.len());
        }
    }

    #[test]
    fn weighted_parts_respect_proportional_budgets() {
        let mut rng = Rng::seed_from(3);
        let items: Vec<u32> = (0..240).collect();
        let caps = [120usize, 60, 60];
        let parts = weighted_balanced_random_partition(&items, &caps, &mut rng).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(flatten_sorted(&parts), items);
        // budgets: ⌈240·120/240⌉ = 120, ⌈240·60/240⌉ = 60
        assert!(parts[0].len() <= 120);
        assert!(parts[1].len() <= 60);
        assert!(parts[2].len() <= 60);
    }

    #[test]
    fn weighted_uniform_caps_reduce_bit_identically_to_balanced() {
        // same seed, same stream: the weighted partitioner with a
        // uniform capacity vector IS balanced_random_partition
        for &(n, l, seed) in &[(103usize, 7usize, 1u64), (64, 8, 2), (5, 10, 3), (0, 4, 4)] {
            let items: Vec<u32> = (0..n as u32).collect();
            let caps = vec![n.div_ceil(l.max(1)).max(1); l];
            let a = balanced_random_partition(&items, l, &mut Rng::seed_from(seed));
            let b = weighted_balanced_random_partition(&items, &caps, &mut Rng::seed_from(seed))
                .unwrap();
            assert_eq!(a, b, "n={n} l={l} seed={seed}");
        }
    }

    #[test]
    fn weighted_full_property_sweep_budget_multiset_determinism_uniform_reduction() {
        use crate::util::check::forall;
        forall(31, 60, |rng| {
            let l = rng.range(1, 12);
            // capacities large enough that one round can hold everything
            let caps: Vec<usize> = (0..l).map(|_| rng.range(1, 120)).collect();
            let total: usize = caps.iter().sum();
            let n = rng.range(0, total + 1);
            let dup_mod = rng.range(1, 64);
            let seed = rng.next_u64();
            (caps, n, dup_mod, seed)
        }, |(caps, n, dup_mod, seed)| {
            let items: Vec<u32> = (0..*n as u32).map(|i| i % *dup_mod as u32).collect();
            let total: usize = caps.iter().sum();
            let run = |s: u64| {
                weighted_balanced_random_partition(&items, caps, &mut Rng::seed_from(s))
                    .unwrap()
            };
            let parts = run(*seed);
            if parts.len() != caps.len() {
                return Err(format!("expected {} parts, got {}", caps.len(), parts.len()));
            }
            // (1) every part ≤ its budget ⌈N·µ_p/Σµ⌉ ≤ µ_p
            for (p, (part, &cap)) in parts.iter().zip(caps.iter()).enumerate() {
                let budget = if *n == 0 { 0 } else { (*n * cap).div_ceil(total) };
                if part.len() > budget {
                    return Err(format!("part {p} has {} > budget {budget}", part.len()));
                }
                if part.len() > cap {
                    return Err(format!("part {p} has {} > capacity {cap}", part.len()));
                }
            }
            // (2) union equals the input multiset
            let mut expected = items.clone();
            expected.sort_unstable();
            if flatten_sorted(&parts) != expected {
                return Err("union is not the input multiset".into());
            }
            // (3) seed-determinism
            if parts != run(*seed) {
                return Err("same seed produced a different partition".into());
            }
            // (4) uniform profile reduces bit-identically
            let uni = vec![caps[0]; caps.len()];
            let l = caps.len();
            let fits: usize = uni.iter().sum();
            if fits >= *n {
                let a =
                    weighted_balanced_random_partition(&items, &uni, &mut Rng::seed_from(*seed))
                        .unwrap();
                let b = balanced_random_partition(&items, l, &mut Rng::seed_from(*seed));
                if a != b {
                    return Err("uniform caps diverged from balanced_random_partition".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn weighted_contiguous_reduces_to_contiguous_for_uniform_caps() {
        let items: Vec<u32> = (0..10).collect();
        let w = weighted_contiguous_partition(&items, &[4, 4, 4]).unwrap();
        assert_eq!(w, contiguous_partition(&items, 3));
        // heterogeneous budgets chunk proportionally: ⌈10·6/12⌉=5, ⌈10·3/12⌉=3
        let h = weighted_contiguous_partition(&items, &[6, 3, 3]).unwrap();
        assert_eq!(h[0], vec![0, 1, 2, 3, 4]);
        assert_eq!(h[1], vec![5, 6, 7]);
        assert_eq!(h[2], vec![8, 9]);
        assert_eq!(flatten_sorted(&h), items);
        // the bounds helper names the identical ranges (the speculative
        // dispatcher's data-dependency map)
        let bounds = weighted_contiguous_bounds(10, &[6, 3, 3]).unwrap();
        assert_eq!(bounds, vec![(0, 5), (5, 8), (8, 10)]);
    }

    #[test]
    fn overloaded_fleet_is_a_structured_error_not_a_panic() {
        // a fleet whose total capacity drops below |A_t| mid-run
        // (scripted shrinking sim schedules, mass worker loss) must fail
        // the round with a structured error the coordinator can report —
        // the old assert! aborted the whole process
        let items: Vec<u32> = (0..10).collect();
        let mut rng = Rng::seed_from(1);
        let err =
            weighted_balanced_random_partition(&items, &[4, 3], &mut rng).unwrap_err();
        match err {
            crate::error::Error::CapacityExceeded { capacity: 7, got: 10, ctx } => {
                assert!(ctx.contains("cannot hold the surviving set"), "ctx: {ctx}");
            }
            other => panic!("wrong error: {other}"),
        }
        let err = weighted_contiguous_partition(&items, &[4, 3]).unwrap_err();
        assert!(
            matches!(err, crate::error::Error::CapacityExceeded { capacity: 7, got: 10, .. }),
            "{err}"
        );
        // empty capacity vectors are structured errors too
        assert!(weighted_balanced_labels(3, &[], &mut rng).is_err());
        assert!(weighted_contiguous_bounds(3, &[]).is_err());
        // the boundary case total == n is fine
        assert!(weighted_balanced_random_partition(&items, &[5, 5], &mut rng).is_ok());
    }

    #[test]
    fn partition_strategy_parses_and_partitions() {
        use crate::error::Error;
        assert_eq!(
            PartitionStrategy::parse("balanced").unwrap(),
            PartitionStrategy::Balanced
        );
        assert_eq!(
            PartitionStrategy::parse("contiguous").unwrap(),
            PartitionStrategy::Contiguous
        );
        assert!(matches!(PartitionStrategy::parse("iid"), Err(Error::Config(_))));
        assert!(PartitionStrategy::parse("zebra").is_err());
        assert_eq!(PartitionStrategy::Balanced.name(), "balanced");
        assert_eq!(PartitionStrategy::Contiguous.name(), "contiguous");

        // each strategy's partition matches its underlying function,
        // rng stream included
        let items: Vec<u32> = (0..40).collect();
        let caps = vec![20usize, 15, 15];
        let mut r1 = Rng::seed_from(8);
        let mut r2 = Rng::seed_from(8);
        let a = PartitionStrategy::Balanced.partition(&items, &caps, &mut r1).unwrap();
        let b = weighted_balanced_random_partition(&items, &caps, &mut r2).unwrap();
        assert_eq!(a, b);
        assert_eq!(r1.next_u64(), r2.next_u64());
        // contiguous consumes no randomness
        let mut r3 = Rng::seed_from(8);
        let c = PartitionStrategy::Contiguous.partition(&items, &caps, &mut r3).unwrap();
        assert_eq!(c, weighted_contiguous_partition(&items, &caps).unwrap());
        assert_eq!(r3.next_u64(), Rng::seed_from(8).next_u64());
    }

    #[test]
    fn full_invariant_sweep_part_ceiling_multiset_determinism() {
        use crate::util::check::forall;
        forall(29, 60, |rng| {
            let n = rng.range(0, 400);
            let l = rng.range(1, 16);
            let dup_mod = rng.range(1, 64);
            let seed = rng.next_u64();
            (n, l, dup_mod, seed)
        }, |&(n, l, dup_mod, seed)| {
            let items: Vec<u32> = (0..n as u32).map(|i| i % dup_mod as u32).collect();
            let run = |s: u64| balanced_random_partition(&items, l, &mut Rng::seed_from(s));
            let parts = run(seed);
            if parts.len() != l {
                return Err(format!("expected {l} parts, got {}", parts.len()));
            }
            // (1) every part ≤ ⌈N/L⌉
            let cap = if n == 0 { 0 } else { n.div_ceil(l) };
            if let Some(over) = parts.iter().find(|p| p.len() > cap) {
                return Err(format!("part of {} exceeds ceiling {cap}", over.len()));
            }
            // (2) union equals the input multiset
            let mut expected = items.clone();
            expected.sort_unstable();
            if flatten_sorted(&parts) != expected {
                return Err("union is not the input multiset".into());
            }
            // (3) seed-determinism
            if parts != run(seed) {
                return Err("same seed produced a different partition".into());
            }
            Ok(())
        });
    }
}

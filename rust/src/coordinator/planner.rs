//! Round planning: machine counts per round and the Proposition 3.1
//! bound on the number of rounds.

use crate::error::{Error, Result};

/// Static plan for a tree-compression run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundPlan {
    pub n: usize,
    pub k: usize,
    pub capacity: usize,
    /// Upper bound on rounds (Prop 3.1): `⌈log_{µ/k}(n/µ)⌉ + 1`.
    pub round_bound: usize,
    /// Predicted machines per round assuming worst-case compression
    /// (every machine returns exactly k items).
    pub machines_per_round: Vec<usize>,
    /// Whether the worst-case simulation reaches one machine. False when
    /// µ is so close to k that `⌈m·k/µ⌉ = m` can stall (the Prop 3.1
    /// analysis drops the ceiling; real runs still converge because
    /// machines return fewer than k items once gains saturate, and the
    /// tree runner enforces a hard round cap — see [`crate::coordinator::tree`]).
    pub worst_case_terminates: bool,
}

impl RoundPlan {
    /// Plan a run. Requires `µ > k` (otherwise a machine cannot even hold
    /// one solution's worth of items plus a candidate — the framework's
    /// standing assumption) and `µ ≥ 1`, `k ≥ 1`.
    pub fn new(n: usize, k: usize, capacity: usize) -> Result<RoundPlan> {
        if k == 0 {
            return Err(Error::invalid("k must be positive"));
        }
        if capacity <= k {
            return Err(Error::invalid(format!(
                "capacity µ={capacity} must exceed k={k} (paper assumption µ > k)"
            )));
        }
        let round_bound = round_bound(n, k, capacity);
        let mut machines = Vec::new();
        let mut remaining = n;
        let mut terminates = true;
        loop {
            let m = remaining.div_ceil(capacity).max(1);
            machines.push(m);
            if m == 1 {
                break;
            }
            let next = m * k; // worst case: every machine emits k items
            if next >= remaining {
                // ⌈m·k/µ⌉ stalls at m: the worst case never reaches one
                // machine (only possible when µ < 2k up to rounding)
                terminates = false;
                break;
            }
            remaining = next;
        }
        Ok(RoundPlan {
            n,
            k,
            capacity,
            round_bound,
            machines_per_round: machines,
            worst_case_terminates: terminates,
        })
    }

    /// Total machine-provisioning count `Σ_t m_t` (the paper's
    /// `O(n/µ)` machines claim — geometric in t).
    pub fn total_machines(&self) -> usize {
        self.machines_per_round.iter().sum()
    }

    pub fn rounds(&self) -> usize {
        self.machines_per_round.len()
    }
}

/// Proposition 3.1: `r ≤ ⌈log_{µ/k}(n/µ)⌉ + 1` for `n ≥ µ > k`;
/// 1 when `n ≤ µ`.
pub fn round_bound(n: usize, k: usize, capacity: usize) -> usize {
    if n <= capacity {
        return 1;
    }
    let ratio = (n as f64) / (capacity as f64);
    let base = (capacity as f64) / (k as f64);
    // guard: µ > k guarantees base > 1
    let r = ratio.ln() / base.ln();
    (r.ceil() as usize).max(0) + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_plan() {
        // Paper Figure 1: n = 16k, µ = 2k -> machines 8, 4, 2, 1 (4 rounds)
        let k = 64;
        let plan = RoundPlan::new(16 * k, k, 2 * k).unwrap();
        assert_eq!(plan.machines_per_round, vec![8, 4, 2, 1]);
        assert_eq!(plan.rounds(), 4);
        assert!(plan.rounds() <= plan.round_bound);
    }

    #[test]
    fn single_round_when_capacity_sufficient() {
        let plan = RoundPlan::new(100, 10, 200).unwrap();
        assert_eq!(plan.machines_per_round, vec![1]);
        assert_eq!(plan.round_bound, 1);
    }

    #[test]
    fn two_rounds_at_sqrt_nk() {
        // µ = sqrt(nk): the classic two-round regime
        let (n, k) = (10_000usize, 25usize);
        let mu = ((n * k) as f64).sqrt() as usize; // 500
        let plan = RoundPlan::new(n, k, mu).unwrap();
        assert_eq!(plan.rounds(), 2, "machines: {:?}", plan.machines_per_round);
        assert!(plan.round_bound >= 2);
    }

    #[test]
    fn round_bound_formula_spot_checks() {
        // n=1024, µ=64, k=16: log_4(16) = 2 -> r ≤ 3
        assert_eq!(round_bound(1024, 16, 64), 3);
        // n ≤ µ
        assert_eq!(round_bound(50, 10, 64), 1);
        // barely multi-round
        assert_eq!(round_bound(65, 10, 64), 2);
    }

    #[test]
    fn rejects_capacity_not_above_k() {
        assert!(RoundPlan::new(100, 10, 10).is_err());
        assert!(RoundPlan::new(100, 10, 5).is_err());
        assert!(RoundPlan::new(100, 0, 50).is_err());
    }

    #[test]
    fn planned_rounds_respect_bound_property() {
        use crate::util::check::forall;
        // µ ≥ 2k: the worst case provably converges (⌈m·k/µ⌉ ≤ ⌈m/2⌉ < m)
        forall(13, 100, |rng| {
            let k = rng.range(1, 64);
            let mu = 2 * k + rng.range(0, 512);
            let n = rng.range(1, 100_000);
            (n, k, mu)
        }, |&(n, k, mu)| {
            let plan = RoundPlan::new(n, k, mu).map_err(|e| e.to_string())?;
            if !plan.worst_case_terminates {
                return Err(format!("stalled with mu={mu} >= 2k={k}"));
            }
            // Prop 3.1 drops the ⌈·⌉ of the partition, which can cost a
            // couple of extra rounds in the true worst case — allow +2.
            if plan.rounds() > plan.round_bound + 2 {
                return Err(format!(
                    "rounds {} > bound {} + 2 for n={n} k={k} mu={mu}",
                    plan.rounds(),
                    plan.round_bound
                ));
            }
            // machine sequence strictly decreasing until 1
            for w in plan.machines_per_round.windows(2) {
                if w[1] >= w[0] {
                    return Err(format!("non-decreasing machines {:?}", plan.machines_per_round));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn stall_detected_when_capacity_barely_above_k() {
        // µ = k+1, large n: ⌈m·k/µ⌉ = m for m ≥ k — worst case stalls.
        let plan = RoundPlan::new(10_000, 10, 11).unwrap();
        assert!(!plan.worst_case_terminates);
        // formula bound still finite
        assert!(plan.round_bound > 0);
    }
}

//! Round planning: machine counts per round and the Proposition 3.1
//! bound on the number of rounds — for the paper's uniform fleet and
//! for heterogeneous [`CapacityProfile`]s.

use crate::coordinator::capacity::CapacityProfile;
use crate::error::{Error, Result};

/// Static plan for a tree-compression run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundPlan {
    pub n: usize,
    pub k: usize,
    /// Effective per-machine capacity governing the round bound: µ for a
    /// uniform fleet, the mean class capacity `⌊Σµ_p/L⌋` for a
    /// heterogeneous one (every prefix of the descending-sorted cyclic
    /// profile averages at least this much — see
    /// [`CapacityProfile::effective_capacity`]).
    pub capacity: usize,
    /// The fleet this plan was computed against.
    pub profile: CapacityProfile,
    /// Upper bound on rounds (Prop 3.1): `⌈log_{µ/k}(n/µ)⌉ + 1` at the
    /// effective µ.
    pub round_bound: usize,
    /// Predicted machines per round assuming worst-case compression
    /// (every machine returns exactly k items). Heterogeneous fleets
    /// use the smallest covering prefix of the sorted profile per round.
    pub machines_per_round: Vec<usize>,
    /// Whether the worst-case simulation reaches one machine. False when
    /// µ is so close to k that `⌈m·k/µ⌉ = m` can stall (the Prop 3.1
    /// analysis drops the ceiling; real runs still converge because
    /// machines return fewer than k items once gains saturate, and the
    /// tree runner enforces a hard round cap — see [`crate::coordinator::tree`]).
    pub worst_case_terminates: bool,
}

impl RoundPlan {
    /// Plan a run on the paper's uniform fleet. Requires `µ > k`
    /// (otherwise a machine cannot even hold one solution's worth of
    /// items plus a candidate — the framework's standing assumption)
    /// and `µ ≥ 1`, `k ≥ 1`.
    ///
    /// ```
    /// use hss::coordinator::RoundPlan;
    ///
    /// // Paper Figure 1: n = 16k, µ = 2k → machines 8, 4, 2, 1
    /// let k = 64;
    /// let plan = RoundPlan::new(16 * k, k, 2 * k).unwrap();
    /// assert_eq!(plan.machines_per_round, vec![8, 4, 2, 1]);
    /// assert!(plan.rounds() <= plan.round_bound);
    ///
    /// // µ must exceed k
    /// assert!(RoundPlan::new(100, 10, 10).is_err());
    /// ```
    pub fn new(n: usize, k: usize, capacity: usize) -> Result<RoundPlan> {
        Self::for_profile(n, k, &CapacityProfile::uniform(capacity))
    }

    /// Plan a run on a heterogeneous fleet. Every capacity class must
    /// exceed k; each round uses the smallest prefix of the cyclic
    /// descending profile whose total capacity covers the surviving
    /// items ([`CapacityProfile::machines_for`]), and the round bound
    /// is Prop 3.1 evaluated at the effective (mean-class) µ, which
    /// lower-bounds every prefix's average capacity.
    pub fn for_profile(n: usize, k: usize, profile: &CapacityProfile) -> Result<RoundPlan> {
        if k == 0 {
            return Err(Error::invalid("k must be positive"));
        }
        if profile.min_capacity() <= k {
            return Err(Error::invalid(format!(
                "capacity µ={} must exceed k={k} (paper assumption µ > k; \
                 profile {profile})",
                profile.min_capacity()
            )));
        }
        let round_bound = round_bound_for(n, k, profile);
        let mut machines = Vec::new();
        let mut remaining = n;
        let mut terminates = true;
        loop {
            let m = profile.machines_for(remaining);
            machines.push(m);
            if m == 1 {
                break;
            }
            let next = m * k; // worst case: every machine emits k items
            if next >= remaining {
                // the machine count stalls: the worst case never reaches
                // one machine (only possible when µ is close to k)
                terminates = false;
                break;
            }
            remaining = next;
        }
        Ok(RoundPlan {
            n,
            k,
            capacity: profile.effective_capacity(),
            profile: profile.clone(),
            round_bound,
            machines_per_round: machines,
            worst_case_terminates: terminates,
        })
    }

    /// Total machine-provisioning count `Σ_t m_t` (the paper's
    /// `O(n/µ)` machines claim — geometric in t).
    pub fn total_machines(&self) -> usize {
        self.machines_per_round.iter().sum()
    }

    pub fn rounds(&self) -> usize {
        self.machines_per_round.len()
    }
}

/// Prop 3.1 round bound for a heterogeneous fleet: 1 when the largest
/// machine holds everything, otherwise [`round_bound`] at the effective
/// (mean-class) capacity.
pub fn round_bound_for(n: usize, k: usize, profile: &CapacityProfile) -> usize {
    if n <= profile.max_capacity() {
        return 1;
    }
    round_bound(n, k, profile.effective_capacity())
}

/// Proposition 3.1: `r ≤ ⌈log_{µ/k}(n/µ)⌉ + 1` for `n ≥ µ > k`;
/// 1 when `n ≤ µ` (the single-round case — one machine holds
/// everything, no logarithm involved).
///
/// Outside the framework's standing assumption `µ > k` the geometric
/// decay argument collapses (the log base is ≤ 1, driving `r` negative,
/// infinite or NaN); [`RoundPlan`] rejects that regime up front, and
/// this standalone helper returns the trivial ceiling `max(n, 1)`
/// instead of laundering a NaN through a saturating float cast.
pub fn round_bound(n: usize, k: usize, capacity: usize) -> usize {
    if n <= capacity {
        // n ≤ µ: explicitly one round — never reaches the formula, so
        // `ratio < 1` can't drive r negative
        return 1;
    }
    if k == 0 || capacity <= k {
        // µ ≤ k (or k = 0): Prop 3.1 does not apply; machines cannot
        // shrink the surviving set geometrically
        return n.max(1);
    }
    let ratio = (n as f64) / (capacity as f64); // > 1 here
    let base = (capacity as f64) / (k as f64); // > 1 here
    let r = ratio.ln() / base.ln(); // finite, > 0
    r.ceil() as usize + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_plan() {
        // Paper Figure 1: n = 16k, µ = 2k -> machines 8, 4, 2, 1 (4 rounds)
        let k = 64;
        let plan = RoundPlan::new(16 * k, k, 2 * k).unwrap();
        assert_eq!(plan.machines_per_round, vec![8, 4, 2, 1]);
        assert_eq!(plan.rounds(), 4);
        assert!(plan.rounds() <= plan.round_bound);
    }

    #[test]
    fn single_round_when_capacity_sufficient() {
        let plan = RoundPlan::new(100, 10, 200).unwrap();
        assert_eq!(plan.machines_per_round, vec![1]);
        assert_eq!(plan.round_bound, 1);
    }

    #[test]
    fn two_rounds_at_sqrt_nk() {
        // µ = sqrt(nk): the classic two-round regime
        let (n, k) = (10_000usize, 25usize);
        let mu = ((n * k) as f64).sqrt() as usize; // 500
        let plan = RoundPlan::new(n, k, mu).unwrap();
        assert_eq!(plan.rounds(), 2, "machines: {:?}", plan.machines_per_round);
        assert!(plan.round_bound >= 2);
    }

    #[test]
    fn round_bound_formula_spot_checks() {
        // n=1024, µ=64, k=16: log_4(16) = 2 -> r ≤ 3
        assert_eq!(round_bound(1024, 16, 64), 3);
        // n ≤ µ
        assert_eq!(round_bound(50, 10, 64), 1);
        // barely multi-round
        assert_eq!(round_bound(65, 10, 64), 2);
    }

    #[test]
    fn round_bound_boundaries_are_explicit() {
        // the n ≤ µ single-round boundary, exactly at and around µ
        assert_eq!(round_bound(64, 10, 64), 1);
        assert_eq!(round_bound(1, 10, 64), 1);
        assert_eq!(round_bound(0, 10, 64), 1);
        // µ = k and µ < k: outside Prop 3.1 — trivial finite ceiling,
        // never a NaN-driven cast (the old `.max(0)` on usize was dead
        // code papering over exactly this)
        assert_eq!(round_bound(100, 10, 10), 100);
        assert_eq!(round_bound(100, 50, 20), 100);
        // k = 0 is degenerate the same way
        assert_eq!(round_bound(100, 0, 10), 100);
        // µ = k+1 (the smallest valid margin) still uses the formula
        let b = round_bound(10_000, 10, 11);
        assert!(b >= 2 && b < usize::MAX, "bound {b}");
        // monotone-ish sanity: more capacity never raises the bound
        assert!(round_bound(10_000, 10, 100) >= round_bound(10_000, 10, 1000));
    }

    #[test]
    fn rejects_capacity_not_above_k() {
        assert!(RoundPlan::new(100, 10, 10).is_err());
        assert!(RoundPlan::new(100, 10, 5).is_err());
        assert!(RoundPlan::new(100, 0, 50).is_err());
    }

    #[test]
    fn planned_rounds_respect_bound_property() {
        use crate::util::check::forall;
        // µ ≥ 2k: the worst case provably converges (⌈m·k/µ⌉ ≤ ⌈m/2⌉ < m)
        forall(13, 100, |rng| {
            let k = rng.range(1, 64);
            let mu = 2 * k + rng.range(0, 512);
            let n = rng.range(1, 100_000);
            (n, k, mu)
        }, |&(n, k, mu)| {
            let plan = RoundPlan::new(n, k, mu).map_err(|e| e.to_string())?;
            if !plan.worst_case_terminates {
                return Err(format!("stalled with mu={mu} >= 2k={k}"));
            }
            // Prop 3.1 drops the ⌈·⌉ of the partition, which can cost a
            // couple of extra rounds in the true worst case — allow +2.
            if plan.rounds() > plan.round_bound + 2 {
                return Err(format!(
                    "rounds {} > bound {} + 2 for n={n} k={k} mu={mu}",
                    plan.rounds(),
                    plan.round_bound
                ));
            }
            // machine sequence strictly decreasing until 1
            for w in plan.machines_per_round.windows(2) {
                if w[1] >= w[0] {
                    return Err(format!("non-decreasing machines {:?}", plan.machines_per_round));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn heterogeneous_plan_uses_covering_prefixes_of_the_sorted_profile() {
        // profile 120,60,60 cycling; n=480, k=10.
        // round 1: prefix sums 120,180,240,360,420,480 → 6 machines
        // round 2: 60 items → largest machine holds them → 1 machine
        let profile = CapacityProfile::parse("120,60,60").unwrap();
        let plan = RoundPlan::for_profile(480, 10, &profile).unwrap();
        assert_eq!(plan.machines_per_round, vec![6, 1]);
        assert_eq!(plan.capacity, 80, "effective µ is the mean class capacity");
        assert!(plan.rounds() <= plan.round_bound + 2);
        assert!(plan.worst_case_terminates);
    }

    #[test]
    fn uniform_profile_plan_matches_scalar_plan_exactly() {
        for &(n, k, mu) in &[(16 * 64usize, 64usize, 128usize), (10_000, 25, 500), (50, 10, 64)] {
            let scalar = RoundPlan::new(n, k, mu).unwrap();
            let profiled =
                RoundPlan::for_profile(n, k, &CapacityProfile::uniform(mu)).unwrap();
            assert_eq!(scalar, profiled);
            assert_eq!(scalar.capacity, mu);
        }
    }

    #[test]
    fn profile_with_a_class_not_above_k_is_rejected() {
        let p = CapacityProfile::parse("500,200,10").unwrap();
        let err = RoundPlan::for_profile(1000, 10, &p).unwrap_err();
        assert!(err.to_string().contains("must exceed k"), "{err}");
        // the same classes all above k are fine
        let p = CapacityProfile::parse("500,200,11").unwrap();
        assert!(RoundPlan::for_profile(1000, 10, &p).is_ok());
    }

    #[test]
    fn single_round_when_largest_machine_holds_everything() {
        // effective µ (mean) is 173 < n, but the largest class covers n
        let p = CapacityProfile::parse("400,60,60").unwrap();
        let plan = RoundPlan::for_profile(380, 10, &p).unwrap();
        assert_eq!(plan.machines_per_round, vec![1]);
        assert_eq!(plan.round_bound, 1);
    }

    #[test]
    fn stall_detected_when_capacity_barely_above_k() {
        // µ = k+1, large n: ⌈m·k/µ⌉ = m for m ≥ k — worst case stalls.
        let plan = RoundPlan::new(10_000, 10, 11).unwrap();
        assert!(!plan.worst_case_terminates);
        // formula bound still finite
        assert!(plan.round_bound > 0);
    }
}

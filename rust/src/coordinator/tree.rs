//! Algorithm 1: TREE-BASED COMPRESSION — the paper's framework.
//!
//! Maintains the surviving item set `A_t`; each round randomly partitions
//! `A_t` across `m_t = ⌈|A_t|/µ⌉` fixed-capacity machines, compresses
//! every part to ≤ k items with the β-nice algorithm, and unions the
//! partial solutions into `A_{t+1}`. Returns the best partial solution
//! observed anywhere (strictly-greater update, Algorithm 1 line 11).
//!
//! Heterogeneous fleets generalize the scalar µ: the backend's
//! [`CapacityProfile`] sizes each round's parts to the machine classes
//! that execute them (`m_t` = smallest covering prefix of the sorted
//! cyclic profile, weighted balanced random partition — see
//! [`crate::coordinator::capacity`]). The profile is re-queried every
//! round, so a fleet that shrinks mid-run (scripted via
//! [`crate::dist::SimBackend`] capacity schedules) is re-planned
//! against the machines that remain.
//!
//! ## Pipelined rounds and speculative dispatch
//!
//! [`TreeRunner::run`] drives rounds through the streaming
//! [`Backend::open_round`] API: partial solutions union into
//! `A_{t+1}` **as they arrive**, and — when every machine's output size
//! is predictable up front (plain cardinality constraint and a
//! fill-to-k compressor, the paper's default setting) — the next
//! round's [`RoundPlan`] and weighted partition are drawn the moment
//! round `t` is submitted, then *filled in* item-by-item as parts
//! complete. By the time the round's last straggler reports, round
//! `t+1` is fully partitioned and is submitted immediately; on the TCP
//! backend its parts reach already-idle persistent dispatchers with no
//! thread teardown or re-handshake in between.
//!
//! Under the **contiguous** partitioner
//! ([`PartitionStrategy::Contiguous`] — GreeDI-style locality-aware
//! sharding), the runner goes one step further: a next-round part's
//! input ids are fully known the moment its *contributing* current
//! parts complete (contiguous bounds map each next part to a window of
//! current parts), so straggler-independent next-round parts are
//! **speculatively dispatched** into an early-opened [`RoundSession`]
//! while the current round's stragglers are still running. Under the
//! paper's balanced random partition nearly every next part draws
//! items from every current part, so speculation there only
//! *prepares* the partition (the PR-4 analysis: dispatch is low-value
//! for balanced, high-value for contiguous).
//!
//! A size misprediction (greedy saturating below k) is detected per
//! part, the speculative session is aborted, and the partition is
//! recomputed from the untouched rng state — so pipelining and
//! speculation are **bit-identical** to the serial barrier path
//! ([`TreeRunner::run_serial`]) on every backend, for both
//! partitioners. Overlap changes wall-clock (reported per round as
//! [`RoundMetrics::straggler_overlap_ms`]), never the answer.

use std::sync::Arc;
use std::time::Instant;

use crate::algorithms::{Compressor, LazyGreedy, Solution};
use crate::constraints::spec::ConstraintSpec;
use crate::coordinator::capacity::CapacityProfile;
use crate::coordinator::metrics::{Metrics, RoundMetrics};
use crate::coordinator::partitioner::{self, PartitionStrategy};
use crate::coordinator::planner::RoundPlan;
use crate::dist::{Backend, LocalBackend, PartEvent, RoundSession};
use crate::error::{Error, Result};
use crate::objectives::Problem;
use crate::trace;
use crate::util::rng::Rng;

/// Builder for [`TreeRunner`].
pub struct TreeBuilder {
    profile: CapacityProfile,
    compressor: Arc<dyn Compressor>,
    partition_mode: PartitionStrategy,
    threads: Option<usize>,
    backend: Option<Arc<dyn Backend>>,
}

impl TreeBuilder {
    /// Start a builder with uniform machine capacity µ and the default
    /// compressor (pure lazy GREEDY).
    pub fn new(capacity: usize) -> Self {
        Self::for_profile(CapacityProfile::uniform(capacity))
    }

    /// Start a builder for a heterogeneous fleet: parts are sized to the
    /// profile's machine classes by the weighted partitioner.
    pub fn for_profile(profile: CapacityProfile) -> Self {
        TreeBuilder {
            profile,
            compressor: Arc::new(LazyGreedy::new()),
            partition_mode: PartitionStrategy::Balanced,
            threads: None,
            backend: None,
        }
    }

    /// Override the fleet profile (ignored when an explicit backend is
    /// installed — the backend's own profile is authoritative).
    pub fn capacity_profile(mut self, profile: CapacityProfile) -> Self {
        self.profile = profile;
        self
    }

    pub fn compressor(mut self, c: Arc<dyn Compressor>) -> Self {
        self.compressor = c;
        self
    }

    /// Partition strategy for every round (`--partitioner`): the
    /// paper's balanced random partition, or the contiguous
    /// locality-aware strategy that unlocks speculative next-round
    /// dispatch.
    pub fn partition_mode(mut self, m: PartitionStrategy) -> Self {
        self.partition_mode = m;
        self
    }

    /// Worker-thread count for the default [`LocalBackend`] (ignored
    /// when an explicit backend is installed).
    pub fn threads(mut self, t: usize) -> Self {
        self.threads = Some(t);
        self
    }

    /// Execute rounds on an explicit backend (tcp workers, fault
    /// simulator, …). The backend's capacity µ becomes authoritative
    /// for round planning so enforcement and planning can never drift.
    pub fn backend(mut self, b: Arc<dyn Backend>) -> Self {
        self.backend = Some(b);
        self
    }

    pub fn build(self) -> TreeRunner {
        let backend: Arc<dyn Backend> = match self.backend {
            Some(b) => b,
            None => {
                let mut local = LocalBackend::with_profile(self.profile);
                if let Some(t) = self.threads {
                    local = local.with_threads(t);
                }
                Arc::new(local)
            }
        };
        TreeRunner {
            capacity: backend.capacity(),
            compressor: self.compressor,
            partition_mode: self.partition_mode,
            backend,
        }
    }
}

/// Result of one tree-compression run.
#[derive(Debug)]
pub struct TreeResult {
    pub best: Solution,
    /// Best solution produced in the *final* round only (what a
    /// framework without Algorithm 1's line-11 best-tracking would
    /// return) — exposed for the best-tracking ablation.
    pub final_round_best: Solution,
    pub rounds: usize,
    /// Prop 3.1 bound for this (n, k, µ).
    pub round_bound: usize,
    pub oracle_evals: u64,
    pub per_round: Vec<RoundMetrics>,
    pub total_machines: u64,
    /// Parts re-executed after a machine loss (0 on a healthy backend).
    pub requeued_parts: u64,
    /// Item-id bytes moved over the coordinator↔machine boundary (the
    /// wire ships ids, never rows — see [`RoundMetrics::bytes_shuffled`]).
    pub bytes_shuffled: u64,
    /// Feature-row bytes resident across machines, summed over rounds.
    pub rows_resident_bytes: u64,
    /// Straggler tail overlapped by the pipelined event loop, summed
    /// over rounds (see [`RoundMetrics::straggler_overlap_ms`]; 0 on
    /// the serial path).
    pub straggler_overlap_ms: f64,
    /// Problem-spec bytes shipped over the wire, summed over rounds
    /// (protocol v4 interning: after round 0, compress requests carry
    /// an O(1) problem id — see [`RoundMetrics::spec_bytes`]).
    pub spec_bytes: u64,
    pub wall_ms: f64,
}

/// Algorithm 1 line 11 round-best selection: NaN-safe total order
/// (`f64::total_cmp`) with strictly-greater updates, so ties keep the
/// *first* maximum (lowest part index) and the choice never depends on
/// machine completion order — and a NaN objective value surfaces in the
/// result instead of panicking the coordinator. Shared with the
/// two-round baselines, which face the same worker-returned values.
pub(crate) fn round_best_of(sols: &[Solution]) -> Solution {
    let mut best: Option<&Solution> = None;
    for s in sols {
        let better = match best {
            None => true,
            Some(b) => s.value.total_cmp(&b.value) == std::cmp::Ordering::Greater,
        };
        if better {
            best = Some(s);
        }
    }
    best.cloned().unwrap_or_default()
}

/// Algorithm 1 runner.
pub struct TreeRunner {
    /// Largest machine capacity of the backend's fleet at build time
    /// (convenience only — planning and partitioning always use the
    /// backend's full, per-round [`CapacityProfile`], so on a
    /// heterogeneous fleet this is µ_max, not every machine's size).
    pub capacity: usize,
    compressor: Arc<dyn Compressor>,
    partition_mode: PartitionStrategy,
    backend: Arc<dyn Backend>,
}

/// The upcoming round, as far as the previous round's pipelined event
/// loop got it while stragglers were still running.
enum Upcoming {
    /// Fully partitioned, not yet submitted (balanced speculation, or
    /// contiguous speculation whose session could not open).
    Planned { machines: usize, parts: Vec<Vec<u32>>, round_seed: u64 },
    /// Partially **dispatched**: a streaming session is open and the
    /// first `submitted` parts are already executing on the backend
    /// (contiguous speculation — parts whose inputs were complete
    /// before the previous round's stragglers finished).
    InFlight {
        session: RoundSession,
        machines: usize,
        parts: Vec<Vec<u32>>,
        submitted: usize,
        round_seed: u64,
    },
}

/// In-flight next-round speculation: the size of every machine's output
/// is predicted up front (`min(k, |part|)` — exact for fill-to-k
/// compressors under a plain cardinality constraint unless gains
/// saturate), which pins `|A_{t+1}|` and therefore the next round's
/// machine count, partition labels and positions before a single part
/// has completed. Completed parts scatter their items straight into the
/// pre-sized next-round parts; a size misprediction kills the
/// speculation (the master rng was never touched, so the honest
/// recomputation is bit-identical to the serial path).
///
/// Works for both first-class strategies: the balanced labels are drawn
/// from a clone of the master rng, the contiguous "labels" are the
/// deterministic chunk bounds (no randomness at all) — which is why
/// contiguous next parts additionally have a *known dependency window*:
/// `filled[p]` reaching the part's size certifies every input of next
/// part `p` is final, making it safe to dispatch while the current
/// round still runs.
struct Speculation {
    /// Predicted output size per current-round part.
    expected: Vec<usize>,
    /// Global index of part `j`'s first output item in `A_{t+1}`
    /// (part-order concatenation).
    offsets: Vec<usize>,
    /// Global index → next-round part (the partitioner's labels).
    labels: Vec<u32>,
    /// Global index → slot within its next-round part (input order —
    /// identical to what `apply_labels` would produce).
    pos: Vec<usize>,
    machines: usize,
    next_parts: Vec<Vec<u32>>,
    /// Items placed so far per next-round part; `filled[p] ==
    /// next_parts[p].len()` certifies part `p`'s contents are final.
    filled: Vec<usize>,
    /// Next-round parts already streamed into the speculative session
    /// (sessions index parts by submission order, so dispatch proceeds
    /// strictly front-to-back over the ready prefix).
    next_submitted: usize,
    round_seed: u64,
    /// Master-rng state after this round's draws — adopted on success.
    rng_after: Rng,
}

impl Speculation {
    fn build(
        strategy: PartitionStrategy,
        current_parts: &[Vec<u32>],
        k_eff: usize,
        profile: &CapacityProfile,
        rng: &Rng,
    ) -> Option<Speculation> {
        let expected: Vec<usize> =
            current_parts.iter().map(|p| p.len().min(k_eff)).collect();
        let n_next: usize = expected.iter().sum();
        if n_next == 0 {
            return None;
        }
        let machines = profile.machines_for(n_next);
        let caps = profile.round_caps(machines);
        let mut rng_next = rng.clone();
        let labels: Vec<u32> = match strategy {
            PartitionStrategy::Balanced => {
                // a fleet that cannot hold the predicted set: let the
                // honest path surface the structured error
                match partitioner::weighted_balanced_labels(n_next, &caps, &mut rng_next) {
                    Ok(l) => l,
                    Err(_) => return None,
                }
            }
            PartitionStrategy::Contiguous => {
                let bounds = match partitioner::weighted_contiguous_bounds(n_next, &caps) {
                    Ok(b) => b,
                    Err(_) => return None,
                };
                let mut labels = vec![0u32; n_next];
                for (p, (lo, hi)) in bounds.into_iter().enumerate() {
                    for l in &mut labels[lo..hi] {
                        *l = p as u32;
                    }
                }
                labels
            }
            PartitionStrategy::Iid => return None,
        };
        let round_seed = rng_next.next_u64();
        let mut sizes = vec![0usize; machines];
        let mut pos = Vec::with_capacity(n_next);
        for &l in &labels {
            pos.push(sizes[l as usize]);
            sizes[l as usize] += 1;
        }
        let next_parts: Vec<Vec<u32>> = sizes.iter().map(|&s| vec![0u32; s]).collect();
        let mut offsets = Vec::with_capacity(expected.len());
        let mut acc = 0usize;
        for &e in &expected {
            offsets.push(acc);
            acc += e;
        }
        Some(Speculation {
            expected,
            offsets,
            labels,
            pos,
            machines,
            next_parts,
            filled: vec![0usize; machines],
            next_submitted: 0,
            round_seed,
            rng_after: rng_next,
        })
    }

    /// Scatter one completed part's items into the pre-sized next-round
    /// parts. Returns `false` (speculation dead) if the part's size
    /// missed the prediction.
    fn place(&mut self, part: usize, items: &[u32]) -> bool {
        if items.len() != self.expected[part] {
            return false;
        }
        let off = self.offsets[part];
        for (d, &item) in items.iter().enumerate() {
            let g = off + d;
            let p = self.labels[g] as usize;
            self.next_parts[p][self.pos[g]] = item;
            self.filled[p] += 1;
        }
        true
    }

    /// Stream every *ready* next-round part (contents certified final,
    /// and everything before it already streamed) into the speculative
    /// session. Returns `false` if the session refused a part —
    /// speculation dies and the honest path takes over.
    fn dispatch_ready(&mut self, session: &mut RoundSession) -> bool {
        while self.next_submitted < self.machines
            && self.filled[self.next_submitted] == self.next_parts[self.next_submitted].len()
        {
            let part = self.next_parts[self.next_submitted].clone();
            if session.submit_part(part).is_err() {
                return false;
            }
            self.next_submitted += 1;
        }
        true
    }
}

impl TreeRunner {
    /// Run on the problem's full ground set — pipelined: rounds are
    /// consumed event-by-event and the next round is pre-computed while
    /// stragglers finish. Bit-identical to [`TreeRunner::run_serial`].
    pub fn run(&self, problem: &Problem, seed: u64) -> Result<TreeResult> {
        let all: Vec<u32> = (0..problem.n() as u32).collect();
        self.run_on(problem, all, seed)
    }

    /// Serial reference path: every round goes through the blocking
    /// [`Backend::run_round`] barrier and all post-processing happens
    /// after it. Kept for the dispatch bench and the bit-identity
    /// regression suite.
    pub fn run_serial(&self, problem: &Problem, seed: u64) -> Result<TreeResult> {
        let all: Vec<u32> = (0..problem.n() as u32).collect();
        self.run_on_serial(problem, all, seed)
    }

    /// Pipelined run on an explicit starting set `A_0` (used by tests
    /// and by the baselines that embed a tree run).
    pub fn run_on(&self, problem: &Problem, a0: Vec<u32>, seed: u64) -> Result<TreeResult> {
        self.run_inner(problem, a0, seed, true)
    }

    /// Serial-barrier run on an explicit starting set `A_0`.
    pub fn run_on_serial(
        &self,
        problem: &Problem,
        a0: Vec<u32>,
        seed: u64,
    ) -> Result<TreeResult> {
        self.run_inner(problem, a0, seed, false)
    }

    /// `true` when every machine's output size is predictable up front:
    /// a fill-to-k compressor under the plain cardinality constraint.
    /// Gates next-round speculation; mispredictions are still handled.
    fn sizes_predictable(&self, problem: &Problem) -> bool {
        self.compressor.full_k()
            && matches!(
                problem.constraint.wire_spec(),
                Some(ConstraintSpec::Cardinality { .. })
            )
    }

    fn run_inner(
        &self,
        problem: &Problem,
        a0: Vec<u32>,
        seed: u64,
        pipelined: bool,
    ) -> Result<TreeResult> {
        // validates µ > k for every machine class up front
        let plan = RoundPlan::for_profile(a0.len(), problem.k, &self.backend.profile())?;
        let bound = plan.round_bound;
        let k_eff = problem.k.min(problem.constraint.max_cardinality());
        let speculate = pipelined
            && self.sizes_predictable(problem)
            && matches!(
                self.partition_mode,
                PartitionStrategy::Balanced | PartitionStrategy::Contiguous
            );
        // Speculative *dispatch* (not just preparation) pays off when a
        // next part's inputs come from a window of current parts — the
        // contiguous regime. Under balanced random nearly every next
        // part draws items from every current part, so dispatch would
        // start ~nothing early; the partition is still pre-computed.
        let dispatch_speculatively =
            speculate && self.partition_mode == PartitionStrategy::Contiguous;

        let metrics = Metrics::new();
        let mut rng = Rng::seed_from(seed ^ 0x7EE5_EED5);
        let mut a = a0;
        let mut best = Solution::empty();
        // reassigned every round; only the last round's value is read
        #[allow(unused_assignments)]
        let mut final_round_best: Option<Solution> = None;
        let evals_before = problem.eval_count();
        let t_start = Instant::now();
        let mut sim_delay_ms = 0.0f64;
        let mut overlap_total = 0.0f64;
        let mut round = 0usize;
        // next round, as far as the previous round's overlap window got it
        let mut prepared: Option<Upcoming> = None;

        loop {
            // Re-query the fleet every round: a scripted backend (sim
            // capacity schedules) may shrink or reshape it mid-run, and
            // parts must be sized to the machines that will execute
            // them. (A prepared round queried the identical profile —
            // the schedule only advances when a round is sealed.)
            let (m_t, parts, round_seed, early_handle) = match prepared.take() {
                Some(Upcoming::Planned { machines, parts, round_seed }) => {
                    (machines, parts, round_seed, None)
                }
                Some(Upcoming::InFlight {
                    mut session,
                    machines,
                    parts,
                    submitted,
                    round_seed,
                }) => {
                    // the previous round completed, so every remaining
                    // part's contents are final: stream them and seal
                    for part in parts.iter().skip(submitted) {
                        session.submit_part(part.clone())?;
                    }
                    let handle = session.close()?;
                    (machines, parts, round_seed, Some(handle))
                }
                None => {
                    let profile = self.backend.profile();
                    let m_t = profile.machines_for(a.len());
                    let caps = profile.round_caps(m_t);
                    let parts = self.partition_mode.partition(&a, &caps, &mut rng)?;
                    let round_seed = rng.next_u64();
                    (m_t, parts, round_seed, None)
                }
            };
            let r_start = Instant::now();
            let r_trace_start = trace::now_us();
            // per-round oracle attribution: the shared counter's delta
            // over the round's event window (remote evals fold in
            // before each Done, so the delta is backend-agnostic)
            let evals_round_start = problem.eval_count();

            let mut slots: Vec<Option<Solution>> = vec![None; m_t];
            let mut requeued_parts = 0usize;
            let mut requeued_ids = 0usize;
            let mut round_delay = 0.0f64;
            let mut overlap_ms = 0.0f64;
            let mut round_spec_bytes = 0u64;

            if pipelined {
                let mut handle = match early_handle {
                    Some(h) => h,
                    None => self.backend.submit_round(
                        problem,
                        self.compressor.as_ref(),
                        &parts,
                        round_seed,
                    )?,
                };
                // Overlap window: with the round in flight and sizes
                // predictable, draw the next round's plan + partition
                // NOW (from a clone — the master rng stays untouched
                // until the prediction is verified). The fleet profile
                // for round t+1 is already observable: schedules
                // advance when a round is sealed.
                let mut spec: Option<Speculation> = if speculate && m_t > 1 {
                    Speculation::build(
                        self.partition_mode,
                        &parts,
                        k_eff,
                        &self.backend.profile(),
                        &rng,
                    )
                } else {
                    None
                };
                if spec.is_some() && trace::enabled() {
                    trace::instant(
                        trace::COORDINATOR_TRACK,
                        "spec.begin",
                        vec![("round", trace::ArgValue::U64(round as u64))],
                    );
                }
                // Contiguous: open the next round's streaming session
                // NOW, so straggler-independent next parts execute while
                // this round's stragglers are still running. If the
                // session cannot open, fall back to prepare-only.
                let mut next_session: Option<RoundSession> = None;
                let mut kill_spec = false;
                if dispatch_speculatively {
                    if let Some(s) = spec.as_mut() {
                        if let Ok(mut sess) = self.backend.open_round(
                            problem,
                            self.compressor.as_ref(),
                            s.round_seed,
                        ) {
                            // zero-size next parts are ready immediately
                            if s.dispatch_ready(&mut sess) {
                                next_session = Some(sess);
                            } else {
                                kill_spec = true; // sess drops → aborted
                            }
                        }
                    }
                }
                if kill_spec {
                    spec = None;
                    if trace::enabled() {
                        trace::instant(
                            trace::COORDINATOR_TRACK,
                            "spec.recompute",
                            vec![("round", trace::ArgValue::U64(round as u64))],
                        );
                    }
                }
                let mut first_done: Option<Instant> = None;
                while let Some(ev) = handle.next_event() {
                    match ev? {
                        PartEvent::Done { part, solution } => {
                            if first_done.is_none() {
                                first_done = Some(Instant::now());
                            }
                            let mut dead = false;
                            if let Some(s) = spec.as_mut() {
                                if !s.place(part, &solution.items) {
                                    dead = true;
                                } else if let Some(sess) = next_session.as_mut() {
                                    // stream next parts whose inputs
                                    // just became final
                                    if !s.dispatch_ready(sess) {
                                        dead = true;
                                    }
                                }
                            }
                            if dead {
                                // misprediction: recompute honestly at
                                // the loop top from the master rng; the
                                // dropped session aborts, discarding any
                                // speculatively dispatched parts
                                spec = None;
                                next_session = None;
                                if trace::enabled() {
                                    trace::instant(
                                        trace::COORDINATOR_TRACK,
                                        "spec.recompute",
                                        vec![
                                            ("round", trace::ArgValue::U64(round as u64)),
                                            ("part", trace::ArgValue::U64(part as u64)),
                                        ],
                                    );
                                }
                            }
                            slots[part] = Some(solution);
                        }
                        PartEvent::Requeued { reshipped_ids, .. } => {
                            requeued_parts += 1;
                            requeued_ids += reshipped_ids;
                        }
                        PartEvent::Delay { virtual_ms, .. } => round_delay += virtual_ms,
                        PartEvent::SpecShipped { bytes } => {
                            round_spec_bytes += bytes as u64
                        }
                        PartEvent::MachineLost { .. } => {}
                    }
                }
                overlap_ms = first_done
                    .map(|t| t.elapsed().as_secs_f64() * 1e3)
                    .unwrap_or(0.0);
                // every prediction held: the next round is ready — adopt
                // the advanced rng and hand over the pre-built partition
                // (possibly already partially executing)
                if let Some(s) = spec {
                    if trace::enabled() {
                        trace::instant(
                            trace::COORDINATOR_TRACK,
                            "spec.adopt",
                            vec![
                                ("round", trace::ArgValue::U64(round as u64)),
                                (
                                    "dispatched_parts",
                                    trace::ArgValue::U64(s.next_submitted as u64),
                                ),
                            ],
                        );
                    }
                    rng = s.rng_after;
                    prepared = Some(match next_session {
                        Some(session) => Upcoming::InFlight {
                            session,
                            machines: s.machines,
                            parts: s.next_parts,
                            submitted: s.next_submitted,
                            round_seed: s.round_seed,
                        },
                        None => Upcoming::Planned {
                            machines: s.machines,
                            parts: s.next_parts,
                            round_seed: s.round_seed,
                        },
                    });
                }
            } else {
                let outcome = self.backend.run_round(
                    problem,
                    self.compressor.as_ref(),
                    &parts,
                    round_seed,
                )?;
                requeued_parts = outcome.requeued_parts;
                requeued_ids = outcome.requeued_ids;
                round_delay = outcome.sim_delay_ms;
                round_spec_bytes = outcome.spec_bytes;
                for (i, s) in outcome.solutions.into_iter().enumerate() {
                    slots[i] = Some(s);
                }
            }
            sim_delay_ms += round_delay;
            overlap_total += overlap_ms;

            let sols = slots
                .into_iter()
                .enumerate()
                .map(|(i, s)| {
                    s.ok_or_else(|| Error::Worker(format!("machine {i} never reported")))
                })
                .collect::<Result<Vec<Solution>>>()?;

            let max_load = parts.iter().map(Vec::len).max().unwrap_or(0);
            let mut next: Vec<u32> = Vec::with_capacity(sols.len() * problem.k);
            final_round_best = Some(round_best_of(&sols));
            for sol in &sols {
                if sol.value > best.value || best.items.is_empty() && !sol.items.is_empty() {
                    best = sol.clone();
                }
                next.extend_from_slice(&sol.items);
            }
            // Parts are disjoint, so the union has no duplicates. The
            // order is part-order concatenation — deterministic (parts
            // and their solutions are keyed by index, never by
            // completion order) and, unlike a sort, known incrementally
            // the moment each part completes, which is what lets the
            // speculative scatter above fill next-round parts in flight.

            let round_evals = problem.eval_count() - evals_round_start;
            if trace::enabled() {
                trace::span(
                    trace::COORDINATOR_TRACK,
                    "round",
                    r_trace_start,
                    vec![
                        ("round", trace::ArgValue::U64(round as u64)),
                        ("machines", trace::ArgValue::U64(m_t as u64)),
                        ("input_items", trace::ArgValue::U64(a.len() as u64)),
                        ("oracle_evals", trace::ArgValue::U64(round_evals)),
                    ],
                );
            }
            metrics.record_round(RoundMetrics {
                round,
                input_items: a.len(),
                machines: m_t,
                max_machine_load: max_load,
                output_items: next.len(),
                requeued_parts,
                // the wire carries item ids only: part ids out to the
                // machines (plus re-shipments after machine loss) and
                // solution ids back — never feature rows
                bytes_shuffled: ((a.len() + requeued_ids + next.len())
                    * std::mem::size_of::<u32>()) as u64,
                rows_resident_bytes: (a.len() * problem.dataset.row_bytes()) as u64,
                wall_ms: r_start.elapsed().as_secs_f64() * 1e3 + round_delay,
                straggler_overlap_ms: overlap_ms,
                spec_bytes: round_spec_bytes,
                oracle_evals: round_evals,
                best_value: best.value,
            });

            round += 1;
            a = next;
            if m_t == 1 {
                break;
            }
            // Hard cap: with µ barely above k the worst case can stall
            // (Prop 3.1 drops the partition ceiling — see planner.rs).
            // Real runs converge because machines emit < k items once
            // gains saturate; if not, stop and return the best partial
            // solution (still covered by the per-round Lemma 3.4 losses).
            if round >= 3 * bound + 8 {
                break;
            }
        }

        Ok(TreeResult {
            best,
            final_round_best: final_round_best.unwrap_or_default(),
            rounds: round,
            round_bound: bound,
            oracle_evals: problem.eval_count() - evals_before,
            per_round: metrics.rounds(),
            total_machines: metrics.total_machines(),
            requeued_parts: metrics.total_requeued(),
            bytes_shuffled: metrics.total_bytes_shuffled(),
            rows_resident_bytes: metrics.total_rows_resident_bytes(),
            straggler_overlap_ms: overlap_total,
            spec_bytes: metrics.total_spec_bytes(),
            // includes injected virtual delay, consistent with per-round wall_ms
            wall_ms: t_start.elapsed().as_secs_f64() * 1e3 + sim_delay_ms,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::baselines;
    use crate::data::synthetic;
    use crate::objectives::coverage::CoverageData;

    #[test]
    fn figure1_trace() {
        // Paper Figure 1: n = 16k, µ = 2k → 4 rounds with 8, 4, 2, 1
        // machines (assuming every machine emits exactly k items).
        let k = 16;
        let ds = Arc::new(synthetic::csn_like(16 * k, 1));
        let p = Problem::exemplar(ds, k, 1);
        let tree = TreeBuilder::new(2 * k).build();
        let res = tree.run(&p, 1).unwrap();
        let machines: Vec<usize> = res.per_round.iter().map(|r| r.machines).collect();
        assert_eq!(machines, vec![8, 4, 2, 1]);
        assert_eq!(res.rounds, 4);
        assert!(res.rounds <= res.round_bound);
    }

    #[test]
    fn solution_is_feasible_and_within_bound() {
        let ds = Arc::new(synthetic::csn_like(600, 2));
        let p = Problem::exemplar(ds, 10, 2);
        let res = TreeBuilder::new(60).build().run(&p, 3).unwrap();
        assert!(res.best.items.len() <= 10);
        assert!(p.constraint.is_feasible(&res.best.items, &p.dataset));
        // no duplicate items
        let set: std::collections::HashSet<_> = res.best.items.iter().collect();
        assert_eq!(set.len(), res.best.items.len());
        assert!(res.rounds <= res.round_bound);
    }

    #[test]
    fn capacity_geq_n_matches_centralized_greedy() {
        // µ ≥ n: Algorithm 1 degenerates to one machine running GREEDY
        let ds = Arc::new(synthetic::csn_like(200, 3));
        let p = Problem::exemplar(ds, 8, 3);
        let res = TreeBuilder::new(400).build().run(&p, 4).unwrap();
        let central = baselines::centralized(&p).unwrap();
        assert_eq!(res.rounds, 1);
        assert_eq!(res.best.items, central.items);
    }

    #[test]
    fn best_value_is_monotone_across_rounds() {
        let ds = Arc::new(synthetic::csn_like(800, 5));
        let p = Problem::exemplar(ds, 10, 5);
        let res = TreeBuilder::new(50).build().run(&p, 6).unwrap();
        let values: Vec<f64> = res.per_round.iter().map(|r| r.best_value).collect();
        for w in values.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        assert!(res.rounds >= 3, "expected a deep tree, got {}", res.rounds);
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = Arc::new(synthetic::csn_like(500, 7));
        let p = Problem::exemplar(ds, 6, 7);
        let t = TreeBuilder::new(40).build();
        let a = t.run(&p, 11).unwrap();
        let b = t.run(&p, 11).unwrap();
        assert_eq!(a.best.items, b.best.items);
        assert_eq!(a.rounds, b.rounds);
    }

    #[test]
    fn never_exceeds_capacity_in_any_round() {
        let ds = Arc::new(synthetic::csn_like(700, 8));
        let p = Problem::exemplar(ds, 9, 8);
        let res = TreeBuilder::new(45).build().run(&p, 9).unwrap();
        for r in &res.per_round {
            assert!(r.max_machine_load <= 45, "round {} load {}", r.round, r.max_machine_load);
        }
    }

    #[test]
    fn coverage_tree_beats_thm33_bound() {
        // E[f(S)] ≥ f(OPT)/(r(1+β)) — check against brute-force OPT on a
        // small coverage instance (single run, generous slack via the
        // bound itself).
        let mut rng = crate::util::rng::Rng::seed_from(21);
        let inst = crate::util::check::gens::coverage(&mut rng, 40, 30);
        let data = CoverageData { covers: inst.covers.clone(), weights: inst.weights.clone() };
        let k = 3;
        let p = Problem::coverage(data.clone(), k, 0);
        let res = TreeBuilder::new(k + 2).build().run(&p, 5).unwrap();
        // brute force OPT
        let n = inst.n;
        let mut opt = 0.0f64;
        for a in 0..n {
            for b in a..n {
                for c in b..n {
                    let v = crate::objectives::coverage::coverage_value(
                        &data,
                        &[a as u32, b as u32, c as u32],
                    );
                    opt = opt.max(v);
                }
            }
        }
        let bound = opt / (res.round_bound as f64 * 2.0); // β = 1
        assert!(
            res.best.value >= bound - 1e-9,
            "tree {} < bound {} (OPT {opt}, r={})",
            res.best.value,
            bound,
            res.round_bound
        );
    }

    #[test]
    fn round_best_keeps_first_max_on_ties_and_tolerates_nan() {
        let a = Solution { items: vec![1], value: 2.0 };
        let b = Solution { items: vec![2], value: 2.0 };
        let c = Solution { items: vec![3], value: 1.0 };
        // tied part values: the lowest part index must win, so the
        // selection is independent of arrival order
        assert_eq!(round_best_of(&[a.clone(), b.clone(), c]).items, vec![1]);
        assert_eq!(round_best_of(&[b, a]).items, vec![2]);
        // a NaN value must not panic (the old partial_cmp().unwrap()
        // did); under total_cmp it ranks above +inf and surfaces
        let nan = Solution { items: vec![9], value: f64::NAN };
        let best = round_best_of(&[Solution { items: vec![1], value: 2.0 }, nan]);
        assert_eq!(best.items, vec![9]);
        assert!(best.value.is_nan());
        assert!(round_best_of(&[]).items.is_empty());
    }

    #[test]
    fn tied_part_values_resolve_to_first_part_through_a_full_run() {
        // modular objective with all-equal weights: every machine's
        // compression has the identical value, so every round is a tie;
        // deterministic contiguous parts make part 0 = lowest ids
        let p = Problem::modular(vec![1.0; 100], 5, 1);
        let res = TreeBuilder::new(25)
            .partition_mode(PartitionStrategy::Contiguous)
            .build()
            .run(&p, 2)
            .unwrap();
        assert_eq!(res.best.items, vec![0, 1, 2, 3, 4]);
        assert_eq!(res.final_round_best.value.to_bits(), 5.0f64.to_bits());
    }

    #[test]
    fn shuffle_accounting_charges_ids_not_rows() {
        // modular dataset has d = 1 → row_bytes = 4, same as one u32 id
        let weights: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = Problem::modular(weights, 5, 1);
        let res = TreeBuilder::new(25).build().run(&p, 2).unwrap();
        assert_eq!(res.rounds, 2);
        let r0 = &res.per_round[0];
        // round 0: 100 ids out to 4 machines, 4·k = 20 solution ids back
        assert_eq!(r0.bytes_shuffled, (100 + 20) * 4);
        assert_eq!(r0.rows_resident_bytes, 100 * 4);
        let r1 = &res.per_round[1];
        assert_eq!(r1.bytes_shuffled, (20 + 5) * 4);
        assert_eq!(r1.rows_resident_bytes, 20 * 4);
        assert_eq!(res.bytes_shuffled, (120 + 25) * 4);
        assert_eq!(res.rows_resident_bytes, 120 * 4);
    }

    #[test]
    fn sim_backend_machine_loss_reports_requeues_and_stays_feasible() {
        use crate::dist::{FaultPlan, SimBackend};
        let ds = Arc::new(synthetic::csn_like(600, 11));
        let p = Problem::exemplar(ds, 10, 11);
        let backend = Arc::new(SimBackend::new(60).with_faults(FaultPlan::lose_per_round(1)));
        let res = TreeBuilder::new(60).backend(backend).build().run(&p, 3).unwrap();
        assert!(!res.best.items.is_empty());
        assert!(res.best.items.len() <= 10);
        assert!(p.constraint.is_feasible(&res.best.items, &p.dataset));
        for r in &res.per_round {
            assert_eq!(r.requeued_parts, 1, "round {} lost machine unreported", r.round);
        }
        assert_eq!(res.requeued_parts, res.rounds as u64);
        // machine loss + requeue must not change the answer
        let healthy = TreeBuilder::new(60).build().run(&p, 3).unwrap();
        assert_eq!(res.best.items, healthy.best.items);
        assert_eq!(res.best.value.to_bits(), healthy.best.value.to_bits());
    }

    #[test]
    fn explicit_backend_capacity_is_authoritative() {
        use crate::dist::LocalBackend;
        let ds = Arc::new(synthetic::csn_like(200, 12));
        let p = Problem::exemplar(ds, 8, 12);
        // builder says 400 (single round), backend says 50 (multi round):
        // the backend wins, keeping planning and enforcement consistent
        let res = TreeBuilder::new(400)
            .backend(Arc::new(LocalBackend::new(50)))
            .build()
            .run(&p, 4)
            .unwrap();
        assert!(res.rounds > 1);
        for r in &res.per_round {
            assert!(r.max_machine_load <= 50);
        }
    }

    #[test]
    fn uniform_profile_reproduces_scalar_capacity_bit_exactly() {
        // `--capacity 200` and `--capacity 200x1` (or an explicit uniform
        // profile) must be the same run: same partitions, same seeds,
        // same answer — the PR 1/2 behavior is a special case, not an
        // approximation.
        let ds = Arc::new(synthetic::csn_like(500, 13));
        let p = Problem::exemplar(ds, 6, 13);
        let scalar = TreeBuilder::new(40).build().run(&p, 11).unwrap();
        let profiled = TreeBuilder::for_profile(CapacityProfile::uniform(40))
            .build()
            .run(&p, 11)
            .unwrap();
        assert_eq!(scalar.best.items, profiled.best.items);
        assert_eq!(scalar.best.value.to_bits(), profiled.best.value.to_bits());
        assert_eq!(scalar.rounds, profiled.rounds);
        let a: Vec<usize> = scalar.per_round.iter().map(|r| r.machines).collect();
        let b: Vec<usize> = profiled.per_round.iter().map(|r| r.machines).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn heterogeneous_profile_runs_and_respects_class_capacities() {
        let ds = Arc::new(synthetic::csn_like(600, 14));
        let p = Problem::exemplar(ds, 10, 14);
        let profile = CapacityProfile::parse("120,60,60").unwrap();
        let res = TreeBuilder::for_profile(profile.clone()).build().run(&p, 5).unwrap();
        assert!(!res.best.items.is_empty());
        assert!(res.best.items.len() <= 10);
        assert!(p.constraint.is_feasible(&res.best.items, &p.dataset));
        assert!(res.rounds >= 2, "600 items over a 240-capacity cycle is multi-round");
        // no machine ever exceeded the largest class; per-class bounds
        // are enforced inside the backend (CapacityExceeded otherwise)
        for r in &res.per_round {
            assert!(r.max_machine_load <= 120, "round {}: load {}", r.round, r.max_machine_load);
        }
        // deterministic per seed
        let again = TreeBuilder::for_profile(profile).build().run(&p, 5).unwrap();
        assert_eq!(res.best.items, again.best.items);
        assert_eq!(res.best.value.to_bits(), again.best.value.to_bits());
    }

    #[test]
    fn heterogeneous_local_and_sim_backends_agree_bit_exactly() {
        use crate::dist::SimBackend;
        let ds = Arc::new(synthetic::csn_like(480, 15));
        let p = Problem::exemplar(ds, 8, 15);
        let profile = CapacityProfile::parse("100,60,60").unwrap();
        let local = TreeBuilder::for_profile(profile.clone()).build().run(&p, 7).unwrap();
        let sim = TreeBuilder::for_profile(profile.clone())
            .backend(Arc::new(SimBackend::with_profile(profile)))
            .build()
            .run(&p, 7)
            .unwrap();
        assert_eq!(local.best.items, sim.best.items);
        assert_eq!(local.best.value.to_bits(), sim.best.value.to_bits());
        assert_eq!(local.rounds, sim.rounds);
    }

    #[test]
    fn shrinking_capacity_schedule_replans_rounds_against_the_surviving_fleet() {
        use crate::dist::SimBackend;
        // The fleet loses its largest machine after round 0: rounds 1+
        // must be partitioned for the smaller survivors instead of
        // overloading a machine class that no longer exists.
        let ds = Arc::new(synthetic::csn_like(400, 16));
        let p = Problem::exemplar(ds, 8, 16);
        let big = CapacityProfile::parse("200,60,60").unwrap();
        let small = CapacityProfile::parse("60,60").unwrap();
        let backend = Arc::new(
            SimBackend::with_profile(big.clone())
                .with_capacity_schedule(vec![big, small]),
        );
        let res = TreeBuilder::for_profile(CapacityProfile::uniform(200))
            .backend(backend)
            .build()
            .run(&p, 9)
            .unwrap();
        assert!(!res.best.items.is_empty());
        assert!(p.constraint.is_feasible(&res.best.items, &p.dataset));
        assert!(res.rounds >= 2);
        // every post-shrink round fits the 60-capacity survivors
        for r in res.per_round.iter().skip(1) {
            assert!(
                r.max_machine_load <= 60,
                "round {} overloaded a lost machine class: {}",
                r.round,
                r.max_machine_load
            );
        }
    }

    #[test]
    fn pipelined_run_is_bit_identical_to_serial_run() {
        // speculation-friendly: exemplar gains fill every machine to k,
        // so the pre-computed next-round partitions are used throughout
        let ds = Arc::new(synthetic::csn_like(600, 21));
        let p = Problem::exemplar(ds, 10, 21);
        let t = TreeBuilder::new(50).build();
        let piped = t.run(&p, 13).unwrap();
        let serial = t.run_serial(&p, 13).unwrap();
        assert_eq!(piped.best.items, serial.best.items);
        assert_eq!(piped.best.value.to_bits(), serial.best.value.to_bits());
        assert_eq!(piped.rounds, serial.rounds);
        assert_eq!(piped.final_round_best.items, serial.final_round_best.items);
        let pm: Vec<usize> = piped.per_round.iter().map(|r| r.machines).collect();
        let sm: Vec<usize> = serial.per_round.iter().map(|r| r.machines).collect();
        assert_eq!(pm, sm);
        // the serial barrier observes nothing mid-round
        for r in &serial.per_round {
            assert_eq!(r.straggler_overlap_ms, 0.0);
        }
    }

    #[test]
    fn contiguous_pipelined_with_speculative_dispatch_is_bit_identical_to_serial() {
        // the contiguous strategy speculatively DISPATCHES next-round
        // parts into an early-opened session; the answer must still be
        // bit-identical to the serial barrier path on local and sim
        let ds = Arc::new(synthetic::csn_like(600, 23));
        let p = Problem::exemplar(ds, 10, 23);
        let t = TreeBuilder::new(50)
            .partition_mode(PartitionStrategy::Contiguous)
            .build();
        let piped = t.run(&p, 13).unwrap();
        let serial = t.run_serial(&p, 13).unwrap();
        assert_eq!(piped.best.items, serial.best.items);
        assert_eq!(piped.best.value.to_bits(), serial.best.value.to_bits());
        assert_eq!(piped.rounds, serial.rounds);
        assert_eq!(piped.final_round_best.items, serial.final_round_best.items);
        let pm: Vec<usize> = piped.per_round.iter().map(|r| r.machines).collect();
        let sm: Vec<usize> = serial.per_round.iter().map(|r| r.machines).collect();
        assert_eq!(pm, sm);
        let po: Vec<usize> = piped.per_round.iter().map(|r| r.output_items).collect();
        let so: Vec<usize> = serial.per_round.iter().map(|r| r.output_items).collect();
        assert_eq!(po, so);

        use crate::dist::SimBackend;
        let sim_piped = TreeBuilder::new(50)
            .partition_mode(PartitionStrategy::Contiguous)
            .backend(Arc::new(SimBackend::new(50)))
            .build()
            .run(&p, 13)
            .unwrap();
        assert_eq!(sim_piped.best.items, serial.best.items);
        assert_eq!(sim_piped.best.value.to_bits(), serial.best.value.to_bits());
    }

    #[test]
    fn contiguous_speculative_misprediction_aborts_and_falls_back_bit_identically() {
        // mostly-zero modular weights: greedy saturates below k, so the
        // speculative session is aborted mid-round and the honest
        // recomputation must still match the serial run
        let mut weights = vec![0.0f64; 200];
        for (i, w) in weights.iter_mut().enumerate().take(200) {
            if i % 7 == 0 {
                *w = 1.0 + i as f64;
            }
        }
        let p = Problem::modular(weights, 5, 2);
        let t = TreeBuilder::new(25)
            .partition_mode(PartitionStrategy::Contiguous)
            .build();
        let piped = t.run(&p, 4).unwrap();
        let serial = t.run_serial(&p, 4).unwrap();
        assert_eq!(piped.best.items, serial.best.items);
        assert_eq!(piped.best.value.to_bits(), serial.best.value.to_bits());
        assert_eq!(piped.rounds, serial.rounds);
        let po: Vec<usize> = piped.per_round.iter().map(|r| r.output_items).collect();
        let so: Vec<usize> = serial.per_round.iter().map(|r| r.output_items).collect();
        assert_eq!(po, so);
    }

    #[test]
    fn contiguous_pipelined_matches_serial_under_sim_faults() {
        use crate::dist::{FaultPlan, SimBackend};
        let ds = Arc::new(synthetic::csn_like(500, 24));
        let p = Problem::exemplar(ds, 8, 24);
        let faults = FaultPlan {
            machine_loss_per_round: 1,
            straggler_prob: 0.5,
            straggler_delay_ms: 5.0,
            ..FaultPlan::default()
        };
        let make = || Arc::new(SimBackend::new(50).with_faults(faults.clone()));
        let build = |b: Arc<SimBackend>| {
            TreeBuilder::new(50)
                .partition_mode(PartitionStrategy::Contiguous)
                .backend(b)
                .build()
        };
        let piped = build(make()).run(&p, 6).unwrap();
        let serial = build(make()).run_serial(&p, 6).unwrap();
        assert_eq!(piped.best.items, serial.best.items);
        assert_eq!(piped.best.value.to_bits(), serial.best.value.to_bits());
        assert_eq!(piped.requeued_parts, serial.requeued_parts);
    }

    #[test]
    fn shrinking_fleet_below_surviving_set_fails_with_structured_error() {
        use crate::dist::SimBackend;
        // A scripted fleet that shrinks is re-planned against the
        // survivors (the cyclic profile always covers |A_t|), and a
        // partitioner handed a fleet that cannot hold the set reports a
        // structured CapacityExceeded — never a panic. The run either
        // completes (re-planning succeeded) or errors structurally.
        let ds = Arc::new(synthetic::csn_like(400, 17));
        let p = Problem::exemplar(ds, 8, 17);
        let big = CapacityProfile::parse("200,60,60").unwrap();
        let small = CapacityProfile::parse("60,60").unwrap();
        let backend = Arc::new(
            SimBackend::with_profile(big.clone()).with_capacity_schedule(vec![big, small]),
        );
        let res = TreeBuilder::new(200).backend(backend).build().run(&p, 9);
        match res {
            Ok(r) => {
                assert!(!r.best.items.is_empty());
                for round in r.per_round.iter().skip(1) {
                    assert!(round.max_machine_load <= 60);
                }
            }
            Err(crate::error::Error::CapacityExceeded { .. }) => {}
            Err(e) => panic!("expected success or CapacityExceeded, got {e}"),
        }
    }

    #[test]
    fn pipelined_run_reports_spec_bytes_once_with_wire_sim() {
        use crate::dist::SimBackend;
        let ds = crate::data::registry::load("csn-2k", 3).unwrap();
        let p = Problem::exemplar(ds, 8, 3);
        let backend = Arc::new(SimBackend::new(300).with_wire_spec(true));
        let res = TreeBuilder::new(300).backend(backend).build().run(&p, 5).unwrap();
        assert!(res.rounds >= 2, "expected a multi-round run");
        assert!(
            res.per_round[0].spec_bytes > 0,
            "round 0 must account the interned spec"
        );
        for r in res.per_round.iter().skip(1) {
            assert_eq!(r.spec_bytes, 0, "round {} re-shipped the spec", r.round);
        }
        assert_eq!(res.spec_bytes, res.per_round[0].spec_bytes);
    }

    #[test]
    fn size_misprediction_falls_back_bit_identically() {
        // mostly-zero modular weights: greedy saturates below k on most
        // machines, so every speculative size prediction dies and the
        // honest recomputation path must still match the serial run
        let mut weights = vec![0.0f64; 200];
        for (i, w) in weights.iter_mut().enumerate().take(200) {
            if i % 7 == 0 {
                *w = 1.0 + i as f64;
            }
        }
        let p = Problem::modular(weights, 5, 2);
        let t = TreeBuilder::new(25).build();
        let piped = t.run(&p, 4).unwrap();
        let serial = t.run_serial(&p, 4).unwrap();
        assert_eq!(piped.best.items, serial.best.items);
        assert_eq!(piped.best.value.to_bits(), serial.best.value.to_bits());
        assert_eq!(piped.rounds, serial.rounds);
        let po: Vec<usize> = piped.per_round.iter().map(|r| r.output_items).collect();
        let so: Vec<usize> = serial.per_round.iter().map(|r| r.output_items).collect();
        assert_eq!(po, so);
    }

    #[test]
    fn pipelined_run_with_sim_faults_matches_serial_and_healthy() {
        use crate::dist::{FaultPlan, SimBackend};
        let ds = Arc::new(synthetic::csn_like(500, 22));
        let p = Problem::exemplar(ds, 8, 22);
        let faults = FaultPlan {
            machine_loss_per_round: 1,
            straggler_prob: 0.5,
            straggler_delay_ms: 5.0,
            ..FaultPlan::default()
        };
        let make = || {
            Arc::new(SimBackend::new(50).with_faults(faults.clone()))
        };
        let piped = TreeBuilder::new(50).backend(make()).build().run(&p, 6).unwrap();
        let serial =
            TreeBuilder::new(50).backend(make()).build().run_serial(&p, 6).unwrap();
        assert_eq!(piped.best.items, serial.best.items);
        assert_eq!(piped.best.value.to_bits(), serial.best.value.to_bits());
        assert_eq!(piped.requeued_parts, serial.requeued_parts);
        // virtual straggler delay is charged identically on both paths
        assert_eq!(piped.wall_ms > 0.0, serial.wall_ms > 0.0);
        let healthy = TreeBuilder::new(50).build().run(&p, 6).unwrap();
        assert_eq!(piped.best.items, healthy.best.items);
    }

    #[test]
    fn iid_partition_mode_runs() {
        // iid partitioning may transiently exceed µ — the runner must
        // surface that as CapacityExceeded *or* succeed; with generous
        // capacity it succeeds.
        let ds = Arc::new(synthetic::csn_like(300, 9));
        let p = Problem::exemplar(ds, 5, 9);
        let res = TreeBuilder::new(120)
            .partition_mode(PartitionStrategy::Iid)
            .build()
            .run(&p, 2);
        match res {
            Ok(r) => assert!(!r.best.items.is_empty()),
            Err(crate::error::Error::CapacityExceeded { .. }) => {}
            Err(e) => panic!("unexpected error {e}"),
        }
    }
}

//! `.fmat` — a minimal binary container for f32 row-major matrices.
//!
//! Layout (little-endian):
//! ```text
//! magic  b"FMAT1\0\0\0"   (8 bytes)
//! n      u64
//! d      u64
//! data   n*d f32
//! ```
//! Used to cache generated datasets and expensive baseline solutions so
//! repeated bench runs don't regenerate them.

use std::io::{Read, Write};
use std::path::Path;

use crate::data::Dataset;
use crate::error::{Error, Result};

const MAGIC: &[u8; 8] = b"FMAT1\0\0\0";

/// Write a dataset to `path` (atomically via a temp file + rename).
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("fmat.tmp");
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        f.write_all(&(ds.n as u64).to_le_bytes())?;
        f.write_all(&(ds.d as u64).to_le_bytes())?;
        // f32 -> LE bytes
        let raw = ds.raw();
        let mut buf = Vec::with_capacity(raw.len() * 4);
        for &x in raw {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
        f.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a dataset from `path`; `name` becomes the in-memory name.
pub fn load(path: &Path, name: &str) -> Result<Dataset> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::DataFormat(format!(
            "{}: bad magic {:?}",
            path.display(),
            magic
        )));
    }
    let mut u = [0u8; 8];
    f.read_exact(&mut u)?;
    let n = u64::from_le_bytes(u) as usize;
    f.read_exact(&mut u)?;
    let d = u64::from_le_bytes(u) as usize;
    let count = n
        .checked_mul(d)
        .ok_or_else(|| Error::DataFormat("n*d overflow".into()))?;
    let mut bytes = vec![0u8; count * 4];
    f.read_exact(&mut bytes)?;
    let mut data = Vec::with_capacity(count);
    for chunk in bytes.chunks_exact(4) {
        data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(Dataset::new(name, n, d, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("hss_fmat_{}", std::process::id()));
        let path = dir.join("t.fmat");
        let mut rng = Rng::seed_from(1);
        let data: Vec<f32> = (0..60).map(|_| rng.normal() as f32).collect();
        let ds = Dataset::new("t", 10, 6, data);
        save(&ds, &path).unwrap();
        let back = load(&path, "t").unwrap();
        assert_eq!(back.n, 10);
        assert_eq!(back.d, 6);
        assert_eq!(back.raw(), ds.raw());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("hss_fmat_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.fmat");
        std::fs::write(&path, b"NOTFMAT!........").unwrap();
        assert!(load(&path, "x").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_io_error() {
        assert!(load(Path::new("/nonexistent/x.fmat"), "x").is_err());
    }
}

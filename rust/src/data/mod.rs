//! Datasets: in-memory container, binary on-disk format, synthetic
//! generators matching the paper's evaluation data (DESIGN.md §5), and a
//! name registry used by the CLI / benches.

pub mod fmat;
pub mod registry;
pub mod spec;
pub mod synthetic;

use std::sync::Arc;

/// A dense row-major f32 dataset. Items are addressed by `u32` ids —
/// the coordinator ships ids over the wire, never rows; rows stay
/// resident on the machines that hold them.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub n: usize,
    pub d: usize,
    data: Vec<f32>,
    /// Generation provenance (wire spec v2): stamped by registry loads
    /// and the synthetic generators, cleared by every mutator, `None`
    /// for matrices assembled from raw data. Only datasets whose bytes
    /// this recipe actually reproduces may cross the wire by spec.
    pub gen: Option<spec::DatasetSpec>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, n: usize, d: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * d, "data length must be n*d");
        Dataset { name: name.into(), n, d, data, gen: None }
    }

    /// Row accessor.
    #[inline]
    pub fn row(&self, i: u32) -> &[f32] {
        let i = i as usize;
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Raw storage (row-major).
    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// Gather the given rows into a new contiguous buffer, padding with
    /// zero rows up to `pad_rows` (the runtime's artifact contract:
    /// zero rows are inert for both objectives).
    pub fn gather_padded(&self, ids: &[u32], pad_rows: usize, pad_d: usize) -> Vec<f32> {
        assert!(pad_rows >= ids.len());
        assert!(pad_d >= self.d);
        let mut out = vec![0.0f32; pad_rows * pad_d];
        for (r, &id) in ids.iter().enumerate() {
            out[r * pad_d..r * pad_d + self.d].copy_from_slice(self.row(id));
        }
        out
    }

    /// Normalize every row to unit L2 norm (paper: TINY and PARKINSONS
    /// are normalized to zero mean, unit norm). Zero rows stay zero.
    /// Invalidates recorded generation provenance — the recipe no
    /// longer reproduces these bytes. (The synthetic generators apply
    /// their preprocessing *before* recording provenance.)
    pub fn normalize_rows(&mut self) {
        self.gen = None;
        for i in 0..self.n {
            let row = &mut self.data[i * self.d..(i + 1) * self.d];
            let norm = row.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for x in row.iter_mut() {
                    *x = (*x as f64 / norm) as f32;
                }
            }
        }
    }

    /// Subtract the per-dimension mean (zero-mean preprocessing).
    /// Invalidates recorded generation provenance, like
    /// [`Dataset::normalize_rows`].
    pub fn center_columns(&mut self) {
        self.gen = None;
        let mut means = vec![0.0f64; self.d];
        for i in 0..self.n {
            for (j, &x) in self.row(i as u32).iter().enumerate() {
                means[j] += x as f64;
            }
        }
        for m in means.iter_mut() {
            *m /= self.n as f64;
        }
        for i in 0..self.n {
            let row = &mut self.data[i * self.d..(i + 1) * self.d];
            for (j, x) in row.iter_mut().enumerate() {
                *x = (*x as f64 - means[j]) as f32;
            }
        }
    }

    /// Size in bytes of one row (used for rows-resident accounting —
    /// the wire itself only ever carries item ids).
    pub fn row_bytes(&self) -> usize {
        self.d * std::mem::size_of::<f32>()
    }
}

/// Shared handle used across coordinator threads.
pub type DatasetRef = Arc<Dataset>;

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::new("toy", 3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn row_access() {
        let d = toy();
        assert_eq!(d.row(0), &[1.0, 2.0]);
        assert_eq!(d.row(2), &[5.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn bad_length_panics() {
        Dataset::new("bad", 2, 3, vec![0.0; 5]);
    }

    #[test]
    fn gather_pads_with_zeros() {
        let d = toy();
        let g = d.gather_padded(&[2, 0], 4, 3);
        assert_eq!(g.len(), 12);
        assert_eq!(&g[0..3], &[5.0, 6.0, 0.0]);
        assert_eq!(&g[3..6], &[1.0, 2.0, 0.0]);
        assert_eq!(&g[6..12], &[0.0; 6]);
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let mut d = toy();
        d.normalize_rows();
        for i in 0..3 {
            let n: f64 = d.row(i).iter().map(|&x| (x as f64).powi(2)).sum();
            assert!((n - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn center_columns_zero_mean() {
        let mut d = toy();
        d.center_columns();
        for j in 0..2 {
            let s: f64 = (0..3).map(|i| d.row(i).to_vec()[j] as f64).sum();
            assert!(s.abs() < 1e-5);
        }
    }
}

//! Named dataset registry with on-disk caching.
//!
//! Maps the DESIGN.md §5 dataset names to generator invocations and
//! caches the generated matrices as `.fmat` under `data_cache/` so bench
//! reruns are instant. `--full` variants keep the paper's sizes where
//! feasible; the default (quick) variants are scaled for the single-core
//! testbed (documented in EXPERIMENTS.md).

use std::path::PathBuf;
use std::sync::Arc;

use crate::data::{fmat, synthetic, Dataset, DatasetRef};
use crate::error::{Error, Result};

/// Catalog entry: how to produce a named dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Spec {
    Csn { n: usize },
    Parkinsons { n: usize },
    Tiny { n: usize, d: usize },
    Webscope { n: usize },
}

impl Spec {
    pub fn generate(&self, name: &str, seed: u64) -> Dataset {
        let mut ds = match *self {
            Spec::Csn { n } => synthetic::csn_like(n, seed),
            Spec::Parkinsons { n } => synthetic::parkinsons_like(n, seed),
            Spec::Tiny { n, d } => synthetic::tiny_like(n, d, seed),
            Spec::Webscope { n } => synthetic::webscope_like(n, seed),
        };
        ds.name = name.to_string();
        // Registry entries ship by catalog identity, overriding the
        // inner generator's provenance — so every load path (generated
        // here or .fmat-cached in `load`) specs identically.
        ds.gen = Some(crate::data::spec::DatasetSpec::Registry {
            name: name.to_string(),
            seed,
        });
        ds
    }

    pub fn n(&self) -> usize {
        match *self {
            Spec::Csn { n } | Spec::Parkinsons { n } | Spec::Webscope { n } => n,
            Spec::Tiny { n, .. } => n,
        }
    }
}

/// Resolve a dataset name (see `names()`) to its generator spec.
pub fn spec(name: &str) -> Result<Spec> {
    Ok(match name {
        // paper-faithful sizes (Table 2)
        "csn-20k" => Spec::Csn { n: 20_000 },
        "parkinsons" => Spec::Parkinsons { n: 5_875 },
        "tiny-10k" => Spec::Tiny { n: 10_000, d: 3072 },
        "webscope-100k" => Spec::Webscope { n: 100_000 },
        // large-scale (scaled from 1M/45M for the single-core testbed)
        "tiny-large" => Spec::Tiny { n: 131_072, d: 64 },
        "webscope-large" => Spec::Webscope { n: 262_144 },
        // quick variants for tests/sweeps on a laptop-scale budget
        "csn-2k" => Spec::Csn { n: 2_000 },
        "tiny-2k" => Spec::Tiny { n: 2_048, d: 3072 },
        "tiny-2k-d64" => Spec::Tiny { n: 2_048, d: 64 },
        "parkinsons-1k" => Spec::Parkinsons { n: 1_000 },
        "webscope-10k" => Spec::Webscope { n: 10_000 },
        other => {
            return Err(Error::Config(format!(
                "unknown dataset '{other}' (known: {})",
                names().join(", ")
            )))
        }
    })
}

/// All registered dataset names.
pub fn names() -> Vec<&'static str> {
    vec![
        "csn-20k",
        "parkinsons",
        "tiny-10k",
        "webscope-100k",
        "tiny-large",
        "webscope-large",
        "csn-2k",
        "tiny-2k",
        "tiny-2k-d64",
        "parkinsons-1k",
        "webscope-10k",
    ]
}

/// Default on-disk cache directory (overridable with HSS_DATA_DIR).
pub fn cache_dir() -> PathBuf {
    std::env::var("HSS_DATA_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("data_cache"))
}

/// Load a dataset by name, generating + caching on first use.
pub fn load(name: &str, seed: u64) -> Result<DatasetRef> {
    let sp = spec(name)?;
    let path = cache_dir().join(format!("{name}_s{seed}.fmat"));
    if path.exists() {
        if let Ok(mut ds) = fmat::load(&path, name) {
            // the on-disk format carries no provenance; stamp the
            // catalog identity so cached loads spec like generated ones
            ds.gen = Some(crate::data::spec::DatasetSpec::Registry {
                name: name.to_string(),
                seed,
            });
            return Ok(Arc::new(ds));
        }
        // fall through to regeneration on a corrupt cache file
    }
    let ds = sp.generate(name, seed);
    // Cache best-effort; generation is deterministic so failure is benign.
    let _ = fmat::save(&ds, &path);
    Ok(Arc::new(ds))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_names_resolve() {
        for n in names() {
            assert!(spec(n).is_ok(), "{n}");
        }
    }

    #[test]
    fn unknown_name_is_config_error() {
        let e = spec("nope").unwrap_err();
        assert!(e.to_string().contains("unknown dataset"));
    }

    #[test]
    fn load_caches_and_reloads() {
        let dir = std::env::temp_dir().join(format!("hss_reg_{}", std::process::id()));
        std::env::set_var("HSS_DATA_DIR", &dir);
        let a = load("csn-2k", 9).unwrap();
        assert!(dir.join("csn-2k_s9.fmat").exists());
        let b = load("csn-2k", 9).unwrap();
        assert_eq!(a.raw(), b.raw());
        std::env::remove_var("HSS_DATA_DIR");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn paper_sizes_match_table2() {
        assert_eq!(spec("parkinsons").unwrap().n(), 5_875);
        assert_eq!(spec("csn-20k").unwrap().n(), 20_000);
        assert_eq!(spec("tiny-10k").unwrap().n(), 10_000);
        assert_eq!(spec("webscope-100k").unwrap().n(), 100_000);
    }
}

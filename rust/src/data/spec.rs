//! Wire-serializable dataset specifications (wire spec v2).
//!
//! Datasets cross the network *by specification*, never by value. Two
//! flavors exist:
//!
//! * **registry** — a named catalog entry ([`crate::data::registry`]);
//!   the receiving side regenerates it from `(name, seed)`.
//! * **synthetic** — an ad-hoc instance of one of the named generator
//!   families in [`crate::data::synthetic`]; the generator records its
//!   own `(family, n, d, seed)` provenance on the [`Dataset`] when it
//!   runs, so any dataset built through those entry points can be
//!   reconstructed remotely even when it is not in the registry.
//!
//! Provenance travels on the [`Dataset`] itself (`gen`), stamped by
//! registry loads and synthetic generators and *cleared by every
//! mutator* — so only datasets whose bytes a recipe actually reproduces
//! are wire-representable. Raw matrices ([`Dataset::new`]) carry no
//! provenance and are rejected by [`DatasetSpec::from_dataset`]: the
//! coordinator cannot ship rows it cannot describe.

use std::sync::Arc;

use crate::data::{registry, synthetic, Dataset, DatasetRef};
use crate::error::{Error, Result};
use crate::util::json::{self, wire_str, wire_u64, wire_usize, Json};

/// A wire-serializable description of a [`Dataset`].
///
/// The JSON forms (normative grammar in `docs/PROTOCOL.md`) parse and
/// serialize losslessly — seeds are decimal strings so full 64-bit
/// words survive JSON's f64 numbers:
///
/// ```
/// use hss::data::spec::DatasetSpec;
/// use hss::util::json::Json;
///
/// let reg = DatasetSpec::from_json(
///     &Json::parse(r#"{"kind":"registry","name":"csn-2k","seed":"42"}"#).unwrap(),
/// ).unwrap();
/// assert_eq!(reg, DatasetSpec::Registry { name: "csn-2k".into(), seed: 42 });
///
/// let synth = DatasetSpec::from_json(
///     &Json::parse(r#"{"kind":"synthetic","generator":"csn","n":64,"d":17,"seed":"9"}"#)
///         .unwrap(),
/// ).unwrap();
/// // a spec regenerates its dataset deterministically on any process
/// let ds = synth.load().unwrap();
/// assert_eq!((ds.n, ds.d), (64, 17));
/// assert_eq!(DatasetSpec::from_dataset(&ds).unwrap(), synth);
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DatasetSpec {
    /// Named registry dataset, regenerated from `(name, seed)`.
    Registry { name: String, seed: u64 },
    /// Ad-hoc synthetic instance with its own generation seed.
    Synthetic { generator: String, n: usize, d: usize, seed: u64 },
}

impl DatasetSpec {
    /// Capture a dataset's wire spec: the recorded generation
    /// provenance, which pins the exact recipe — registry `(name,
    /// seed)` or synthetic `(family, n, d, seed)` — that produced the
    /// bytes. Provenance is the *only* path: registry loads stamp it,
    /// synthetic generators record it, and every mutator clears it, so
    /// a dataset whose bytes no longer match any recipe (raw matrix,
    /// post-generation mutation) can never ship a stale spec. Note a
    /// direct `parkinsons_like(n, s)` call shares its *name* with the
    /// registry entry `"parkinsons"` but not its size or seed — which
    /// is why names are never used for spec capture.
    pub fn from_dataset(ds: &Dataset) -> Result<DatasetSpec> {
        ds.gen.clone().ok_or_else(|| {
            Error::invalid(format!(
                "dataset '{}' has no generation provenance (raw matrix, or \
                 mutated after generation); workers reconstruct datasets from \
                 specs and cannot receive ad-hoc matrices",
                ds.name
            ))
        })
    }

    /// Reconstruct the dataset from its own recorded seed.
    pub fn load(&self) -> Result<DatasetRef> {
        match self {
            DatasetSpec::Registry { name, seed } => registry::load(name, *seed),
            DatasetSpec::Synthetic { generator, n, d, seed } => {
                let ds = match generator.as_str() {
                    "csn" => synthetic::csn_like(*n, *seed),
                    "parkinsons" => synthetic::parkinsons_like(*n, *seed),
                    "tiny" => synthetic::tiny_like(*n, *d, *seed),
                    "webscope" => synthetic::webscope_like(*n, *seed),
                    other => {
                        return Err(Error::Protocol(format!(
                            "unknown synthetic generator '{other}'"
                        )))
                    }
                };
                if ds.n != *n || ds.d != *d {
                    return Err(Error::Protocol(format!(
                        "synthetic spec asked for ({n}, {d}) but generator \
                         '{generator}' produced ({}, {})",
                        ds.n, ds.d
                    )));
                }
                Ok(Arc::new(ds))
            }
        }
    }

    /// Memoization key for worker-side dataset caches: everything the
    /// generated matrix depends on.
    pub fn cache_key(&self) -> (String, u64) {
        match self {
            DatasetSpec::Registry { name, seed } => (format!("registry/{name}"), *seed),
            DatasetSpec::Synthetic { generator, n, d, seed } => {
                (format!("synthetic/{generator}/{n}x{d}"), *seed)
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            DatasetSpec::Registry { name, seed } => json::obj(vec![
                ("kind", json::s("registry")),
                ("name", json::s(name)),
                ("seed", Json::Str(seed.to_string())),
            ]),
            DatasetSpec::Synthetic { generator, n, d, seed } => json::obj(vec![
                ("kind", json::s("synthetic")),
                ("generator", json::s(generator)),
                ("n", json::num(*n as f64)),
                ("d", json::num(*d as f64)),
                ("seed", Json::Str(seed.to_string())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<DatasetSpec> {
        match wire_str(v, "kind")? {
            "registry" => Ok(DatasetSpec::Registry {
                name: wire_str(v, "name")?.to_string(),
                seed: wire_u64(v, "seed")?,
            }),
            "synthetic" => Ok(DatasetSpec::Synthetic {
                generator: wire_str(v, "generator")?.to_string(),
                n: wire_usize(v, "n")?,
                d: wire_usize(v, "d")?,
                seed: wire_u64(v, "seed")?,
            }),
            other => Err(Error::Protocol(format!("unknown dataset spec kind '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(spec: &DatasetSpec) -> DatasetSpec {
        DatasetSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap()).unwrap()
    }

    #[test]
    fn json_roundtrips() {
        for spec in [
            DatasetSpec::Registry { name: "csn-2k".into(), seed: u64::MAX - 3 },
            DatasetSpec::Synthetic {
                generator: "tiny".into(),
                n: 256,
                d: 48,
                seed: u64::MAX - 17,
            },
        ] {
            assert_eq!(roundtrip(&spec), spec);
        }
    }

    #[test]
    fn synthetic_provenance_is_recorded_and_reconstructs_bit_exactly() {
        let ds = synthetic::csn_like(64, 9);
        let spec = DatasetSpec::from_dataset(&ds).unwrap();
        assert_eq!(
            spec,
            DatasetSpec::Synthetic { generator: "csn".into(), n: 64, d: 17, seed: 9 }
        );
        let again = spec.load().unwrap();
        assert_eq!(again.raw(), ds.raw());
    }

    #[test]
    fn all_generator_families_reconstruct() {
        let cases: Vec<Dataset> = vec![
            synthetic::csn_like(40, 1),
            synthetic::parkinsons_like(30, 2),
            synthetic::tiny_like(20, 32, 3),
            synthetic::webscope_like(25, 4),
        ];
        for ds in cases {
            let spec = DatasetSpec::from_dataset(&ds).unwrap();
            let back = spec.load().unwrap();
            assert_eq!(back.raw(), ds.raw(), "{spec:?}");
            assert_eq!((back.n, back.d), (ds.n, ds.d));
        }
    }

    #[test]
    fn registry_loads_are_stamped_with_catalog_identity() {
        // catalog identity overrides the inner generator provenance, so
        // registry datasets spec identically whether generated fresh or
        // loaded from the .fmat cache (which stores no provenance)
        let ds = registry::spec("csn-2k").unwrap().generate("csn-2k", 7);
        let spec = DatasetSpec::from_dataset(&ds).unwrap();
        assert_eq!(spec, DatasetSpec::Registry { name: "csn-2k".into(), seed: 7 });
        // the spec carries its own seed: reconstruction cannot drift to
        // some other run's seed
        assert_eq!(spec.cache_key().1, 7);
    }

    #[test]
    fn generator_sharing_a_registry_name_ships_as_synthetic() {
        // "parkinsons" is both a generator family and a registry entry
        // (n=5875). A direct parkinsons_like call must ship its own
        // (n, seed) — resolving by name would either error (size
        // mismatch) or silently regenerate with the wrong seed.
        let ds = synthetic::parkinsons_like(30, 2);
        let spec = DatasetSpec::from_dataset(&ds).unwrap();
        assert_eq!(
            spec,
            DatasetSpec::Synthetic { generator: "parkinsons".into(), n: 30, d: 22, seed: 2 }
        );
        assert_eq!(spec.load().unwrap().raw(), ds.raw());
    }

    #[test]
    fn mutating_a_dataset_invalidates_its_provenance() {
        // the recorded recipe no longer reproduces the bytes, so the
        // dataset must stop being wire-representable instead of
        // silently shipping the pre-mutation matrix
        let mut ds = synthetic::csn_like(32, 1);
        assert!(DatasetSpec::from_dataset(&ds).is_ok());
        ds.normalize_rows();
        assert!(ds.gen.is_none());
        assert!(DatasetSpec::from_dataset(&ds).is_err());

        let mut ds = synthetic::csn_like(32, 1);
        ds.center_columns();
        assert!(DatasetSpec::from_dataset(&ds).is_err());

        // registry-generated datasets are covered by the same invariant
        let mut ds = registry::spec("csn-2k").unwrap().generate("csn-2k", 7);
        assert!(DatasetSpec::from_dataset(&ds).is_ok());
        ds.center_columns();
        assert!(DatasetSpec::from_dataset(&ds).is_err());
    }

    #[test]
    fn raw_matrices_are_rejected() {
        let ds = Dataset::new("adhoc", 4, 2, vec![0.0; 8]);
        assert!(DatasetSpec::from_dataset(&ds).is_err());
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            r#"{"name":"csn-2k"}"#,
            r#"{"kind":"warp"}"#,
            r#"{"kind":"registry","seed":"1"}"#,
            r#"{"kind":"registry","name":"csn-2k"}"#,
            r#"{"kind":"synthetic","generator":"csn","n":10}"#,
            r#"{"kind":"synthetic","generator":"csn","n":10,"d":17,"seed":-1}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(DatasetSpec::from_json(&v).is_err(), "accepted {bad}");
        }
        // unknown generator family fails at load, with a protocol error
        let spec = DatasetSpec::Synthetic { generator: "warp".into(), n: 4, d: 2, seed: 0 };
        assert!(spec.load().is_err());
        // dimension mismatch with the family fails at load
        let spec = DatasetSpec::Synthetic { generator: "csn".into(), n: 8, d: 3, seed: 0 };
        assert!(spec.load().is_err());
    }
}

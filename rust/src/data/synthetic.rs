//! Synthetic equivalents of the paper's evaluation datasets.
//!
//! The originals (CSN accelerometer features, Tiny Images, Parkinsons
//! voice measurements, Yahoo Webscope R6A click features) are not
//! redistributable / not available offline, so we generate data with the
//! same dimensionality, scale and geometric character (DESIGN.md §4-5).
//! Both objective families only interact with the data through pairwise
//! euclidean geometry, so mixture-of-Gaussians surrogates with matching
//! (n, d) exercise exactly the same code paths and trade-off curves.

use crate::data::spec::DatasetSpec;
use crate::data::Dataset;
use crate::util::rng::Rng;

/// Record wire provenance on a generated dataset: the named generator
/// families below are deterministic in `(n, d, seed)`, so this spec is
/// enough to rebuild the exact matrix on a remote worker.
fn with_provenance(mut ds: Dataset, generator: &str, seed: u64) -> Dataset {
    ds.gen = Some(DatasetSpec::Synthetic {
        generator: generator.to_string(),
        n: ds.n,
        d: ds.d,
        seed,
    });
    ds
}

/// Mixture-of-Gaussians generator: `centers` cluster centres at scale
/// `spread`, isotropic within-cluster noise `sigma`, optional heavy-tail
/// bursts (probability `burst_p`, multiplier `burst_scale`).
pub struct MixtureSpec {
    pub n: usize,
    pub d: usize,
    pub centers: usize,
    pub spread: f64,
    pub sigma: f64,
    pub burst_p: f64,
    pub burst_scale: f64,
}

pub fn mixture(name: &str, spec: &MixtureSpec, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from(seed);
    let mut centers = Vec::with_capacity(spec.centers);
    for _ in 0..spec.centers {
        let c: Vec<f64> = (0..spec.d).map(|_| rng.normal() * spec.spread).collect();
        centers.push(c);
    }
    let mut data = Vec::with_capacity(spec.n * spec.d);
    for _ in 0..spec.n {
        let c = &centers[rng.below(spec.centers)];
        let scale = if rng.bool(spec.burst_p) {
            spec.sigma * spec.burst_scale
        } else {
            spec.sigma
        };
        for j in 0..spec.d {
            data.push((c[j] + rng.normal() * scale) as f32);
        }
    }
    Dataset::new(name, spec.n, spec.d, data)
}

/// CSN-like: 17-dim accelerometer feature vectors, 20k points; bursts
/// model rare seismic events among background (walking/idle) clusters.
pub fn csn_like(n: usize, seed: u64) -> Dataset {
    let ds = mixture(
        "csn",
        &MixtureSpec {
            n,
            d: 17,
            centers: 12,
            spread: 2.0,
            sigma: 0.6,
            burst_p: 0.02,
            burst_scale: 6.0,
        },
        seed,
    );
    with_provenance(ds, "csn", seed)
}

/// Parkinsons-like: 22 biomedical voice attributes, 5875 points;
/// correlated clusters, normalized to zero mean / unit norm like the
/// paper's preprocessing.
pub fn parkinsons_like(n: usize, seed: u64) -> Dataset {
    let mut ds = mixture(
        "parkinsons",
        &MixtureSpec {
            n,
            d: 22,
            centers: 6,
            spread: 1.5,
            sigma: 0.8,
            burst_p: 0.0,
            burst_scale: 1.0,
        },
        seed,
    );
    ds.center_columns();
    ds.normalize_rows();
    with_provenance(ds, "parkinsons", seed)
}

/// Tiny-Images-like: unit-norm vectors in `d` dims (3072 for the 10k
/// subset; 64 for the scaled 1M-class run — see DESIGN.md §4). Structure
/// from a modest number of visual-class centres.
pub fn tiny_like(n: usize, d: usize, seed: u64) -> Dataset {
    let mut ds = mixture(
        "tiny",
        &MixtureSpec {
            n,
            d,
            centers: 32,
            spread: 1.0,
            sigma: 0.5,
            burst_p: 0.0,
            burst_scale: 1.0,
        },
        seed,
    );
    ds.normalize_rows();
    with_provenance(ds, "tiny", seed)
}

/// Webscope-R6A-like: 6-dim user features from the logistic-regression
/// featurization of the original dataset — entries in [0,1], rows on the
/// probability simplex plus a constant-ish first feature.
pub fn webscope_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from(seed);
    let d = 6;
    // a few user archetypes, Dirichlet-ish mixing
    let archetypes = 8;
    let mut protos = Vec::new();
    for _ in 0..archetypes {
        let mut p: Vec<f64> = (0..d).map(|_| rng.f64() + 0.05).collect();
        let s: f64 = p.iter().sum();
        p.iter_mut().for_each(|x| *x /= s);
        protos.push(p);
    }
    let mut data = Vec::with_capacity(n * d);
    for _ in 0..n {
        let p = &protos[rng.below(archetypes)];
        let mut row: Vec<f64> = p
            .iter()
            .map(|&x| (x + 0.15 * rng.normal()).max(1e-3))
            .collect();
        let s: f64 = row.iter().sum();
        row.iter_mut().for_each(|x| *x /= s);
        for x in row {
            data.push(x as f32);
        }
    }
    with_provenance(Dataset::new("webscope", n, d, data), "webscope", seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sq_norm;

    #[test]
    fn shapes_match_spec() {
        let ds = csn_like(500, 1);
        assert_eq!((ds.n, ds.d), (500, 17));
        let ds = parkinsons_like(200, 1);
        assert_eq!((ds.n, ds.d), (200, 22));
        let ds = tiny_like(100, 48, 1);
        assert_eq!((ds.n, ds.d), (100, 48));
        let ds = webscope_like(300, 1);
        assert_eq!((ds.n, ds.d), (300, 6));
    }

    #[test]
    fn deterministic_by_seed() {
        let a = csn_like(100, 42);
        let b = csn_like(100, 42);
        assert_eq!(a.raw(), b.raw());
        let c = csn_like(100, 43);
        assert_ne!(a.raw(), c.raw());
    }

    #[test]
    fn tiny_rows_unit_norm() {
        let ds = tiny_like(50, 32, 2);
        for i in 0..ds.n {
            assert!((sq_norm(ds.row(i as u32)) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn webscope_rows_on_simplex() {
        let ds = webscope_like(50, 3);
        for i in 0..ds.n {
            let row = ds.row(i as u32);
            assert!(row.iter().all(|&x| x > 0.0));
            let s: f64 = row.iter().map(|&x| x as f64).sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn csn_bursts_produce_outliers() {
        let ds = csn_like(5_000, 4);
        let norms: Vec<f64> = (0..ds.n).map(|i| sq_norm(ds.row(i as u32)).sqrt()).collect();
        let mean = norms.iter().sum::<f64>() / norms.len() as f64;
        let max = norms.iter().copied().max_by(f64::total_cmp).unwrap_or(0.0);
        assert!(max > 2.0 * mean, "expected heavy tail: max {max} mean {mean}");
    }

    #[test]
    fn clusters_are_distinguishable() {
        // mixture data should have much larger spread than within-cluster
        // noise: the nearest-neighbor distance of a random subset should be
        // well below the average pairwise distance.
        let ds = csn_like(300, 5);
        let mut rng = crate::util::rng::Rng::seed_from(5);
        let ids = rng.sample_indices(ds.n, 60);
        let mut all = Vec::new();
        let mut nn = Vec::new();
        for (a, &i) in ids.iter().enumerate() {
            let mut best = f64::INFINITY;
            for (b, &j) in ids.iter().enumerate() {
                if a == b {
                    continue;
                }
                let d = crate::linalg::sq_dist(ds.row(i), ds.row(j));
                all.push(d);
                best = best.min(d);
            }
            nn.push(best);
        }
        let mean_all = all.iter().sum::<f64>() / all.len() as f64;
        let mean_nn = nn.iter().sum::<f64>() / nn.len() as f64;
        assert!(mean_nn < 0.5 * mean_all, "nn {mean_nn} vs all {mean_all}");
    }
}

//! In-process backend: the fixed-capacity thread pool that used to live
//! inside `coordinator::cluster`, refactored behind [`Backend`].
//!
//! Machines execute on a small pool of OS threads (the testbed is a
//! single host); XLA work funnels through the engine's device thread.
//! Rounds are streaming ([`Backend::open_round`]): parts enter a shared
//! condvar-driven work queue the moment they are submitted — while
//! earlier parts of the same round are already executing — and worker
//! threads stream a [`PartEvent::Done`] the moment each machine
//! finishes, so a consumer can overlap next-round work (and, under a
//! contiguous partitioner, next-round *dispatch*) with in-flight
//! machines instead of idling at the round barrier.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};

use crate::algorithms::Compressor;
use crate::coordinator::capacity::CapacityProfile;
use crate::dist::{Backend, PartEvent, RoundSession, RoundSink};
use crate::error::Result;
use crate::objectives::Problem;
use crate::trace;

/// Thread-pool execution backend with hard per-machine capacities.
pub struct LocalBackend {
    profile: CapacityProfile,
    threads: usize,
}

/// Everything a round's worker threads share. Owned (the threads
/// outlive the caller's borrows): the [`Problem`] clone shares the
/// dataset, constraint and eval-counter Arcs, so cloning is cheap and
/// oracle accounting still lands on the caller's counter.
struct LocalRound {
    problem: Problem,
    compressor: Box<dyn Compressor>,
    queue: Mutex<LocalQueue>,
    cv: Condvar,
}

/// The round's streamed work queue: tasks accumulate as the session
/// submits parts; `closed` tells idle workers the list is final.
struct LocalQueue {
    tasks: VecDeque<(usize, Vec<u32>, u64)>,
    closed: bool,
}

/// Session sink feeding a round's shared queue. Worker threads are
/// spawned lazily, one per submitted part up to the configured pool
/// width — an empty round spawns nothing, a 1-part round spawns one
/// thread, and a speculative session costs only what it dispatches.
struct LocalSink {
    round: Arc<LocalRound>,
    tx: mpsc::Sender<Result<PartEvent>>,
    threads: usize,
    spawned: usize,
}

impl RoundSink for LocalSink {
    fn submit(&mut self, idx: usize, part: Vec<u32>, seed: u64) -> Result<()> {
        {
            // invariant: queue critical sections only push/pop/flag —
            // compression runs outside the lock, so no holder panics
            // and the mutex is never poisoned
            let mut q = self.round.queue.lock().unwrap();
            q.tasks.push_back((idx, part, seed));
        }
        self.round.cv.notify_one();
        if self.spawned < self.threads {
            let thread_id = self.spawned;
            self.spawned += 1;
            let round = Arc::clone(&self.round);
            let tx = self.tx.clone();
            std::thread::spawn(move || worker_loop(round, tx, thread_id));
        }
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        // invariant: non-panicking critical section (see submit)
        let mut q = self.round.queue.lock().unwrap();
        q.closed = true;
        drop(q);
        self.round.cv.notify_all();
        Ok(())
    }

    fn abort(&mut self) {
        // invariant: non-panicking critical section (see submit)
        let mut q = self.round.queue.lock().unwrap();
        // discard queued work; in-flight results go to a channel whose
        // receiver is gone, which stops the workers
        q.tasks.clear();
        q.closed = true;
        drop(q);
        self.round.cv.notify_all();
    }
}

impl LocalBackend {
    /// Uniform fleet: every machine holds µ items (the paper's setting).
    pub fn new(capacity: usize) -> Self {
        Self::with_profile(CapacityProfile::uniform(capacity))
    }

    /// Heterogeneous fleet: virtual machine `j` holds `µ_{j mod L}`.
    pub fn with_profile(profile: CapacityProfile) -> Self {
        LocalBackend { profile, threads: Self::default_threads() }
    }

    /// Default worker-thread count: host parallelism, clamped to the
    /// single-host testbed's useful range.
    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .clamp(1, 8)
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl Backend for LocalBackend {
    fn name(&self) -> &'static str {
        "local"
    }

    fn profile(&self) -> CapacityProfile {
        self.profile.clone()
    }

    fn open_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        round_seed: u64,
    ) -> Result<RoundSession> {
        let round = Arc::new(LocalRound {
            problem: problem.clone(),
            compressor: compressor.boxed_clone(),
            queue: Mutex::new(LocalQueue { tasks: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        });
        let (tx, rx) = mpsc::channel();
        // worker threads are spawned by the sink as parts stream in
        Ok(RoundSession::new(
            Box::new(LocalSink {
                round,
                tx,
                threads: self.threads.max(1),
                spawned: 0,
            }),
            rx,
            self.profile.clone(),
            round_seed,
        ))
    }
}

/// One pool thread: drain the round's queue until it is closed and
/// empty (or the consumer gives up). `thread_id` names the thread's
/// trace track (`local-<id>`).
fn worker_loop(round: Arc<LocalRound>, tx: mpsc::Sender<Result<PartEvent>>, thread_id: usize) {
    loop {
        let task = {
            // invariant: non-panicking critical section (see submit)
            let mut q = round.queue.lock().unwrap();
            loop {
                if let Some(t) = q.tasks.pop_front() {
                    break Some(t);
                }
                if q.closed {
                    break None;
                }
                // invariant: wait() re-acquires the same never-poisoned
                // queue mutex
                q = round.cv.wait(q).unwrap();
            }
        };
        let Some((idx, part, seed)) = task else { break };
        let t0 = trace::now_us();
        let sol = round.compressor.compress(&round.problem, &part, seed);
        if trace::enabled() {
            trace::span(
                &format!("local-{thread_id}"),
                "execute",
                t0,
                vec![
                    ("part", trace::ArgValue::U64(idx as u64)),
                    ("items", trace::ArgValue::U64(part.len() as u64)),
                ],
            );
        }
        let event = match sol {
            Ok(solution) => Ok(PartEvent::Done { part: idx, solution }),
            Err(e) => Err(e),
        };
        let fatal = event.is_err();
        // a closed channel means the consumer gave up on the round —
        // stop quietly
        if tx.send(event).is_err() || fatal {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::data::synthetic;
    use crate::error::Error;

    #[test]
    fn matches_trait_contract_on_order_and_capacity() {
        let ds = Arc::new(synthetic::csn_like(120, 2));
        let p = Problem::exemplar(ds, 3, 2);
        let backend = LocalBackend::new(40).with_threads(3);
        let parts: Vec<Vec<u32>> = (0..4).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        assert_eq!(out.solutions.len(), 4);
        assert_eq!(out.requeued_parts, 0);
        for (i, s) in out.solutions.iter().enumerate() {
            for &item in &s.items {
                assert!(parts[i].contains(&item), "machine {i} leaked items");
            }
        }
    }

    #[test]
    fn events_stream_one_done_per_part() {
        let ds = Arc::new(synthetic::csn_like(120, 4));
        let p = Problem::exemplar(ds, 3, 4);
        let backend = LocalBackend::new(40).with_threads(2);
        let parts: Vec<Vec<u32>> = (0..4).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let mut handle = backend.submit_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        let mut seen = vec![false; parts.len()];
        while let Some(ev) = handle.next_event() {
            match ev.unwrap() {
                PartEvent::Done { part, solution } => {
                    assert!(!seen[part], "part {part} completed twice");
                    seen[part] = true;
                    assert!(!solution.items.is_empty());
                }
                other => panic!("unexpected event on a healthy local round: {other:?}"),
            }
        }
        assert!(seen.iter().all(|&s| s), "missing Done events: {seen:?}");
        assert_eq!(handle.completed(), 4);
        // streamed events must agree with the barrier wrapper bit-exactly
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        assert_eq!(out.solutions.len(), 4);
    }

    #[test]
    fn streamed_parts_match_the_batch_round_bit_exactly() {
        // parts submitted one at a time (earlier parts already
        // executing) must produce the identical round: positional seeds
        // come from submission order, not submission timing
        let ds = Arc::new(synthetic::csn_like(120, 6));
        let p = Problem::exemplar(ds, 3, 6);
        let backend = LocalBackend::new(40).with_threads(2);
        let parts: Vec<Vec<u32>> = (0..4).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let mut session = backend.open_round(&p, &LazyGreedy::new(), 5).unwrap();
        for part in &parts {
            session.submit_part(part.clone()).unwrap();
        }
        let streamed = session.close().unwrap().finish().unwrap();
        let batch = backend.run_round(&p, &LazyGreedy::new(), &parts, 5).unwrap();
        assert_eq!(streamed.solutions.len(), batch.solutions.len());
        for (x, y) in streamed.solutions.iter().zip(&batch.solutions) {
            assert_eq!(x.items, y.items);
            assert_eq!(x.value.to_bits(), y.value.to_bits());
        }
    }

    #[test]
    fn capacity_error_carries_machine_index() {
        let ds = Arc::new(synthetic::csn_like(100, 1));
        let p = Problem::exemplar(ds, 5, 1);
        let backend = LocalBackend::new(10);
        let parts = vec![(0..5).collect::<Vec<u32>>(), (0..11).collect::<Vec<u32>>()];
        let err = backend.run_round(&p, &LazyGreedy::new(), &parts, 0).unwrap_err();
        match err {
            Error::CapacityExceeded { capacity: 10, got: 11, ctx } => {
                assert!(ctx.contains("machine 1"), "ctx: {ctx}");
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn heterogeneous_profile_enforces_per_machine_classes() {
        let ds = Arc::new(synthetic::csn_like(100, 1));
        let p = Problem::exemplar(ds, 3, 1);
        let profile = CapacityProfile::parse("40,20").unwrap();
        let backend = LocalBackend::with_profile(profile.clone());
        assert_eq!(backend.profile(), profile);
        assert_eq!(backend.capacity(), 40);
        // parts sized to the cycle 40, 20, 40 pass…
        let fits = vec![
            (0..40).collect::<Vec<u32>>(),
            (40..60).collect::<Vec<u32>>(),
            (60..100).collect::<Vec<u32>>(),
        ];
        let out = backend.run_round(&p, &LazyGreedy::new(), &fits, 5).unwrap();
        assert_eq!(out.solutions.len(), 3);
        // …but a 30-item part on the 20-class machine is rejected
        let overloaded = vec![(0..40).collect::<Vec<u32>>(), (40..70).collect::<Vec<u32>>()];
        let err = backend.run_round(&p, &LazyGreedy::new(), &overloaded, 5).unwrap_err();
        assert!(matches!(err, Error::CapacityExceeded { capacity: 20, got: 30, .. }), "{err}");
    }
}

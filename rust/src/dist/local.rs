//! In-process backend: the fixed-capacity thread pool that used to live
//! inside `coordinator::cluster`, refactored behind [`Backend`].
//!
//! Machines execute on a small pool of OS threads (the testbed is a
//! single host); XLA work funnels through the engine's device thread.
//! Rounds are event-driven ([`Backend::submit_round`]): worker threads
//! stream a [`PartEvent::Done`] the moment each machine finishes, so a
//! consumer can overlap next-round work with in-flight machines instead
//! of idling at the round barrier.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use crate::algorithms::Compressor;
use crate::coordinator::capacity::CapacityProfile;
use crate::dist::{enforce_profile, machine_seeds, Backend, PartEvent, RoundHandle};
use crate::error::Result;
use crate::objectives::Problem;

/// Thread-pool execution backend with hard per-machine capacities.
pub struct LocalBackend {
    profile: CapacityProfile,
    threads: usize,
}

/// Everything a round's worker threads share. Owned (the threads
/// outlive the caller's borrows): the [`Problem`] clone shares the
/// dataset, constraint and eval-counter Arcs, so cloning is cheap and
/// oracle accounting still lands on the caller's counter.
struct LocalRound {
    problem: Problem,
    compressor: Box<dyn Compressor>,
    parts: Vec<Vec<u32>>,
    seeds: Vec<u64>,
    next: AtomicUsize,
}

impl LocalBackend {
    /// Uniform fleet: every machine holds µ items (the paper's setting).
    pub fn new(capacity: usize) -> Self {
        Self::with_profile(CapacityProfile::uniform(capacity))
    }

    /// Heterogeneous fleet: virtual machine `j` holds `µ_{j mod L}`.
    pub fn with_profile(profile: CapacityProfile) -> Self {
        LocalBackend { profile, threads: Self::default_threads() }
    }

    /// Default worker-thread count: host parallelism, clamped to the
    /// single-host testbed's useful range.
    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .clamp(1, 8)
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl Backend for LocalBackend {
    fn name(&self) -> &'static str {
        "local"
    }

    fn profile(&self) -> CapacityProfile {
        self.profile.clone()
    }

    fn submit_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundHandle> {
        // capacity enforcement before any work starts
        enforce_profile(&self.profile, parts)?;
        if parts.is_empty() {
            return Ok(RoundHandle::empty());
        }

        let round = Arc::new(LocalRound {
            problem: problem.clone(),
            compressor: compressor.boxed_clone(),
            parts: parts.to_vec(),
            // per-machine deterministic seeds
            seeds: machine_seeds(round_seed, parts.len()),
            next: AtomicUsize::new(0),
        });
        let (tx, rx) = mpsc::channel();
        let workers = self.threads.min(parts.len()).max(1);
        for _ in 0..workers {
            let round = Arc::clone(&round);
            let tx = tx.clone();
            std::thread::spawn(move || loop {
                let i = round.next.fetch_add(1, Ordering::Relaxed);
                if i >= round.parts.len() {
                    break;
                }
                let sol =
                    round.compressor.compress(&round.problem, &round.parts[i], round.seeds[i]);
                let event = match sol {
                    Ok(solution) => Ok(PartEvent::Done { part: i, solution }),
                    Err(e) => Err(e),
                };
                let fatal = event.is_err();
                // a closed channel means the consumer gave up on the
                // round — stop quietly
                if tx.send(event).is_err() || fatal {
                    break;
                }
            });
        }
        Ok(RoundHandle::new(rx, parts.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::data::synthetic;
    use crate::error::Error;

    #[test]
    fn matches_trait_contract_on_order_and_capacity() {
        let ds = Arc::new(synthetic::csn_like(120, 2));
        let p = Problem::exemplar(ds, 3, 2);
        let backend = LocalBackend::new(40).with_threads(3);
        let parts: Vec<Vec<u32>> = (0..4).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        assert_eq!(out.solutions.len(), 4);
        assert_eq!(out.requeued_parts, 0);
        for (i, s) in out.solutions.iter().enumerate() {
            for &item in &s.items {
                assert!(parts[i].contains(&item), "machine {i} leaked items");
            }
        }
    }

    #[test]
    fn events_stream_one_done_per_part() {
        let ds = Arc::new(synthetic::csn_like(120, 4));
        let p = Problem::exemplar(ds, 3, 4);
        let backend = LocalBackend::new(40).with_threads(2);
        let parts: Vec<Vec<u32>> = (0..4).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let mut handle = backend.submit_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        let mut seen = vec![false; parts.len()];
        while let Some(ev) = handle.next_event() {
            match ev.unwrap() {
                PartEvent::Done { part, solution } => {
                    assert!(!seen[part], "part {part} completed twice");
                    seen[part] = true;
                    assert!(!solution.items.is_empty());
                }
                other => panic!("unexpected event on a healthy local round: {other:?}"),
            }
        }
        assert!(seen.iter().all(|&s| s), "missing Done events: {seen:?}");
        assert_eq!(handle.completed(), 4);
        // streamed events must agree with the barrier wrapper bit-exactly
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        assert_eq!(out.solutions.len(), 4);
    }

    #[test]
    fn capacity_error_carries_machine_index() {
        let ds = Arc::new(synthetic::csn_like(100, 1));
        let p = Problem::exemplar(ds, 5, 1);
        let backend = LocalBackend::new(10);
        let parts = vec![(0..5).collect::<Vec<u32>>(), (0..11).collect::<Vec<u32>>()];
        let err = backend.run_round(&p, &LazyGreedy::new(), &parts, 0).unwrap_err();
        match err {
            Error::CapacityExceeded { capacity: 10, got: 11, ctx } => {
                assert!(ctx.contains("machine 1"), "ctx: {ctx}");
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn heterogeneous_profile_enforces_per_machine_classes() {
        let ds = Arc::new(synthetic::csn_like(100, 1));
        let p = Problem::exemplar(ds, 3, 1);
        let profile = CapacityProfile::parse("40,20").unwrap();
        let backend = LocalBackend::with_profile(profile.clone());
        assert_eq!(backend.profile(), profile);
        assert_eq!(backend.capacity(), 40);
        // parts sized to the cycle 40, 20, 40 pass…
        let fits = vec![
            (0..40).collect::<Vec<u32>>(),
            (40..60).collect::<Vec<u32>>(),
            (60..100).collect::<Vec<u32>>(),
        ];
        let out = backend.run_round(&p, &LazyGreedy::new(), &fits, 5).unwrap();
        assert_eq!(out.solutions.len(), 3);
        // …but a 30-item part on the 20-class machine is rejected
        let overloaded = vec![(0..40).collect::<Vec<u32>>(), (40..70).collect::<Vec<u32>>()];
        let err = backend.run_round(&p, &LazyGreedy::new(), &overloaded, 5).unwrap_err();
        assert!(matches!(err, Error::CapacityExceeded { capacity: 20, got: 30, .. }), "{err}");
    }
}

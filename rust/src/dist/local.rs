//! In-process backend: the fixed-capacity thread pool that used to live
//! inside `coordinator::cluster`, refactored behind [`Backend`].
//!
//! Machines execute on a small pool of OS threads (the testbed is a
//! single host); XLA work funnels through the engine's device thread.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::algorithms::{Compressor, Solution};
use crate::coordinator::capacity::CapacityProfile;
use crate::dist::{enforce_profile, machine_seeds, Backend, RoundOutcome};
use crate::error::{Error, Result};
use crate::objectives::Problem;

/// Thread-pool execution backend with hard per-machine capacities.
pub struct LocalBackend {
    profile: CapacityProfile,
    threads: usize,
}

impl LocalBackend {
    /// Uniform fleet: every machine holds µ items (the paper's setting).
    pub fn new(capacity: usize) -> Self {
        Self::with_profile(CapacityProfile::uniform(capacity))
    }

    /// Heterogeneous fleet: virtual machine `j` holds `µ_{j mod L}`.
    pub fn with_profile(profile: CapacityProfile) -> Self {
        LocalBackend { profile, threads: Self::default_threads() }
    }

    /// Default worker-thread count: host parallelism, clamped to the
    /// single-host testbed's useful range.
    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .clamp(1, 8)
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl Backend for LocalBackend {
    fn name(&self) -> &'static str {
        "local"
    }

    fn profile(&self) -> CapacityProfile {
        self.profile.clone()
    }

    fn run_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundOutcome> {
        // capacity enforcement before any work starts
        enforce_profile(&self.profile, parts)?;

        // per-machine deterministic seeds
        let seeds = machine_seeds(round_seed, parts.len());

        let next = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<Result<Solution>>>> =
            Mutex::new((0..parts.len()).map(|_| None).collect());

        let workers = self.threads.min(parts.len()).max(1);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= parts.len() {
                        break;
                    }
                    let sol = compressor.compress(problem, &parts[i], seeds[i]);
                    results.lock().unwrap()[i] = Some(sol);
                });
            }
        });

        let results = results.into_inner().unwrap();
        let mut solutions = Vec::with_capacity(parts.len());
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Some(Ok(sol)) => solutions.push(sol),
                Some(Err(e)) => return Err(e),
                None => return Err(Error::Worker(format!("machine {i} never ran"))),
            }
        }
        Ok(RoundOutcome { solutions, requeued_parts: 0, requeued_ids: 0, sim_delay_ms: 0.0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::data::synthetic;
    use std::sync::Arc;

    #[test]
    fn matches_trait_contract_on_order_and_capacity() {
        let ds = Arc::new(synthetic::csn_like(120, 2));
        let p = Problem::exemplar(ds, 3, 2);
        let backend = LocalBackend::new(40).with_threads(3);
        let parts: Vec<Vec<u32>> = (0..4).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        assert_eq!(out.solutions.len(), 4);
        assert_eq!(out.requeued_parts, 0);
        for (i, s) in out.solutions.iter().enumerate() {
            for &item in &s.items {
                assert!(parts[i].contains(&item), "machine {i} leaked items");
            }
        }
    }

    #[test]
    fn capacity_error_carries_machine_index() {
        let ds = Arc::new(synthetic::csn_like(100, 1));
        let p = Problem::exemplar(ds, 5, 1);
        let backend = LocalBackend::new(10);
        let parts = vec![(0..5).collect::<Vec<u32>>(), (0..11).collect::<Vec<u32>>()];
        let err = backend.run_round(&p, &LazyGreedy::new(), &parts, 0).unwrap_err();
        match err {
            Error::CapacityExceeded { capacity: 10, got: 11, ctx } => {
                assert!(ctx.contains("machine 1"), "ctx: {ctx}");
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn heterogeneous_profile_enforces_per_machine_classes() {
        let ds = Arc::new(synthetic::csn_like(100, 1));
        let p = Problem::exemplar(ds, 3, 1);
        let profile = CapacityProfile::parse("40,20").unwrap();
        let backend = LocalBackend::with_profile(profile.clone());
        assert_eq!(backend.profile(), profile);
        assert_eq!(backend.capacity(), 40);
        // parts sized to the cycle 40, 20, 40 pass…
        let fits = vec![
            (0..40).collect::<Vec<u32>>(),
            (40..60).collect::<Vec<u32>>(),
            (60..100).collect::<Vec<u32>>(),
        ];
        let out = backend.run_round(&p, &LazyGreedy::new(), &fits, 5).unwrap();
        assert_eq!(out.solutions.len(), 3);
        // …but a 30-item part on the 20-class machine is rejected
        let overloaded = vec![(0..40).collect::<Vec<u32>>(), (40..70).collect::<Vec<u32>>()];
        let err = backend.run_round(&p, &LazyGreedy::new(), &overloaded, 5).unwrap_err();
        assert!(matches!(err, Error::CapacityExceeded { capacity: 20, got: 30, .. }), "{err}");
    }
}

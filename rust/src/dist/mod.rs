//! Pluggable execution backends for the coordinator.
//!
//! The tree framework (and the two-round baselines) express each round as
//! "compress every part of a partition on a fixed-capacity machine". This
//! module abstracts *where* those machines live behind the [`Backend`]
//! trait, with three implementations:
//!
//! | backend            | machines are…                | use case                      |
//! |--------------------|------------------------------|-------------------------------|
//! | [`LocalBackend`]   | worker threads in-process    | default; single-host runs     |
//! | [`TcpBackend`]     | `hss worker` processes over a| real multi-process / multi-   |
//! |                    | length-prefixed TCP protocol | host horizontal scaling       |
//! | [`SimBackend`]     | a deterministic single-thread| fault-tolerance & robustness  |
//! |                    | simulator with fault injection| experiments, scenario tests  |
//!
//! All backends share the same contract: capacity is enforced *before*
//! any work starts (fixed capacity is the paper's premise), per-machine
//! seeds are derived positionally from the round seed, and solutions are
//! keyed by part index — so for a given `(problem, parts, round_seed)`
//! all three backends produce **identical** solutions. Fault injection
//! and wire transport change cost and availability, never the answer.
//!
//! Rounds are **streaming** (Backend v3): the required trait method is
//! [`Backend::open_round`], which returns an incremental
//! [`RoundSession`] — parts are submitted one at a time
//! ([`RoundSession::submit_part`]) and start executing immediately,
//! while earlier parts of the same logical round are still in flight;
//! [`RoundSession::close`] seals the part list and hands back the
//! [`RoundHandle`] streaming per-part [`PartEvent`]s as machines report
//! — completions, requeues after machine loss, fleet departures,
//! injected virtual delay, problem-spec shipments. The one-shot
//! [`Backend::submit_round`] (open + submit all + close) and the
//! classic blocking [`Backend::run_round`] barrier are provided
//! wrappers, so single-round call sites are unchanged while the tree
//! runner overlaps next-round preparation — and, under a contiguous
//! partitioner, next-round *dispatch* — with a round's stragglers.
//! [`TcpBackend`] additionally allows the next round's session to open
//! while stragglers from the current one drain.
//!
//! Fleets may be **capacity-heterogeneous**: every backend carries a
//! [`CapacityProfile`] (per-machine-class µ_p, cyclic — see
//! [`crate::coordinator::capacity`]) instead of a single scalar, and
//! enforcement checks part `j` against the virtual capacity `µ_{j mod
//! L}` the planner sized it for. [`TcpBackend`] additionally learns each
//! worker's real µ from the protocol handshake and dispatches a part
//! only to workers that can hold it.

pub mod local;
pub mod protocol;
pub mod sim;
pub mod tcp;
pub mod worker;

pub use local::LocalBackend;
pub use sim::{FaultPlan, SimBackend};
pub use tcp::TcpBackend;

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::algorithms::{Compressor, Solution};
use crate::constraints::Constraint;
use crate::coordinator::capacity::CapacityProfile;
use crate::data::DatasetRef;
use crate::dist::protocol::ProblemSpec;
use crate::error::{Error, Result};
use crate::objectives::{Objective, Problem};
use crate::runtime::EngineChoice;
use crate::trace;
use crate::util::rng::Rng;

/// Outcome of one compression round executed by a backend.
#[derive(Debug, Clone, Default)]
pub struct RoundOutcome {
    /// One solution per part, order preserved.
    pub solutions: Vec<Solution>,
    /// Parts that were dispatched to a machine that was lost (worker
    /// disconnect, injected fault) and re-executed elsewhere.
    pub requeued_parts: usize,
    /// Item ids shipped a *second* time because their machine was lost
    /// mid-flight — shuffle accounting charges these on top of the
    /// first dispatch of every part.
    pub requeued_ids: usize,
    /// Virtual wall-clock added by injected stragglers/retries
    /// ([`SimBackend`] only; 0 elsewhere).
    pub sim_delay_ms: f64,
    /// [`ProblemSpec`] bytes shipped over the wire this round (protocol
    /// v4 interning: a spec crosses once per (worker connection,
    /// problem identity); after that every compress request carries an
    /// O(1) problem id). 0 on backends with no wire.
    pub spec_bytes: u64,
}

/// Per-worker utilization and telemetry accumulated over a backend's
/// lifetime (protocol v5). Produced by [`Backend::worker_stats`]; the
/// run summary and the dispatch bench report these. Purely
/// observational — stats never influence dispatch or the answer.
///
/// Counter semantics: `parts`, `oracle_evals`, `busy_ms`,
/// `queue_wait_ms` and the `bulk_gain_*` pair are *sums* over completed
/// parts; the cache fields are the worker's own cumulative gauges
/// (dataset cache = process lifetime, problem-id table = connection
/// lifetime), so the coordinator keeps the latest reported value rather
/// than summing; `engine` is likewise a latest-wins gauge naming the
/// compute engine serving the worker's current connection.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerStats {
    /// Worker identity (`host:port` for TCP fleets).
    pub addr: String,
    /// Parts this worker completed (requeued attempts don't count).
    pub parts: u64,
    /// Worker-side oracle evaluations folded into completed parts.
    pub oracle_evals: u64,
    /// Total worker-reported execute wall time, milliseconds.
    pub busy_ms: f64,
    /// Total worker-reported request queue wait, milliseconds.
    pub queue_wait_ms: f64,
    /// Worker dataset-cache hits (cumulative gauge, process lifetime).
    pub dataset_hits: u64,
    /// Worker dataset-cache misses (cumulative gauge).
    pub dataset_misses: u64,
    /// Problem-id-table hits on the worker's current connection.
    pub problem_hits: u64,
    /// Problem-id-table misses (unknown id → spec reship needed).
    pub problem_misses: u64,
    /// Problem-id-table evictions on the worker's current connection.
    pub problem_evictions: u64,
    /// Payload bytes (sent + received) exchanged with this worker over
    /// binary-negotiated connections (protocol v6). Sum over the
    /// backend's lifetime, charged whatever the part's outcome.
    pub payload_bytes_binary: u64,
    /// Payload bytes exchanged over JSON-mode connections — nonzero for
    /// JSON-only peers and for pre-negotiation handshake traffic.
    pub payload_bytes_json: u64,
    /// Wire name of the compute engine serving this worker's current
    /// connection (`native` / `xla`), set at handshake and reconfirmed
    /// by each solution's telemetry. Empty until a handshake resolves.
    pub engine: String,
    /// Batched-gain (`gains_for`) calls this worker's oracles answered,
    /// summed over completed parts (protocol v6 engine telemetry).
    pub bulk_gain_calls: u64,
    /// Candidates evaluated across those batched calls (sum).
    pub bulk_gain_candidates: u64,
}

impl WorkerStats {
    /// The stats accumulated *since* an earlier snapshot of the same
    /// worker: sum counters subtract, gauge fields (cache counters,
    /// `engine`) keep `self`'s latest value — the snapshot/delta API
    /// that lets each job report only its own interval instead of the
    /// backend's process-lifetime totals. Saturating, so a worker
    /// reconnect that resets a sum never yields a negative delta.
    pub fn delta_since(&self, earlier: &WorkerStats) -> WorkerStats {
        WorkerStats {
            addr: self.addr.clone(),
            parts: self.parts.saturating_sub(earlier.parts),
            oracle_evals: self.oracle_evals.saturating_sub(earlier.oracle_evals),
            busy_ms: (self.busy_ms - earlier.busy_ms).max(0.0),
            queue_wait_ms: (self.queue_wait_ms - earlier.queue_wait_ms).max(0.0),
            // gauges: the worker's own cumulative counters and the
            // connection's engine are latest-wins, not interval sums
            dataset_hits: self.dataset_hits,
            dataset_misses: self.dataset_misses,
            problem_hits: self.problem_hits,
            problem_misses: self.problem_misses,
            problem_evictions: self.problem_evictions,
            payload_bytes_binary: self
                .payload_bytes_binary
                .saturating_sub(earlier.payload_bytes_binary),
            payload_bytes_json: self
                .payload_bytes_json
                .saturating_sub(earlier.payload_bytes_json),
            engine: self.engine.clone(),
            bulk_gain_calls: self.bulk_gain_calls.saturating_sub(earlier.bulk_gain_calls),
            bulk_gain_candidates: self
                .bulk_gain_candidates
                .saturating_sub(earlier.bulk_gain_candidates),
        }
    }
}

/// Per-worker delta between two [`Backend::worker_stats`] snapshots,
/// matched by address. Workers absent from `earlier` (joined since the
/// snapshot) delta against a zero baseline; workers absent from `now`
/// are dropped (they contributed nothing in the interval).
pub fn stats_delta(now: &[WorkerStats], earlier: &[WorkerStats]) -> Vec<WorkerStats> {
    now.iter()
        .map(|w| match earlier.iter().find(|e| e.addr == w.addr) {
            Some(e) => w.delta_since(e),
            None => w.clone(),
        })
        .collect()
}

/// One observable state change of an in-flight round.
///
/// Events stream out of a [`RoundHandle`] as they happen, so the
/// coordinator can overlap next-round preparation with the round's
/// stragglers instead of idling at a barrier. Ordering guarantees (also
/// documented normatively in `docs/PROTOCOL.md`):
///
/// * each part produces **exactly one** [`PartEvent::Done`] per round
///   (or the round fails with an error before that);
/// * every [`PartEvent::Requeued`] for a part precedes that part's
///   `Done`;
/// * events for *different* parts arrive in completion order, which is
///   execution-dependent — consumers must never let it influence the
///   answer (solutions are keyed by part index for exactly this reason).
#[derive(Debug, Clone)]
pub enum PartEvent {
    /// Part `part` finished on some machine.
    Done {
        part: usize,
        solution: Solution,
    },
    /// Part `part` was in flight on a machine that was lost; it went
    /// back on the queue and its `reshipped_ids` item ids will cross
    /// the coordinator↔machine boundary a second time.
    Requeued {
        part: usize,
        reshipped_ids: usize,
    },
    /// A machine left the fleet mid-round (worker disconnect, injected
    /// fault). Purely informational — the affected part surfaces
    /// separately as [`PartEvent::Requeued`].
    MachineLost {
        machine: String,
        detail: String,
    },
    /// Injected virtual straggler latency ([`SimBackend`] only).
    Delay {
        part: usize,
        virtual_ms: f64,
    },
    /// A full [`ProblemSpec`] crossed the coordinator↔machine boundary
    /// (protocol v4 `define-problem` interning: once per (worker
    /// connection, problem identity); every other request ships an O(1)
    /// problem id). Purely cost telemetry — never changes the answer.
    SpecShipped {
        bytes: usize,
    },
}

/// Receiving end of one submitted round: yields [`PartEvent`]s as they
/// happen and aggregates them into a [`RoundOutcome`].
///
/// Two consumption styles:
///
/// * **barrier** — call [`RoundHandle::finish`] immediately after
///   submitting; it drains every event and returns the classic
///   [`RoundOutcome`] (this is what the [`Backend::run_round`] default
///   wrapper does);
/// * **pipelined** — loop on [`RoundHandle::next_event`] and react to
///   each event as it arrives (the tree runner unions partial
///   solutions and prepares the next round while stragglers finish).
///   `next_event` returns `None` the moment the last part completes —
///   *before* any backend-internal teardown — so the consumer never
///   waits on machinery, only on results.
pub struct RoundHandle {
    rx: mpsc::Receiver<Result<PartEvent>>,
    expected: usize,
    done: usize,
    failed: bool,
}

impl RoundHandle {
    /// Wrap a backend's event channel; `expected` is the round's part
    /// count (the handle completes after that many `Done` events).
    pub fn new(rx: mpsc::Receiver<Result<PartEvent>>, expected: usize) -> RoundHandle {
        RoundHandle { rx, expected, done: 0, failed: false }
    }

    /// A handle for an empty round (no parts): completes immediately.
    pub fn empty() -> RoundHandle {
        let (_tx, rx) = mpsc::channel();
        RoundHandle::new(rx, 0)
    }

    /// Number of parts this round was submitted with.
    pub fn parts(&self) -> usize {
        self.expected
    }

    /// Parts that have reported `Done` so far.
    pub fn completed(&self) -> usize {
        self.done
    }

    /// Block for the next event. Returns `None` once every part has
    /// completed (or after a fatal error has been yielded). A backend
    /// that drops its event channel before the round is complete
    /// surfaces as an error event, never a silent `None`.
    pub fn next_event(&mut self) -> Option<Result<PartEvent>> {
        if self.failed || self.done >= self.expected {
            return None;
        }
        match self.rx.recv() {
            Ok(Ok(ev)) => {
                if matches!(ev, PartEvent::Done { .. }) {
                    self.done += 1;
                }
                if trace::enabled() {
                    match &ev {
                        PartEvent::Done { part, solution } => trace::instant(
                            trace::COORDINATOR_TRACK,
                            "part.done",
                            vec![
                                ("part", trace::ArgValue::U64(*part as u64)),
                                (
                                    "items",
                                    trace::ArgValue::U64(solution.items.len() as u64),
                                ),
                            ],
                        ),
                        PartEvent::Requeued { part, reshipped_ids } => trace::instant(
                            trace::COORDINATOR_TRACK,
                            "part.requeued",
                            vec![
                                ("part", trace::ArgValue::U64(*part as u64)),
                                (
                                    "reshipped_ids",
                                    trace::ArgValue::U64(*reshipped_ids as u64),
                                ),
                            ],
                        ),
                        PartEvent::MachineLost { machine, detail } => trace::instant(
                            trace::COORDINATOR_TRACK,
                            "machine.lost",
                            vec![
                                ("machine", trace::ArgValue::Str(machine.clone())),
                                ("detail", trace::ArgValue::Str(detail.clone())),
                            ],
                        ),
                        PartEvent::Delay { .. } | PartEvent::SpecShipped { .. } => {}
                    }
                }
                Some(Ok(ev))
            }
            Ok(Err(e)) => {
                self.failed = true;
                Some(Err(e))
            }
            Err(_) => {
                self.failed = true;
                Some(Err(Error::Worker(format!(
                    "round ended after {} of {} parts — backend dropped the event \
                     channel without a fatal error",
                    self.done, self.expected
                ))))
            }
        }
    }

    /// Drain every remaining event into a [`RoundOutcome`]. Call this
    /// on a freshly-submitted handle (it slots solutions by part index;
    /// events already pulled via [`RoundHandle::next_event`] are gone).
    pub fn finish(mut self) -> Result<RoundOutcome> {
        let mut solutions: Vec<Option<Solution>> =
            (0..self.expected).map(|_| None).collect();
        let mut requeued_parts = 0usize;
        let mut requeued_ids = 0usize;
        let mut sim_delay_ms = 0.0f64;
        let mut spec_bytes = 0u64;
        while let Some(ev) = self.next_event() {
            match ev? {
                PartEvent::Done { part, solution } => solutions[part] = Some(solution),
                PartEvent::Requeued { reshipped_ids, .. } => {
                    requeued_parts += 1;
                    requeued_ids += reshipped_ids;
                }
                PartEvent::Delay { virtual_ms, .. } => sim_delay_ms += virtual_ms,
                PartEvent::SpecShipped { bytes } => spec_bytes += bytes as u64,
                PartEvent::MachineLost { .. } => {}
            }
        }
        let solutions = solutions
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                s.ok_or_else(|| {
                    Error::Worker(format!("part {i} completed without a solution"))
                })
            })
            .collect::<Result<Vec<Solution>>>()?;
        Ok(RoundOutcome { solutions, requeued_parts, requeued_ids, sim_delay_ms, spec_bytes })
    }
}

/// Backend-side receiving end of one streaming round: accepts parts in
/// index order and seals or cancels the round. Implemented by each
/// backend; driven through the backend-agnostic [`RoundSession`], which
/// owns capacity enforcement and part indexing.
pub trait RoundSink: Send {
    /// Accept part `idx` (indices arrive strictly sequentially from 0)
    /// with its positional per-machine `seed` (drawn by the session —
    /// seed derivation is a cross-backend invariant, so no backend can
    /// drift). The part may start executing immediately — earlier parts
    /// of the same round are allowed to be in flight already.
    fn submit(&mut self, idx: usize, part: Vec<u32>, seed: u64) -> Result<()>;

    /// Seal the round: no further parts. Already-submitted parts keep
    /// running; the round completes when all of them have reported.
    fn close(&mut self) -> Result<()>;

    /// Cancel the round: queued parts are discarded, in-flight results
    /// are dropped on arrival. Used when a speculatively-dispatched
    /// round turns out to be mispredicted. Must be idempotent with
    /// [`RoundSink::close`] (whichever comes first wins).
    fn abort(&mut self);
}

/// One incrementally-submitted round (Backend v3): obtained from
/// [`Backend::open_round`], fed via [`RoundSession::submit_part`], and
/// sealed with [`RoundSession::close`], which returns the round's
/// [`RoundHandle`]. Parts execute while later parts are still being
/// submitted; part indices (and therefore positional seeds) are
/// assigned by submission order, so a streamed round is bit-identical
/// to the same parts submitted at once. Dropping an unclosed session
/// aborts the round.
pub struct RoundSession {
    sink: Option<Box<dyn RoundSink>>,
    rx: Option<mpsc::Receiver<Result<PartEvent>>>,
    profile: CapacityProfile,
    seed_rng: Rng,
    submitted: usize,
}

impl RoundSession {
    /// Wrap a backend's part sink and event channel. `profile` is the
    /// fleet profile parts are enforced against (part `j` must fit the
    /// virtual machine `µ_{j mod L}` it will be sized for);
    /// `round_seed` seeds the positional per-machine seed stream (one
    /// draw per submitted part, identical across backends).
    pub fn new(
        sink: Box<dyn RoundSink>,
        rx: mpsc::Receiver<Result<PartEvent>>,
        profile: CapacityProfile,
        round_seed: u64,
    ) -> RoundSession {
        if trace::enabled() {
            trace::instant(
                trace::COORDINATOR_TRACK,
                "open_round",
                vec![("round_seed", trace::ArgValue::U64(round_seed))],
            );
        }
        RoundSession {
            sink: Some(sink),
            rx: Some(rx),
            profile,
            seed_rng: Rng::seed_from(round_seed),
            submitted: 0,
        }
    }

    /// Parts submitted so far (the next part gets this index).
    pub fn submitted(&self) -> usize {
        self.submitted
    }

    /// Submit the round's next part. Capacity is enforced here, before
    /// the backend sees the part — the same pre-dispatch contract as
    /// the one-shot [`Backend::submit_round`].
    pub fn submit_part(&mut self, part: Vec<u32>) -> Result<()> {
        let idx = self.submitted;
        let cap = self.profile.virtual_capacity(idx);
        if part.len() > cap {
            return Err(Error::CapacityExceeded {
                capacity: cap,
                got: part.len(),
                ctx: format!(" (machine {idx} of a streaming round)"),
            });
        }
        // commit the seed draw only on success, so a refused part never
        // desynchronizes the positional stream
        let mut advanced = self.seed_rng.clone();
        let seed = advanced.next_u64();
        let sink = self
            .sink
            .as_mut()
            .ok_or_else(|| Error::invalid("round session already closed"))?;
        let items = part.len();
        sink.submit(idx, part, seed)?;
        if trace::enabled() {
            trace::instant(
                trace::COORDINATOR_TRACK,
                "submit_part",
                vec![
                    ("part", trace::ArgValue::U64(idx as u64)),
                    ("items", trace::ArgValue::U64(items as u64)),
                ],
            );
        }
        self.seed_rng = advanced;
        self.submitted += 1;
        Ok(())
    }

    /// Submit a batch of parts in order.
    pub fn submit_parts(&mut self, parts: &[Vec<u32>]) -> Result<()> {
        for p in parts {
            self.submit_part(p.clone())?;
        }
        Ok(())
    }

    /// Seal the round and return the handle draining its events. The
    /// handle completes after one `Done` per submitted part.
    pub fn close(mut self) -> Result<RoundHandle> {
        let mut sink = self
            .sink
            .take()
            .ok_or_else(|| Error::invalid("round session already closed"))?;
        sink.close()?;
        if trace::enabled() {
            trace::instant(
                trace::COORDINATOR_TRACK,
                "close_round",
                vec![("parts", trace::ArgValue::U64(self.submitted as u64))],
            );
        }
        // invariant: rx is populated at construction and taken exactly
        // once, here — close() consumes self
        let rx = self.rx.take().expect("session channel taken before close");
        Ok(RoundHandle::new(rx, self.submitted))
    }

    /// Cancel the round (explicit form of dropping the session): queued
    /// parts are discarded and in-flight results dropped on arrival.
    pub fn abort(mut self) {
        if let Some(mut sink) = self.sink.take() {
            sink.abort();
        }
    }
}

impl Drop for RoundSession {
    fn drop(&mut self) {
        // an unclosed session is a cancelled round, never a leaked job
        if let Some(mut sink) = self.sink.take() {
            sink.abort();
        }
    }
}

/// An execution substrate for one compression round over a partition.
///
/// v3 contract: the required method is the streaming
/// [`Backend::open_round`]; the one-shot event-driven
/// [`Backend::submit_round`] and the blocking [`Backend::run_round`]
/// are provided wrappers (open + submit + close, then optionally
/// drain), so call sites that want the classic semantics keep working
/// unchanged.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// The fleet's capacity profile *for the upcoming round*. Uniform
    /// for the paper's setting; heterogeneous fleets return their
    /// per-class µ_p vector. The tree runner queries this every round,
    /// so a backend whose fleet changes mid-run (e.g. a scripted
    /// [`SimBackend`] capacity schedule) is re-planned against the
    /// fleet that will actually execute.
    fn profile(&self) -> CapacityProfile;

    /// Largest single-machine capacity µ this backend can grant (the
    /// profile's first class). Kept as the scalar convenience for call
    /// sites that only need "how big can one part be".
    fn capacity(&self) -> usize {
        self.profile().max_capacity()
    }

    /// Open one streaming round (Backend v3): parts are submitted
    /// incrementally through the returned [`RoundSession`] and may
    /// start executing while later parts are still unknown — the
    /// foundation of speculative next-round dispatch. Part `j` runs on
    /// a machine of the profile's virtual capacity `µ_{j mod L}` with a
    /// positional per-machine seed derived from `round_seed`, so the
    /// streamed round is bit-identical to the same parts submitted at
    /// once, regardless of arrival order or requeueing along the way.
    /// Backends may allow a new round's session to open while an
    /// earlier round's stragglers drain ([`TcpBackend`] does).
    fn open_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        round_seed: u64,
    ) -> Result<RoundSession>;

    /// One-shot wrapper over [`Backend::open_round`]: submit every part
    /// of a fully-known round and stream [`PartEvent`]s as machines
    /// report. Fails with [`Error::CapacityExceeded`] if any part
    /// exceeds its machine's capacity, before any work starts.
    fn submit_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundHandle> {
        // batch-validate up front so a capacity error reports the
        // offending machine against the full round, with no work started
        enforce_profile(&self.profile(), parts)?;
        let mut session = self.open_round(problem, compressor, round_seed)?;
        session.submit_parts(parts)?;
        session.close()
    }

    /// Per-worker utilization and telemetry accumulated so far
    /// (protocol v5). Backends without per-worker accounting return an
    /// empty vector; [`TcpBackend`] reports one entry per fleet worker,
    /// sorted by address. Observational only — never affects dispatch
    /// or the answer.
    fn worker_stats(&self) -> Vec<WorkerStats> {
        Vec::new()
    }

    /// [`Backend::open_round`] with a caller-chosen attribution *scope*
    /// (`hss serve` uses one scope per job). Work executed under the
    /// round is additionally accounted to the scope, retrievable via
    /// [`Backend::worker_stats_scoped`] — attribution never affects
    /// dispatch or the answer, so the default simply ignores the scope.
    fn open_round_scoped(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        round_seed: u64,
        _scope: u64,
    ) -> Result<RoundSession> {
        self.open_round(problem, compressor, round_seed)
    }

    /// Per-worker stats restricted to work submitted under `scope` via
    /// [`Backend::open_round_scoped`]. Empty on backends without
    /// per-scope accounting (jobs on those fall back to lifetime
    /// snapshot deltas — see [`stats_delta`]).
    fn worker_stats_scoped(&self, _scope: u64) -> Vec<WorkerStats> {
        Vec::new()
    }

    /// Drop the per-scope accounting for `scope` (a job's stats were
    /// recorded; the backend may reclaim the entries). No-op by default.
    fn release_scope(&self, _scope: u64) {}

    /// Permanently shut the backend's fleet down: [`TcpBackend`] sends
    /// every worker the protocol `shutdown` frame and blocks until the
    /// dispatchers exit; in-process backends have nothing to do. Called
    /// by `hss serve` once a graceful drain completes.
    fn shutdown_fleet(&self) {}

    /// Barrier wrapper over [`Backend::submit_round`]: block until every
    /// part completes and return one solution per part, order preserved.
    fn run_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundOutcome> {
        self.submit_round(problem, compressor, parts, round_seed)?.finish()
    }
}

/// Which backend a run should use — parsed from config/CLI and built
/// into a concrete [`Backend`] with [`BackendChoice::build`].
#[derive(Debug, Clone, PartialEq, Default)]
pub enum BackendChoice {
    /// In-process thread pool (the default).
    #[default]
    Local,
    /// Real worker processes at the given `host:port` addresses.
    Tcp { workers: Vec<String> },
    /// Deterministic fault-injecting simulator. `schedule` scripts the
    /// fleet per round (`--sim-capacity-schedule PROFILE[;PROFILE…]`,
    /// config `sim.capacity_schedule`); empty means a static fleet.
    Sim { faults: FaultPlan, schedule: Vec<CapacityProfile> },
}

impl BackendChoice {
    /// Parse a backend name from config/CLI (`local` | `tcp` | `sim`).
    pub fn parse(name: &str) -> Result<BackendChoice> {
        Ok(match name {
            "local" => BackendChoice::Local,
            "tcp" => BackendChoice::Tcp { workers: Vec::new() },
            "sim" => {
                BackendChoice::Sim { faults: FaultPlan::default(), schedule: Vec::new() }
            }
            other => {
                return Err(Error::Config(format!(
                    "unknown backend '{other}' (known: local, tcp, sim)"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendChoice::Local => "local",
            BackendChoice::Tcp { .. } => "tcp",
            BackendChoice::Sim { .. } => "sim",
        }
    }

    /// Build the concrete backend for the given capacity profile.
    /// `threads` is the local thread-pool width (ignored by tcp/sim).
    pub fn build(
        &self,
        profile: &CapacityProfile,
        threads: Option<usize>,
    ) -> Result<Arc<dyn Backend>> {
        self.build_with_engine(profile, threads, EngineChoice::Native)
    }

    /// [`BackendChoice::build`] plus the compute engine to request from
    /// tcp workers at handshake. Local and sim backends execute against
    /// the submitted problem's own engine in-process, so `engine` only
    /// reaches tcp fleets (where workers pinned with `--engine` still
    /// win per connection).
    pub fn build_with_engine(
        &self,
        profile: &CapacityProfile,
        threads: Option<usize>,
        engine: EngineChoice,
    ) -> Result<Arc<dyn Backend>> {
        Ok(match self {
            BackendChoice::Local => {
                let mut b = LocalBackend::with_profile(profile.clone());
                if let Some(t) = threads {
                    b = b.with_threads(t);
                }
                Arc::new(b)
            }
            BackendChoice::Tcp { workers } => Arc::new(
                TcpBackend::with_profile(profile.clone(), workers.clone())?
                    .with_engine_choice(engine),
            ),
            BackendChoice::Sim { faults, schedule } => {
                let mut b =
                    SimBackend::with_profile(profile.clone()).with_faults(faults.clone());
                if !schedule.is_empty() {
                    b = b.with_capacity_schedule(schedule.clone());
                }
                Arc::new(b)
            }
        })
    }
}

/// Shared pre-dispatch check against a heterogeneous fleet: part `j`
/// must fit the virtual machine `µ_{j mod L}` it was sized for.
pub(crate) fn enforce_profile(profile: &CapacityProfile, parts: &[Vec<u32>]) -> Result<()> {
    for (i, p) in parts.iter().enumerate() {
        let cap = profile.virtual_capacity(i);
        if p.len() > cap {
            return Err(Error::CapacityExceeded {
                capacity: cap,
                got: p.len(),
                ctx: format!(" (machine {i} of {})", parts.len()),
            });
        }
    }
    Ok(())
}

/// A problem interned for the wire (protocol v4): a stable id, the spec
/// it stands for, and the spec's serialized size (the bytes saved every
/// time the id ships instead).
#[derive(Clone)]
pub(crate) struct InternedSpec {
    pub id: u64,
    pub spec: Arc<ProblemSpec>,
    pub bytes: usize,
    /// `true` the first time this problem identity was interned on this
    /// coordinator (a brand-new id was minted).
    pub fresh: bool,
}

/// Cheap identity key for a [`Problem`]: the `Arc`s pin the referenced
/// dataset/constraint alive, so pointer equality is a sound (and O(1))
/// stand-in for "same problem" — the scalar fields catch rebuilds of
/// the same dataset under different parameters.
struct ProblemKey {
    dataset: DatasetRef,
    constraint: Arc<dyn Constraint>,
    k: usize,
    seed: u64,
    eval_len: usize,
    obj_tag: u8,
    h2_bits: u64,
    sigma2_bits: u64,
}

impl ProblemKey {
    fn of(p: &Problem) -> ProblemKey {
        // Exhaustive on purpose: a new (or newly wire-representable)
        // objective MUST get its own tag here, or two problems differing
        // only in objective would alias to one interned spec. The
        // non-wire variants still key distinctly even though interning
        // them fails in from_problem.
        let (obj_tag, h2_bits, sigma2_bits) = match &p.objective {
            Objective::Exemplar => (0u8, 0u64, 0u64),
            Objective::LogDet { h2, sigma2 } => (1, h2.to_bits(), sigma2.to_bits()),
            Objective::Coverage(_) => (2, 0, 0),
            Objective::Modular(_) => (3, 0, 0),
        };
        ProblemKey {
            dataset: p.dataset.clone(),
            constraint: p.constraint.clone(),
            k: p.k,
            seed: p.seed,
            eval_len: p.eval_ids.len(),
            obj_tag,
            h2_bits,
            sigma2_bits,
        }
    }

    fn matches(&self, other: &ProblemKey) -> bool {
        Arc::ptr_eq(&self.dataset, &other.dataset)
            && Arc::ptr_eq(&self.constraint, &other.constraint)
            && self.k == other.k
            && self.seed == other.seed
            && self.eval_len == other.eval_len
            && self.obj_tag == other.obj_tag
            && self.h2_bits == other.h2_bits
            && self.sigma2_bits == other.sigma2_bits
    }
}

struct InternEntry {
    key: ProblemKey,
    id: u64,
    spec: Arc<ProblemSpec>,
    bytes: usize,
}

/// Coordinator-side problem interner (protocol v4): memoizes
/// [`ProblemSpec::from_problem`] per problem *identity*, so a
/// multi-round run serializes the spec once instead of once per round,
/// and assigns each distinct spec a short id that rides in every
/// compress request. Two `Problem` values that serialize to the same
/// spec share one id even when their identity keys differ (e.g. a
/// re-loaded dataset `Arc`).
#[derive(Default)]
pub(crate) struct SpecInterner {
    entries: Mutex<Vec<InternEntry>>,
}

impl SpecInterner {
    pub fn new() -> SpecInterner {
        SpecInterner::default()
    }

    pub fn intern(&self, p: &Problem) -> Result<InternedSpec> {
        let key = ProblemKey::of(p);
        // invariant: interner critical sections only compare keys and
        // clone Arcs — they cannot panic, so the mutex is never poisoned
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter().find(|e| e.key.matches(&key)) {
            return Ok(InternedSpec {
                id: e.id,
                spec: e.spec.clone(),
                bytes: e.bytes,
                fresh: false,
            });
        }
        // identity miss: pay from_problem once, then dedupe by content
        let spec = ProblemSpec::from_problem(p)?;
        if let Some(e) = entries.iter().find(|e| *e.spec == spec) {
            let (id, spec, bytes) = (e.id, e.spec.clone(), e.bytes);
            // remember the new identity key as an alias of the same id,
            // so the next lookup is a pointer comparison again
            entries.push(InternEntry { key, id, spec: spec.clone(), bytes });
            return Ok(InternedSpec { id, spec, bytes, fresh: false });
        }
        let id = entries.iter().map(|e| e.id + 1).max().unwrap_or(0);
        let bytes = spec.to_json().to_string().len();
        let spec = Arc::new(spec);
        entries.push(InternEntry { key, id, spec: spec.clone(), bytes });
        Ok(InternedSpec { id, spec, bytes, fresh: true })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enforce_profile_names_the_machine() {
        let parts = vec![vec![0, 1], vec![0, 1, 2, 3]];
        let err = enforce_profile(&CapacityProfile::uniform(3), &parts).unwrap_err();
        match err {
            Error::CapacityExceeded { capacity, got, ctx } => {
                assert_eq!(capacity, 3);
                assert_eq!(got, 4);
                assert!(ctx.contains("machine 1 of 2"), "ctx: {ctx}");
            }
            other => panic!("wrong error {other}"),
        }
        assert!(enforce_profile(&CapacityProfile::uniform(4), &parts).is_ok());
    }

    fn stats(addr: &str, parts: u64, busy: f64) -> WorkerStats {
        WorkerStats {
            addr: addr.into(),
            parts,
            oracle_evals: parts * 10,
            busy_ms: busy,
            queue_wait_ms: busy / 10.0,
            dataset_hits: 7,
            dataset_misses: 1,
            problem_hits: 5,
            problem_misses: 2,
            problem_evictions: 0,
            payload_bytes_binary: parts * 100,
            payload_bytes_json: parts * 50,
            engine: "native".into(),
            bulk_gain_calls: parts * 3,
            bulk_gain_candidates: parts * 30,
        }
    }

    #[test]
    fn delta_since_subtracts_sums_and_keeps_gauges() {
        let earlier = stats("w:1", 4, 40.0);
        let mut now = stats("w:1", 10, 100.0);
        now.dataset_hits = 20; // gauge moved
        now.engine = "xla".into();
        let d = now.delta_since(&earlier);
        assert_eq!(d.parts, 6);
        assert_eq!(d.oracle_evals, 60);
        assert!((d.busy_ms - 60.0).abs() < 1e-9);
        assert!((d.queue_wait_ms - 6.0).abs() < 1e-9);
        assert_eq!(d.payload_bytes_binary, 600);
        assert_eq!(d.payload_bytes_json, 300);
        assert_eq!(d.bulk_gain_calls, 18);
        assert_eq!(d.bulk_gain_candidates, 180);
        // gauges are latest-wins, not differences
        assert_eq!(d.dataset_hits, 20);
        assert_eq!(d.problem_hits, 5);
        assert_eq!(d.engine, "xla");
    }

    #[test]
    fn delta_since_saturates_after_a_counter_reset() {
        let earlier = stats("w:1", 9, 90.0);
        let now = stats("w:1", 2, 20.0); // worker restarted mid-interval
        let d = now.delta_since(&earlier);
        assert_eq!(d.parts, 0);
        assert_eq!(d.busy_ms, 0.0);
    }

    #[test]
    fn stats_delta_matches_by_addr_and_handles_joins() {
        let earlier = vec![stats("w:1", 4, 40.0)];
        let now = vec![stats("w:1", 6, 60.0), stats("w:2", 3, 30.0)];
        let d = stats_delta(&now, &earlier);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].addr, "w:1");
        assert_eq!(d[0].parts, 2);
        // w:2 joined after the snapshot: full value is its own interval
        assert_eq!(d[1].addr, "w:2");
        assert_eq!(d[1].parts, 3);
    }

    #[test]
    fn enforce_profile_checks_each_part_against_its_virtual_machine() {
        let profile = CapacityProfile::parse("4,2").unwrap();
        // virtual capacities cycle 4, 2, 4, 2, …
        let fits = vec![vec![0, 1, 2, 3], vec![0, 1], vec![0], vec![0, 1]];
        assert!(enforce_profile(&profile, &fits).is_ok());
        // part 1 sized for the large class overloads the small one
        let overloaded = vec![vec![0, 1], vec![0, 1, 2]];
        let err = enforce_profile(&profile, &overloaded).unwrap_err();
        match err {
            Error::CapacityExceeded { capacity: 2, got: 3, ctx } => {
                assert!(ctx.contains("machine 1 of 2"), "ctx: {ctx}");
            }
            other => panic!("wrong error {other}"),
        }
    }

    /// Sink that records the seeds the session hands it.
    struct SeedSink {
        seeds: Arc<Mutex<Vec<u64>>>,
    }

    impl RoundSink for SeedSink {
        fn submit(&mut self, _idx: usize, _part: Vec<u32>, seed: u64) -> Result<()> {
            self.seeds.lock().unwrap().push(seed);
            Ok(())
        }
        fn close(&mut self) -> Result<()> {
            Ok(())
        }
        fn abort(&mut self) {}
    }

    fn session_seeds(round_seed: u64, parts: usize) -> Vec<u64> {
        let seeds = Arc::new(Mutex::new(Vec::new()));
        let (_tx, rx) = mpsc::channel();
        let mut s = RoundSession::new(
            Box::new(SeedSink { seeds: Arc::clone(&seeds) }),
            rx,
            CapacityProfile::uniform(10),
            round_seed,
        );
        for i in 0..parts {
            s.submit_part(vec![i as u32]).unwrap();
        }
        s.close().unwrap();
        let out = seeds.lock().unwrap().clone();
        out
    }

    #[test]
    fn session_seeds_are_positional_and_deterministic() {
        // positional: part j's seed depends only on (round_seed, j), so
        // a round streamed in pieces equals the same round submitted at
        // once — and which machine executes a part never matters
        let a = session_seeds(7, 5);
        let b = session_seeds(7, 3);
        assert_eq!(&a[..3], &b[..]);
        assert_ne!(a[0], a[1]);
    }

    #[test]
    fn round_handle_completes_at_last_done_and_finish_slots_by_part() {
        let (tx, rx) = mpsc::channel();
        // events out of part order, requeue before the requeued part's Done
        tx.send(Ok(PartEvent::Done {
            part: 1,
            solution: Solution { items: vec![5], value: 1.0 },
        }))
        .unwrap();
        tx.send(Ok(PartEvent::Requeued { part: 0, reshipped_ids: 7 })).unwrap();
        tx.send(Ok(PartEvent::Delay { part: 0, virtual_ms: 12.5 })).unwrap();
        tx.send(Ok(PartEvent::Done {
            part: 0,
            solution: Solution { items: vec![2], value: 3.0 },
        }))
        .unwrap();
        // tx deliberately NOT dropped: the handle must complete on the
        // last Done without waiting for backend teardown
        let handle = RoundHandle::new(rx, 2);
        let out = handle.finish().unwrap();
        assert_eq!(out.solutions.len(), 2);
        assert_eq!(out.solutions[0].items, vec![2]);
        assert_eq!(out.solutions[1].items, vec![5]);
        assert_eq!(out.requeued_parts, 1);
        assert_eq!(out.requeued_ids, 7);
        assert_eq!(out.sim_delay_ms, 12.5);
        drop(tx);
    }

    #[test]
    fn round_handle_surfaces_fatal_errors_and_dropped_channels() {
        let (tx, rx) = mpsc::channel::<Result<PartEvent>>();
        tx.send(Err(Error::Transport("boom".into()))).unwrap();
        let err = RoundHandle::new(rx, 3).finish().unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");

        // channel dropped before completion: an error, never a hang or
        // a silent success
        let (tx2, rx2) = mpsc::channel::<Result<PartEvent>>();
        drop(tx2);
        let err = RoundHandle::new(rx2, 2).finish().unwrap_err();
        assert!(err.to_string().contains("0 of 2"), "{err}");

        // empty rounds complete immediately
        let out = RoundHandle::empty().finish().unwrap();
        assert!(out.solutions.is_empty());
    }

    #[test]
    fn finish_aggregates_spec_shipments() {
        let (tx, rx) = mpsc::channel();
        tx.send(Ok(PartEvent::SpecShipped { bytes: 120 })).unwrap();
        tx.send(Ok(PartEvent::Done {
            part: 0,
            solution: Solution { items: vec![1], value: 1.0 },
        }))
        .unwrap();
        let out = RoundHandle::new(rx, 1).finish().unwrap();
        assert_eq!(out.spec_bytes, 120);
        drop(tx);
    }

    /// Recording sink: captures submissions so the session contract
    /// (sequential indices, enforcement before the sink, abort-on-drop)
    /// is testable without a real backend.
    struct RecordingSink {
        log: Arc<Mutex<Vec<String>>>,
    }

    impl RoundSink for RecordingSink {
        fn submit(&mut self, idx: usize, part: Vec<u32>, _seed: u64) -> Result<()> {
            self.log.lock().unwrap().push(format!("submit {idx} ({} items)", part.len()));
            Ok(())
        }
        fn close(&mut self) -> Result<()> {
            self.log.lock().unwrap().push("close".into());
            Ok(())
        }
        fn abort(&mut self) {
            self.log.lock().unwrap().push("abort".into());
        }
    }

    #[test]
    fn round_session_enforces_capacity_and_indexes_sequentially() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let (_tx, rx) = mpsc::channel();
        let mut s = RoundSession::new(
            Box::new(RecordingSink { log: Arc::clone(&log) }),
            rx,
            CapacityProfile::parse("3,2").unwrap(),
            9,
        );
        s.submit_part(vec![1, 2, 3]).unwrap();
        s.submit_part(vec![4]).unwrap();
        // part 2 cycles back to the large class
        s.submit_part(vec![5, 6, 7]).unwrap();
        // part 3 is sized for the small class: 3 items must be refused
        // BEFORE the sink sees them, and the index must not advance
        let err = s.submit_part(vec![8, 9, 10]).unwrap_err();
        assert!(
            matches!(err, Error::CapacityExceeded { capacity: 2, got: 3, .. }),
            "{err}"
        );
        assert_eq!(s.submitted(), 3);
        let handle = s.close().unwrap();
        assert_eq!(handle.parts(), 3);
        assert_eq!(
            *log.lock().unwrap(),
            vec!["submit 0 (3 items)", "submit 1 (1 items)", "submit 2 (3 items)", "close"]
        );
    }

    #[test]
    fn dropping_an_unclosed_session_aborts_the_round() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let (_tx, rx) = mpsc::channel();
        let mut s = RoundSession::new(
            Box::new(RecordingSink { log: Arc::clone(&log) }),
            rx,
            CapacityProfile::uniform(10),
            9,
        );
        s.submit_part(vec![1]).unwrap();
        drop(s);
        assert_eq!(*log.lock().unwrap(), vec!["submit 0 (1 items)", "abort"]);
    }

    #[test]
    fn spec_interner_memoizes_by_identity_and_dedupes_by_content() {
        let ds = crate::data::registry::load("csn-2k", 5).unwrap();
        let p = Problem::exemplar(ds.clone(), 7, 5);
        let interner = SpecInterner::new();
        let a = interner.intern(&p).unwrap();
        assert!(a.fresh, "first intern mints a fresh id");
        assert!(a.bytes > 0);
        // same identity: memo hit, no re-serialization signalled
        let b = interner.intern(&p).unwrap();
        assert_eq!(a.id, b.id);
        assert!(!b.fresh);
        // a clone shares every Arc — still the same identity
        let c = interner.intern(&p.clone()).unwrap();
        assert_eq!(a.id, c.id);
        assert!(!c.fresh);
        // a re-built problem with fresh Arcs but the identical spec
        // dedupes by content onto the same id
        let rebuilt = Problem::exemplar(
            crate::data::registry::load("csn-2k", 5).unwrap(),
            7,
            5,
        );
        let d = interner.intern(&rebuilt).unwrap();
        assert_eq!(a.id, d.id);
        assert!(!d.fresh);
        // a genuinely different problem mints a different id
        let other = Problem::exemplar(ds, 9, 5);
        let e = interner.intern(&other).unwrap();
        assert_ne!(a.id, e.id);
        assert!(e.fresh);
        // problems the wire cannot describe are rejected
        let adhoc = Problem::modular(vec![1.0; 8], 2, 0);
        assert!(interner.intern(&adhoc).is_err());
    }

    #[test]
    fn backend_choice_parses() {
        assert_eq!(BackendChoice::parse("local").unwrap(), BackendChoice::Local);
        assert_eq!(BackendChoice::parse("tcp").unwrap().name(), "tcp");
        assert_eq!(BackendChoice::parse("sim").unwrap().name(), "sim");
        assert!(BackendChoice::parse("mpi").is_err());
    }
}

//! Pluggable execution backends for the coordinator.
//!
//! The tree framework (and the two-round baselines) express each round as
//! "compress every part of a partition on a fixed-capacity machine". This
//! module abstracts *where* those machines live behind the [`Backend`]
//! trait, with three implementations:
//!
//! | backend            | machines are…                | use case                      |
//! |--------------------|------------------------------|-------------------------------|
//! | [`LocalBackend`]   | worker threads in-process    | default; single-host runs     |
//! | [`TcpBackend`]     | `hss worker` processes over a| real multi-process / multi-   |
//! |                    | length-prefixed TCP protocol | host horizontal scaling       |
//! | [`SimBackend`]     | a deterministic single-thread| fault-tolerance & robustness  |
//! |                    | simulator with fault injection| experiments, scenario tests  |
//!
//! All backends share the same contract: capacity is enforced *before*
//! any work starts (fixed capacity is the paper's premise), per-machine
//! seeds are derived positionally from the round seed, and solutions come
//! back in part order — so for a given `(problem, parts, round_seed)` all
//! three backends produce **identical** solutions. Fault injection and
//! wire transport change cost and availability, never the answer.
//!
//! Fleets may be **capacity-heterogeneous**: every backend carries a
//! [`CapacityProfile`] (per-machine-class µ_p, cyclic — see
//! [`crate::coordinator::capacity`]) instead of a single scalar, and
//! enforcement checks part `j` against the virtual capacity `µ_{j mod
//! L}` the planner sized it for. [`TcpBackend`] additionally learns each
//! worker's real µ from the protocol-v3 handshake and dispatches a part
//! only to workers that can hold it.

pub mod local;
pub mod protocol;
pub mod sim;
pub mod tcp;
pub mod worker;

pub use local::LocalBackend;
pub use sim::{FaultPlan, SimBackend};
pub use tcp::TcpBackend;

use std::sync::Arc;

use crate::algorithms::{Compressor, Solution};
use crate::coordinator::capacity::CapacityProfile;
use crate::error::{Error, Result};
use crate::objectives::Problem;
use crate::util::rng::Rng;

/// Outcome of one compression round executed by a backend.
#[derive(Debug, Clone, Default)]
pub struct RoundOutcome {
    /// One solution per part, order preserved.
    pub solutions: Vec<Solution>,
    /// Parts that were dispatched to a machine that was lost (worker
    /// disconnect, injected fault) and re-executed elsewhere.
    pub requeued_parts: usize,
    /// Item ids shipped a *second* time because their machine was lost
    /// mid-flight — shuffle accounting charges these on top of the
    /// first dispatch of every part.
    pub requeued_ids: usize,
    /// Virtual wall-clock added by injected stragglers/retries
    /// ([`SimBackend`] only; 0 elsewhere).
    pub sim_delay_ms: f64,
}

/// An execution substrate for one compression round over a partition.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// The fleet's capacity profile *for the upcoming round*. Uniform
    /// for the paper's setting; heterogeneous fleets return their
    /// per-class µ_p vector. The tree runner queries this every round,
    /// so a backend whose fleet changes mid-run (e.g. a scripted
    /// [`SimBackend`] capacity schedule) is re-planned against the
    /// fleet that will actually execute.
    fn profile(&self) -> CapacityProfile;

    /// Largest single-machine capacity µ this backend can grant (the
    /// profile's first class). Kept as the scalar convenience for call
    /// sites that only need "how big can one part be".
    fn capacity(&self) -> usize {
        self.profile().max_capacity()
    }

    /// Execute one round: run `compressor` on every part (part `j` on a
    /// machine of the profile's virtual capacity `µ_{j mod L}`) and
    /// return one solution per part, order preserved. Must fail with
    /// [`Error::CapacityExceeded`] if any part exceeds its machine's
    /// capacity, before any work starts.
    fn run_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundOutcome>;
}

/// Which backend a run should use — parsed from config/CLI and built
/// into a concrete [`Backend`] with [`BackendChoice::build`].
#[derive(Debug, Clone, PartialEq, Default)]
pub enum BackendChoice {
    /// In-process thread pool (the default).
    #[default]
    Local,
    /// Real worker processes at the given `host:port` addresses.
    Tcp { workers: Vec<String> },
    /// Deterministic fault-injecting simulator.
    Sim { faults: FaultPlan },
}

impl BackendChoice {
    /// Parse a backend name from config/CLI (`local` | `tcp` | `sim`).
    pub fn parse(name: &str) -> Result<BackendChoice> {
        Ok(match name {
            "local" => BackendChoice::Local,
            "tcp" => BackendChoice::Tcp { workers: Vec::new() },
            "sim" => BackendChoice::Sim { faults: FaultPlan::default() },
            other => {
                return Err(Error::Config(format!(
                    "unknown backend '{other}' (known: local, tcp, sim)"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendChoice::Local => "local",
            BackendChoice::Tcp { .. } => "tcp",
            BackendChoice::Sim { .. } => "sim",
        }
    }

    /// Build the concrete backend for the given capacity profile.
    /// `threads` is the local thread-pool width (ignored by tcp/sim).
    pub fn build(
        &self,
        profile: &CapacityProfile,
        threads: Option<usize>,
    ) -> Result<Arc<dyn Backend>> {
        Ok(match self {
            BackendChoice::Local => {
                let mut b = LocalBackend::with_profile(profile.clone());
                if let Some(t) = threads {
                    b = b.with_threads(t);
                }
                Arc::new(b)
            }
            BackendChoice::Tcp { workers } => {
                Arc::new(TcpBackend::with_profile(profile.clone(), workers.clone())?)
            }
            BackendChoice::Sim { faults } => Arc::new(
                SimBackend::with_profile(profile.clone()).with_faults(faults.clone()),
            ),
        })
    }
}

/// Shared pre-dispatch check against a heterogeneous fleet: part `j`
/// must fit the virtual machine `µ_{j mod L}` it was sized for.
pub(crate) fn enforce_profile(profile: &CapacityProfile, parts: &[Vec<u32>]) -> Result<()> {
    for (i, p) in parts.iter().enumerate() {
        let cap = profile.virtual_capacity(i);
        if p.len() > cap {
            return Err(Error::CapacityExceeded {
                capacity: cap,
                got: p.len(),
                ctx: format!(" (machine {i} of {})", parts.len()),
            });
        }
    }
    Ok(())
}

/// Positional per-machine seeds derived from the round seed — identical
/// across backends (and across thread counts) so a round's output never
/// depends on the execution substrate.
pub(crate) fn machine_seeds(round_seed: u64, machines: usize) -> Vec<u64> {
    let mut seed_rng = Rng::seed_from(round_seed);
    (0..machines).map(|_| seed_rng.next_u64()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enforce_profile_names_the_machine() {
        let parts = vec![vec![0, 1], vec![0, 1, 2, 3]];
        let err = enforce_profile(&CapacityProfile::uniform(3), &parts).unwrap_err();
        match err {
            Error::CapacityExceeded { capacity, got, ctx } => {
                assert_eq!(capacity, 3);
                assert_eq!(got, 4);
                assert!(ctx.contains("machine 1 of 2"), "ctx: {ctx}");
            }
            other => panic!("wrong error {other}"),
        }
        assert!(enforce_profile(&CapacityProfile::uniform(4), &parts).is_ok());
    }

    #[test]
    fn enforce_profile_checks_each_part_against_its_virtual_machine() {
        let profile = CapacityProfile::parse("4,2").unwrap();
        // virtual capacities cycle 4, 2, 4, 2, …
        let fits = vec![vec![0, 1, 2, 3], vec![0, 1], vec![0], vec![0, 1]];
        assert!(enforce_profile(&profile, &fits).is_ok());
        // part 1 sized for the large class overloads the small one
        let overloaded = vec![vec![0, 1], vec![0, 1, 2]];
        let err = enforce_profile(&profile, &overloaded).unwrap_err();
        match err {
            Error::CapacityExceeded { capacity: 2, got: 3, ctx } => {
                assert!(ctx.contains("machine 1 of 2"), "ctx: {ctx}");
            }
            other => panic!("wrong error {other}"),
        }
    }

    #[test]
    fn machine_seeds_are_positional_and_deterministic() {
        let a = machine_seeds(7, 5);
        let b = machine_seeds(7, 3);
        assert_eq!(&a[..3], &b[..]);
        assert_ne!(a[0], a[1]);
    }

    #[test]
    fn backend_choice_parses() {
        assert_eq!(BackendChoice::parse("local").unwrap(), BackendChoice::Local);
        assert_eq!(BackendChoice::parse("tcp").unwrap().name(), "tcp");
        assert_eq!(BackendChoice::parse("sim").unwrap().name(), "sim");
        assert!(BackendChoice::parse("mpi").is_err());
    }
}

//! Pluggable execution backends for the coordinator.
//!
//! The tree framework (and the two-round baselines) express each round as
//! "compress every part of a partition on a fixed-capacity machine". This
//! module abstracts *where* those machines live behind the [`Backend`]
//! trait, with three implementations:
//!
//! | backend            | machines are…                | use case                      |
//! |--------------------|------------------------------|-------------------------------|
//! | [`LocalBackend`]   | worker threads in-process    | default; single-host runs     |
//! | [`TcpBackend`]     | `hss worker` processes over a| real multi-process / multi-   |
//! |                    | length-prefixed TCP protocol | host horizontal scaling       |
//! | [`SimBackend`]     | a deterministic single-thread| fault-tolerance & robustness  |
//! |                    | simulator with fault injection| experiments, scenario tests  |
//!
//! All backends share the same contract: capacity is enforced *before*
//! any work starts (fixed capacity is the paper's premise), per-machine
//! seeds are derived positionally from the round seed, and solutions are
//! keyed by part index — so for a given `(problem, parts, round_seed)`
//! all three backends produce **identical** solutions. Fault injection
//! and wire transport change cost and availability, never the answer.
//!
//! Rounds are **event-driven** (Backend v2): the required trait method
//! is [`Backend::submit_round`], which returns a [`RoundHandle`]
//! streaming per-part [`PartEvent`]s as machines report — completions,
//! requeues after machine loss, fleet departures, injected virtual
//! delay. The classic blocking [`Backend::run_round`] barrier is a
//! provided wrapper (submit + drain), so single-round call sites are
//! unchanged while the tree runner overlaps next-round preparation with
//! a round's stragglers.
//!
//! Fleets may be **capacity-heterogeneous**: every backend carries a
//! [`CapacityProfile`] (per-machine-class µ_p, cyclic — see
//! [`crate::coordinator::capacity`]) instead of a single scalar, and
//! enforcement checks part `j` against the virtual capacity `µ_{j mod
//! L}` the planner sized it for. [`TcpBackend`] additionally learns each
//! worker's real µ from the protocol-v3 handshake and dispatches a part
//! only to workers that can hold it.

pub mod local;
pub mod protocol;
pub mod sim;
pub mod tcp;
pub mod worker;

pub use local::LocalBackend;
pub use sim::{FaultPlan, SimBackend};
pub use tcp::TcpBackend;

use std::sync::mpsc;
use std::sync::Arc;

use crate::algorithms::{Compressor, Solution};
use crate::coordinator::capacity::CapacityProfile;
use crate::error::{Error, Result};
use crate::objectives::Problem;
use crate::util::rng::Rng;

/// Outcome of one compression round executed by a backend.
#[derive(Debug, Clone, Default)]
pub struct RoundOutcome {
    /// One solution per part, order preserved.
    pub solutions: Vec<Solution>,
    /// Parts that were dispatched to a machine that was lost (worker
    /// disconnect, injected fault) and re-executed elsewhere.
    pub requeued_parts: usize,
    /// Item ids shipped a *second* time because their machine was lost
    /// mid-flight — shuffle accounting charges these on top of the
    /// first dispatch of every part.
    pub requeued_ids: usize,
    /// Virtual wall-clock added by injected stragglers/retries
    /// ([`SimBackend`] only; 0 elsewhere).
    pub sim_delay_ms: f64,
}

/// One observable state change of an in-flight round (Backend v2).
///
/// Events stream out of a [`RoundHandle`] as they happen, so the
/// coordinator can overlap next-round preparation with the round's
/// stragglers instead of idling at a barrier. Ordering guarantees (also
/// documented normatively in `docs/PROTOCOL.md`):
///
/// * each part produces **exactly one** [`PartEvent::Done`] per round
///   (or the round fails with an error before that);
/// * every [`PartEvent::Requeued`] for a part precedes that part's
///   `Done`;
/// * events for *different* parts arrive in completion order, which is
///   execution-dependent — consumers must never let it influence the
///   answer (solutions are keyed by part index for exactly this reason).
#[derive(Debug, Clone)]
pub enum PartEvent {
    /// Part `part` finished on some machine.
    Done {
        part: usize,
        solution: Solution,
    },
    /// Part `part` was in flight on a machine that was lost; it went
    /// back on the queue and its `reshipped_ids` item ids will cross
    /// the coordinator↔machine boundary a second time.
    Requeued {
        part: usize,
        reshipped_ids: usize,
    },
    /// A machine left the fleet mid-round (worker disconnect, injected
    /// fault). Purely informational — the affected part surfaces
    /// separately as [`PartEvent::Requeued`].
    MachineLost {
        machine: String,
        detail: String,
    },
    /// Injected virtual straggler latency ([`SimBackend`] only).
    Delay {
        part: usize,
        virtual_ms: f64,
    },
}

/// Receiving end of one submitted round: yields [`PartEvent`]s as they
/// happen and aggregates them into a [`RoundOutcome`].
///
/// Two consumption styles:
///
/// * **barrier** — call [`RoundHandle::finish`] immediately after
///   submitting; it drains every event and returns the classic
///   [`RoundOutcome`] (this is what the [`Backend::run_round`] default
///   wrapper does);
/// * **pipelined** — loop on [`RoundHandle::next_event`] and react to
///   each event as it arrives (the tree runner unions partial
///   solutions and prepares the next round while stragglers finish).
///   `next_event` returns `None` the moment the last part completes —
///   *before* any backend-internal teardown — so the consumer never
///   waits on machinery, only on results.
pub struct RoundHandle {
    rx: mpsc::Receiver<Result<PartEvent>>,
    expected: usize,
    done: usize,
    failed: bool,
}

impl RoundHandle {
    /// Wrap a backend's event channel; `expected` is the round's part
    /// count (the handle completes after that many `Done` events).
    pub fn new(rx: mpsc::Receiver<Result<PartEvent>>, expected: usize) -> RoundHandle {
        RoundHandle { rx, expected, done: 0, failed: false }
    }

    /// A handle for an empty round (no parts): completes immediately.
    pub fn empty() -> RoundHandle {
        let (_tx, rx) = mpsc::channel();
        RoundHandle::new(rx, 0)
    }

    /// Number of parts this round was submitted with.
    pub fn parts(&self) -> usize {
        self.expected
    }

    /// Parts that have reported `Done` so far.
    pub fn completed(&self) -> usize {
        self.done
    }

    /// Block for the next event. Returns `None` once every part has
    /// completed (or after a fatal error has been yielded). A backend
    /// that drops its event channel before the round is complete
    /// surfaces as an error event, never a silent `None`.
    pub fn next_event(&mut self) -> Option<Result<PartEvent>> {
        if self.failed || self.done >= self.expected {
            return None;
        }
        match self.rx.recv() {
            Ok(Ok(ev)) => {
                if matches!(ev, PartEvent::Done { .. }) {
                    self.done += 1;
                }
                Some(Ok(ev))
            }
            Ok(Err(e)) => {
                self.failed = true;
                Some(Err(e))
            }
            Err(_) => {
                self.failed = true;
                Some(Err(Error::Worker(format!(
                    "round ended after {} of {} parts — backend dropped the event \
                     channel without a fatal error",
                    self.done, self.expected
                ))))
            }
        }
    }

    /// Drain every remaining event into a [`RoundOutcome`]. Call this
    /// on a freshly-submitted handle (it slots solutions by part index;
    /// events already pulled via [`RoundHandle::next_event`] are gone).
    pub fn finish(mut self) -> Result<RoundOutcome> {
        let mut solutions: Vec<Option<Solution>> =
            (0..self.expected).map(|_| None).collect();
        let mut requeued_parts = 0usize;
        let mut requeued_ids = 0usize;
        let mut sim_delay_ms = 0.0f64;
        while let Some(ev) = self.next_event() {
            match ev? {
                PartEvent::Done { part, solution } => solutions[part] = Some(solution),
                PartEvent::Requeued { reshipped_ids, .. } => {
                    requeued_parts += 1;
                    requeued_ids += reshipped_ids;
                }
                PartEvent::Delay { virtual_ms, .. } => sim_delay_ms += virtual_ms,
                PartEvent::MachineLost { .. } => {}
            }
        }
        let solutions = solutions
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                s.ok_or_else(|| {
                    Error::Worker(format!("part {i} completed without a solution"))
                })
            })
            .collect::<Result<Vec<Solution>>>()?;
        Ok(RoundOutcome { solutions, requeued_parts, requeued_ids, sim_delay_ms })
    }
}

/// An execution substrate for one compression round over a partition.
///
/// v2 contract: the required method is the event-driven
/// [`Backend::submit_round`]; the blocking [`Backend::run_round`] is a
/// provided wrapper (submit + drain) so call sites that want the
/// classic barrier semantics keep working unchanged.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// The fleet's capacity profile *for the upcoming round*. Uniform
    /// for the paper's setting; heterogeneous fleets return their
    /// per-class µ_p vector. The tree runner queries this every round,
    /// so a backend whose fleet changes mid-run (e.g. a scripted
    /// [`SimBackend`] capacity schedule) is re-planned against the
    /// fleet that will actually execute.
    fn profile(&self) -> CapacityProfile;

    /// Largest single-machine capacity µ this backend can grant (the
    /// profile's first class). Kept as the scalar convenience for call
    /// sites that only need "how big can one part be".
    fn capacity(&self) -> usize {
        self.profile().max_capacity()
    }

    /// Start one round: run `compressor` on every part (part `j` on a
    /// machine of the profile's virtual capacity `µ_{j mod L}`) and
    /// stream [`PartEvent`]s as machines report. Must fail with
    /// [`Error::CapacityExceeded`] if any part exceeds its machine's
    /// capacity, before any work starts. Solutions are keyed by part
    /// index and use positional per-machine seeds, so the event arrival
    /// order (and any requeueing along the way) never changes the
    /// answer.
    fn submit_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundHandle>;

    /// Barrier wrapper over [`Backend::submit_round`]: block until every
    /// part completes and return one solution per part, order preserved.
    fn run_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundOutcome> {
        self.submit_round(problem, compressor, parts, round_seed)?.finish()
    }
}

/// Which backend a run should use — parsed from config/CLI and built
/// into a concrete [`Backend`] with [`BackendChoice::build`].
#[derive(Debug, Clone, PartialEq, Default)]
pub enum BackendChoice {
    /// In-process thread pool (the default).
    #[default]
    Local,
    /// Real worker processes at the given `host:port` addresses.
    Tcp { workers: Vec<String> },
    /// Deterministic fault-injecting simulator. `schedule` scripts the
    /// fleet per round (`--sim-capacity-schedule PROFILE[;PROFILE…]`,
    /// config `sim.capacity_schedule`); empty means a static fleet.
    Sim { faults: FaultPlan, schedule: Vec<CapacityProfile> },
}

impl BackendChoice {
    /// Parse a backend name from config/CLI (`local` | `tcp` | `sim`).
    pub fn parse(name: &str) -> Result<BackendChoice> {
        Ok(match name {
            "local" => BackendChoice::Local,
            "tcp" => BackendChoice::Tcp { workers: Vec::new() },
            "sim" => {
                BackendChoice::Sim { faults: FaultPlan::default(), schedule: Vec::new() }
            }
            other => {
                return Err(Error::Config(format!(
                    "unknown backend '{other}' (known: local, tcp, sim)"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendChoice::Local => "local",
            BackendChoice::Tcp { .. } => "tcp",
            BackendChoice::Sim { .. } => "sim",
        }
    }

    /// Build the concrete backend for the given capacity profile.
    /// `threads` is the local thread-pool width (ignored by tcp/sim).
    pub fn build(
        &self,
        profile: &CapacityProfile,
        threads: Option<usize>,
    ) -> Result<Arc<dyn Backend>> {
        Ok(match self {
            BackendChoice::Local => {
                let mut b = LocalBackend::with_profile(profile.clone());
                if let Some(t) = threads {
                    b = b.with_threads(t);
                }
                Arc::new(b)
            }
            BackendChoice::Tcp { workers } => {
                Arc::new(TcpBackend::with_profile(profile.clone(), workers.clone())?)
            }
            BackendChoice::Sim { faults, schedule } => {
                let mut b =
                    SimBackend::with_profile(profile.clone()).with_faults(faults.clone());
                if !schedule.is_empty() {
                    b = b.with_capacity_schedule(schedule.clone());
                }
                Arc::new(b)
            }
        })
    }
}

/// Shared pre-dispatch check against a heterogeneous fleet: part `j`
/// must fit the virtual machine `µ_{j mod L}` it was sized for.
pub(crate) fn enforce_profile(profile: &CapacityProfile, parts: &[Vec<u32>]) -> Result<()> {
    for (i, p) in parts.iter().enumerate() {
        let cap = profile.virtual_capacity(i);
        if p.len() > cap {
            return Err(Error::CapacityExceeded {
                capacity: cap,
                got: p.len(),
                ctx: format!(" (machine {i} of {})", parts.len()),
            });
        }
    }
    Ok(())
}

/// Positional per-machine seeds derived from the round seed — identical
/// across backends (and across thread counts) so a round's output never
/// depends on the execution substrate.
pub(crate) fn machine_seeds(round_seed: u64, machines: usize) -> Vec<u64> {
    let mut seed_rng = Rng::seed_from(round_seed);
    (0..machines).map(|_| seed_rng.next_u64()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enforce_profile_names_the_machine() {
        let parts = vec![vec![0, 1], vec![0, 1, 2, 3]];
        let err = enforce_profile(&CapacityProfile::uniform(3), &parts).unwrap_err();
        match err {
            Error::CapacityExceeded { capacity, got, ctx } => {
                assert_eq!(capacity, 3);
                assert_eq!(got, 4);
                assert!(ctx.contains("machine 1 of 2"), "ctx: {ctx}");
            }
            other => panic!("wrong error {other}"),
        }
        assert!(enforce_profile(&CapacityProfile::uniform(4), &parts).is_ok());
    }

    #[test]
    fn enforce_profile_checks_each_part_against_its_virtual_machine() {
        let profile = CapacityProfile::parse("4,2").unwrap();
        // virtual capacities cycle 4, 2, 4, 2, …
        let fits = vec![vec![0, 1, 2, 3], vec![0, 1], vec![0], vec![0, 1]];
        assert!(enforce_profile(&profile, &fits).is_ok());
        // part 1 sized for the large class overloads the small one
        let overloaded = vec![vec![0, 1], vec![0, 1, 2]];
        let err = enforce_profile(&profile, &overloaded).unwrap_err();
        match err {
            Error::CapacityExceeded { capacity: 2, got: 3, ctx } => {
                assert!(ctx.contains("machine 1 of 2"), "ctx: {ctx}");
            }
            other => panic!("wrong error {other}"),
        }
    }

    #[test]
    fn machine_seeds_are_positional_and_deterministic() {
        let a = machine_seeds(7, 5);
        let b = machine_seeds(7, 3);
        assert_eq!(&a[..3], &b[..]);
        assert_ne!(a[0], a[1]);
    }

    #[test]
    fn round_handle_completes_at_last_done_and_finish_slots_by_part() {
        let (tx, rx) = mpsc::channel();
        // events out of part order, requeue before the requeued part's Done
        tx.send(Ok(PartEvent::Done {
            part: 1,
            solution: Solution { items: vec![5], value: 1.0 },
        }))
        .unwrap();
        tx.send(Ok(PartEvent::Requeued { part: 0, reshipped_ids: 7 })).unwrap();
        tx.send(Ok(PartEvent::Delay { part: 0, virtual_ms: 12.5 })).unwrap();
        tx.send(Ok(PartEvent::Done {
            part: 0,
            solution: Solution { items: vec![2], value: 3.0 },
        }))
        .unwrap();
        // tx deliberately NOT dropped: the handle must complete on the
        // last Done without waiting for backend teardown
        let handle = RoundHandle::new(rx, 2);
        let out = handle.finish().unwrap();
        assert_eq!(out.solutions.len(), 2);
        assert_eq!(out.solutions[0].items, vec![2]);
        assert_eq!(out.solutions[1].items, vec![5]);
        assert_eq!(out.requeued_parts, 1);
        assert_eq!(out.requeued_ids, 7);
        assert_eq!(out.sim_delay_ms, 12.5);
        drop(tx);
    }

    #[test]
    fn round_handle_surfaces_fatal_errors_and_dropped_channels() {
        let (tx, rx) = mpsc::channel::<Result<PartEvent>>();
        tx.send(Err(Error::Transport("boom".into()))).unwrap();
        let err = RoundHandle::new(rx, 3).finish().unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");

        // channel dropped before completion: an error, never a hang or
        // a silent success
        let (tx2, rx2) = mpsc::channel::<Result<PartEvent>>();
        drop(tx2);
        let err = RoundHandle::new(rx2, 2).finish().unwrap_err();
        assert!(err.to_string().contains("0 of 2"), "{err}");

        // empty rounds complete immediately
        let out = RoundHandle::empty().finish().unwrap();
        assert!(out.solutions.is_empty());
    }

    #[test]
    fn backend_choice_parses() {
        assert_eq!(BackendChoice::parse("local").unwrap(), BackendChoice::Local);
        assert_eq!(BackendChoice::parse("tcp").unwrap().name(), "tcp");
        assert_eq!(BackendChoice::parse("sim").unwrap().name(), "sim");
        assert!(BackendChoice::parse("mpi").is_err());
    }
}

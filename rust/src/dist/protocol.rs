//! Wire protocol between the coordinator ([`crate::dist::TcpBackend`])
//! and `hss worker` processes.
//!
//! Transport: length-prefixed frames — a 4-byte big-endian payload
//! length followed by a UTF-8 JSON document (the crate's own
//! [`crate::util::json`] codec; no external serialization dependency).
//!
//! Losslessness: item ids are `u32` (exact in JSON's f64 numbers) and
//! objective values are `f64` serialized via Rust's shortest-roundtrip
//! `Display`, so a solution survives the wire bit-exactly. Seeds are full
//! 64-bit words and are therefore encoded as **decimal strings** — an
//! f64 number would silently drop low bits past 2^53.
//!
//! Problems cross the wire *by specification*, not by value: datasets in
//! the registry are generated deterministically from `(name, seed)`, so a
//! [`ProblemSpec`] of a few bytes reconstructs the exact same ground set
//! and evaluation subsample on the worker — the coordinator ships item
//! ids, never rows (the paper's shuffle model).

use std::io::{Read, Write};

use crate::algorithms::{Compressor, LazyGreedy, RandomCompressor, StochasticGreedy, ThresholdGreedy};
use crate::data::{registry, DatasetRef};
use crate::error::{Error, Result};
use crate::objectives::{Objective, Problem};
use crate::util::json::{self, Json};

/// Protocol version — bumped on any incompatible message change; worker
/// and coordinator refuse to pair across versions.
pub const PROTOCOL_VERSION: usize = 1;

/// Hard cap on frame payloads (64 MiB — a part of 10^6 ids is ~8 MB of
/// JSON; anything bigger than this is a corrupt or hostile frame).
pub const MAX_FRAME: usize = 64 << 20;

// ---------------------------------------------------------------------------
// framed transport
// ---------------------------------------------------------------------------

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(Error::Protocol(format!(
            "outgoing frame of {} bytes exceeds MAX_FRAME {MAX_FRAME}",
            payload.len()
        )));
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_be_bytes(len_bytes) as usize;
    if len > MAX_FRAME {
        return Err(Error::Protocol(format!(
            "incoming frame of {len} bytes exceeds MAX_FRAME {MAX_FRAME}"
        )));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Serialize + frame one message.
pub fn send_msg<W: Write>(w: &mut W, msg: &Json) -> Result<()> {
    write_frame(w, msg.to_string().as_bytes())
}

/// Read + parse one message.
pub fn recv_msg<R: Read>(r: &mut R) -> Result<Json> {
    let bytes = read_frame(r)?;
    let text = std::str::from_utf8(&bytes)
        .map_err(|_| Error::Protocol("frame is not UTF-8".into()))?;
    Json::parse(text)
}

// ---------------------------------------------------------------------------
// lossless u64 encoding
// ---------------------------------------------------------------------------

fn ju64(x: u64) -> Json {
    Json::Str(x.to_string())
}

fn req_u64(v: &Json, key: &str) -> Result<u64> {
    let field = v
        .get(key)
        .ok_or_else(|| Error::Protocol(format!("missing field '{key}'")))?;
    json::as_lossless_u64(field)
        .ok_or_else(|| Error::Protocol(format!("field '{key}' is not a u64")))
}

fn req_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::Protocol(format!("missing number field '{key}'")))
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Protocol(format!("missing integer field '{key}'")))
}

fn req_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Protocol(format!("missing string field '{key}'")))
}

fn items_to_json(items: &[u32]) -> Json {
    Json::Arr(items.iter().map(|&i| Json::Num(i as f64)).collect())
}

fn items_from_json(v: &Json, key: &str) -> Result<Vec<u32>> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Protocol(format!("missing array field '{key}'")))?;
    arr.iter()
        .map(|x| {
            x.as_f64()
                .filter(|v| *v >= 0.0 && v.fract() == 0.0 && *v <= u32::MAX as f64)
                .map(|v| v as u32)
                .ok_or_else(|| Error::Protocol(format!("'{key}' contains a non-u32 entry")))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// problem + compressor specifications
// ---------------------------------------------------------------------------

/// A wire-serializable description of a [`Problem`]. Restricted to
/// registry datasets, the two paper objectives, and the plain
/// cardinality constraint — exactly what distributed runs use; richer
/// constraint/objective shipping is an open item.
#[derive(Debug, Clone, PartialEq)]
pub struct ProblemSpec {
    pub dataset: String,
    /// `"exemplar"` or `"logdet"`.
    pub objective: String,
    pub k: usize,
    pub seed: u64,
    /// Exemplar evaluation-subsample size (0 for logdet).
    pub eval_m: usize,
    /// LogDet kernel parameters (0 for exemplar).
    pub h2: f64,
    pub sigma2: f64,
}

impl ProblemSpec {
    /// Capture a problem's wire spec. Fails for problems that are not
    /// wire-representable (non-registry dataset, test objectives,
    /// hereditary constraints beyond plain cardinality).
    pub fn from_problem(p: &Problem) -> Result<ProblemSpec> {
        let sp = registry::spec(&p.dataset.name).map_err(|_| {
            Error::invalid(format!(
                "dataset '{}' is not in the registry; tcp workers reconstruct \
                 datasets from (name, seed) and cannot receive ad-hoc matrices",
                p.dataset.name
            ))
        })?;
        if sp.n() != p.dataset.n {
            return Err(Error::invalid(format!(
                "dataset '{}' has n={} but the registry generates n={}",
                p.dataset.name,
                p.dataset.n,
                sp.n()
            )));
        }
        if p.constraint.name() != format!("card({})", p.k) {
            return Err(Error::invalid(format!(
                "constraint '{}' is not wire-representable (only card(k))",
                p.constraint.name()
            )));
        }
        let (objective, eval_m, h2, sigma2) = match &p.objective {
            Objective::Exemplar => ("exemplar", p.eval_ids.len(), 0.0, 0.0),
            Objective::LogDet { h2, sigma2 } => ("logdet", 0, *h2, *sigma2),
            other => {
                return Err(Error::invalid(format!(
                    "objective '{}' is not wire-representable",
                    other.name()
                )))
            }
        };
        Ok(ProblemSpec {
            dataset: p.dataset.name.clone(),
            objective: objective.to_string(),
            k: p.k,
            seed: p.seed,
            eval_m,
            h2,
            sigma2,
        })
    }

    /// Reconstruct the problem on the receiving side. Deterministic:
    /// dataset generation, eval-subsample draw and constraint all derive
    /// from the spec alone.
    pub fn materialize(&self) -> Result<Problem> {
        self.materialize_on(registry::load(&self.dataset, self.seed)?)
    }

    /// Same, over an already-loaded dataset handle (worker-side caching:
    /// many specs — different k, eval_m — share one dataset Arc instead
    /// of each holding its own copy of the matrix).
    pub fn materialize_on(&self, ds: DatasetRef) -> Result<Problem> {
        match self.objective.as_str() {
            "exemplar" => Ok(Problem::exemplar_with_eval(ds, self.k, self.seed, self.eval_m)),
            "logdet" => {
                let mut p = Problem::logdet(ds, self.k, self.seed);
                p.objective = Objective::LogDet { h2: self.h2, sigma2: self.sigma2 };
                Ok(p)
            }
            other => Err(Error::Protocol(format!("unknown objective '{other}'"))),
        }
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("dataset", json::s(&self.dataset)),
            ("objective", json::s(&self.objective)),
            ("k", json::num(self.k as f64)),
            ("seed", ju64(self.seed)),
            ("eval_m", json::num(self.eval_m as f64)),
            ("h2", json::num(self.h2)),
            ("sigma2", json::num(self.sigma2)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ProblemSpec> {
        Ok(ProblemSpec {
            dataset: req_str(v, "dataset")?.to_string(),
            objective: req_str(v, "objective")?.to_string(),
            k: req_usize(v, "k")?,
            seed: req_u64(v, "seed")?,
            eval_m: req_usize(v, "eval_m")?,
            h2: req_f64(v, "h2")?,
            sigma2: req_f64(v, "sigma2")?,
        })
    }

}

/// Map a compressor's `name()` to a wire tag, failing for compressors
/// that cannot be reconstructed remotely (e.g. the XLA-engine-bound
/// ones — workers run the pure path).
pub fn compressor_wire_name(c: &dyn Compressor) -> Result<String> {
    let name = c.name();
    // validate round-trip now so dispatch fails fast with a clear error
    compressor_from_name(&name).map_err(|_| {
        Error::invalid(format!(
            "compressor '{name}' is not wire-representable; tcp workers support \
             greedy, random, stochastic-greedy(eps=..), threshold-greedy(eps=..)"
        ))
    })?;
    Ok(name)
}

/// Reconstruct a compressor from its wire tag.
pub fn compressor_from_name(name: &str) -> Result<Box<dyn Compressor>> {
    fn eps_of(name: &str, prefix: &str) -> Option<f64> {
        let rest = name.strip_prefix(prefix)?.strip_suffix(')')?;
        rest.parse::<f64>().ok().filter(|e| *e > 0.0 && *e < 1.0)
    }
    if name == "greedy" {
        return Ok(Box::new(LazyGreedy::new()));
    }
    if name == "random" {
        return Ok(Box::new(RandomCompressor::new()));
    }
    if let Some(eps) = eps_of(name, "stochastic-greedy(eps=") {
        return Ok(Box::new(StochasticGreedy::new(eps)));
    }
    if let Some(eps) = eps_of(name, "threshold-greedy(eps=") {
        return Ok(Box::new(ThresholdGreedy::new(eps)));
    }
    Err(Error::Protocol(format!("unknown compressor '{name}'")))
}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

/// Coordinator → worker.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Handshake: version check, capacity discovery.
    Hello,
    /// Compress one part on one fixed-capacity machine.
    Compress {
        problem: ProblemSpec,
        compressor: String,
        part: Vec<u32>,
        seed: u64,
    },
    /// Orderly worker shutdown.
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Hello => json::obj(vec![
                ("type", json::s("hello")),
                ("version", json::num(PROTOCOL_VERSION as f64)),
            ]),
            Request::Compress { problem, compressor, part, seed } => json::obj(vec![
                ("type", json::s("compress")),
                ("problem", problem.to_json()),
                ("compressor", json::s(compressor)),
                ("part", items_to_json(part)),
                ("seed", ju64(*seed)),
            ]),
            Request::Shutdown => json::obj(vec![("type", json::s("shutdown"))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request> {
        match req_str(v, "type")? {
            "hello" => {
                let version = req_usize(v, "version")?;
                if version != PROTOCOL_VERSION {
                    return Err(Error::Protocol(format!(
                        "version mismatch: peer speaks v{version}, this build speaks v{PROTOCOL_VERSION}"
                    )));
                }
                Ok(Request::Hello)
            }
            "compress" => {
                let problem_json = v
                    .get("problem")
                    .ok_or_else(|| Error::Protocol("missing field 'problem'".into()))?;
                Ok(Request::Compress {
                    problem: ProblemSpec::from_json(problem_json)?,
                    compressor: req_str(v, "compressor")?.to_string(),
                    part: items_from_json(v, "part")?,
                    seed: req_u64(v, "seed")?,
                })
            }
            "shutdown" => Ok(Request::Shutdown),
            other => Err(Error::Protocol(format!("unknown request type '{other}'"))),
        }
    }
}

/// Worker → coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Handshake reply: the worker's fixed capacity µ.
    Hello { capacity: usize },
    /// One machine's compression result plus its per-call metrics.
    Solution { items: Vec<u32>, value: f64, evals: u64, wall_ms: f64 },
    /// The request failed on the worker (capacity violation, bad spec…).
    Error { msg: String },
    /// Shutdown acknowledged.
    Bye,
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Hello { capacity } => json::obj(vec![
                ("type", json::s("hello")),
                ("version", json::num(PROTOCOL_VERSION as f64)),
                ("capacity", json::num(*capacity as f64)),
            ]),
            Response::Solution { items, value, evals, wall_ms } => json::obj(vec![
                ("type", json::s("solution")),
                ("items", items_to_json(items)),
                ("value", json::num(*value)),
                ("evals", ju64(*evals)),
                ("wall_ms", json::num(*wall_ms)),
            ]),
            Response::Error { msg } => json::obj(vec![
                ("type", json::s("error")),
                ("msg", json::s(msg)),
            ]),
            Response::Bye => json::obj(vec![("type", json::s("bye"))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        match req_str(v, "type")? {
            "hello" => {
                let version = req_usize(v, "version")?;
                if version != PROTOCOL_VERSION {
                    return Err(Error::Protocol(format!(
                        "version mismatch: peer speaks v{version}, this build speaks v{PROTOCOL_VERSION}"
                    )));
                }
                Ok(Response::Hello { capacity: req_usize(v, "capacity")? })
            }
            "solution" => Ok(Response::Solution {
                items: items_from_json(v, "items")?,
                value: req_f64(v, "value")?,
                evals: req_u64(v, "evals")?,
                wall_ms: req_f64(v, "wall_ms")?,
            }),
            "error" => Ok(Response::Error { msg: req_str(v, "msg")?.to_string() }),
            "bye" => Ok(Response::Bye),
            other => Err(Error::Protocol(format!("unknown response type '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[0xF0, 0x9F, 0x8E, 0x89]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
        assert_eq!(read_frame(&mut r).unwrap(), vec![0xF0, 0x9F, 0x8E, 0x89]);
        // EOF surfaces as an io error, not a hang
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_be_bytes());
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert!(err.to_string().contains("MAX_FRAME"), "{err}");
    }

    #[test]
    fn requests_roundtrip() {
        let spec = ProblemSpec {
            dataset: "csn-2k".into(),
            objective: "exemplar".into(),
            k: 25,
            seed: u64::MAX - 12345,
            eval_m: 2000,
            h2: 0.0,
            sigma2: 0.0,
        };
        let req = Request::Compress {
            problem: spec,
            compressor: "greedy".into(),
            part: vec![0, 7, 4_000_000_000],
            seed: 0xDEAD_BEEF_DEAD_BEEF,
        };
        let back = Request::from_json(&Json::parse(&req.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(req, back);
        for r in [Request::Hello, Request::Shutdown] {
            let b = Request::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(r, b);
        }
    }

    #[test]
    fn responses_roundtrip_with_exact_f64() {
        // a value with a long mantissa that an imprecise codec would mangle
        let value = 123.456_789_012_345_67_f64 / 3.0;
        let resp = Response::Solution {
            items: vec![1, 2, 3],
            value,
            evals: 987_654_321,
            wall_ms: 1.25,
        };
        let back =
            Response::from_json(&Json::parse(&resp.to_json().to_string()).unwrap()).unwrap();
        match back {
            Response::Solution { value: v, items, evals, .. } => {
                assert_eq!(v.to_bits(), value.to_bits(), "f64 mangled on the wire");
                assert_eq!(items, vec![1, 2, 3]);
                assert_eq!(evals, 987_654_321);
            }
            other => panic!("wrong response {other:?}"),
        }
        let err = Response::Error { msg: "nope".into() };
        let b = Response::from_json(&Json::parse(&err.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(err, b);
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let msg = Json::parse(r#"{"type":"hello","version":999}"#).unwrap();
        assert!(Request::from_json(&msg).is_err());
        assert!(Response::from_json(&msg).is_err());
    }

    #[test]
    fn problem_spec_roundtrips_and_materializes() {
        let spec = ProblemSpec {
            dataset: "csn-2k".into(),
            objective: "exemplar".into(),
            k: 10,
            seed: 42,
            eval_m: 2000,
            h2: 0.0,
            sigma2: 0.0,
        };
        let back = ProblemSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        let p = spec.materialize().unwrap();
        assert_eq!(p.n(), 2000);
        assert_eq!(p.k, 10);
        // spec extraction from the materialized problem is the identity
        assert_eq!(ProblemSpec::from_problem(&p).unwrap(), spec);
    }

    #[test]
    fn non_registry_problem_is_rejected() {
        let ds = std::sync::Arc::new(crate::data::synthetic::csn_like(64, 1));
        let p = Problem::exemplar(ds, 4, 1); // dataset name "csn", not registered
        assert!(ProblemSpec::from_problem(&p).is_err());
    }

    #[test]
    fn compressors_roundtrip_by_name() {
        for name in ["greedy", "random", "stochastic-greedy(eps=0.5)", "threshold-greedy(eps=0.25)"] {
            let c = compressor_from_name(name).unwrap();
            assert_eq!(c.name(), name, "wire name not stable");
            assert_eq!(compressor_wire_name(c.as_ref()).unwrap(), name);
        }
        assert!(compressor_from_name("xla-greedy").is_err());
        assert!(compressor_from_name("stochastic-greedy(eps=2.0)").is_err());
    }
}

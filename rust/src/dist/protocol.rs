//! Wire protocol between the coordinator ([`crate::dist::TcpBackend`])
//! and `hss worker` processes. The normative specification lives in
//! `docs/PROTOCOL.md`; this module is the reference implementation.
//!
//! Transport: length-prefixed frames — a 4-byte big-endian payload
//! length followed by a UTF-8 JSON control document (the crate's own
//! [`crate::util::json`] codec; no external serialization dependency).
//! On connections negotiated to [`PayloadMode::Binary`] (protocol v6),
//! bulk number arrays leave the control document: each becomes a
//! length-prefixed little-endian blob appended *after* the document
//! inside the same frame, with a small `{"blob":i,"elem":…,"count":n}`
//! marker object left in its place. Blobs decode by bounds-checked
//! slice reinterpretation (`chunks_exact` + `from_le_bytes`), never a
//! per-element text parse, and hot control-frame fields are read with
//! the [`lazy`](crate::util::json::lazy) byte scanner instead of a
//! full-tree parse.
//!
//! Losslessness: item ids are `u32` (exact in JSON's f64 numbers) and
//! objective values are `f64` serialized via Rust's shortest-roundtrip
//! `Display`, so a solution survives the wire bit-exactly. Seeds are full
//! 64-bit words and are therefore encoded as **decimal strings** — an
//! f64 number would silently drop low bits past 2^53.
//!
//! Problems cross the wire *by specification*, not by value: datasets —
//! registry entries or recorded ad-hoc synthetic instances
//! ([`DatasetSpec`]) — regenerate deterministically from a few bytes of
//! spec, hereditary constraints rebuild from their construction recipe
//! ([`ConstraintSpec`]: cardinality, knapsack with weight-generator
//! specs, partition matroids, intersections), and the coordinator ships
//! item ids, never rows (the paper's shuffle model).

use std::io::{Read, Write};

use crate::algorithms::{
    Compressor, LazyGreedy, RandomCompressor, StochasticGreedy, ThresholdGreedy,
};
use crate::constraints::spec::ConstraintSpec;
use crate::data::spec::DatasetSpec;
use crate::data::DatasetRef;
use crate::error::{Error, Result};
use crate::objectives::{Objective, Problem};
use crate::runtime::EngineChoice;
use crate::util::json::lazy::{self, LazyDoc};
use crate::util::json::{self, wire_f64, wire_str, wire_u64, wire_usize, Json};

/// Protocol version — bumped on any incompatible message change; worker
/// and coordinator refuse to pair across versions (see
/// `docs/PROTOCOL.md` for the normative wire spec). v2 added
/// [`DatasetSpec`]/[`ConstraintSpec`] problem shipping (hereditary
/// constraints + ad-hoc datasets). v3 made the worker's handshake
/// capacity advertisement *load-bearing* — coordinators dispatch by
/// capacity fit over heterogeneous fleets — and added the virtual
/// machine capacity `cap` to every compress request so workers enforce
/// the planned per-machine bound, not just their own physical µ. v4
/// interns problems: a [`Request::DefineProblem`] ships the full
/// [`ProblemSpec`] **once per (connection, problem identity)** and
/// every [`Request::Compress`] carries the short `problem_id` instead
/// of the spec — killing the per-round spec re-serialization and
/// shrinking every subsequent request to O(part). Workers keep the id
/// table per connection, so a coordinator re-interns transparently on
/// fresh or reconnected workers. v5 adds **telemetry**: the handshake
/// carries a coordinator clock echo (`clock_ms` → `clock_echo_ms`) so
/// worker-side timings can be aligned to the coordinator's trace
/// timeline, and every solution response carries a [`Telemetry`] block
/// (queue-wait ms plus cumulative dataset-cache and problem-id-table
/// hit/miss/eviction counters) alongside the per-call `evals` /
/// `wall_ms` that existed since v1. Telemetry is observational only —
/// it never changes dispatch decisions or answers. v6 adds the
/// **negotiated binary payload encoding**: a worker that is willing to
/// receive blob sections advertises `payload: "binary"` in its hello
/// reply (after the coordinator advertised it first), and from then on
/// both sides of that connection may append length-prefixed
/// little-endian blobs after the JSON control document — `compress`
/// part ids and `solution` item ids as u32 blocks, explicit
/// constraint weight/group tables inside `define-problem` as f64/u32
/// blocks. Handshake frames themselves are always pure JSON, a peer
/// that stays silent about `payload` gets pure-JSON frames for the
/// whole connection, and both encodings are bit-identical in decoded
/// meaning (the differential tests in `rust/tests/protocol_fuzz.rs`
/// enforce it). v1–v5 peers are rejected at handshake. v6 also carries
/// the **negotiated compute engine** (additive — no version bump): a
/// coordinator may request `engine: "xla"` in its hello, a worker
/// answers with the engine it will actually serve the connection with
/// (its pinned `--engine` wins over the request), an absent token means
/// `native` — the dependency-free batched kernel backend every build
/// carries — so engine-silent peers keep handshaking unchanged, and an
/// unknown engine name is a protocol error. Solution telemetry gained
/// `engine` / `bulk_gain_calls` / `bulk_gain_candidates` under the same
/// additive rule (absent parses as empty/zero).
///
/// Pipelined/streaming dispatch (the coordinator's Backend v3 —
/// persistent per-worker dispatchers, next-round parts speculatively
/// dispatched while stragglers finish) is **protocol-invisible**:
/// workers simply observe back-to-back `compress` requests across round
/// boundaries on one warm connection. The normative statement of the
/// streaming semantics (event ordering, in-flight next-round parts) is
/// `docs/PROTOCOL.md` §6.1.
pub const PROTOCOL_VERSION: usize = 6;

/// Hard cap on frame payloads (64 MiB — a part of 10^6 ids is ~8 MB of
/// JSON; anything bigger than this is a corrupt or hostile frame).
pub const MAX_FRAME: usize = 64 << 20;

// ---------------------------------------------------------------------------
// framed transport
// ---------------------------------------------------------------------------

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(Error::Protocol(format!(
            "outgoing frame of {} bytes exceeds MAX_FRAME {MAX_FRAME}",
            payload.len()
        )));
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_be_bytes(len_bytes) as usize;
    if len > MAX_FRAME {
        return Err(Error::Protocol(format!(
            "incoming frame of {len} bytes exceeds MAX_FRAME {MAX_FRAME}"
        )));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Serialize + frame one message.
pub fn send_msg<W: Write>(w: &mut W, msg: &Json) -> Result<()> {
    write_frame(w, msg.to_string().as_bytes())
}

/// Read + parse one message.
pub fn recv_msg<R: Read>(r: &mut R) -> Result<Json> {
    let bytes = read_frame(r)?;
    let text = std::str::from_utf8(&bytes)
        .map_err(|_| Error::Protocol("frame is not UTF-8".into()))?;
    Json::parse(text)
}

// ---------------------------------------------------------------------------
// negotiated payload encoding (protocol v6)
// ---------------------------------------------------------------------------

/// Per-connection payload encoding, fixed at handshake time (protocol
/// v6). The coordinator advertises `payload: "binary"` in its hello;
/// a binary-capable worker echoes it back and the connection switches
/// to [`PayloadMode::Binary`] for every subsequent frame. A peer that
/// omits the field — or a worker launched with `--payload json` —
/// keeps the connection on pure-JSON frames, so mixed fleets work
/// per-connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PayloadMode {
    /// Pure JSON frames — the handshake default and the fallback for
    /// peers that never advertise `binary`.
    #[default]
    Json,
    /// JSON control document followed by a blob section: bulk number
    /// arrays ship as length-prefixed little-endian blocks and are
    /// replaced in the document by `{"blob":…}` markers.
    Binary,
}

impl PayloadMode {
    /// The handshake token for this mode.
    pub fn wire_name(self) -> &'static str {
        match self {
            PayloadMode::Json => "json",
            PayloadMode::Binary => "binary",
        }
    }

    /// Read an optional `payload` field from a hello frame; absent
    /// means JSON (pre-announcement peers and `--payload json` workers
    /// never emit the field).
    fn from_hello(v: &Json) -> Result<PayloadMode> {
        match v.get("payload") {
            None => Ok(PayloadMode::Json),
            Some(Json::Str(s)) if s == "json" => Ok(PayloadMode::Json),
            Some(Json::Str(s)) if s == "binary" => Ok(PayloadMode::Binary),
            Some(other) => Err(Error::Protocol(format!(
                "unknown payload encoding {other}"
            ))),
        }
    }
}

/// Read the optional `engine` token from a hello frame (v6, additive):
/// absent means [`EngineChoice::Native`] — the batched CPU kernel
/// backend every build carries — so engine-silent peers keep
/// handshaking unchanged; an unknown name is a protocol error rather
/// than a silent fallback, because the peers would disagree about
/// which compute substrate served the connection.
fn engine_from_hello(v: &Json) -> Result<EngineChoice> {
    match v.get("engine") {
        None => Ok(EngineChoice::Native),
        Some(Json::Str(s)) if s == "native" => Ok(EngineChoice::Native),
        Some(Json::Str(s)) if s == "xla" => Ok(EngineChoice::Xla),
        Some(other) => Err(Error::Protocol(format!("unknown engine {other}"))),
    }
}

/// Builder for a frame's blob section: each `push_*` appends one
/// `[u32 LE byte-length][bytes]` block and returns the marker object
/// (`{"blob":index,"count":elements,"elem":"u32"|"f64"}`) to embed in
/// the control document where the array used to be.
#[derive(Default)]
struct BlobWriter {
    section: Vec<u8>,
    count: usize,
}

impl BlobWriter {
    fn marker(idx: usize, elem: &str, count: usize) -> Json {
        json::obj(vec![
            ("blob", json::num(idx as f64)),
            ("elem", json::s(elem)),
            ("count", json::num(count as f64)),
        ])
    }

    fn push_u32s(&mut self, items: &[u32]) -> Json {
        self.section.extend_from_slice(&((items.len() * 4) as u32).to_le_bytes());
        for &x in items {
            self.section.extend_from_slice(&x.to_le_bytes());
        }
        let m = Self::marker(self.count, "u32", items.len());
        self.count += 1;
        m
    }

    fn push_f64s(&mut self, xs: &[f64]) -> Json {
        self.section.extend_from_slice(&((xs.len() * 8) as u32).to_le_bytes());
        for &x in xs {
            self.section.extend_from_slice(&x.to_le_bytes());
        }
        let m = Self::marker(self.count, "f64", xs.len());
        self.count += 1;
        m
    }
}

/// Serialize a control document and append the blob section: the
/// complete frame payload for a binary-mode message. (Oversized
/// results are caught by [`write_frame`]'s [`MAX_FRAME`] check.)
fn doc_with_blobs(doc: Json, blobs: BlobWriter) -> Vec<u8> {
    let mut bytes = doc.to_string().into_bytes();
    bytes.extend_from_slice(&blobs.section);
    bytes
}

/// Zero-copy view of a received frame's blob section: borrows the
/// frame buffer and hands out bounds-checked typed vectors. Every
/// malformation — truncated length prefix, declared length past the
/// end of the frame, byte length disagreeing with a marker's element
/// count — is a structured [`Error::Protocol`], never a panic.
struct BlobSection<'a> {
    blobs: Vec<&'a [u8]>,
}

impl<'a> BlobSection<'a> {
    /// Split `tail` (the frame bytes after the JSON control document)
    /// into its length-prefixed blobs.
    fn parse(tail: &'a [u8]) -> Result<BlobSection<'a>> {
        let mut blobs = Vec::new();
        let mut rest = tail;
        while !rest.is_empty() {
            if rest.len() < 4 {
                return Err(Error::Protocol(format!(
                    "truncated blob length prefix: {} trailing bytes",
                    rest.len()
                )));
            }
            let (len_bytes, after) = rest.split_at(4);
            let len =
                u32::from_le_bytes([len_bytes[0], len_bytes[1], len_bytes[2], len_bytes[3]])
                    as usize;
            if len > after.len() {
                return Err(Error::Protocol(format!(
                    "blob of {len} bytes overruns the frame ({} bytes left)",
                    after.len()
                )));
            }
            let (body, next) = after.split_at(len);
            blobs.push(body);
            rest = next;
        }
        Ok(BlobSection { blobs })
    }

    /// Resolve a marker object to its raw blob plus declared element
    /// count, validating the element tag.
    fn resolve(&self, marker: &Json, elem: &str, elem_size: usize) -> Result<(&'a [u8], usize)> {
        let idx = wire_usize(marker, "blob")?;
        let tag = wire_str(marker, "elem")?;
        let count = wire_usize(marker, "count")?;
        if tag != elem {
            return Err(Error::Protocol(format!(
                "expected a {elem} blob, marker says '{tag}'"
            )));
        }
        let body = self.blobs.get(idx).copied().ok_or_else(|| {
            Error::Protocol(format!(
                "marker names blob {idx} but the frame carries {}",
                self.blobs.len()
            ))
        })?;
        // detects both misaligned blobs (length not a multiple of the
        // element size) and count/length disagreements
        if body.len() != count.saturating_mul(elem_size) {
            return Err(Error::Protocol(format!(
                "{elem} blob is {} bytes but its marker declares {count} elements",
                body.len()
            )));
        }
        Ok((body, count))
    }

    fn u32s(&self, marker: &Json) -> Result<Vec<u32>> {
        let (body, _) = self.resolve(marker, "u32", 4)?;
        Ok(body.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn f64s(&self, marker: &Json) -> Result<Vec<f64>> {
        let (body, _) = self.resolve(marker, "f64", 8)?;
        Ok(body
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    /// Materialize a marker back into the number array it replaced —
    /// bit-exact, because the values never pass through decimal text.
    fn inline(&self, marker: &Json) -> Result<Json> {
        match wire_str(marker, "elem")? {
            "u32" => Ok(Json::Arr(
                self.u32s(marker)?.into_iter().map(|i| Json::Num(i as f64)).collect(),
            )),
            "f64" => Ok(Json::Arr(self.f64s(marker)?.into_iter().map(Json::Num).collect())),
            other => Err(Error::Protocol(format!("unknown blob element type '{other}'"))),
        }
    }
}

/// Split a received frame payload at the end of its JSON control
/// document (`end`, from [`LazyDoc::scan`]). Binary-mode connections
/// may carry a blob section there; on a JSON-mode connection anything
/// but trailing whitespace is a protocol violation — an unnegotiated
/// peer must never be handed blob bytes.
fn split_blob_section(
    payload: &[u8],
    end: usize,
    mode: PayloadMode,
) -> Result<Option<BlobSection<'_>>> {
    let tail = payload.get(end..).unwrap_or(&[]);
    match mode {
        PayloadMode::Binary => Ok(Some(BlobSection::parse(tail)?)),
        PayloadMode::Json => {
            if tail.iter().any(|b| !b.is_ascii_whitespace()) {
                return Err(Error::Protocol(
                    "trailing bytes after the document on a json-payload connection".into(),
                ));
            }
            Ok(None)
        }
    }
}

/// Full-tree parse of a frame's control document (`payload[..end]`) —
/// the cold-path decoder and the reference the lazy path must agree
/// with.
fn control_doc(payload: &[u8], end: usize) -> Result<Json> {
    let text = std::str::from_utf8(payload.get(..end).unwrap_or(payload))
        .map_err(|_| Error::Protocol("frame is not UTF-8".into()))?;
    Json::parse(text)
}

/// Decode an id array field that may arrive as a JSON number array or
/// (binary mode) a blob marker. The JSON spelling takes the
/// tree-free [`lazy::parse_u32_array`] fast path with a full-parse
/// fallback, so both spellings decode without materializing the
/// document.
fn ids_from_doc(doc: &LazyDoc, key: &str, blobs: &Option<BlobSection>) -> Result<Vec<u32>> {
    let raw = doc
        .raw(key)
        .ok_or_else(|| Error::Protocol(format!("missing array field '{key}'")))?;
    match raw.first() {
        Some(b'{') => {
            let Some(blobs) = blobs else {
                return Err(Error::Protocol(format!(
                    "'{key}' is a blob marker on a json-payload connection"
                )));
            };
            let marker = Json::parse(
                std::str::from_utf8(raw)
                    .map_err(|_| Error::Protocol("frame is not UTF-8".into()))?,
            )?;
            blobs.u32s(&marker)
        }
        Some(b'[') => {
            if let Some(ids) = lazy::parse_u32_array(raw)? {
                return Ok(ids);
            }
            let arr = Json::parse(
                std::str::from_utf8(raw)
                    .map_err(|_| Error::Protocol("frame is not UTF-8".into()))?,
            )?;
            let items = arr
                .as_arr()
                .ok_or_else(|| Error::Protocol(format!("missing array field '{key}'")))?;
            u32s_from_arr(items, key)
        }
        _ => Err(Error::Protocol(format!("missing array field '{key}'"))),
    }
}

/// Pull explicit constraint tables out of a spec document into the
/// blob section: `{"gen":"explicit","w":[…]}` weight tables become f64
/// blobs and `{"gen":"explicit","of":[…]}` group tables become u32
/// blobs, each replaced by its marker. Everything else rides in the
/// document verbatim — generator-spec'd constraints are already a few
/// bytes.
fn extract_table_blobs(v: &mut Json, blobs: &mut BlobWriter) {
    match v {
        Json::Obj(map) => {
            let explicit = matches!(map.get("gen"), Some(Json::Str(s)) if s == "explicit");
            for (key, child) in map.iter_mut() {
                if explicit && key == "w" {
                    if let Some(xs) = as_f64_table(child) {
                        *child = blobs.push_f64s(&xs);
                        continue;
                    }
                }
                if explicit && key == "of" {
                    if let Some(ids) = as_u32_table(child) {
                        *child = blobs.push_u32s(&ids);
                        continue;
                    }
                }
                extract_table_blobs(child, blobs);
            }
        }
        Json::Arr(arr) => {
            for child in arr {
                extract_table_blobs(child, blobs);
            }
        }
        _ => {}
    }
}

fn as_f64_table(v: &Json) -> Option<Vec<f64>> {
    v.as_arr()?.iter().map(Json::as_f64).collect()
}

fn as_u32_table(v: &Json) -> Option<Vec<u32>> {
    v.as_arr()?
        .iter()
        .map(|x| {
            x.as_f64()
                .filter(|v| *v >= 0.0 && v.fract() == 0.0 && *v <= u32::MAX as f64)
                .map(|v| v as u32)
        })
        .collect()
}

/// Inverse of [`extract_table_blobs`]: replace every blob marker in a
/// decoded spec document with its number array, so `from_json` sees
/// exactly what a JSON-mode frame would have carried.
fn inline_table_blobs(v: &mut Json, blobs: &BlobSection) -> Result<()> {
    let is_marker = matches!(
        v,
        Json::Obj(m) if m.contains_key("blob") && m.contains_key("elem") && m.contains_key("count")
    );
    if is_marker {
        let inlined = blobs.inline(v)?;
        *v = inlined;
        return Ok(());
    }
    match v {
        Json::Obj(map) => {
            for child in map.values_mut() {
                inline_table_blobs(child, blobs)?;
            }
        }
        Json::Arr(arr) => {
            for child in arr {
                inline_table_blobs(child, blobs)?;
            }
        }
        _ => {}
    }
    Ok(())
}

/// Frame + send one request on a connection negotiated to `mode`,
/// returning the payload size in bytes (the per-worker
/// binary-vs-json byte split).
pub fn send_request<W: Write>(w: &mut W, req: &Request, mode: PayloadMode) -> Result<usize> {
    let payload = req.encode(mode);
    write_frame(w, &payload)?;
    Ok(payload.len())
}

/// Receive + decode one request, returning it with the payload size.
pub fn recv_request<R: Read>(r: &mut R, mode: PayloadMode) -> Result<(Request, usize)> {
    let payload = read_frame(r)?;
    Ok((Request::decode(&payload, mode)?, payload.len()))
}

/// Frame + send one response (see [`send_request`]).
pub fn send_response<W: Write>(w: &mut W, resp: &Response, mode: PayloadMode) -> Result<usize> {
    let payload = resp.encode(mode);
    write_frame(w, &payload)?;
    Ok(payload.len())
}

/// Receive + decode one response, returning it with the payload size.
pub fn recv_response<R: Read>(r: &mut R, mode: PayloadMode) -> Result<(Response, usize)> {
    let payload = read_frame(r)?;
    Ok((Response::decode(&payload, mode)?, payload.len()))
}

// ---------------------------------------------------------------------------
// lossless u64 encoding
// ---------------------------------------------------------------------------

fn ju64(x: u64) -> Json {
    Json::Str(x.to_string())
}

/// Objective values may legitimately go non-finite (degenerate
/// kernels); JSON has no NaN/±inf literal, so those encode as the
/// string tokens `"NaN"` / `"inf"` / `"-inf"`. Infinities round-trip
/// exactly; NaN comes back as the canonical quiet NaN.
fn jvalue(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Str(x.to_string())
    }
}

fn value_from_json(v: &Json, key: &str) -> Result<f64> {
    scalar_value(v.get(key), key)
}

/// The objective-value decoding convention on one scalar (shared by the
/// full-tree and lazy readers): string tokens must be non-finite, null
/// tolerated as NaN (the generic writer's encoding for non-finite),
/// numbers pass through.
fn scalar_value(x: Option<&Json>, key: &str) -> Result<f64> {
    match x {
        Some(Json::Str(s)) => s
            .parse::<f64>()
            .ok()
            .filter(|x| !x.is_finite())
            .ok_or_else(|| {
                Error::Protocol(format!("field '{key}' is not a non-finite token"))
            }),
        Some(Json::Null) => Ok(f64::NAN),
        Some(Json::Num(n)) => Ok(*n),
        _ => Err(Error::Protocol(format!("missing number field '{key}'"))),
    }
}

fn items_to_json(items: &[u32]) -> Json {
    Json::Arr(items.iter().map(|&i| Json::Num(i as f64)).collect())
}

fn items_from_json(v: &Json, key: &str) -> Result<Vec<u32>> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Protocol(format!("missing array field '{key}'")))?;
    u32s_from_arr(arr, key)
}

fn u32s_from_arr(arr: &[Json], key: &str) -> Result<Vec<u32>> {
    arr.iter()
        .map(|x| {
            x.as_f64()
                .filter(|v| *v >= 0.0 && v.fract() == 0.0 && *v <= u32::MAX as f64)
                .map(|v| v as u32)
                .ok_or_else(|| Error::Protocol(format!("'{key}' contains a non-u32 entry")))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// problem + compressor specifications
// ---------------------------------------------------------------------------

/// A wire-serializable description of a [`Problem`]: dataset spec +
/// objective + hereditary-constraint spec. Covers registry and recorded
/// ad-hoc synthetic datasets, the two paper objectives, and every
/// constraint with a recorded construction recipe (wire spec v2).
///
/// Size note: generator-spec'd constraints keep the spec a few bytes,
/// but `Explicit` weight/group tables are O(n) and ride along in every
/// `compress` request (and are bounded by [`MAX_FRAME`]). Prefer the
/// generator forms for large ground sets; shipping the spec once per
/// connection is a known follow-up.
#[derive(Debug, Clone, PartialEq)]
pub struct ProblemSpec {
    pub dataset: DatasetSpec,
    /// `"exemplar"` or `"logdet"`.
    pub objective: String,
    pub k: usize,
    pub seed: u64,
    /// Exemplar evaluation-subsample size (0 for logdet).
    pub eval_m: usize,
    /// LogDet kernel parameters (0 for exemplar).
    pub h2: f64,
    pub sigma2: f64,
    /// Hereditary constraint, rebuilt on the worker from its recipe.
    pub constraint: ConstraintSpec,
}

impl ProblemSpec {
    /// Capture a problem's wire spec. Fails for problems that are not
    /// wire-representable (raw ad-hoc matrices, test objectives,
    /// constraints without a recorded construction recipe).
    pub fn from_problem(p: &Problem) -> Result<ProblemSpec> {
        let dataset = DatasetSpec::from_dataset(&p.dataset)?;
        let constraint = p.constraint.wire_spec().ok_or_else(|| {
            Error::invalid(format!(
                "constraint '{}' is not wire-representable (no construction \
                 recipe recorded)",
                p.constraint.name()
            ))
        })?;
        let (objective, eval_m, h2, sigma2) = match &p.objective {
            Objective::Exemplar => ("exemplar", p.eval_ids.len(), 0.0, 0.0),
            Objective::LogDet { h2, sigma2 } => ("logdet", 0, *h2, *sigma2),
            other => {
                return Err(Error::invalid(format!(
                    "objective '{}' is not wire-representable",
                    other.name()
                )))
            }
        };
        Ok(ProblemSpec {
            dataset,
            objective: objective.to_string(),
            k: p.k,
            seed: p.seed,
            eval_m,
            h2,
            sigma2,
            constraint,
        })
    }

    /// Reconstruct the problem on the receiving side. Deterministic:
    /// dataset generation, eval-subsample draw and constraint all derive
    /// from the spec alone.
    pub fn materialize(&self) -> Result<Problem> {
        self.materialize_on(self.dataset.load()?)
    }

    /// Same, over an already-loaded dataset handle (worker-side caching:
    /// many specs — different k, eval_m, constraints — share one dataset
    /// Arc instead of each holding its own copy of the matrix).
    pub fn materialize_on(&self, ds: DatasetRef) -> Result<Problem> {
        let constraint = self.constraint.build(&ds)?;
        self.materialize_with(ds, constraint)
    }

    /// Same, with an externally built constraint (worker-side
    /// memoization: constraint tables like row-norm weights are O(n·d)
    /// to build and identical across the parts of a round). The caller
    /// must have built `constraint` from this spec's `constraint` field
    /// over `ds`.
    pub fn materialize_with(
        &self,
        ds: DatasetRef,
        constraint: std::sync::Arc<dyn crate::constraints::Constraint>,
    ) -> Result<Problem> {
        let p = match self.objective.as_str() {
            "exemplar" => Problem::exemplar_with_eval(ds, self.k, self.seed, self.eval_m),
            "logdet" => {
                let mut p = Problem::logdet(ds, self.k, self.seed);
                p.objective = Objective::LogDet { h2: self.h2, sigma2: self.sigma2 };
                p
            }
            other => return Err(Error::Protocol(format!("unknown objective '{other}'"))),
        };
        Ok(p.with_constraint(constraint))
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("dataset", self.dataset.to_json()),
            ("objective", json::s(&self.objective)),
            ("k", json::num(self.k as f64)),
            ("seed", ju64(self.seed)),
            ("eval_m", json::num(self.eval_m as f64)),
            ("h2", json::num(self.h2)),
            ("sigma2", json::num(self.sigma2)),
            ("constraint", self.constraint.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ProblemSpec> {
        let dataset_json = v
            .get("dataset")
            .ok_or_else(|| Error::Protocol("missing field 'dataset'".into()))?;
        let constraint_json = v
            .get("constraint")
            .ok_or_else(|| Error::Protocol("missing field 'constraint'".into()))?;
        Ok(ProblemSpec {
            dataset: DatasetSpec::from_json(dataset_json)?,
            objective: wire_str(v, "objective")?.to_string(),
            k: wire_usize(v, "k")?,
            seed: wire_u64(v, "seed")?,
            eval_m: wire_usize(v, "eval_m")?,
            h2: wire_f64(v, "h2")?,
            sigma2: wire_f64(v, "sigma2")?,
            constraint: ConstraintSpec::from_json(constraint_json)?,
        })
    }
}

/// Map a compressor's `name()` to a wire tag, failing for compressors
/// that cannot be reconstructed remotely (e.g. the XLA-engine-bound
/// ones — workers run the pure path).
pub fn compressor_wire_name(c: &dyn Compressor) -> Result<String> {
    let name = c.name();
    // validate round-trip now so dispatch fails fast with a clear error
    compressor_from_name(&name).map_err(|_| {
        Error::invalid(format!(
            "compressor '{name}' is not wire-representable; tcp workers support \
             greedy, random, stochastic-greedy(eps=..), threshold-greedy(eps=..)"
        ))
    })?;
    Ok(name)
}

/// Reconstruct a compressor from its wire tag.
pub fn compressor_from_name(name: &str) -> Result<Box<dyn Compressor>> {
    fn eps_of(name: &str, prefix: &str) -> Option<f64> {
        let rest = name.strip_prefix(prefix)?.strip_suffix(')')?;
        rest.parse::<f64>().ok().filter(|e| *e > 0.0 && *e < 1.0)
    }
    if name == "greedy" {
        return Ok(Box::new(LazyGreedy::new()));
    }
    if name == "random" {
        return Ok(Box::new(RandomCompressor::new()));
    }
    if let Some(eps) = eps_of(name, "stochastic-greedy(eps=") {
        return Ok(Box::new(StochasticGreedy::new(eps)));
    }
    if let Some(eps) = eps_of(name, "threshold-greedy(eps=") {
        return Ok(Box::new(ThresholdGreedy::new(eps)));
    }
    Err(Error::Protocol(format!("unknown compressor '{name}'")))
}

// ---------------------------------------------------------------------------
// worker telemetry (protocol v5)
// ---------------------------------------------------------------------------

/// Worker-side telemetry riding on every [`Response::Solution`]
/// (protocol v5). `queue_wait_ms` is per-request; the cache counters
/// are **cumulative gauges** over the worker process (dataset cache)
/// or the current connection (problem-id table), so the coordinator
/// keeps the latest value per worker instead of summing. Purely
/// observational — omitted fields parse as zero and nothing here ever
/// influences dispatch or answers.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Telemetry {
    /// Time between the worker reading the request frame and starting
    /// the compression compute, including any injected straggle sleep —
    /// the worker-side queueing component of end-to-end latency.
    pub queue_wait_ms: f64,
    /// Dataset-cache hits (process lifetime).
    pub dataset_hits: u64,
    /// Dataset-cache misses (process lifetime).
    pub dataset_misses: u64,
    /// Interned-problem-table hits (connection lifetime).
    pub problem_hits: u64,
    /// Compress requests naming an unknown/evicted problem id
    /// (connection lifetime).
    pub problem_misses: u64,
    /// Interned problems evicted by the table bound (connection
    /// lifetime).
    pub problem_evictions: u64,
    /// Wire name of the compute engine that served this request
    /// (`native` / `xla`). A gauge like the cache counters — the
    /// coordinator keeps the latest value per worker. Absent (pre-engine
    /// workers) parses as `""`.
    pub engine: String,
    /// Batched-gain (`gains_for`) calls the oracle answered while
    /// compressing this part (per-request sum).
    pub bulk_gain_calls: u64,
    /// Total candidates evaluated across those batched calls
    /// (per-request sum).
    pub bulk_gain_candidates: u64,
}

impl Telemetry {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("queue_wait_ms", json::num(self.queue_wait_ms)),
            ("dataset_hits", ju64(self.dataset_hits)),
            ("dataset_misses", ju64(self.dataset_misses)),
            ("problem_hits", ju64(self.problem_hits)),
            ("problem_misses", ju64(self.problem_misses)),
            ("problem_evictions", ju64(self.problem_evictions)),
            ("engine", json::s(&self.engine)),
            ("bulk_gain_calls", ju64(self.bulk_gain_calls)),
            ("bulk_gain_candidates", ju64(self.bulk_gain_candidates)),
        ])
    }

    /// Parse from an optional `telemetry` object; a missing block or
    /// missing fields default to zero (telemetry must never fail a
    /// frame that carries a valid solution).
    pub fn from_json(v: Option<&Json>) -> Telemetry {
        let Some(v) = v else { return Telemetry::default() };
        let u = |key: &str| v.get(key).and_then(json::as_lossless_u64).unwrap_or(0);
        Telemetry {
            queue_wait_ms: v.get("queue_wait_ms").and_then(Json::as_f64).unwrap_or(0.0),
            dataset_hits: u("dataset_hits"),
            dataset_misses: u("dataset_misses"),
            problem_hits: u("problem_hits"),
            problem_misses: u("problem_misses"),
            problem_evictions: u("problem_evictions"),
            engine: v.get("engine").and_then(Json::as_str).unwrap_or("").to_string(),
            bulk_gain_calls: u("bulk_gain_calls"),
            bulk_gain_candidates: u("bulk_gain_candidates"),
        }
    }
}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

/// Coordinator → worker.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Handshake: version check, capacity discovery, clock alignment,
    /// payload-encoding negotiation (v6).
    Hello {
        /// The coordinator's trace clock (ms since its trace epoch) at
        /// send time, echoed back by the worker so worker-side spans
        /// can be aligned to the coordinator timeline (skew bounded by
        /// the handshake RTT). 0.0 when the coordinator is not tracing.
        clock_ms: f64,
        /// The payload encoding the coordinator is willing to receive
        /// and send on this connection. The connection runs binary only
        /// if the worker echoes `binary` back; hello frames themselves
        /// are always pure JSON.
        payload: PayloadMode,
        /// The compute engine the coordinator asks the worker to serve
        /// this connection with. Advisory — a worker pinned with
        /// `--engine` answers with its own choice; the response states
        /// the engine actually in effect. Absent on the wire means
        /// `native`.
        engine: EngineChoice,
    },
    /// Intern a problem on this connection (v4): ship the full
    /// [`ProblemSpec`] once under a coordinator-chosen id; every
    /// subsequent [`Request::Compress`] for the same problem carries
    /// the O(1) id instead of the spec. The table is **per
    /// connection** — a reconnecting coordinator re-interns.
    DefineProblem { id: u64, problem: ProblemSpec },
    /// Compress one part on one fixed-capacity machine.
    Compress {
        /// Id of a problem previously interned on this connection via
        /// [`Request::DefineProblem`]; an unknown id is answered with
        /// an error telling the coordinator to re-intern.
        problem_id: u64,
        compressor: String,
        part: Vec<u32>,
        /// Capacity of the *virtual machine* this part was sized for
        /// (`µ_{j mod L}` of the round's capacity profile). The worker
        /// enforces `part.len() ≤ min(cap, own µ)` — the second bound
        /// catches a coordinator dispatching to too-small workers, the
        /// first catches a partitioner overfilling a machine class.
        cap: usize,
        seed: u64,
    },
    /// Orderly worker shutdown.
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Hello { clock_ms, payload, engine } => {
                let mut fields = vec![
                    ("type", json::s("hello")),
                    ("version", json::num(PROTOCOL_VERSION as f64)),
                    ("clock_ms", json::num(*clock_ms)),
                ];
                // emitted only when advertising binary, so JSON-mode
                // hellos are byte-identical to their pre-v6 shape
                if *payload == PayloadMode::Binary {
                    fields.push(("payload", json::s(payload.wire_name())));
                }
                // same rule for the engine token: `native` is the
                // wire default and stays off the wire
                if *engine != EngineChoice::Native {
                    fields.push(("engine", json::s(engine.wire_name())));
                }
                json::obj(fields)
            }
            Request::DefineProblem { id, problem } => json::obj(vec![
                ("type", json::s("define-problem")),
                ("id", ju64(*id)),
                ("problem", problem.to_json()),
            ]),
            Request::Compress { problem_id, compressor, part, cap, seed } => json::obj(vec![
                ("type", json::s("compress")),
                ("problem_id", ju64(*problem_id)),
                ("compressor", json::s(compressor)),
                ("part", items_to_json(part)),
                ("cap", json::num(*cap as f64)),
                ("seed", ju64(*seed)),
            ]),
            Request::Shutdown => json::obj(vec![("type", json::s("shutdown"))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request> {
        match wire_str(v, "type")? {
            "hello" => {
                let version = wire_usize(v, "version")?;
                if version != PROTOCOL_VERSION {
                    return Err(Error::Protocol(format!(
                        "version mismatch: peer speaks v{version}, this build speaks v{PROTOCOL_VERSION}"
                    )));
                }
                // telemetry field: absent or malformed defaults to 0.0
                // (a coordinator that is not tracing sends 0.0 anyway)
                let clock_ms = v.get("clock_ms").and_then(Json::as_f64).unwrap_or(0.0);
                Ok(Request::Hello {
                    clock_ms,
                    payload: PayloadMode::from_hello(v)?,
                    engine: engine_from_hello(v)?,
                })
            }
            "define-problem" => {
                let problem_json = v
                    .get("problem")
                    .ok_or_else(|| Error::Protocol("missing field 'problem'".into()))?;
                Ok(Request::DefineProblem {
                    id: wire_u64(v, "id")?,
                    problem: ProblemSpec::from_json(problem_json)?,
                })
            }
            "compress" => Ok(Request::Compress {
                problem_id: wire_u64(v, "problem_id")?,
                compressor: wire_str(v, "compressor")?.to_string(),
                part: items_from_json(v, "part")?,
                cap: wire_usize(v, "cap")?,
                seed: wire_u64(v, "seed")?,
            }),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(Error::Protocol(format!("unknown request type '{other}'"))),
        }
    }

    /// Encode for a connection negotiated to `mode`: the complete frame
    /// payload. [`PayloadMode::Json`] frames are exactly
    /// `to_json().to_string()`; binary-mode `compress` frames ship the
    /// part as a u32 blob and `define-problem` frames ship explicit
    /// constraint tables as f64/u32 blobs. Hello and shutdown frames
    /// are identical in both modes.
    pub fn encode(&self, mode: PayloadMode) -> Vec<u8> {
        if mode == PayloadMode::Json {
            return self.to_json().to_string().into_bytes();
        }
        match self {
            Request::Compress { problem_id, compressor, part, cap, seed } => {
                let mut blobs = BlobWriter::default();
                let doc = json::obj(vec![
                    ("type", json::s("compress")),
                    ("problem_id", ju64(*problem_id)),
                    ("compressor", json::s(compressor)),
                    ("part", blobs.push_u32s(part)),
                    ("cap", json::num(*cap as f64)),
                    ("seed", ju64(*seed)),
                ]);
                doc_with_blobs(doc, blobs)
            }
            Request::DefineProblem { .. } => {
                let mut doc = self.to_json();
                let mut blobs = BlobWriter::default();
                extract_table_blobs(&mut doc, &mut blobs);
                doc_with_blobs(doc, blobs)
            }
            _ => self.to_json().to_string().into_bytes(),
        }
    }

    /// Decode a frame payload received on a connection negotiated to
    /// `mode`. The hot frame (`compress`) takes the lazy-scanner path:
    /// only the fields the worker dispatches on are materialized, and
    /// the part ids come straight from the blob section (binary mode)
    /// or the [`lazy::parse_u32_array`] fast path (JSON mode) without
    /// building a [`Json`] tree. Everything else goes through the
    /// full-tree parser, whose semantics the lazy path must match.
    pub fn decode(payload: &[u8], mode: PayloadMode) -> Result<Request> {
        let (doc, end) = LazyDoc::scan(payload)?;
        let blobs = split_blob_section(payload, end, mode)?;
        match doc.str("type")?.as_str() {
            "compress" => Ok(Request::Compress {
                problem_id: doc.u64("problem_id")?,
                compressor: doc.str("compressor")?,
                part: ids_from_doc(&doc, "part", &blobs)?,
                cap: doc.usize("cap")?,
                seed: doc.u64("seed")?,
            }),
            "define-problem" => {
                let mut tree = control_doc(payload, end)?;
                if let Some(blobs) = &blobs {
                    inline_table_blobs(&mut tree, blobs)?;
                }
                Request::from_json(&tree)
            }
            _ => Request::from_json(&control_doc(payload, end)?),
        }
    }
}

/// Worker → coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Handshake reply: the worker's fixed capacity µ, the coordinator
    /// clock echoed back (protocol v5 — lets the coordinator bound
    /// clock skew by the handshake RTT), and the negotiated payload
    /// encoding (v6): `binary` only if the worker is binary-capable
    /// *and* the coordinator advertised it; everything after this
    /// frame uses the mode stated here. `engine` is the compute engine
    /// the worker will actually serve this connection with — its pinned
    /// `--engine` wins over the coordinator's request.
    Hello { capacity: usize, clock_echo_ms: f64, payload: PayloadMode, engine: EngineChoice },
    /// [`Request::DefineProblem`] acknowledged: the id is now live on
    /// this connection.
    Defined { id: u64 },
    /// One machine's compression result plus its per-call metrics and
    /// worker telemetry (protocol v5).
    Solution { items: Vec<u32>, value: f64, evals: u64, wall_ms: f64, telemetry: Telemetry },
    /// The request failed on the worker (capacity violation, bad spec,
    /// unknown problem id…).
    Error { msg: String },
    /// Shutdown acknowledged.
    Bye,
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Hello { capacity, clock_echo_ms, payload, engine } => {
                let mut fields = vec![
                    ("type", json::s("hello")),
                    ("version", json::num(PROTOCOL_VERSION as f64)),
                    ("capacity", json::num(*capacity as f64)),
                    ("clock_echo_ms", json::num(*clock_echo_ms)),
                ];
                if *payload == PayloadMode::Binary {
                    fields.push(("payload", json::s(payload.wire_name())));
                }
                if *engine != EngineChoice::Native {
                    fields.push(("engine", json::s(engine.wire_name())));
                }
                json::obj(fields)
            }
            Response::Defined { id } => json::obj(vec![
                ("type", json::s("defined")),
                ("id", ju64(*id)),
            ]),
            Response::Solution { items, value, evals, wall_ms, telemetry } => json::obj(vec![
                ("type", json::s("solution")),
                ("items", items_to_json(items)),
                ("value", jvalue(*value)),
                ("evals", ju64(*evals)),
                ("wall_ms", json::num(*wall_ms)),
                ("telemetry", telemetry.to_json()),
            ]),
            Response::Error { msg } => json::obj(vec![
                ("type", json::s("error")),
                ("msg", json::s(msg)),
            ]),
            Response::Bye => json::obj(vec![("type", json::s("bye"))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        match wire_str(v, "type")? {
            "hello" => {
                let version = wire_usize(v, "version")?;
                if version != PROTOCOL_VERSION {
                    return Err(Error::Protocol(format!(
                        "version mismatch: peer speaks v{version}, this build speaks v{PROTOCOL_VERSION}"
                    )));
                }
                Ok(Response::Hello {
                    capacity: wire_usize(v, "capacity")?,
                    clock_echo_ms: v.get("clock_echo_ms").and_then(Json::as_f64).unwrap_or(0.0),
                    payload: PayloadMode::from_hello(v)?,
                    engine: engine_from_hello(v)?,
                })
            }
            "defined" => Ok(Response::Defined { id: wire_u64(v, "id")? }),
            "solution" => Ok(Response::Solution {
                items: items_from_json(v, "items")?,
                // non-finite objectives surface (NaN-safe round-best
                // selection) instead of failing the frame and being
                // misread as a lost worker
                value: value_from_json(v, "value")?,
                evals: wire_u64(v, "evals")?,
                wall_ms: wire_f64(v, "wall_ms")?,
                telemetry: Telemetry::from_json(v.get("telemetry")),
            }),
            "error" => Ok(Response::Error { msg: wire_str(v, "msg")?.to_string() }),
            "bye" => Ok(Response::Bye),
            other => Err(Error::Protocol(format!("unknown response type '{other}'"))),
        }
    }

    /// Encode for a connection negotiated to `mode` (see
    /// [`Request::encode`]): binary-mode `solution` frames ship their
    /// item ids as a u32 blob; every other response is identical in
    /// both modes.
    pub fn encode(&self, mode: PayloadMode) -> Vec<u8> {
        if mode == PayloadMode::Json {
            return self.to_json().to_string().into_bytes();
        }
        match self {
            Response::Solution { items, value, evals, wall_ms, telemetry } => {
                let mut blobs = BlobWriter::default();
                let doc = json::obj(vec![
                    ("type", json::s("solution")),
                    ("items", blobs.push_u32s(items)),
                    ("value", jvalue(*value)),
                    ("evals", ju64(*evals)),
                    ("wall_ms", json::num(*wall_ms)),
                    ("telemetry", telemetry.to_json()),
                ]);
                doc_with_blobs(doc, blobs)
            }
            _ => self.to_json().to_string().into_bytes(),
        }
    }

    /// Decode a frame payload received on a connection negotiated to
    /// `mode`. The hot frame (`solution`) takes the lazy-scanner path —
    /// the coordinator's dispatcher reads every solution the fleet
    /// produces; everything else goes through the full-tree parser.
    pub fn decode(payload: &[u8], mode: PayloadMode) -> Result<Response> {
        let (doc, end) = LazyDoc::scan(payload)?;
        let blobs = split_blob_section(payload, end, mode)?;
        match doc.str("type")?.as_str() {
            "solution" => Ok(Response::Solution {
                items: ids_from_doc(&doc, "items", &blobs)?,
                value: scalar_value(doc.json_opt("value")?.as_ref(), "value")?,
                evals: doc.u64("evals")?,
                wall_ms: doc.f64("wall_ms")?,
                telemetry: Telemetry::from_json(doc.json_opt("telemetry")?.as_ref()),
            }),
            _ => Response::from_json(&control_doc(payload, end)?),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[0xF0, 0x9F, 0x8E, 0x89]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
        assert_eq!(read_frame(&mut r).unwrap(), vec![0xF0, 0x9F, 0x8E, 0x89]);
        // EOF surfaces as an io error, not a hang
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_be_bytes());
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert!(err.to_string().contains("MAX_FRAME"), "{err}");
    }

    fn card_spec(dataset: &str, k: usize, seed: u64, eval_m: usize) -> ProblemSpec {
        ProblemSpec {
            dataset: DatasetSpec::Registry { name: dataset.into(), seed },
            objective: "exemplar".into(),
            k,
            seed,
            eval_m,
            h2: 0.0,
            sigma2: 0.0,
            constraint: ConstraintSpec::Cardinality { k },
        }
    }

    #[test]
    fn requests_roundtrip() {
        let spec = card_spec("csn-2k", 25, u64::MAX - 12345, 2000);
        let define = Request::DefineProblem { id: u64::MAX - 2, problem: spec };
        let back =
            Request::from_json(&Json::parse(&define.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(define, back);
        let req = Request::Compress {
            problem_id: 3,
            compressor: "greedy".into(),
            part: vec![0, 7, 4_000_000_000],
            cap: 200,
            seed: 0xDEAD_BEEF_DEAD_BEEF,
        };
        let back = Request::from_json(&Json::parse(&req.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(req, back);
        for r in [
            Request::Hello {
                clock_ms: 12.5,
                payload: PayloadMode::Binary,
                engine: EngineChoice::Native,
            },
            Request::Hello {
                clock_ms: 0.0,
                payload: PayloadMode::Json,
                engine: EngineChoice::Xla,
            },
            Request::Shutdown,
        ] {
            let b = Request::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(r, b);
        }
    }

    #[test]
    fn engine_token_negotiates_and_rejects_unknown_names() {
        // silent peers mean native — the additive-token rule that keeps
        // pre-engine hellos handshaking unchanged
        let bare = Json::parse(r#"{"type":"hello","version":6,"clock_ms":0}"#).unwrap();
        match Request::from_json(&bare).unwrap() {
            Request::Hello { engine, .. } => assert_eq!(engine, EngineChoice::Native),
            other => panic!("wrong request {other:?}"),
        }
        // a native hello stays byte-identical to the pre-engine shape
        let native = Request::Hello {
            clock_ms: 0.0,
            payload: PayloadMode::Json,
            engine: EngineChoice::Native,
        };
        assert!(!native.to_json().to_string().contains("engine"));
        // xla round-trips through the explicit token
        let xla = Response::Hello {
            capacity: 9,
            clock_echo_ms: 1.5,
            payload: PayloadMode::Json,
            engine: EngineChoice::Xla,
        };
        let text = xla.to_json().to_string();
        assert!(text.contains(r#""engine":"xla""#), "{text}");
        assert_eq!(Response::from_json(&Json::parse(&text).unwrap()).unwrap(), xla);
        // an unknown engine is a loud protocol error, not a silent
        // fallback that would leave the ends disagreeing about the
        // compute substrate
        let odd = Json::parse(
            r#"{"type":"hello","version":6,"capacity":7,"engine":"tpu-pod"}"#,
        )
        .unwrap();
        let err = Response::from_json(&odd).unwrap_err();
        assert!(err.to_string().contains("unknown engine"), "{err}");
    }

    #[test]
    fn handshake_echoes_the_coordinator_clock() {
        // v5: the worker reflects the coordinator's trace clock so
        // worker spans can be aligned to the coordinator timeline
        let hello = Response::Hello {
            capacity: 128,
            clock_echo_ms: 417.25,
            payload: PayloadMode::Binary,
            engine: EngineChoice::Native,
        };
        let back =
            Response::from_json(&Json::parse(&hello.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(hello, back);
        // a hello without the echo (malformed telemetry) still parses,
        // defaulting the echo to 0 — telemetry must never fail a frame —
        // and a hello silent about `payload` negotiates JSON
        let bare = Json::parse(r#"{"type":"hello","version":6,"capacity":7}"#).unwrap();
        assert_eq!(
            Response::from_json(&bare).unwrap(),
            Response::Hello {
                capacity: 7,
                clock_echo_ms: 0.0,
                payload: PayloadMode::Json,
                engine: EngineChoice::Native,
            }
        );
        // an unknown payload token is a loud mismatch, not a silent
        // JSON fallback that would desync the two ends of a connection
        let odd =
            Json::parse(r#"{"type":"hello","version":6,"capacity":7,"payload":"zstd"}"#).unwrap();
        assert!(Response::from_json(&odd).is_err());
    }

    #[test]
    fn solution_telemetry_roundtrips_and_defaults_to_zero() {
        let telemetry = Telemetry {
            queue_wait_ms: 3.5,
            dataset_hits: 11,
            dataset_misses: 2,
            problem_hits: 40,
            problem_misses: 1,
            problem_evictions: 5,
            engine: "native".into(),
            bulk_gain_calls: 6,
            bulk_gain_candidates: 190,
        };
        let resp = Response::Solution {
            items: vec![9],
            value: 1.0,
            evals: 77,
            wall_ms: 0.5,
            telemetry: telemetry.clone(),
        };
        let back =
            Response::from_json(&Json::parse(&resp.to_json().to_string()).unwrap()).unwrap();
        match back {
            Response::Solution { telemetry: t, .. } => assert_eq!(t, telemetry),
            other => panic!("wrong response {other:?}"),
        }
        // a solution frame without the telemetry block parses with a
        // zeroed block instead of failing
        let bare = Json::parse(
            r#"{"type":"solution","items":[1],"value":2.0,"evals":"3","wall_ms":0.25}"#,
        )
        .unwrap();
        match Response::from_json(&bare).unwrap() {
            Response::Solution { telemetry: t, .. } => assert_eq!(t, Telemetry::default()),
            other => panic!("wrong response {other:?}"),
        }
    }

    #[test]
    fn compress_requests_ship_an_o1_problem_id_not_the_spec() {
        // the size argument behind v4 interning: the compress frame must
        // not grow with the problem spec (explicit tables, long dataset
        // names…) — only with the part itself
        let spec = card_spec("csn-2k", 25, 42, 2000);
        let define_len = Request::DefineProblem { id: 7, problem: spec }
            .to_json()
            .to_string()
            .len();
        let compress = Request::Compress {
            problem_id: 7,
            compressor: "greedy".into(),
            part: vec![1, 2, 3],
            cap: 200,
            seed: 9,
        };
        let compress_len = compress.to_json().to_string().len();
        assert!(
            compress_len < define_len,
            "compress frame ({compress_len} B) should be smaller than the \
             interned spec ({define_len} B)"
        );
        assert!(!compress.to_json().to_string().contains("dataset"));
        // the defined ack rounds-trip
        let ack = Response::Defined { id: 7 };
        let b = Response::from_json(&Json::parse(&ack.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(ack, b);
    }

    #[test]
    fn responses_roundtrip_with_exact_f64() {
        // a value with a long mantissa that an imprecise codec would mangle
        let value = 123.456_789_012_345_67_f64 / 3.0;
        let resp = Response::Solution {
            items: vec![1, 2, 3],
            value,
            evals: 987_654_321,
            wall_ms: 1.25,
            telemetry: Telemetry::default(),
        };
        let back =
            Response::from_json(&Json::parse(&resp.to_json().to_string()).unwrap()).unwrap();
        match back {
            Response::Solution { value: v, items, evals, .. } => {
                assert_eq!(v.to_bits(), value.to_bits(), "f64 mangled on the wire");
                assert_eq!(items, vec![1, 2, 3]);
                assert_eq!(evals, 987_654_321);
            }
            other => panic!("wrong response {other:?}"),
        }
        let err = Response::Error { msg: "nope".into() };
        let b = Response::from_json(&Json::parse(&err.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(err, b);
    }

    #[test]
    fn non_finite_solution_values_survive_the_wire() {
        // NaN/±inf have no JSON literal; they cross as string tokens
        // and come back intact instead of producing an unparseable
        // frame that would be misdiagnosed as a lost worker
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let resp = Response::Solution {
                items: vec![4, 2],
                value: v,
                evals: 10,
                wall_ms: 0.5,
                telemetry: Telemetry::default(),
            };
            let text = resp.to_json().to_string();
            let back = Response::from_json(&Json::parse(&text).unwrap()).unwrap();
            match back {
                Response::Solution { items, value, evals, .. } => {
                    assert_eq!(items, vec![4, 2]);
                    if v.is_nan() {
                        assert!(value.is_nan(), "NaN mangled into {value}");
                    } else {
                        assert_eq!(value.to_bits(), v.to_bits(), "{v} mangled into {value}");
                    }
                    assert_eq!(evals, 10);
                }
                other => panic!("wrong response {other:?}"),
            }
        }
        // a finite value smuggled as a string is still rejected
        let bad = Json::parse(
            r#"{"type":"solution","items":[],"value":"1.5","evals":"1","wall_ms":0}"#,
        )
        .unwrap();
        assert!(Response::from_json(&bad).is_err());
    }

    #[test]
    fn version_mismatch_is_rejected() {
        // future versions and the retired v1–v5 are all refused
        for bad in [
            r#"{"type":"hello","version":999}"#,
            r#"{"type":"hello","version":1}"#,
            r#"{"type":"hello","version":2}"#,
            r#"{"type":"hello","version":3}"#,
            r#"{"type":"hello","version":4}"#,
            r#"{"type":"hello","version":5}"#,
        ] {
            let msg = Json::parse(bad).unwrap();
            assert!(Request::from_json(&msg).is_err(), "{bad}");
            assert!(Response::from_json(&msg).is_err(), "{bad}");
        }
    }

    #[test]
    fn legacy_compress_frames_are_rejected() {
        let req = Request::Compress {
            problem_id: 1,
            compressor: "greedy".into(),
            part: vec![1, 2],
            cap: 64,
            seed: 9,
        };
        // a v2-shaped request (no 'cap') must fail loudly
        let v = Json::parse(&req.to_json().to_string()).unwrap();
        let mut obj = v.as_obj().unwrap().clone();
        obj.remove("cap");
        let err = Request::from_json(&Json::Obj(obj)).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        // a v3-shaped request (inline 'problem' spec, no 'problem_id')
        // must fail loudly too
        let v = Json::parse(&req.to_json().to_string()).unwrap();
        let mut obj = v.as_obj().unwrap().clone();
        obj.remove("problem_id");
        obj.insert(
            "problem".into(),
            card_spec("csn-2k", 5, 1, 100).to_json(),
        );
        let err = Request::from_json(&Json::Obj(obj)).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        // a define-problem frame without its spec is refused
        let d = Request::DefineProblem { id: 2, problem: card_spec("csn-2k", 5, 1, 100) };
        let v = Json::parse(&d.to_json().to_string()).unwrap();
        let mut obj = v.as_obj().unwrap().clone();
        obj.remove("problem");
        assert!(Request::from_json(&Json::Obj(obj)).is_err());
    }

    #[test]
    fn problem_spec_roundtrips_and_materializes() {
        let spec = card_spec("csn-2k", 10, 42, 2000);
        let back = ProblemSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        let p = spec.materialize().unwrap();
        assert_eq!(p.n(), 2000);
        assert_eq!(p.k, 10);
        // spec extraction from the materialized problem is the identity
        assert_eq!(ProblemSpec::from_problem(&p).unwrap(), spec);
    }

    #[test]
    fn adhoc_synthetic_problem_with_constraints_roundtrips() {
        use crate::constraints::{Intersection, Knapsack, PartitionMatroid};
        use std::sync::Arc;

        // a non-registry dataset with recorded provenance, under an
        // intersection of generator-spec'd hereditary constraints
        let ds = Arc::new(crate::data::synthetic::csn_like(64, 9));
        let cons = Intersection::new(vec![
            Arc::new(Knapsack::from_row_norms(&ds, 300.0, 6)),
            Arc::new(PartitionMatroid::round_robin(64, 4, 2, 6)),
        ]);
        let p = Problem::exemplar(ds, 6, 9).with_constraint(Arc::new(cons));

        let spec = ProblemSpec::from_problem(&p).unwrap();
        let echoed =
            ProblemSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(spec, echoed);

        let q = echoed.materialize().unwrap();
        assert_eq!(q.dataset.raw(), p.dataset.raw(), "dataset not rebuilt bit-exactly");
        assert_eq!(q.constraint.name(), p.constraint.name());
        assert_eq!(q.eval_ids, p.eval_ids);
        // the rebuilt constraint makes the same feasibility decisions
        for item in 0..64u32 {
            assert_eq!(
                q.constraint.can_add(&[3, 10], item, &q.dataset),
                p.constraint.can_add(&[3, 10], item, &p.dataset),
                "feasibility diverged at item {item}"
            );
        }
    }

    #[test]
    fn raw_matrix_problem_is_rejected() {
        // a dataset with no registry entry and no recorded provenance
        let ds = std::sync::Arc::new(crate::data::Dataset::new("adhoc", 8, 2, vec![0.0; 16]));
        let p = Problem::exemplar(ds, 4, 1);
        assert!(ProblemSpec::from_problem(&p).is_err());
    }

    #[test]
    fn unrecorded_constraint_is_rejected() {
        use crate::constraints::Constraint;

        struct Opaque;
        impl Constraint for Opaque {
            fn name(&self) -> String {
                "opaque".into()
            }
            fn can_add(&self, _: &[u32], _: u32, _: &crate::data::Dataset) -> bool {
                true
            }
            fn max_cardinality(&self) -> usize {
                usize::MAX
            }
        }
        let ds = crate::data::registry::load("csn-2k", 1).unwrap();
        let p = Problem::exemplar(ds, 4, 1).with_constraint(std::sync::Arc::new(Opaque));
        let err = ProblemSpec::from_problem(&p).unwrap_err();
        assert!(err.to_string().contains("not wire-representable"), "{err}");
    }

    #[test]
    fn malformed_problem_spec_frames_are_rejected() {
        let good = card_spec("csn-2k", 5, 1, 100).to_json().to_string();
        assert!(ProblemSpec::from_json(&Json::parse(&good).unwrap()).is_ok());
        // drop each required field in turn: every mutilation must fail
        for field in ["dataset", "objective", "k", "seed", "eval_m", "h2", "sigma2", "constraint"]
        {
            let v = Json::parse(&good).unwrap();
            let mut obj = v.as_obj().unwrap().clone();
            obj.remove(field);
            let err = ProblemSpec::from_json(&Json::Obj(obj)).unwrap_err();
            assert!(matches!(err, Error::Protocol(_)), "dropping '{field}': {err}");
        }
        // and a v1-shaped frame (string dataset, no constraint) is refused
        let v1 = r#"{"dataset":"csn-2k","objective":"exemplar","k":5,"seed":"1",
                     "eval_m":100,"h2":0,"sigma2":0}"#;
        assert!(ProblemSpec::from_json(&Json::parse(v1).unwrap()).is_err());
    }

    #[test]
    fn compressors_roundtrip_by_name() {
        for name in
            ["greedy", "random", "stochastic-greedy(eps=0.5)", "threshold-greedy(eps=0.25)"]
        {
            let c = compressor_from_name(name).unwrap();
            assert_eq!(c.name(), name, "wire name not stable");
            assert_eq!(compressor_wire_name(c.as_ref()).unwrap(), name);
        }
        assert!(compressor_from_name("xla-greedy").is_err());
        assert!(compressor_from_name("stochastic-greedy(eps=2.0)").is_err());
    }

    // -- protocol v6: negotiated binary payloads ---------------------------

    #[test]
    fn compress_frames_decode_identically_in_both_modes() {
        let req = Request::Compress {
            problem_id: u64::MAX - 9,
            compressor: "stochastic-greedy(eps=0.5)".into(),
            part: vec![0, 7, 4_000_000_000, u32::MAX],
            cap: 200,
            seed: 0xDEAD_BEEF_DEAD_BEEF,
        };
        for mode in [PayloadMode::Json, PayloadMode::Binary] {
            let payload = req.encode(mode);
            assert_eq!(Request::decode(&payload, mode).unwrap(), req, "{mode:?}");
        }
        // the binary doc carries a marker, not the id array
        let bin = req.encode(PayloadMode::Binary);
        let (doc, end) = LazyDoc::scan(&bin).unwrap();
        assert!(doc.raw("part").unwrap().starts_with(b"{"));
        assert_eq!(&bin[end..end + 4], &16u32.to_le_bytes(), "4 ids = 16 blob bytes");
        // an empty part still frames and decodes cleanly
        let empty = Request::Compress {
            problem_id: 1,
            compressor: "greedy".into(),
            part: vec![],
            cap: 1,
            seed: 0,
        };
        let payload = empty.encode(PayloadMode::Binary);
        assert_eq!(Request::decode(&payload, PayloadMode::Binary).unwrap(), empty);
    }

    #[test]
    fn solution_frames_round_trip_bit_exactly_in_both_modes() {
        for value in
            [1.5, 123.456_789_012_345_67 / 3.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY]
        {
            let resp = Response::Solution {
                items: vec![3, 1, 4, 1_000_000_000],
                value,
                evals: u64::MAX - 1,
                wall_ms: 0.125,
                telemetry: Telemetry { queue_wait_ms: 1.5, dataset_hits: 2, ..Default::default() },
            };
            for mode in [PayloadMode::Json, PayloadMode::Binary] {
                let payload = resp.encode(mode);
                match Response::decode(&payload, mode).unwrap() {
                    Response::Solution { items, value: v, evals, wall_ms, telemetry } => {
                        assert_eq!(items, vec![3, 1, 4, 1_000_000_000]);
                        if value.is_nan() {
                            assert!(v.is_nan());
                        } else {
                            assert_eq!(v.to_bits(), value.to_bits(), "{mode:?}");
                        }
                        assert_eq!(evals, u64::MAX - 1);
                        assert_eq!(wall_ms, 0.125);
                        assert_eq!(telemetry.dataset_hits, 2);
                    }
                    other => panic!("wrong response {other:?}"),
                }
            }
        }
    }

    #[test]
    fn explicit_constraint_tables_ride_as_blobs_bit_exactly() {
        use crate::constraints::spec::{GroupSpec, WeightSpec};
        // weights with long mantissas that decimal text could mangle
        let w: Vec<f64> = (0..64).map(|i| (i as f64 + 0.1) / 3.0).collect();
        let of: Vec<u32> = (0..64).map(|i| i % 5).collect();
        let mut spec = card_spec("csn-2k", 5, 1, 100);
        spec.constraint = ConstraintSpec::Intersection(vec![
            ConstraintSpec::Knapsack {
                budget: 10.0,
                k: 5,
                weights: WeightSpec::Explicit(w.clone()),
            },
            ConstraintSpec::PartitionMatroid {
                k: 5,
                caps: vec![1; 5],
                groups: GroupSpec::Explicit(of.clone()),
            },
        ]);
        let req = Request::DefineProblem { id: 3, problem: spec };
        let bin = req.encode(PayloadMode::Binary);
        // both tables left the document for the blob section
        let (_, end) = LazyDoc::scan(&bin).unwrap();
        let text = std::str::from_utf8(&bin[..end]).unwrap();
        assert!(text.contains(r#""blob":0"#) && text.contains(r#""blob":1"#), "{text}");
        assert!(
            !text.contains(r#""w":["#) && !text.contains(r#""of":["#),
            "tables still inline: {text}"
        );
        assert_eq!(Request::decode(&bin, PayloadMode::Binary).unwrap(), req);
        // and the JSON mode still carries them inline, identically
        let json = req.encode(PayloadMode::Json);
        assert_eq!(Request::decode(&json, PayloadMode::Json).unwrap(), req);
    }

    #[test]
    fn malformed_blob_sections_surface_structured_errors() {
        let req = Request::Compress {
            problem_id: 1,
            compressor: "greedy".into(),
            part: vec![1, 2, 3],
            cap: 8,
            seed: 4,
        };
        let good = req.encode(PayloadMode::Binary);
        let (_, end) = LazyDoc::scan(&good).unwrap();
        // truncated length prefix (1–3 trailing bytes)
        for cut in 1..4usize {
            let bad = &good[..end + cut];
            let err = Request::decode(bad, PayloadMode::Binary).unwrap_err();
            assert!(matches!(err, Error::Protocol(_)), "cut={cut}: {err}");
        }
        // declared length runs past the end of the frame
        let mut overrun = good[..end].to_vec();
        overrun.extend_from_slice(&(u32::MAX).to_le_bytes());
        overrun.extend_from_slice(&[0u8; 8]);
        let err = Request::decode(&overrun, PayloadMode::Binary).unwrap_err();
        assert!(err.to_string().contains("overruns"), "{err}");
        // misaligned blob: 13 bytes cannot hold 3 u32s
        let mut misaligned = good[..end].to_vec();
        misaligned.extend_from_slice(&13u32.to_le_bytes());
        misaligned.extend_from_slice(&[0u8; 13]);
        let err = Request::decode(&misaligned, PayloadMode::Binary).unwrap_err();
        assert!(err.to_string().contains("declares"), "{err}");
        // marker pointing at a blob the frame does not carry
        let doc_only = &good[..end];
        let err = Request::decode(doc_only, PayloadMode::Binary).unwrap_err();
        assert!(err.to_string().contains("marker names blob"), "{err}");
        // a binary frame handed to a json-mode connection is refused
        let err = Request::decode(&good, PayloadMode::Json).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
    }

    #[test]
    fn hello_and_shutdown_frames_are_mode_invariant() {
        // handshake frames must be identical bytes in both modes —
        // negotiation happens *inside* them, so they can never depend
        // on its outcome
        let hello = Request::Hello {
            clock_ms: 2.5,
            payload: PayloadMode::Binary,
            engine: EngineChoice::Xla,
        };
        assert_eq!(hello.encode(PayloadMode::Json), hello.encode(PayloadMode::Binary));
        assert_eq!(
            Request::Shutdown.encode(PayloadMode::Json),
            Request::Shutdown.encode(PayloadMode::Binary)
        );
        let reply = Response::Hello {
            capacity: 4,
            clock_echo_ms: 2.5,
            payload: PayloadMode::Binary,
            engine: EngineChoice::Native,
        };
        assert_eq!(reply.encode(PayloadMode::Json), reply.encode(PayloadMode::Binary));
        assert_eq!(
            Response::Bye.encode(PayloadMode::Json),
            Response::Bye.encode(PayloadMode::Binary)
        );
        // and they decode on a binary connection (empty blob section)
        let payload = hello.encode(PayloadMode::Binary);
        assert_eq!(Request::decode(&payload, PayloadMode::Binary).unwrap(), hello);
    }

    #[test]
    fn send_recv_helpers_report_payload_bytes() {
        let req = Request::Compress {
            problem_id: 2,
            compressor: "greedy".into(),
            part: (0..1000).collect(),
            cap: 1000,
            seed: 7,
        };
        for mode in [PayloadMode::Json, PayloadMode::Binary] {
            let mut buf = Vec::new();
            let sent = send_request(&mut buf, &req, mode).unwrap();
            assert_eq!(sent, buf.len() - 4, "prefix excluded from the byte count");
            let (back, received) = recv_request(&mut Cursor::new(buf), mode).unwrap();
            assert_eq!(back, req);
            assert_eq!(received, sent);
        }
        // binary moves 1000 ids in 4 bytes each vs ≥2 digits + comma
        let jn = req.encode(PayloadMode::Json).len();
        let bn = req.encode(PayloadMode::Binary).len();
        assert!(bn < jn, "binary frame ({bn} B) not smaller than JSON ({jn} B)");
    }
}

//! Wire protocol between the coordinator ([`crate::dist::TcpBackend`])
//! and `hss worker` processes. The normative specification lives in
//! `docs/PROTOCOL.md`; this module is the reference implementation.
//!
//! Transport: length-prefixed frames — a 4-byte big-endian payload
//! length followed by a UTF-8 JSON document (the crate's own
//! [`crate::util::json`] codec; no external serialization dependency).
//!
//! Losslessness: item ids are `u32` (exact in JSON's f64 numbers) and
//! objective values are `f64` serialized via Rust's shortest-roundtrip
//! `Display`, so a solution survives the wire bit-exactly. Seeds are full
//! 64-bit words and are therefore encoded as **decimal strings** — an
//! f64 number would silently drop low bits past 2^53.
//!
//! Problems cross the wire *by specification*, not by value: datasets —
//! registry entries or recorded ad-hoc synthetic instances
//! ([`DatasetSpec`]) — regenerate deterministically from a few bytes of
//! spec, hereditary constraints rebuild from their construction recipe
//! ([`ConstraintSpec`]: cardinality, knapsack with weight-generator
//! specs, partition matroids, intersections), and the coordinator ships
//! item ids, never rows (the paper's shuffle model).

use std::io::{Read, Write};

use crate::algorithms::{
    Compressor, LazyGreedy, RandomCompressor, StochasticGreedy, ThresholdGreedy,
};
use crate::constraints::spec::ConstraintSpec;
use crate::data::spec::DatasetSpec;
use crate::data::DatasetRef;
use crate::error::{Error, Result};
use crate::objectives::{Objective, Problem};
use crate::util::json::{self, wire_f64, wire_str, wire_u64, wire_usize, Json};

/// Protocol version — bumped on any incompatible message change; worker
/// and coordinator refuse to pair across versions (see
/// `docs/PROTOCOL.md` for the normative wire spec). v2 added
/// [`DatasetSpec`]/[`ConstraintSpec`] problem shipping (hereditary
/// constraints + ad-hoc datasets). v3 made the worker's handshake
/// capacity advertisement *load-bearing* — coordinators dispatch by
/// capacity fit over heterogeneous fleets — and added the virtual
/// machine capacity `cap` to every compress request so workers enforce
/// the planned per-machine bound, not just their own physical µ. v4
/// interns problems: a [`Request::DefineProblem`] ships the full
/// [`ProblemSpec`] **once per (connection, problem identity)** and
/// every [`Request::Compress`] carries the short `problem_id` instead
/// of the spec — killing the per-round spec re-serialization and
/// shrinking every subsequent request to O(part). Workers keep the id
/// table per connection, so a coordinator re-interns transparently on
/// fresh or reconnected workers. v5 adds **telemetry**: the handshake
/// carries a coordinator clock echo (`clock_ms` → `clock_echo_ms`) so
/// worker-side timings can be aligned to the coordinator's trace
/// timeline, and every solution response carries a [`Telemetry`] block
/// (queue-wait ms plus cumulative dataset-cache and problem-id-table
/// hit/miss/eviction counters) alongside the per-call `evals` /
/// `wall_ms` that existed since v1. Telemetry is observational only —
/// it never changes dispatch decisions or answers. v1–v4 peers are
/// rejected at handshake.
///
/// Pipelined/streaming dispatch (the coordinator's Backend v3 —
/// persistent per-worker dispatchers, next-round parts speculatively
/// dispatched while stragglers finish) is **protocol-invisible**:
/// workers simply observe back-to-back `compress` requests across round
/// boundaries on one warm connection. The normative statement of the
/// streaming semantics (event ordering, in-flight next-round parts) is
/// `docs/PROTOCOL.md` §6.1.
pub const PROTOCOL_VERSION: usize = 5;

/// Hard cap on frame payloads (64 MiB — a part of 10^6 ids is ~8 MB of
/// JSON; anything bigger than this is a corrupt or hostile frame).
pub const MAX_FRAME: usize = 64 << 20;

// ---------------------------------------------------------------------------
// framed transport
// ---------------------------------------------------------------------------

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(Error::Protocol(format!(
            "outgoing frame of {} bytes exceeds MAX_FRAME {MAX_FRAME}",
            payload.len()
        )));
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_be_bytes(len_bytes) as usize;
    if len > MAX_FRAME {
        return Err(Error::Protocol(format!(
            "incoming frame of {len} bytes exceeds MAX_FRAME {MAX_FRAME}"
        )));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Serialize + frame one message.
pub fn send_msg<W: Write>(w: &mut W, msg: &Json) -> Result<()> {
    write_frame(w, msg.to_string().as_bytes())
}

/// Read + parse one message.
pub fn recv_msg<R: Read>(r: &mut R) -> Result<Json> {
    let bytes = read_frame(r)?;
    let text = std::str::from_utf8(&bytes)
        .map_err(|_| Error::Protocol("frame is not UTF-8".into()))?;
    Json::parse(text)
}

// ---------------------------------------------------------------------------
// lossless u64 encoding
// ---------------------------------------------------------------------------

fn ju64(x: u64) -> Json {
    Json::Str(x.to_string())
}

/// Objective values may legitimately go non-finite (degenerate
/// kernels); JSON has no NaN/±inf literal, so those encode as the
/// string tokens `"NaN"` / `"inf"` / `"-inf"`. Infinities round-trip
/// exactly; NaN comes back as the canonical quiet NaN.
fn jvalue(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Str(x.to_string())
    }
}

fn value_from_json(v: &Json, key: &str) -> Result<f64> {
    match v.get(key) {
        Some(Json::Str(s)) => s
            .parse::<f64>()
            .ok()
            .filter(|x| !x.is_finite())
            .ok_or_else(|| {
                Error::Protocol(format!("field '{key}' is not a non-finite token"))
            }),
        // tolerate null (the generic writer's encoding for non-finite)
        Some(Json::Null) => Ok(f64::NAN),
        _ => wire_f64(v, key),
    }
}

fn items_to_json(items: &[u32]) -> Json {
    Json::Arr(items.iter().map(|&i| Json::Num(i as f64)).collect())
}

fn items_from_json(v: &Json, key: &str) -> Result<Vec<u32>> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Protocol(format!("missing array field '{key}'")))?;
    arr.iter()
        .map(|x| {
            x.as_f64()
                .filter(|v| *v >= 0.0 && v.fract() == 0.0 && *v <= u32::MAX as f64)
                .map(|v| v as u32)
                .ok_or_else(|| Error::Protocol(format!("'{key}' contains a non-u32 entry")))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// problem + compressor specifications
// ---------------------------------------------------------------------------

/// A wire-serializable description of a [`Problem`]: dataset spec +
/// objective + hereditary-constraint spec. Covers registry and recorded
/// ad-hoc synthetic datasets, the two paper objectives, and every
/// constraint with a recorded construction recipe (wire spec v2).
///
/// Size note: generator-spec'd constraints keep the spec a few bytes,
/// but `Explicit` weight/group tables are O(n) and ride along in every
/// `compress` request (and are bounded by [`MAX_FRAME`]). Prefer the
/// generator forms for large ground sets; shipping the spec once per
/// connection is a known follow-up.
#[derive(Debug, Clone, PartialEq)]
pub struct ProblemSpec {
    pub dataset: DatasetSpec,
    /// `"exemplar"` or `"logdet"`.
    pub objective: String,
    pub k: usize,
    pub seed: u64,
    /// Exemplar evaluation-subsample size (0 for logdet).
    pub eval_m: usize,
    /// LogDet kernel parameters (0 for exemplar).
    pub h2: f64,
    pub sigma2: f64,
    /// Hereditary constraint, rebuilt on the worker from its recipe.
    pub constraint: ConstraintSpec,
}

impl ProblemSpec {
    /// Capture a problem's wire spec. Fails for problems that are not
    /// wire-representable (raw ad-hoc matrices, test objectives,
    /// constraints without a recorded construction recipe).
    pub fn from_problem(p: &Problem) -> Result<ProblemSpec> {
        let dataset = DatasetSpec::from_dataset(&p.dataset)?;
        let constraint = p.constraint.wire_spec().ok_or_else(|| {
            Error::invalid(format!(
                "constraint '{}' is not wire-representable (no construction \
                 recipe recorded)",
                p.constraint.name()
            ))
        })?;
        let (objective, eval_m, h2, sigma2) = match &p.objective {
            Objective::Exemplar => ("exemplar", p.eval_ids.len(), 0.0, 0.0),
            Objective::LogDet { h2, sigma2 } => ("logdet", 0, *h2, *sigma2),
            other => {
                return Err(Error::invalid(format!(
                    "objective '{}' is not wire-representable",
                    other.name()
                )))
            }
        };
        Ok(ProblemSpec {
            dataset,
            objective: objective.to_string(),
            k: p.k,
            seed: p.seed,
            eval_m,
            h2,
            sigma2,
            constraint,
        })
    }

    /// Reconstruct the problem on the receiving side. Deterministic:
    /// dataset generation, eval-subsample draw and constraint all derive
    /// from the spec alone.
    pub fn materialize(&self) -> Result<Problem> {
        self.materialize_on(self.dataset.load()?)
    }

    /// Same, over an already-loaded dataset handle (worker-side caching:
    /// many specs — different k, eval_m, constraints — share one dataset
    /// Arc instead of each holding its own copy of the matrix).
    pub fn materialize_on(&self, ds: DatasetRef) -> Result<Problem> {
        let constraint = self.constraint.build(&ds)?;
        self.materialize_with(ds, constraint)
    }

    /// Same, with an externally built constraint (worker-side
    /// memoization: constraint tables like row-norm weights are O(n·d)
    /// to build and identical across the parts of a round). The caller
    /// must have built `constraint` from this spec's `constraint` field
    /// over `ds`.
    pub fn materialize_with(
        &self,
        ds: DatasetRef,
        constraint: std::sync::Arc<dyn crate::constraints::Constraint>,
    ) -> Result<Problem> {
        let p = match self.objective.as_str() {
            "exemplar" => Problem::exemplar_with_eval(ds, self.k, self.seed, self.eval_m),
            "logdet" => {
                let mut p = Problem::logdet(ds, self.k, self.seed);
                p.objective = Objective::LogDet { h2: self.h2, sigma2: self.sigma2 };
                p
            }
            other => return Err(Error::Protocol(format!("unknown objective '{other}'"))),
        };
        Ok(p.with_constraint(constraint))
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("dataset", self.dataset.to_json()),
            ("objective", json::s(&self.objective)),
            ("k", json::num(self.k as f64)),
            ("seed", ju64(self.seed)),
            ("eval_m", json::num(self.eval_m as f64)),
            ("h2", json::num(self.h2)),
            ("sigma2", json::num(self.sigma2)),
            ("constraint", self.constraint.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ProblemSpec> {
        let dataset_json = v
            .get("dataset")
            .ok_or_else(|| Error::Protocol("missing field 'dataset'".into()))?;
        let constraint_json = v
            .get("constraint")
            .ok_or_else(|| Error::Protocol("missing field 'constraint'".into()))?;
        Ok(ProblemSpec {
            dataset: DatasetSpec::from_json(dataset_json)?,
            objective: wire_str(v, "objective")?.to_string(),
            k: wire_usize(v, "k")?,
            seed: wire_u64(v, "seed")?,
            eval_m: wire_usize(v, "eval_m")?,
            h2: wire_f64(v, "h2")?,
            sigma2: wire_f64(v, "sigma2")?,
            constraint: ConstraintSpec::from_json(constraint_json)?,
        })
    }
}

/// Map a compressor's `name()` to a wire tag, failing for compressors
/// that cannot be reconstructed remotely (e.g. the XLA-engine-bound
/// ones — workers run the pure path).
pub fn compressor_wire_name(c: &dyn Compressor) -> Result<String> {
    let name = c.name();
    // validate round-trip now so dispatch fails fast with a clear error
    compressor_from_name(&name).map_err(|_| {
        Error::invalid(format!(
            "compressor '{name}' is not wire-representable; tcp workers support \
             greedy, random, stochastic-greedy(eps=..), threshold-greedy(eps=..)"
        ))
    })?;
    Ok(name)
}

/// Reconstruct a compressor from its wire tag.
pub fn compressor_from_name(name: &str) -> Result<Box<dyn Compressor>> {
    fn eps_of(name: &str, prefix: &str) -> Option<f64> {
        let rest = name.strip_prefix(prefix)?.strip_suffix(')')?;
        rest.parse::<f64>().ok().filter(|e| *e > 0.0 && *e < 1.0)
    }
    if name == "greedy" {
        return Ok(Box::new(LazyGreedy::new()));
    }
    if name == "random" {
        return Ok(Box::new(RandomCompressor::new()));
    }
    if let Some(eps) = eps_of(name, "stochastic-greedy(eps=") {
        return Ok(Box::new(StochasticGreedy::new(eps)));
    }
    if let Some(eps) = eps_of(name, "threshold-greedy(eps=") {
        return Ok(Box::new(ThresholdGreedy::new(eps)));
    }
    Err(Error::Protocol(format!("unknown compressor '{name}'")))
}

// ---------------------------------------------------------------------------
// worker telemetry (protocol v5)
// ---------------------------------------------------------------------------

/// Worker-side telemetry riding on every [`Response::Solution`]
/// (protocol v5). `queue_wait_ms` is per-request; the cache counters
/// are **cumulative gauges** over the worker process (dataset cache)
/// or the current connection (problem-id table), so the coordinator
/// keeps the latest value per worker instead of summing. Purely
/// observational — omitted fields parse as zero and nothing here ever
/// influences dispatch or answers.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Telemetry {
    /// Time between the worker reading the request frame and starting
    /// the compression compute, including any injected straggle sleep —
    /// the worker-side queueing component of end-to-end latency.
    pub queue_wait_ms: f64,
    /// Dataset-cache hits (process lifetime).
    pub dataset_hits: u64,
    /// Dataset-cache misses (process lifetime).
    pub dataset_misses: u64,
    /// Interned-problem-table hits (connection lifetime).
    pub problem_hits: u64,
    /// Compress requests naming an unknown/evicted problem id
    /// (connection lifetime).
    pub problem_misses: u64,
    /// Interned problems evicted by the table bound (connection
    /// lifetime).
    pub problem_evictions: u64,
}

impl Telemetry {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("queue_wait_ms", json::num(self.queue_wait_ms)),
            ("dataset_hits", ju64(self.dataset_hits)),
            ("dataset_misses", ju64(self.dataset_misses)),
            ("problem_hits", ju64(self.problem_hits)),
            ("problem_misses", ju64(self.problem_misses)),
            ("problem_evictions", ju64(self.problem_evictions)),
        ])
    }

    /// Parse from an optional `telemetry` object; a missing block or
    /// missing fields default to zero (telemetry must never fail a
    /// frame that carries a valid solution).
    pub fn from_json(v: Option<&Json>) -> Telemetry {
        let Some(v) = v else { return Telemetry::default() };
        let u = |key: &str| v.get(key).and_then(json::as_lossless_u64).unwrap_or(0);
        Telemetry {
            queue_wait_ms: v.get("queue_wait_ms").and_then(Json::as_f64).unwrap_or(0.0),
            dataset_hits: u("dataset_hits"),
            dataset_misses: u("dataset_misses"),
            problem_hits: u("problem_hits"),
            problem_misses: u("problem_misses"),
            problem_evictions: u("problem_evictions"),
        }
    }
}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

/// Coordinator → worker.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Handshake: version check, capacity discovery, clock alignment.
    Hello {
        /// The coordinator's trace clock (ms since its trace epoch) at
        /// send time, echoed back by the worker so worker-side spans
        /// can be aligned to the coordinator timeline (skew bounded by
        /// the handshake RTT). 0.0 when the coordinator is not tracing.
        clock_ms: f64,
    },
    /// Intern a problem on this connection (v4): ship the full
    /// [`ProblemSpec`] once under a coordinator-chosen id; every
    /// subsequent [`Request::Compress`] for the same problem carries
    /// the O(1) id instead of the spec. The table is **per
    /// connection** — a reconnecting coordinator re-interns.
    DefineProblem { id: u64, problem: ProblemSpec },
    /// Compress one part on one fixed-capacity machine.
    Compress {
        /// Id of a problem previously interned on this connection via
        /// [`Request::DefineProblem`]; an unknown id is answered with
        /// an error telling the coordinator to re-intern.
        problem_id: u64,
        compressor: String,
        part: Vec<u32>,
        /// Capacity of the *virtual machine* this part was sized for
        /// (`µ_{j mod L}` of the round's capacity profile). The worker
        /// enforces `part.len() ≤ min(cap, own µ)` — the second bound
        /// catches a coordinator dispatching to too-small workers, the
        /// first catches a partitioner overfilling a machine class.
        cap: usize,
        seed: u64,
    },
    /// Orderly worker shutdown.
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Hello { clock_ms } => json::obj(vec![
                ("type", json::s("hello")),
                ("version", json::num(PROTOCOL_VERSION as f64)),
                ("clock_ms", json::num(*clock_ms)),
            ]),
            Request::DefineProblem { id, problem } => json::obj(vec![
                ("type", json::s("define-problem")),
                ("id", ju64(*id)),
                ("problem", problem.to_json()),
            ]),
            Request::Compress { problem_id, compressor, part, cap, seed } => json::obj(vec![
                ("type", json::s("compress")),
                ("problem_id", ju64(*problem_id)),
                ("compressor", json::s(compressor)),
                ("part", items_to_json(part)),
                ("cap", json::num(*cap as f64)),
                ("seed", ju64(*seed)),
            ]),
            Request::Shutdown => json::obj(vec![("type", json::s("shutdown"))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Request> {
        match wire_str(v, "type")? {
            "hello" => {
                let version = wire_usize(v, "version")?;
                if version != PROTOCOL_VERSION {
                    return Err(Error::Protocol(format!(
                        "version mismatch: peer speaks v{version}, this build speaks v{PROTOCOL_VERSION}"
                    )));
                }
                // telemetry field: absent or malformed defaults to 0.0
                // (a coordinator that is not tracing sends 0.0 anyway)
                let clock_ms = v.get("clock_ms").and_then(Json::as_f64).unwrap_or(0.0);
                Ok(Request::Hello { clock_ms })
            }
            "define-problem" => {
                let problem_json = v
                    .get("problem")
                    .ok_or_else(|| Error::Protocol("missing field 'problem'".into()))?;
                Ok(Request::DefineProblem {
                    id: wire_u64(v, "id")?,
                    problem: ProblemSpec::from_json(problem_json)?,
                })
            }
            "compress" => Ok(Request::Compress {
                problem_id: wire_u64(v, "problem_id")?,
                compressor: wire_str(v, "compressor")?.to_string(),
                part: items_from_json(v, "part")?,
                cap: wire_usize(v, "cap")?,
                seed: wire_u64(v, "seed")?,
            }),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(Error::Protocol(format!("unknown request type '{other}'"))),
        }
    }
}

/// Worker → coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Handshake reply: the worker's fixed capacity µ, plus the
    /// coordinator clock echoed back (protocol v5 — lets the
    /// coordinator bound clock skew by the handshake RTT).
    Hello { capacity: usize, clock_echo_ms: f64 },
    /// [`Request::DefineProblem`] acknowledged: the id is now live on
    /// this connection.
    Defined { id: u64 },
    /// One machine's compression result plus its per-call metrics and
    /// worker telemetry (protocol v5).
    Solution { items: Vec<u32>, value: f64, evals: u64, wall_ms: f64, telemetry: Telemetry },
    /// The request failed on the worker (capacity violation, bad spec,
    /// unknown problem id…).
    Error { msg: String },
    /// Shutdown acknowledged.
    Bye,
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Hello { capacity, clock_echo_ms } => json::obj(vec![
                ("type", json::s("hello")),
                ("version", json::num(PROTOCOL_VERSION as f64)),
                ("capacity", json::num(*capacity as f64)),
                ("clock_echo_ms", json::num(*clock_echo_ms)),
            ]),
            Response::Defined { id } => json::obj(vec![
                ("type", json::s("defined")),
                ("id", ju64(*id)),
            ]),
            Response::Solution { items, value, evals, wall_ms, telemetry } => json::obj(vec![
                ("type", json::s("solution")),
                ("items", items_to_json(items)),
                ("value", jvalue(*value)),
                ("evals", ju64(*evals)),
                ("wall_ms", json::num(*wall_ms)),
                ("telemetry", telemetry.to_json()),
            ]),
            Response::Error { msg } => json::obj(vec![
                ("type", json::s("error")),
                ("msg", json::s(msg)),
            ]),
            Response::Bye => json::obj(vec![("type", json::s("bye"))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Response> {
        match wire_str(v, "type")? {
            "hello" => {
                let version = wire_usize(v, "version")?;
                if version != PROTOCOL_VERSION {
                    return Err(Error::Protocol(format!(
                        "version mismatch: peer speaks v{version}, this build speaks v{PROTOCOL_VERSION}"
                    )));
                }
                Ok(Response::Hello {
                    capacity: wire_usize(v, "capacity")?,
                    clock_echo_ms: v.get("clock_echo_ms").and_then(Json::as_f64).unwrap_or(0.0),
                })
            }
            "defined" => Ok(Response::Defined { id: wire_u64(v, "id")? }),
            "solution" => Ok(Response::Solution {
                items: items_from_json(v, "items")?,
                // non-finite objectives surface (NaN-safe round-best
                // selection) instead of failing the frame and being
                // misread as a lost worker
                value: value_from_json(v, "value")?,
                evals: wire_u64(v, "evals")?,
                wall_ms: wire_f64(v, "wall_ms")?,
                telemetry: Telemetry::from_json(v.get("telemetry")),
            }),
            "error" => Ok(Response::Error { msg: wire_str(v, "msg")?.to_string() }),
            "bye" => Ok(Response::Bye),
            other => Err(Error::Protocol(format!("unknown response type '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[0xF0, 0x9F, 0x8E, 0x89]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
        assert_eq!(read_frame(&mut r).unwrap(), vec![0xF0, 0x9F, 0x8E, 0x89]);
        // EOF surfaces as an io error, not a hang
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_be_bytes());
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert!(err.to_string().contains("MAX_FRAME"), "{err}");
    }

    fn card_spec(dataset: &str, k: usize, seed: u64, eval_m: usize) -> ProblemSpec {
        ProblemSpec {
            dataset: DatasetSpec::Registry { name: dataset.into(), seed },
            objective: "exemplar".into(),
            k,
            seed,
            eval_m,
            h2: 0.0,
            sigma2: 0.0,
            constraint: ConstraintSpec::Cardinality { k },
        }
    }

    #[test]
    fn requests_roundtrip() {
        let spec = card_spec("csn-2k", 25, u64::MAX - 12345, 2000);
        let define = Request::DefineProblem { id: u64::MAX - 2, problem: spec };
        let back =
            Request::from_json(&Json::parse(&define.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(define, back);
        let req = Request::Compress {
            problem_id: 3,
            compressor: "greedy".into(),
            part: vec![0, 7, 4_000_000_000],
            cap: 200,
            seed: 0xDEAD_BEEF_DEAD_BEEF,
        };
        let back = Request::from_json(&Json::parse(&req.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(req, back);
        for r in [Request::Hello { clock_ms: 12.5 }, Request::Shutdown] {
            let b = Request::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(r, b);
        }
    }

    #[test]
    fn handshake_echoes_the_coordinator_clock() {
        // v5: the worker reflects the coordinator's trace clock so
        // worker spans can be aligned to the coordinator timeline
        let hello = Response::Hello { capacity: 128, clock_echo_ms: 417.25 };
        let back =
            Response::from_json(&Json::parse(&hello.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(hello, back);
        // a hello without the echo (malformed telemetry) still parses,
        // defaulting the echo to 0 — telemetry must never fail a frame
        let bare = Json::parse(r#"{"type":"hello","version":5,"capacity":7}"#).unwrap();
        assert_eq!(
            Response::from_json(&bare).unwrap(),
            Response::Hello { capacity: 7, clock_echo_ms: 0.0 }
        );
    }

    #[test]
    fn solution_telemetry_roundtrips_and_defaults_to_zero() {
        let telemetry = Telemetry {
            queue_wait_ms: 3.5,
            dataset_hits: 11,
            dataset_misses: 2,
            problem_hits: 40,
            problem_misses: 1,
            problem_evictions: 5,
        };
        let resp = Response::Solution {
            items: vec![9],
            value: 1.0,
            evals: 77,
            wall_ms: 0.5,
            telemetry: telemetry.clone(),
        };
        let back =
            Response::from_json(&Json::parse(&resp.to_json().to_string()).unwrap()).unwrap();
        match back {
            Response::Solution { telemetry: t, .. } => assert_eq!(t, telemetry),
            other => panic!("wrong response {other:?}"),
        }
        // a solution frame without the telemetry block parses with a
        // zeroed block instead of failing
        let bare = Json::parse(
            r#"{"type":"solution","items":[1],"value":2.0,"evals":"3","wall_ms":0.25}"#,
        )
        .unwrap();
        match Response::from_json(&bare).unwrap() {
            Response::Solution { telemetry: t, .. } => assert_eq!(t, Telemetry::default()),
            other => panic!("wrong response {other:?}"),
        }
    }

    #[test]
    fn compress_requests_ship_an_o1_problem_id_not_the_spec() {
        // the size argument behind v4 interning: the compress frame must
        // not grow with the problem spec (explicit tables, long dataset
        // names…) — only with the part itself
        let spec = card_spec("csn-2k", 25, 42, 2000);
        let define_len = Request::DefineProblem { id: 7, problem: spec }
            .to_json()
            .to_string()
            .len();
        let compress = Request::Compress {
            problem_id: 7,
            compressor: "greedy".into(),
            part: vec![1, 2, 3],
            cap: 200,
            seed: 9,
        };
        let compress_len = compress.to_json().to_string().len();
        assert!(
            compress_len < define_len,
            "compress frame ({compress_len} B) should be smaller than the \
             interned spec ({define_len} B)"
        );
        assert!(!compress.to_json().to_string().contains("dataset"));
        // the defined ack rounds-trip
        let ack = Response::Defined { id: 7 };
        let b = Response::from_json(&Json::parse(&ack.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(ack, b);
    }

    #[test]
    fn responses_roundtrip_with_exact_f64() {
        // a value with a long mantissa that an imprecise codec would mangle
        let value = 123.456_789_012_345_67_f64 / 3.0;
        let resp = Response::Solution {
            items: vec![1, 2, 3],
            value,
            evals: 987_654_321,
            wall_ms: 1.25,
            telemetry: Telemetry::default(),
        };
        let back =
            Response::from_json(&Json::parse(&resp.to_json().to_string()).unwrap()).unwrap();
        match back {
            Response::Solution { value: v, items, evals, .. } => {
                assert_eq!(v.to_bits(), value.to_bits(), "f64 mangled on the wire");
                assert_eq!(items, vec![1, 2, 3]);
                assert_eq!(evals, 987_654_321);
            }
            other => panic!("wrong response {other:?}"),
        }
        let err = Response::Error { msg: "nope".into() };
        let b = Response::from_json(&Json::parse(&err.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(err, b);
    }

    #[test]
    fn non_finite_solution_values_survive_the_wire() {
        // NaN/±inf have no JSON literal; they cross as string tokens
        // and come back intact instead of producing an unparseable
        // frame that would be misdiagnosed as a lost worker
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let resp = Response::Solution {
                items: vec![4, 2],
                value: v,
                evals: 10,
                wall_ms: 0.5,
                telemetry: Telemetry::default(),
            };
            let text = resp.to_json().to_string();
            let back = Response::from_json(&Json::parse(&text).unwrap()).unwrap();
            match back {
                Response::Solution { items, value, evals, .. } => {
                    assert_eq!(items, vec![4, 2]);
                    if v.is_nan() {
                        assert!(value.is_nan(), "NaN mangled into {value}");
                    } else {
                        assert_eq!(value.to_bits(), v.to_bits(), "{v} mangled into {value}");
                    }
                    assert_eq!(evals, 10);
                }
                other => panic!("wrong response {other:?}"),
            }
        }
        // a finite value smuggled as a string is still rejected
        let bad = Json::parse(
            r#"{"type":"solution","items":[],"value":"1.5","evals":"1","wall_ms":0}"#,
        )
        .unwrap();
        assert!(Response::from_json(&bad).is_err());
    }

    #[test]
    fn version_mismatch_is_rejected() {
        // future versions and the retired v1–v4 are all refused
        for bad in [
            r#"{"type":"hello","version":999}"#,
            r#"{"type":"hello","version":1}"#,
            r#"{"type":"hello","version":2}"#,
            r#"{"type":"hello","version":3}"#,
            r#"{"type":"hello","version":4}"#,
        ] {
            let msg = Json::parse(bad).unwrap();
            assert!(Request::from_json(&msg).is_err(), "{bad}");
            assert!(Response::from_json(&msg).is_err(), "{bad}");
        }
    }

    #[test]
    fn legacy_compress_frames_are_rejected() {
        let req = Request::Compress {
            problem_id: 1,
            compressor: "greedy".into(),
            part: vec![1, 2],
            cap: 64,
            seed: 9,
        };
        // a v2-shaped request (no 'cap') must fail loudly
        let v = Json::parse(&req.to_json().to_string()).unwrap();
        let mut obj = v.as_obj().unwrap().clone();
        obj.remove("cap");
        let err = Request::from_json(&Json::Obj(obj)).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        // a v3-shaped request (inline 'problem' spec, no 'problem_id')
        // must fail loudly too
        let v = Json::parse(&req.to_json().to_string()).unwrap();
        let mut obj = v.as_obj().unwrap().clone();
        obj.remove("problem_id");
        obj.insert(
            "problem".into(),
            card_spec("csn-2k", 5, 1, 100).to_json(),
        );
        let err = Request::from_json(&Json::Obj(obj)).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
        // a define-problem frame without its spec is refused
        let d = Request::DefineProblem { id: 2, problem: card_spec("csn-2k", 5, 1, 100) };
        let v = Json::parse(&d.to_json().to_string()).unwrap();
        let mut obj = v.as_obj().unwrap().clone();
        obj.remove("problem");
        assert!(Request::from_json(&Json::Obj(obj)).is_err());
    }

    #[test]
    fn problem_spec_roundtrips_and_materializes() {
        let spec = card_spec("csn-2k", 10, 42, 2000);
        let back = ProblemSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        let p = spec.materialize().unwrap();
        assert_eq!(p.n(), 2000);
        assert_eq!(p.k, 10);
        // spec extraction from the materialized problem is the identity
        assert_eq!(ProblemSpec::from_problem(&p).unwrap(), spec);
    }

    #[test]
    fn adhoc_synthetic_problem_with_constraints_roundtrips() {
        use crate::constraints::{Intersection, Knapsack, PartitionMatroid};
        use std::sync::Arc;

        // a non-registry dataset with recorded provenance, under an
        // intersection of generator-spec'd hereditary constraints
        let ds = Arc::new(crate::data::synthetic::csn_like(64, 9));
        let cons = Intersection::new(vec![
            Arc::new(Knapsack::from_row_norms(&ds, 300.0, 6)),
            Arc::new(PartitionMatroid::round_robin(64, 4, 2, 6)),
        ]);
        let p = Problem::exemplar(ds, 6, 9).with_constraint(Arc::new(cons));

        let spec = ProblemSpec::from_problem(&p).unwrap();
        let echoed =
            ProblemSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(spec, echoed);

        let q = echoed.materialize().unwrap();
        assert_eq!(q.dataset.raw(), p.dataset.raw(), "dataset not rebuilt bit-exactly");
        assert_eq!(q.constraint.name(), p.constraint.name());
        assert_eq!(q.eval_ids, p.eval_ids);
        // the rebuilt constraint makes the same feasibility decisions
        for item in 0..64u32 {
            assert_eq!(
                q.constraint.can_add(&[3, 10], item, &q.dataset),
                p.constraint.can_add(&[3, 10], item, &p.dataset),
                "feasibility diverged at item {item}"
            );
        }
    }

    #[test]
    fn raw_matrix_problem_is_rejected() {
        // a dataset with no registry entry and no recorded provenance
        let ds = std::sync::Arc::new(crate::data::Dataset::new("adhoc", 8, 2, vec![0.0; 16]));
        let p = Problem::exemplar(ds, 4, 1);
        assert!(ProblemSpec::from_problem(&p).is_err());
    }

    #[test]
    fn unrecorded_constraint_is_rejected() {
        use crate::constraints::Constraint;

        struct Opaque;
        impl Constraint for Opaque {
            fn name(&self) -> String {
                "opaque".into()
            }
            fn can_add(&self, _: &[u32], _: u32, _: &crate::data::Dataset) -> bool {
                true
            }
            fn max_cardinality(&self) -> usize {
                usize::MAX
            }
        }
        let ds = crate::data::registry::load("csn-2k", 1).unwrap();
        let p = Problem::exemplar(ds, 4, 1).with_constraint(std::sync::Arc::new(Opaque));
        let err = ProblemSpec::from_problem(&p).unwrap_err();
        assert!(err.to_string().contains("not wire-representable"), "{err}");
    }

    #[test]
    fn malformed_problem_spec_frames_are_rejected() {
        let good = card_spec("csn-2k", 5, 1, 100).to_json().to_string();
        assert!(ProblemSpec::from_json(&Json::parse(&good).unwrap()).is_ok());
        // drop each required field in turn: every mutilation must fail
        for field in ["dataset", "objective", "k", "seed", "eval_m", "h2", "sigma2", "constraint"]
        {
            let v = Json::parse(&good).unwrap();
            let mut obj = v.as_obj().unwrap().clone();
            obj.remove(field);
            let err = ProblemSpec::from_json(&Json::Obj(obj)).unwrap_err();
            assert!(matches!(err, Error::Protocol(_)), "dropping '{field}': {err}");
        }
        // and a v1-shaped frame (string dataset, no constraint) is refused
        let v1 = r#"{"dataset":"csn-2k","objective":"exemplar","k":5,"seed":"1",
                     "eval_m":100,"h2":0,"sigma2":0}"#;
        assert!(ProblemSpec::from_json(&Json::parse(v1).unwrap()).is_err());
    }

    #[test]
    fn compressors_roundtrip_by_name() {
        for name in
            ["greedy", "random", "stochastic-greedy(eps=0.5)", "threshold-greedy(eps=0.25)"]
        {
            let c = compressor_from_name(name).unwrap();
            assert_eq!(c.name(), name, "wire name not stable");
            assert_eq!(compressor_wire_name(c.as_ref()).unwrap(), name);
        }
        assert!(compressor_from_name("xla-greedy").is_err());
        assert!(compressor_from_name("stochastic-greedy(eps=2.0)").is_err());
    }
}

//! Deterministic single-thread cluster simulator with fault injection.
//!
//! `SimBackend` executes every machine sequentially on the calling
//! thread and injects *scripted* faults from a seeded RNG stream:
//!
//! * **machine loss** — a machine vanishes before reporting; its part is
//!   requeued to a fresh replacement machine (same part, same positional
//!   seed, so the answer is unchanged — only cost and the requeue
//!   counter move). Losses come in two flavors: a deterministic
//!   per-round quota (`machine_loss_per_round`, the scenario knob used
//!   by robustness tests) and a Bernoulli rate (`loss_prob`) with a
//!   bounded retry budget.
//! * **stragglers** — a machine finishes late; the simulator charges
//!   `straggler_delay_ms` of *virtual* time (no real sleeping, so the
//!   scenario suite stays fast) and reports it in
//!   [`RoundOutcome::sim_delay_ms`].
//!
//! Everything derives from `(fault seed, round seed, machine index)`, so
//! a scenario replays bit-exactly — the point of a simulator: explore
//! failure schedules the real TCP runtime can only hit by accident.

use std::collections::HashSet;

use crate::algorithms::{Compressor, Solution};
use crate::dist::{enforce_capacity, machine_seeds, Backend, RoundOutcome};
use crate::error::{Error, Result};
use crate::objectives::Problem;
use crate::util::rng::Rng;

/// Fault-injection script for [`SimBackend`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for the fault stream (independent of algorithmic seeds).
    pub seed: u64,
    /// Exactly this many machines (clamped to the round's machine count)
    /// are lost per round, chosen uniformly — the deterministic scenario
    /// knob ("what if one machine dies every round?").
    pub machine_loss_per_round: usize,
    /// Additionally, each machine execution is lost with this
    /// probability (replacements can be lost again).
    pub loss_prob: f64,
    /// Retry budget per part before the round fails.
    pub max_retries: usize,
    /// Each machine straggles with this probability…
    pub straggler_prob: f64,
    /// …adding this much virtual latency.
    pub straggler_delay_ms: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            machine_loss_per_round: 0,
            loss_prob: 0.0,
            max_retries: 3,
            straggler_prob: 0.0,
            straggler_delay_ms: 0.0,
        }
    }
}

impl FaultPlan {
    /// Convenience scenario: lose exactly `n` machines per round.
    pub fn lose_per_round(n: usize) -> Self {
        FaultPlan { machine_loss_per_round: n, ..FaultPlan::default() }
    }
}

/// Deterministic fault-injecting execution backend.
pub struct SimBackend {
    capacity: usize,
    faults: FaultPlan,
}

impl SimBackend {
    pub fn new(capacity: usize) -> Self {
        SimBackend { capacity, faults: FaultPlan::default() }
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn run_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundOutcome> {
        enforce_capacity(self.capacity, parts)?;
        let seeds = machine_seeds(round_seed, parts.len());

        // fault stream: independent of the algorithmic seed stream so
        // enabling faults never perturbs the solutions themselves
        let mut frng = Rng::seed_from(
            self.faults.seed ^ round_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let quota = self.faults.machine_loss_per_round.min(parts.len());
        let lost_this_round: HashSet<usize> = if quota > 0 {
            frng.sample_indices(parts.len(), quota)
                .into_iter()
                .map(|i| i as usize)
                .collect()
        } else {
            HashSet::new()
        };

        let mut solutions: Vec<Solution> = Vec::with_capacity(parts.len());
        let mut requeued = 0usize;
        let mut delay_ms = 0.0f64;

        for (i, part) in parts.iter().enumerate() {
            // scripted loss: the original machine never reports
            let mut attempts = 0usize;
            if lost_this_round.contains(&i) {
                requeued += 1;
                attempts += 1;
            }
            // Bernoulli losses on top (replacements included)
            while self.faults.loss_prob > 0.0 && frng.bool(self.faults.loss_prob) {
                requeued += 1;
                attempts += 1;
                if attempts > self.faults.max_retries {
                    return Err(Error::Worker(format!(
                        "sim: machine {i} of {} lost {attempts} times (retry budget {})",
                        parts.len(),
                        self.faults.max_retries
                    )));
                }
            }
            if frng.bool(self.faults.straggler_prob) {
                delay_ms += self.faults.straggler_delay_ms;
            }
            // every retry replays the machine's full work
            delay_ms += attempts as f64 * self.faults.straggler_delay_ms;

            // same part, same positional seed — replacements change cost,
            // never the answer
            solutions.push(compressor.compress(problem, part, seeds[i])?);
        }

        Ok(RoundOutcome { solutions, requeued_parts: requeued, sim_delay_ms: delay_ms })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::data::synthetic;
    use crate::dist::LocalBackend;
    use std::sync::Arc;

    fn setup(n: usize, seed: u64) -> (Problem, Vec<Vec<u32>>) {
        let ds = Arc::new(synthetic::csn_like(n, seed));
        let p = Problem::exemplar(ds, 4, seed);
        let parts: Vec<Vec<u32>> = (0..4)
            .map(|i| ((i * n / 4) as u32..((i + 1) * n / 4) as u32).collect())
            .collect();
        (p, parts)
    }

    #[test]
    fn no_faults_matches_local_backend_bit_exactly() {
        let (p, parts) = setup(200, 1);
        let sim = SimBackend::new(64);
        let local = LocalBackend::new(64).with_threads(3);
        let a = sim.run_round(&p, &LazyGreedy::new(), &parts, 9).unwrap();
        let b = local.run_round(&p, &LazyGreedy::new(), &parts, 9).unwrap();
        assert_eq!(a.solutions.len(), b.solutions.len());
        for (x, y) in a.solutions.iter().zip(&b.solutions) {
            assert_eq!(x.items, y.items);
            assert_eq!(x.value.to_bits(), y.value.to_bits());
        }
        assert_eq!(a.requeued_parts, 0);
        assert_eq!(a.sim_delay_ms, 0.0);
    }

    #[test]
    fn scripted_loss_requeues_without_changing_solutions() {
        let (p, parts) = setup(200, 2);
        let healthy = SimBackend::new(64);
        let faulty = SimBackend::new(64).with_faults(FaultPlan::lose_per_round(1));
        let a = healthy.run_round(&p, &LazyGreedy::new(), &parts, 5).unwrap();
        let b = faulty.run_round(&p, &LazyGreedy::new(), &parts, 5).unwrap();
        assert_eq!(b.requeued_parts, 1, "exactly one machine lost per round");
        for (x, y) in a.solutions.iter().zip(&b.solutions) {
            assert_eq!(x.items, y.items, "faults must not change answers");
        }
    }

    #[test]
    fn fault_schedule_is_deterministic() {
        let (p, parts) = setup(240, 3);
        let faults = FaultPlan {
            seed: 11,
            machine_loss_per_round: 1,
            loss_prob: 0.3,
            max_retries: 10,
            straggler_prob: 0.5,
            straggler_delay_ms: 25.0,
        };
        let s1 = SimBackend::new(64).with_faults(faults.clone());
        let s2 = SimBackend::new(64).with_faults(faults);
        let a = s1.run_round(&p, &LazyGreedy::new(), &parts, 7).unwrap();
        let b = s2.run_round(&p, &LazyGreedy::new(), &parts, 7).unwrap();
        assert_eq!(a.requeued_parts, b.requeued_parts);
        assert_eq!(a.sim_delay_ms, b.sim_delay_ms);
        assert!(a.requeued_parts >= 1);
    }

    #[test]
    fn retry_budget_exhaustion_fails_the_round() {
        let (p, parts) = setup(100, 4);
        let sim = SimBackend::new(64).with_faults(FaultPlan {
            loss_prob: 1.0, // every attempt dies
            max_retries: 2,
            ..FaultPlan::default()
        });
        let err = sim.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap_err();
        assert!(matches!(err, Error::Worker(_)), "{err}");
        assert!(err.to_string().contains("retry budget"), "{err}");
    }

    #[test]
    fn stragglers_accumulate_virtual_delay_only() {
        let (p, parts) = setup(100, 5);
        let sim = SimBackend::new(64).with_faults(FaultPlan {
            straggler_prob: 1.0,
            straggler_delay_ms: 40.0,
            ..FaultPlan::default()
        });
        let t0 = std::time::Instant::now();
        let out = sim.run_round(&p, &LazyGreedy::new(), &parts, 2).unwrap();
        assert_eq!(out.sim_delay_ms, 40.0 * parts.len() as f64);
        // virtual time must not be real time
        assert!(t0.elapsed().as_millis() < 100, "simulator slept for real");
    }
}

//! Deterministic single-thread cluster simulator with fault injection.
//!
//! `SimBackend` executes every machine sequentially (one simulator
//! thread per round) and injects *scripted* faults from a seeded RNG
//! stream:
//!
//! * **machine loss** — a machine vanishes before reporting; its part is
//!   requeued to a fresh replacement machine (same part, same positional
//!   seed, so the answer is unchanged — only cost and the requeue
//!   counter move). Losses come in two flavors: a deterministic
//!   per-round quota (`machine_loss_per_round`, the scenario knob used
//!   by robustness tests) and a Bernoulli rate (`loss_prob`) with a
//!   bounded retry budget.
//! * **stragglers** — a machine finishes late; the simulator charges
//!   `straggler_delay_ms` of *virtual* time (no real sleeping, so the
//!   scenario suite stays fast) and reports it in
//!   [`crate::dist::RoundOutcome::sim_delay_ms`].
//!
//! Everything derives from `(fault seed, round seed, machine index)`, so
//! a scenario replays bit-exactly — the point of a simulator: explore
//! failure schedules the real TCP runtime can only hit by accident.
//!
//! Rounds are streaming ([`crate::dist::Backend::open_round`]): the
//! machine loop runs on a background thread fed by the session's part
//! stream (machines execute the moment their part arrives, in
//! submission order) and streams [`crate::dist::PartEvent`]s (machine
//! losses, requeues, virtual straggler delay, completions) in
//! deterministic machine order, so the pipelined tree runner sees the
//! same fault telemetry a real fleet would emit — replayable, one
//! event stream per scenario. The one scripted-fault knob that needs
//! the round's machine count up front (`machine_loss_per_round`)
//! buffers the stream until the session closes — virtual time is
//! unaffected and the fault stream stays bit-identical to the
//! pre-streaming simulator.
//!
//! The simulator can additionally run **wire-faithful**
//! ([`SimBackend::with_wire_spec`]): the problem and compressor are
//! serialized through the wire spec, parsed back and rebuilt exactly
//! as a TCP worker would, then executed on the reconstruction — a
//! deterministic, socket-free check that the wire encoding loses
//! nothing. Spec serialization is interned per problem identity
//! (protocol v4 semantics): the JSON round-trip runs once per distinct
//! problem, surfaces as one [`crate::dist::PartEvent::SpecShipped`],
//! and later rounds reuse the interned spec — the sim analogue of the
//! TCP backend's once-per-connection `define-problem`.
//!
//! Wire-faithful mode also round-trips **both protocol v6 payload
//! encodings**: the interned spec must survive a `define-problem` frame
//! in JSON *and* binary form, and every machine's part ids and solution
//! echo through both encodings bit-exactly before the part reports —
//! so an encoding divergence fails the round instead of silently
//! changing an answer.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::algorithms::Compressor;
use crate::constraints::Constraint;
use crate::coordinator::capacity::CapacityProfile;
use crate::data::DatasetRef;
use crate::dist::protocol::{
    compressor_from_name, compressor_wire_name, PayloadMode, ProblemSpec, Request, Response,
    Telemetry,
};
use crate::dist::{Backend, PartEvent, RoundSession, RoundSink, SpecInterner};
use crate::error::{Error, Result};
use crate::objectives::{EvalCounter, Problem};
use crate::trace;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Fault-injection script for [`SimBackend`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for the fault stream (independent of algorithmic seeds).
    pub seed: u64,
    /// Exactly this many machines (clamped to the round's machine count)
    /// are lost per round, chosen uniformly — the deterministic scenario
    /// knob ("what if one machine dies every round?").
    pub machine_loss_per_round: usize,
    /// Additionally, each machine execution is lost with this
    /// probability (replacements can be lost again).
    pub loss_prob: f64,
    /// Retry budget per part before the round fails.
    pub max_retries: usize,
    /// Each machine straggles with this probability…
    pub straggler_prob: f64,
    /// …adding this much virtual latency.
    pub straggler_delay_ms: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            machine_loss_per_round: 0,
            loss_prob: 0.0,
            max_retries: 3,
            straggler_prob: 0.0,
            straggler_delay_ms: 0.0,
        }
    }
}

impl FaultPlan {
    /// Convenience scenario: lose exactly `n` machines per round.
    pub fn lose_per_round(n: usize) -> Self {
        FaultPlan { machine_loss_per_round: n, ..FaultPlan::default() }
    }
}

/// Deterministic fault-injecting execution backend.
pub struct SimBackend {
    profile: CapacityProfile,
    /// Scripted fleet evolution: the profile for executed round `r` is
    /// `capacity_schedule[min(r, len-1)]` (the last entry persists).
    /// Empty means the static `profile` for every round. This is the
    /// scenario knob for "the fleet shrinks mid-run" / "the largest
    /// machine is decommissioned after round 0" — the tree re-queries
    /// [`Backend::profile`] every round and re-plans its partition
    /// against the fleet that will actually execute.
    capacity_schedule: Vec<CapacityProfile>,
    /// Rounds executed so far (advances the schedule; shared with the
    /// round sessions, which advance it when they close).
    rounds_run: Arc<AtomicUsize>,
    faults: FaultPlan,
    wire_spec: bool,
    /// Wire-mode spec interner (protocol v4 semantics): serialization +
    /// JSON round-trip once per problem identity, not once per round.
    interner: SpecInterner,
    /// Wire-mode memo of the last reconstructed dataset and built
    /// constraint (the expensive parts of materializing a spec) — the
    /// sim analogue of the TCP worker's `DatasetCache`, so a
    /// multi-round run regenerates the matrix and the constraint
    /// tables once, not once per round.
    wire_memo: Mutex<Option<WireMemo>>,
}

/// `((dataset key, constraint spec), dataset, constraint)`.
type WireMemo = (((String, u64), String), DatasetRef, Arc<dyn Constraint>);

impl SimBackend {
    /// Uniform fleet of capacity-µ machines (the paper's setting).
    pub fn new(capacity: usize) -> Self {
        Self::with_profile(CapacityProfile::uniform(capacity))
    }

    /// Heterogeneous fleet: virtual machine `j` holds `µ_{j mod L}`.
    pub fn with_profile(profile: CapacityProfile) -> Self {
        SimBackend {
            profile,
            capacity_schedule: Vec::new(),
            rounds_run: Arc::new(AtomicUsize::new(0)),
            faults: FaultPlan::default(),
            wire_spec: false,
            interner: SpecInterner::new(),
            wire_memo: Mutex::new(None),
        }
    }

    /// Script the fleet per round: round `r` runs on
    /// `schedule[min(r, len-1)]`. Use a shrinking schedule to replay
    /// "machines are lost between rounds" deterministically.
    ///
    /// The round counter is cumulative across `run_round` calls (the
    /// backend cannot observe run boundaries), so a scheduled backend
    /// scripts **one** run; to replay the scenario on the same backend,
    /// call [`SimBackend::reset_schedule`] between runs — otherwise the
    /// next run resumes wherever the schedule left off.
    pub fn with_capacity_schedule(mut self, schedule: Vec<CapacityProfile>) -> Self {
        self.capacity_schedule = schedule;
        self
    }

    /// Rewind the capacity schedule to round 0, so the next run replays
    /// the scripted fleet evolution from the start.
    pub fn reset_schedule(&self) {
        // relaxed: rounds advance strictly from the coordinator thread
        // (open/close are &self but serial per run); the counter carries
        // no other state
        self.rounds_run.store(0, Ordering::Relaxed);
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Round-trip problem + compressor through the wire spec each round
    /// and execute on the reconstruction (TCP-worker semantics, without
    /// sockets). Rejects problems that are not wire-representable.
    pub fn with_wire_spec(mut self, on: bool) -> Self {
        self.wire_spec = on;
        self
    }

    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn profile(&self) -> CapacityProfile {
        if self.capacity_schedule.is_empty() {
            return self.profile.clone();
        }
        // relaxed: read on the coordinator thread that also advances it
        let r = self.rounds_run.load(Ordering::Relaxed);
        self.capacity_schedule[r.min(self.capacity_schedule.len() - 1)].clone()
    }

    fn open_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        round_seed: u64,
    ) -> Result<RoundSession> {
        // Wire-faithful mode: what a TCP worker would actually run. The
        // reconstruction (and its rejections) happen synchronously at
        // open, like the TCP backend's interning. The JSON round-trip
        // check runs once per problem identity — later rounds reuse the
        // interned spec, mirroring protocol v4.
        let wire: Option<(Problem, Box<dyn Compressor>, Option<usize>)> = if self.wire_spec {
            let interned = self.interner.intern(problem)?;
            if interned.fresh {
                let echoed =
                    ProblemSpec::from_json(&Json::parse(&interned.spec.to_json().to_string())?)?;
                if echoed != *interned.spec {
                    return Err(Error::Protocol(
                        "problem spec did not survive a JSON round-trip".into(),
                    ));
                }
                // v6: the spec must also survive a define-problem frame
                // in BOTH payload encodings (the binary encoder lifts
                // explicit constraint tables into little-endian blobs)
                let define = Request::DefineProblem {
                    id: interned.id,
                    problem: (*interned.spec).clone(),
                };
                for mode in [PayloadMode::Json, PayloadMode::Binary] {
                    match Request::decode(&define.encode(mode), mode)? {
                        Request::DefineProblem { problem, .. }
                            if problem == *interned.spec => {}
                        _ => {
                            return Err(Error::Protocol(format!(
                                "problem spec did not survive a {} define-problem \
                                 round-trip",
                                mode.wire_name()
                            )))
                        }
                    }
                }
            }
            let comp = compressor_from_name(&compressor_wire_name(compressor)?)?;
            let key = (
                interned.spec.dataset.cache_key(),
                interned.spec.constraint.to_json().to_string(),
            );
            let (ds, constraint) = {
                // invariant: wire_memo critical sections only clone Arcs
                // and compare keys — they cannot panic, so the mutex is
                // never poisoned
                let mut memo = self.wire_memo.lock().unwrap();
                match &*memo {
                    Some((k, ds, c)) if *k == key => (ds.clone(), c.clone()),
                    _ => {
                        let ds = interned.spec.dataset.load()?;
                        let c = interned.spec.constraint.build(&ds)?;
                        *memo = Some((key, ds.clone(), c.clone()));
                        (ds, c)
                    }
                }
            };
            let shipped = if interned.fresh { Some(interned.bytes) } else { None };
            // the reconstructed problem serves with the submitter's
            // compute engine — the sim analogue of a worker honoring
            // the engine negotiated for the connection
            let problem_run =
                interned.spec.materialize_with(ds, constraint)?.with_compute(problem.compute.clone());
            Some((problem_run, comp, shipped))
        } else {
            None
        };
        let (problem_run, compressor_run, spec_shipped) = match wire {
            Some((p, c, shipped)) => (p, c, shipped),
            None => (problem.clone(), compressor.boxed_clone(), None),
        };

        let round = SimRound {
            problem: problem_run,
            compressor: compressor_run,
            faults: self.faults.clone(),
            round_seed,
            // wire mode reconstructs a problem with a fresh counter;
            // fold its oracle work back into the caller's (the tcp
            // backend does the same for remote evals)
            fold_evals: if self.wire_spec { Some(problem.evals.clone()) } else { None },
        };
        let (tx, rx) = mpsc::channel();
        if let Some(bytes) = spec_shipped {
            // one spec "shipment" per problem identity — the sim
            // analogue of the TCP define-problem byte accounting
            let _ = tx.send(Ok(PartEvent::SpecShipped { bytes }));
        }
        let (parts_tx, parts_rx) = mpsc::channel();
        std::thread::spawn(move || round.execute(parts_rx, tx));
        Ok(RoundSession::new(
            Box::new(SimSink {
                parts_tx: Some(parts_tx),
                rounds_run: Arc::clone(&self.rounds_run),
                open: true,
            }),
            rx,
            self.profile(),
            round_seed,
        ))
    }
}

/// Session sink feeding the simulator's machine loop.
struct SimSink {
    parts_tx: Option<mpsc::Sender<(usize, Vec<u32>, u64)>>,
    rounds_run: Arc<AtomicUsize>,
    open: bool,
}

impl RoundSink for SimSink {
    fn submit(&mut self, idx: usize, part: Vec<u32>, seed: u64) -> Result<()> {
        if let Some(tx) = &self.parts_tx {
            // a dead executor (fatal injected fault) is reported via the
            // event channel, never here
            let _ = tx.send((idx, part, seed));
        }
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        if self.open {
            self.open = false;
            // dropping the sender seals the part stream
            self.parts_tx = None;
            // the scripted fleet schedule advances only when a round is
            // actually sealed for execution — an aborted speculation or
            // a failed submission must not consume a scheduled fleet
            self.rounds_run.fetch_add(1, Ordering::Relaxed); // relaxed: coordinator-thread counter
        }
        Ok(())
    }

    fn abort(&mut self) {
        self.open = false;
        self.parts_tx = None;
    }
}

/// One in-flight simulated round: the sequential machine loop, moved to
/// a background thread so fault/straggler events stream out as they
/// "happen" in virtual time — and, without a scripted loss quota,
/// machines run the moment the session submits their part.
struct SimRound {
    problem: Problem,
    compressor: Box<dyn Compressor>,
    faults: FaultPlan,
    round_seed: u64,
    fold_evals: Option<EvalCounter>,
}

impl SimRound {
    fn execute(
        self,
        parts_rx: mpsc::Receiver<(usize, Vec<u32>, u64)>,
        tx: mpsc::Sender<Result<PartEvent>>,
    ) {
        // wire mode: reconstruction oracle calls folded so far
        let mut folded = 0u64;
        // fault stream: independent of the algorithmic seed stream so
        // enabling faults never perturbs the solutions themselves
        let mut frng = Rng::seed_from(
            self.faults.seed ^ self.round_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        if self.faults.machine_loss_per_round > 0 {
            // scripted per-round loss quotas draw the lost set from the
            // round's machine count, so this mode buffers the stream
            // until the session closes; the frng consumption order is
            // identical to the pre-streaming simulator
            let tasks: Vec<(usize, Vec<u32>, u64)> = parts_rx.iter().collect();
            let quota = self.faults.machine_loss_per_round.min(tasks.len());
            let lost_this_round: HashSet<usize> = if quota > 0 {
                frng.sample_indices(tasks.len(), quota)
                    .into_iter()
                    .map(|i| i as usize)
                    .collect()
            } else {
                HashSet::new()
            };
            for (pos, (idx, part, seed)) in tasks.into_iter().enumerate() {
                let scripted = lost_this_round.contains(&pos);
                if !self.run_machine(idx, &part, seed, scripted, &mut frng, &mut folded, &tx)
                {
                    return;
                }
            }
        } else {
            // no quota: each machine executes the moment its part
            // arrives — submission order IS machine order, so the fault
            // stream is unchanged
            while let Ok((idx, part, seed)) = parts_rx.recv() {
                if !self.run_machine(idx, &part, seed, false, &mut frng, &mut folded, &tx) {
                    return;
                }
            }
        }
    }

    /// Simulate one machine (and its replacements after injected
    /// losses). Returns `false` when the round is over (fatal fault or
    /// the consumer gave up).
    #[allow(clippy::too_many_arguments)]
    fn run_machine(
        &self,
        i: usize,
        part: &[u32],
        seed: u64,
        scripted_loss: bool,
        frng: &mut Rng,
        folded: &mut u64,
        tx: &mpsc::Sender<Result<PartEvent>>,
    ) -> bool {
        // scripted loss: the original machine never reports
        let mut attempts = 0usize;
        if scripted_loss {
            attempts += 1;
            let _ = tx.send(Ok(PartEvent::MachineLost {
                machine: format!("sim-{i}"),
                detail: "scripted machine loss".into(),
            }));
            let _ = tx.send(Ok(PartEvent::Requeued { part: i, reshipped_ids: part.len() }));
        }
        // Bernoulli losses on top (replacements included)
        while self.faults.loss_prob > 0.0 && frng.bool(self.faults.loss_prob) {
            attempts += 1;
            let _ = tx.send(Ok(PartEvent::Requeued { part: i, reshipped_ids: part.len() }));
            if attempts > self.faults.max_retries {
                let _ = tx.send(Err(Error::Worker(format!(
                    "sim: machine {i} lost {attempts} times (retry budget {})",
                    self.faults.max_retries
                ))));
                return false;
            }
        }
        let mut delay_ms = 0.0f64;
        if frng.bool(self.faults.straggler_prob) {
            delay_ms += self.faults.straggler_delay_ms;
        }
        // every retry replays the machine's full work and re-ships
        // the part's ids to the replacement machine
        delay_ms += attempts as f64 * self.faults.straggler_delay_ms;
        if delay_ms > 0.0 {
            let _ = tx.send(Ok(PartEvent::Delay { part: i, virtual_ms: delay_ms }));
        }

        // wire-faithful (v6): the part's ids cross the simulated wire in
        // both payload encodings before executing; a divergent echo
        // fails the round instead of silently changing an answer
        if self.fold_evals.is_some() {
            if let Err(e) = self.echo_part_both_encodings(part, seed) {
                let _ = tx.send(Err(e));
                return false;
            }
        }
        // same part, same positional seed — replacements change cost,
        // never the answer
        let t0 = trace::now_us();
        match self.compressor.compress(&self.problem, part, seed) {
            Ok(solution) => {
                // wire-faithful (v6): the solution echoes through both
                // payload encodings bit-exactly before it reports
                if self.fold_evals.is_some() {
                    if let Err(e) = echo_solution_both_encodings(&solution) {
                        let _ = tx.send(Err(e));
                        return false;
                    }
                }
                if trace::enabled() {
                    trace::span(
                        &format!("sim-{i}"),
                        "execute",
                        t0,
                        vec![
                            ("part", trace::ArgValue::U64(i as u64)),
                            ("virtual_delay_ms", trace::ArgValue::F64(delay_ms)),
                        ],
                    );
                }
                // fold BEFORE announcing completion: a consumer that
                // reads the shared counter the moment the round's
                // last part reports must see every oracle call
                if let Some(evals) = &self.fold_evals {
                    let now = self.problem.eval_count();
                    // relaxed: the channel send below is the publishing
                    // edge — its internal synchronization makes this
                    // fold visible to whoever receives the Done event
                    evals.fetch_add(now - *folded, std::sync::atomic::Ordering::Relaxed);
                    *folded = now;
                }
                // a closed channel means the consumer gave up
                tx.send(Ok(PartEvent::Done { part: i, solution })).is_ok()
            }
            Err(e) => {
                let _ = tx.send(Err(e));
                false
            }
        }
    }

    /// Wire-faithful echo of one machine's compress request through
    /// BOTH payload encodings (protocol v6): the decoded frame must be
    /// identical to the original in each — the socket-free analogue of
    /// the TCP backend's binary/JSON bit-identity guarantee.
    fn echo_part_both_encodings(&self, part: &[u32], seed: u64) -> Result<()> {
        let req = Request::Compress {
            // the id is immaterial here: this echoes the encoding, not
            // the interning protocol
            problem_id: 0,
            compressor: compressor_wire_name(self.compressor.as_ref())?,
            part: part.to_vec(),
            cap: part.len(),
            seed,
        };
        for mode in [PayloadMode::Json, PayloadMode::Binary] {
            if Request::decode(&req.encode(mode), mode)? != req {
                return Err(Error::Protocol(format!(
                    "compress request did not survive the {} payload encoding",
                    mode.wire_name()
                )));
            }
        }
        Ok(())
    }
}

/// Wire-faithful echo of one machine's solution through BOTH payload
/// encodings (protocol v6): items and value must come back bit-exact
/// (NaN/±inf values included, which is why the comparison is on bits).
fn echo_solution_both_encodings(solution: &crate::algorithms::Solution) -> Result<()> {
    let resp = Response::Solution {
        items: solution.items.clone(),
        value: solution.value,
        evals: 0,
        wall_ms: 0.0,
        telemetry: Telemetry::default(),
    };
    for mode in [PayloadMode::Json, PayloadMode::Binary] {
        match Response::decode(&resp.encode(mode), mode)? {
            Response::Solution { items, value, .. }
                if items == solution.items
                    && value.to_bits() == solution.value.to_bits() => {}
            _ => {
                return Err(Error::Protocol(format!(
                    "solution did not survive the {} payload encoding",
                    mode.wire_name()
                )))
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::data::synthetic;
    use crate::dist::LocalBackend;
    use std::sync::Arc;

    fn setup(n: usize, seed: u64) -> (Problem, Vec<Vec<u32>>) {
        let ds = Arc::new(synthetic::csn_like(n, seed));
        let p = Problem::exemplar(ds, 4, seed);
        let parts: Vec<Vec<u32>> = (0..4)
            .map(|i| ((i * n / 4) as u32..((i + 1) * n / 4) as u32).collect())
            .collect();
        (p, parts)
    }

    #[test]
    fn no_faults_matches_local_backend_bit_exactly() {
        let (p, parts) = setup(200, 1);
        let sim = SimBackend::new(64);
        let local = LocalBackend::new(64).with_threads(3);
        let a = sim.run_round(&p, &LazyGreedy::new(), &parts, 9).unwrap();
        let b = local.run_round(&p, &LazyGreedy::new(), &parts, 9).unwrap();
        assert_eq!(a.solutions.len(), b.solutions.len());
        for (x, y) in a.solutions.iter().zip(&b.solutions) {
            assert_eq!(x.items, y.items);
            assert_eq!(x.value.to_bits(), y.value.to_bits());
        }
        assert_eq!(a.requeued_parts, 0);
        assert_eq!(a.sim_delay_ms, 0.0);
    }

    #[test]
    fn scripted_loss_requeues_without_changing_solutions() {
        let (p, parts) = setup(200, 2);
        let healthy = SimBackend::new(64);
        let faulty = SimBackend::new(64).with_faults(FaultPlan::lose_per_round(1));
        let a = healthy.run_round(&p, &LazyGreedy::new(), &parts, 5).unwrap();
        let b = faulty.run_round(&p, &LazyGreedy::new(), &parts, 5).unwrap();
        assert_eq!(b.requeued_parts, 1, "exactly one machine lost per round");
        // the lost part's ids ship a second time (parts are 50 ids each)
        assert_eq!(b.requeued_ids, 50);
        assert_eq!(a.requeued_ids, 0);
        for (x, y) in a.solutions.iter().zip(&b.solutions) {
            assert_eq!(x.items, y.items, "faults must not change answers");
        }
    }

    #[test]
    fn fault_events_stream_in_machine_order_with_requeues_before_done() {
        let (p, parts) = setup(200, 2);
        let sim = SimBackend::new(64).with_faults(FaultPlan {
            machine_loss_per_round: 1,
            straggler_prob: 1.0,
            straggler_delay_ms: 10.0,
            ..FaultPlan::default()
        });
        let mut handle = sim.submit_round(&p, &LazyGreedy::new(), &parts, 5).unwrap();
        let mut requeues = 0;
        let mut losses = 0;
        let mut delay = 0.0;
        let mut done_parts: Vec<usize> = Vec::new();
        let mut pending_requeue: Option<usize> = None;
        while let Some(ev) = handle.next_event() {
            match ev.unwrap() {
                PartEvent::Done { part, .. } => {
                    if let Some(rq) = pending_requeue.take() {
                        assert_eq!(rq, part, "requeue must precede its part's Done");
                    }
                    done_parts.push(part);
                }
                PartEvent::Requeued { part, reshipped_ids } => {
                    requeues += 1;
                    assert_eq!(reshipped_ids, 50);
                    pending_requeue = Some(part);
                }
                PartEvent::MachineLost { machine, .. } => {
                    losses += 1;
                    assert!(machine.starts_with("sim-"), "{machine}");
                }
                PartEvent::Delay { virtual_ms, .. } => delay += virtual_ms,
                PartEvent::SpecShipped { .. } => {
                    panic!("non-wire sim must not ship specs")
                }
            }
        }
        assert_eq!(done_parts, vec![0, 1, 2, 3], "sim executes machines in order");
        assert_eq!(requeues, 1, "exactly one scripted loss");
        assert_eq!(losses, 1);
        // every machine straggles 10 ms; the lost one replays once more
        assert_eq!(delay, 10.0 * parts.len() as f64 + 10.0);
    }

    #[test]
    fn fault_schedule_is_deterministic() {
        let (p, parts) = setup(240, 3);
        let faults = FaultPlan {
            seed: 11,
            machine_loss_per_round: 1,
            loss_prob: 0.3,
            max_retries: 10,
            straggler_prob: 0.5,
            straggler_delay_ms: 25.0,
        };
        let s1 = SimBackend::new(64).with_faults(faults.clone());
        let s2 = SimBackend::new(64).with_faults(faults);
        let a = s1.run_round(&p, &LazyGreedy::new(), &parts, 7).unwrap();
        let b = s2.run_round(&p, &LazyGreedy::new(), &parts, 7).unwrap();
        assert_eq!(a.requeued_parts, b.requeued_parts);
        assert_eq!(a.sim_delay_ms, b.sim_delay_ms);
        assert!(a.requeued_parts >= 1);
    }

    #[test]
    fn wire_spec_mode_reconstructs_problem_and_matches_bit_exactly() {
        use crate::constraints::Knapsack;

        // registry problem under a generator-spec'd knapsack: the wire
        // mode rebuilds both from JSON and must match direct execution
        let ds = crate::data::registry::load("csn-2k", 3).unwrap();
        let knap = Knapsack::from_row_norms(&ds, 400.0, 8);
        let p = Problem::exemplar(ds, 8, 3).with_constraint(Arc::new(knap));
        let parts: Vec<Vec<u32>> =
            (0..4).map(|i| (i * 50..(i + 1) * 50).collect()).collect();

        let direct = SimBackend::new(64)
            .run_round(&p, &LazyGreedy::new(), &parts, 9)
            .unwrap();
        let wired = SimBackend::new(64)
            .with_wire_spec(true)
            .run_round(&p, &LazyGreedy::new(), &parts, 9)
            .unwrap();
        assert_eq!(direct.solutions.len(), wired.solutions.len());
        for (x, y) in direct.solutions.iter().zip(&wired.solutions) {
            assert_eq!(x.items, y.items, "wire round-trip changed a solution");
            assert_eq!(x.value.to_bits(), y.value.to_bits());
        }
        for s in &wired.solutions {
            assert!(p.constraint.is_feasible(&s.items, &p.dataset));
        }

        // problems the wire cannot describe are rejected up front
        let adhoc = Problem::modular(vec![1.0; 20], 3, 0);
        let one_part = vec![(0..10).collect::<Vec<u32>>()];
        assert!(SimBackend::new(64)
            .with_wire_spec(true)
            .run_round(&adhoc, &LazyGreedy::new(), &one_part, 0)
            .is_err());
    }

    #[test]
    fn heterogeneous_profile_matches_local_backend_bit_exactly() {
        let (p, _) = setup(240, 7);
        let profile = CapacityProfile::parse("120,60,60").unwrap();
        // parts sized to the cycle 120, 60, 60
        let parts: Vec<Vec<u32>> = vec![
            (0..120).collect(),
            (120..180).collect(),
            (180..240).collect(),
        ];
        let sim = SimBackend::with_profile(profile.clone());
        let local = LocalBackend::with_profile(profile).with_threads(3);
        let a = sim.run_round(&p, &LazyGreedy::new(), &parts, 9).unwrap();
        let b = local.run_round(&p, &LazyGreedy::new(), &parts, 9).unwrap();
        for (x, y) in a.solutions.iter().zip(&b.solutions) {
            assert_eq!(x.items, y.items);
            assert_eq!(x.value.to_bits(), y.value.to_bits());
        }
    }

    #[test]
    fn capacity_schedule_shrinks_the_fleet_between_rounds() {
        let (p, _) = setup(200, 8);
        let big = CapacityProfile::parse("100,50,50").unwrap();
        let small = CapacityProfile::parse("50,50").unwrap();
        let sim = SimBackend::with_profile(big.clone())
            .with_capacity_schedule(vec![big.clone(), small.clone()]);
        // round 0 sees the full fleet
        assert_eq!(sim.profile(), big);
        let parts0: Vec<Vec<u32>> = vec![(0..100).collect(), (100..150).collect(), (150..200).collect()];
        sim.run_round(&p, &LazyGreedy::new(), &parts0, 1).unwrap();
        // round 1 onward sees the shrunken fleet; the last entry persists
        assert_eq!(sim.profile(), small);
        // a 100-item part no longer fits anywhere
        let too_big: Vec<Vec<u32>> = vec![(0..100).collect()];
        let err = sim.run_round(&p, &LazyGreedy::new(), &too_big, 2).unwrap_err();
        assert!(matches!(err, Error::CapacityExceeded { capacity: 50, got: 100, .. }), "{err}");
        // schedule did not advance past the failed round's enforcement…
        let parts1: Vec<Vec<u32>> = vec![(0..50).collect(), (50..100).collect()];
        sim.run_round(&p, &LazyGreedy::new(), &parts1, 3).unwrap();
        assert_eq!(sim.profile(), small);
        // …and resetting rewinds the scripted scenario to round 0, so a
        // reused backend replays the same fleet evolution
        sim.reset_schedule();
        assert_eq!(sim.profile(), big);
        sim.run_round(&p, &LazyGreedy::new(), &parts0, 1).unwrap();
        assert_eq!(sim.profile(), small);
    }

    #[test]
    fn aborted_sessions_do_not_advance_the_capacity_schedule() {
        let (p, parts) = setup(100, 6);
        let big = CapacityProfile::uniform(64);
        let small = CapacityProfile::uniform(32);
        let sim = SimBackend::with_profile(big.clone())
            .with_capacity_schedule(vec![big.clone(), small.clone()]);
        // an opened-then-aborted round (cancelled speculation) must not
        // consume a scheduled fleet
        let sess = sim.open_round(&p, &LazyGreedy::new(), 1).unwrap();
        sess.abort();
        assert_eq!(sim.profile(), big, "abort consumed a scheduled round");
        // a sealed round does
        sim.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        assert_eq!(sim.profile(), small);
    }

    #[test]
    fn streamed_parts_match_the_batch_round_with_faults() {
        let (p, parts) = setup(200, 9);
        let faults = FaultPlan {
            straggler_prob: 0.5,
            straggler_delay_ms: 15.0,
            loss_prob: 0.2,
            max_retries: 10,
            ..FaultPlan::default()
        };
        let streamed = {
            let sim = SimBackend::new(64).with_faults(faults.clone());
            let mut sess = sim.open_round(&p, &LazyGreedy::new(), 4).unwrap();
            for part in &parts {
                sess.submit_part(part.clone()).unwrap();
            }
            sess.close().unwrap().finish().unwrap()
        };
        let batch = SimBackend::new(64)
            .with_faults(faults)
            .run_round(&p, &LazyGreedy::new(), &parts, 4)
            .unwrap();
        assert_eq!(streamed.solutions.len(), batch.solutions.len());
        for (x, y) in streamed.solutions.iter().zip(&batch.solutions) {
            assert_eq!(x.items, y.items);
            assert_eq!(x.value.to_bits(), y.value.to_bits());
        }
        // the injected fault stream is identical too, not just the answer
        assert_eq!(streamed.requeued_parts, batch.requeued_parts);
        assert_eq!(streamed.sim_delay_ms, batch.sim_delay_ms);
    }

    #[test]
    fn wire_mode_interns_the_spec_once_per_problem_identity() {
        let ds = crate::data::registry::load("csn-2k", 3).unwrap();
        let p = Problem::exemplar(ds, 6, 3);
        let parts: Vec<Vec<u32>> =
            (0..4).map(|i| (i * 50..(i + 1) * 50).collect()).collect();
        let sim = SimBackend::new(64).with_wire_spec(true);
        let r0 = sim.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        assert!(r0.spec_bytes > 0, "first round must serialize the spec");
        let r1 = sim.run_round(&p, &LazyGreedy::new(), &parts, 2).unwrap();
        assert_eq!(r1.spec_bytes, 0, "second round must reuse the interned spec");
        // plain (non-wire) mode never ships specs
        let plain = SimBackend::new(64)
            .run_round(&p, &LazyGreedy::new(), &parts, 1)
            .unwrap();
        assert_eq!(plain.spec_bytes, 0);
    }

    #[test]
    fn retry_budget_exhaustion_fails_the_round() {
        let (p, parts) = setup(100, 4);
        let sim = SimBackend::new(64).with_faults(FaultPlan {
            loss_prob: 1.0, // every attempt dies
            max_retries: 2,
            ..FaultPlan::default()
        });
        let err = sim.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap_err();
        assert!(matches!(err, Error::Worker(_)), "{err}");
        assert!(err.to_string().contains("retry budget"), "{err}");
    }

    #[test]
    fn stragglers_accumulate_virtual_delay_only() {
        let (p, parts) = setup(100, 5);
        let sim = SimBackend::new(64).with_faults(FaultPlan {
            straggler_prob: 1.0,
            straggler_delay_ms: 40.0,
            ..FaultPlan::default()
        });
        let t0 = std::time::Instant::now();
        let out = sim.run_round(&p, &LazyGreedy::new(), &parts, 2).unwrap();
        assert_eq!(out.sim_delay_ms, 40.0 * parts.len() as f64);
        // virtual time must not be real time
        assert!(t0.elapsed().as_millis() < 100, "simulator slept for real");
    }
}

//! Coordinator-side TCP backend: shard a round's parts over real
//! `hss worker` processes.
//!
//! Dispatch model: one I/O thread per worker pulls part indices from a
//! shared queue (work stealing — a fast worker drains more parts), sends
//! a `compress` request over its persistent connection, and waits for
//! the reply. Transport failures mark the worker dead and **requeue**
//! the part for the surviving workers (counted in
//! [`RoundOutcome::requeued_parts`]); application errors reported by a
//! worker (capacity violation, bad spec) abort the round — retrying
//! elsewhere cannot fix those.
//!
//! Determinism: per-machine seeds are positional
//! ([`crate::dist::machine_seeds`]), so *which* worker executes a part —
//! and any requeueing along the way — never changes the result. A
//! `TcpBackend` run returns bit-identical solutions to [`LocalBackend`]
//! for the same `(problem, parts, round_seed)` — including under
//! hereditary constraints, which cross the wire as construction recipes
//! ([`crate::constraints::spec::ConstraintSpec`], wire spec v2).
//!
//! [`LocalBackend`]: crate::dist::LocalBackend

use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::algorithms::{Compressor, Solution};
use crate::dist::protocol::{
    compressor_wire_name, recv_msg, send_msg, ProblemSpec, Request, Response,
};
use crate::dist::{enforce_capacity, machine_seeds, Backend, RoundOutcome};
use crate::error::{Error, Result};
use crate::objectives::Problem;

/// A persistent, handshaken connection to one worker process.
struct WorkerConn {
    addr: String,
    stream: TcpStream,
}

impl WorkerConn {
    fn connect(addr: &str, required_capacity: usize) -> Result<WorkerConn> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::transport(addr, format!("connect failed: {e}")))?;
        stream.set_nodelay(true).ok();
        // Handshake-only timeout: a worker busy with another coordinator
        // parks this connection in its accept backlog; fail fast so the
        // slot goes dead and other workers absorb the queue instead of
        // the round hanging. Cleared after the handshake — compression
        // time is legitimately unbounded.
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(10)))
            .ok();
        let mut conn = WorkerConn { addr: addr.to_string(), stream };
        let reply = conn.roundtrip(&Request::Hello)?;
        conn.stream.set_read_timeout(None).ok();
        match reply {
            Response::Hello { capacity } if capacity >= required_capacity => Ok(conn),
            Response::Hello { capacity } => Err(Error::transport(
                addr,
                format!("worker capacity {capacity} < required µ={required_capacity}"),
            )),
            other => Err(Error::Protocol(format!(
                "{addr}: expected hello, got {other:?}"
            ))),
        }
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        send_msg(&mut self.stream, &req.to_json())
            .map_err(|e| Error::transport(&self.addr, e))?;
        let msg = recv_msg(&mut self.stream).map_err(|e| Error::transport(&self.addr, e))?;
        Response::from_json(&msg)
    }
}

/// Per-worker slot: address plus the live connection (lazily created,
/// reused across rounds, dropped on failure).
struct Slot {
    addr: String,
    conn: Option<WorkerConn>,
    dead: bool,
}

/// Execution backend over real worker processes at `host:port` addresses.
pub struct TcpBackend {
    capacity: usize,
    slots: Mutex<Vec<Slot>>,
}

impl TcpBackend {
    /// Create a backend over the given worker addresses. Connections are
    /// established lazily and connect failures are retried on the next
    /// round, so workers may come up after the backend is constructed —
    /// or even mid-run.
    pub fn new(capacity: usize, workers: Vec<String>) -> Result<TcpBackend> {
        if workers.is_empty() {
            return Err(Error::invalid(
                "tcp backend needs at least one worker address (--workers host:port[,host:port…])",
            ));
        }
        // Dedupe: a worker serves one coordinator connection at a time,
        // so a second connection to the same address would park in its
        // accept backlog holding a part in flight.
        let mut seen = std::collections::HashSet::new();
        let slots = workers
            .into_iter()
            .filter(|addr| seen.insert(addr.clone()))
            .map(|addr| Slot { addr, conn: None, dead: false })
            .collect();
        Ok(TcpBackend { capacity, slots: Mutex::new(slots) })
    }

    /// Addresses this backend was configured with.
    pub fn worker_addrs(&self) -> Vec<String> {
        self.slots.lock().unwrap().iter().map(|s| s.addr.clone()).collect()
    }

    /// Ask every reachable worker to shut down (best effort; used by
    /// orderly teardown paths and tests).
    pub fn shutdown_workers(&self) {
        let mut slots = self.slots.lock().unwrap();
        for slot in slots.iter_mut() {
            let conn = match slot.conn.take() {
                Some(c) => Some(c),
                None if !slot.dead => WorkerConn::connect(&slot.addr, 0).ok(),
                None => None,
            };
            if let Some(mut c) = conn {
                let _ = c.roundtrip(&Request::Shutdown);
            }
            slot.dead = true;
        }
    }
}

impl Backend for TcpBackend {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn run_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundOutcome> {
        enforce_capacity(self.capacity, parts)?;
        let spec = ProblemSpec::from_problem(problem)?;
        let comp_name = compressor_wire_name(compressor)?;
        let seeds = machine_seeds(round_seed, parts.len());

        let queue: Mutex<VecDeque<usize>> = Mutex::new((0..parts.len()).collect());
        let results: Mutex<Vec<Option<(Solution, u64)>>> =
            Mutex::new((0..parts.len()).map(|_| None).collect());
        let completed = AtomicUsize::new(0);
        let requeued = AtomicUsize::new(0);
        let requeued_ids = AtomicUsize::new(0);
        let fatal: Mutex<Option<Error>> = Mutex::new(None);
        let abort = AtomicBool::new(false);
        let last_transport_err: Mutex<Option<String>> = Mutex::new(None);

        let mut slots = self.slots.lock().unwrap();
        std::thread::scope(|scope| {
            for slot in slots.iter_mut() {
                if slot.dead {
                    continue;
                }
                let queue = &queue;
                let results = &results;
                let completed = &completed;
                let requeued = &requeued;
                let requeued_ids = &requeued_ids;
                let fatal = &fatal;
                let abort = &abort;
                let last_transport_err = &last_transport_err;
                let spec = &spec;
                let comp_name = &comp_name;
                let seeds = &seeds;
                scope.spawn(move || {
                    loop {
                        if abort.load(Ordering::Relaxed) {
                            break;
                        }
                        let job = queue.lock().unwrap().pop_front();
                        let Some(i) = job else {
                            if completed.load(Ordering::Relaxed) >= parts.len() {
                                break;
                            }
                            // A peer still holds a part in flight; if its
                            // machine is lost, the part comes back to the
                            // queue — stay alive to steal it. (Every exit
                            // path on a failing peer requeues first, so
                            // unfinished work is always either queued or
                            // held by a live worker.)
                            std::thread::sleep(std::time::Duration::from_millis(1));
                            continue;
                        };
                        // (re)connect lazily
                        if slot.conn.is_none() {
                            match WorkerConn::connect(&slot.addr, self.capacity) {
                                Ok(c) => slot.conn = Some(c),
                                Err(e) => {
                                    // Never dispatched: not a requeue. The
                                    // slot sits out the rest of this round
                                    // only — workers are allowed to come up
                                    // late, so the next round retries the
                                    // connect. (`dead` is reserved for
                                    // mid-flight failures.)
                                    queue.lock().unwrap().push_back(i);
                                    *last_transport_err.lock().unwrap() = Some(e.to_string());
                                    break;
                                }
                            }
                        }
                        let conn = slot.conn.as_mut().unwrap();
                        let request = Request::Compress {
                            problem: spec.clone(),
                            compressor: comp_name.clone(),
                            part: parts[i].clone(),
                            seed: seeds[i],
                        };
                        match conn.roundtrip(&request) {
                            Ok(Response::Solution { items, value, evals, .. }) => {
                                results.lock().unwrap()[i] =
                                    Some((Solution { items, value }, evals));
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            Ok(Response::Error { msg }) => {
                                // the worker is alive and rejected the job:
                                // retrying elsewhere cannot help
                                *fatal.lock().unwrap() =
                                    Some(Error::Worker(format!("{}: {msg}", slot.addr)));
                                abort.store(true, Ordering::Relaxed);
                                break;
                            }
                            Ok(other) => {
                                *fatal.lock().unwrap() = Some(Error::Protocol(format!(
                                    "{}: unexpected reply {other:?}",
                                    slot.addr
                                )));
                                abort.store(true, Ordering::Relaxed);
                                break;
                            }
                            Err(e) => {
                                // transport failure mid-flight: lose the
                                // machine, requeue the part elsewhere
                                requeued.fetch_add(1, Ordering::Relaxed);
                                requeued_ids.fetch_add(parts[i].len(), Ordering::Relaxed);
                                queue.lock().unwrap().push_back(i);
                                *last_transport_err.lock().unwrap() = Some(e.to_string());
                                slot.conn = None;
                                slot.dead = true;
                                break;
                            }
                        }
                    }
                });
            }
        });
        drop(slots);

        if let Some(e) = fatal.into_inner().unwrap() {
            return Err(e);
        }
        let results = results.into_inner().unwrap();
        let last_err = last_transport_err.into_inner().unwrap();
        let mut solutions = Vec::with_capacity(parts.len());
        let mut total_evals = 0u64;
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Some((sol, evals)) => {
                    solutions.push(sol);
                    total_evals += evals;
                }
                None => {
                    let detail =
                        last_err.unwrap_or_else(|| "no worker reachable".into());
                    return Err(Error::Transport(format!(
                        "part {i} of {} unprocessed — all workers lost ({detail})",
                        parts.len()
                    )));
                }
            }
        }
        // fold remote oracle work into the problem's shared counter so
        // the Table-1 evals metric stays comparable across backends
        problem
            .evals
            .fetch_add(total_evals, std::sync::atomic::Ordering::Relaxed);
        Ok(RoundOutcome {
            solutions,
            requeued_parts: requeued.into_inner(),
            requeued_ids: requeued_ids.into_inner(),
            sim_delay_ms: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_empty_worker_list() {
        assert!(TcpBackend::new(100, vec![]).is_err());
    }

    #[test]
    fn duplicate_worker_addresses_collapse_to_one_slot() {
        // two connections to one single-connection worker would deadlock
        let b = TcpBackend::new(
            100,
            vec!["127.0.0.1:7070".into(), "127.0.0.1:7070".into(), "127.0.0.1:7071".into()],
        )
        .unwrap();
        assert_eq!(b.worker_addrs(), vec!["127.0.0.1:7070", "127.0.0.1:7071"]);
    }

    #[test]
    fn unreachable_workers_fail_with_transport_error() {
        // 127.0.0.1:1 — connect is refused immediately on any sane host
        let backend = TcpBackend::new(50, vec!["127.0.0.1:1".into()]).unwrap();
        // from_problem runs before dispatch, so the problem must be
        // wire-representable for the failure to reach the transport layer
        let p = crate::objectives::Problem::exemplar(
            crate::data::registry::load("csn-2k", 1).unwrap(),
            5,
            1,
        );
        let parts = vec![(0..10).collect::<Vec<u32>>()];
        let err = backend
            .run_round(&p, &crate::algorithms::LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
    }

    #[test]
    fn non_wire_problem_fails_before_connecting() {
        let backend = TcpBackend::new(50, vec!["127.0.0.1:1".into()]).unwrap();
        let p = crate::objectives::Problem::modular(vec![1.0; 20], 3, 0);
        let parts = vec![(0..10).collect::<Vec<u32>>()];
        let err = backend
            .run_round(&p, &crate::algorithms::LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err}");
    }
}

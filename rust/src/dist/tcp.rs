//! Coordinator-side TCP backend: shard a round's parts over real
//! `hss worker` processes.
//!
//! Dispatch model (Backend v2): one **persistent dispatcher thread per
//! worker** lives for the backend's whole lifetime, parked on a condition
//! variable between rounds. [`Backend::submit_round`] publishes the
//! round as a shared job (part queue + wire-ready problem spec) and
//! notifies the dispatchers; each one pulls the first queued part its
//! worker can hold, runs the request/response roundtrip over its warm
//! connection, and streams a [`PartEvent`] the moment the reply lands.
//! There is **no per-round thread spawn/teardown and no sleep-polling**:
//! every dispatcher transition (handshake resolved, part completed,
//! worker lost, round submitted) is condvar-driven, so an idle worker
//! starts the next round's first part the instant it is published —
//! while another worker's straggling part from the previous moment is
//! still the only thing the old barrier design would have let anyone
//! look at.
//!
//! Workers advertise their fixed capacity µ in the protocol-v3
//! handshake, and dispatch is **capacity-fitting**: a worker only claims
//! parts it can hold, so a heterogeneous fleet (capacities 500, 200,
//! 200…) serves a weighted partition with every part on a machine big
//! enough for it — work stealing still applies among the workers a part
//! fits. Transport failures mark the worker dead and **requeue** the
//! part for the surviving workers *that can hold it* (surfaced as
//! [`PartEvent::Requeued`] / [`PartEvent::MachineLost`]); once every
//! pending handshake has resolved, a queued part no surviving worker can
//! hold fails the round with a transport error (the stall detector —
//! evaluated on state transitions, never by polling). Application errors
//! reported by a worker (capacity violation, bad spec) abort the round —
//! retrying elsewhere cannot fix those.
//!
//! Determinism: per-machine seeds are positional
//! (`machine_seeds` in [`crate::dist`]), so *which* worker executes a part —
//! and any requeueing along the way — never changes the result. A
//! `TcpBackend` run returns bit-identical solutions to [`LocalBackend`]
//! for the same `(problem, parts, round_seed)` — including under
//! hereditary constraints, which cross the wire as construction recipes
//! ([`crate::constraints::spec::ConstraintSpec`]), and including
//! heterogeneous capacity profiles.
//!
//! [`LocalBackend`]: crate::dist::LocalBackend

use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Condvar, Mutex};

use crate::algorithms::{Compressor, Solution};
use crate::coordinator::capacity::CapacityProfile;
use crate::dist::protocol::{
    compressor_wire_name, recv_msg, send_msg, ProblemSpec, Request, Response,
};
use crate::dist::{enforce_profile, machine_seeds, Backend, PartEvent, RoundHandle};
use crate::error::{Error, Result};
use crate::objectives::{EvalCounter, Problem};

/// A persistent, handshaken connection to one worker process.
struct WorkerConn {
    addr: String,
    stream: TcpStream,
    /// Fixed capacity µ the worker advertised at handshake.
    capacity: usize,
}

impl WorkerConn {
    fn connect(addr: &str) -> Result<WorkerConn> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::transport(addr, format!("connect failed: {e}")))?;
        stream.set_nodelay(true).ok();
        // Handshake-only timeout: a worker busy with another coordinator
        // parks this connection in its accept backlog; fail fast so the
        // slot goes dead and other workers absorb the queue instead of
        // the round hanging. Cleared after the handshake — compression
        // time is legitimately unbounded.
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(10)))
            .ok();
        let mut conn = WorkerConn { addr: addr.to_string(), stream, capacity: 0 };
        let reply = conn.roundtrip(&Request::Hello)?;
        conn.stream.set_read_timeout(None).ok();
        match reply {
            Response::Hello { capacity } => {
                conn.capacity = capacity;
                Ok(conn)
            }
            other => Err(Error::Protocol(format!(
                "{addr}: expected hello, got {other:?}"
            ))),
        }
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        send_msg(&mut self.stream, &req.to_json())
            .map_err(|e| Error::transport(&self.addr, e))?;
        let msg = recv_msg(&mut self.stream).map_err(|e| Error::transport(&self.addr, e))?;
        Response::from_json(&msg)
    }
}

/// Round context shared by every dispatcher serving it — owned data
/// only, since dispatchers outlive the submitter's borrows.
struct RoundCtx {
    spec: ProblemSpec,
    comp_name: String,
    parts: Vec<Vec<u32>>,
    seeds: Vec<u64>,
    /// Planned virtual machine capacity per part (protocol v3 `cap`).
    caps: Vec<usize>,
    /// The submitting problem's shared oracle counter: remote evals fold
    /// in as each solution arrives, keeping Table-1 metrics comparable
    /// across backends.
    evals: EvalCounter,
    tx: mpsc::Sender<Result<PartEvent>>,
}

/// The currently in-flight round.
struct Job {
    ctx: Arc<RoundCtx>,
    queue: VecDeque<usize>,
    in_flight: usize,
    /// Most recent transport-level failure detail (connect refused,
    /// reset mid-flight) — context for stall-detector errors.
    last_err: Option<String>,
}

/// Dispatcher-visible state of one worker address.
struct Slot {
    addr: String,
    /// Advertised µ once a handshake has succeeded. `None` means the
    /// stall detector must wait for this slot's handshake to resolve
    /// before concluding that a part fits no one.
    capacity: Option<usize>,
    /// Permanent: the worker failed mid-flight. Connect *refusals* are
    /// not permanent — the slot merely sits out the round (`out_epoch`)
    /// and retries when the next one is submitted.
    dead: bool,
    /// Epoch whose connect attempt failed; the slot is unavailable for
    /// that round only (workers may come up late, even mid-run).
    out_epoch: u64,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum ShutdownKind {
    /// Exit dispatchers without touching workers (backend dropped).
    Quiet,
    /// Ask every reachable worker process to exit first.
    Workers,
}

struct FleetState {
    slots: Vec<Slot>,
    job: Option<Job>,
    /// Bumped once per submitted round; guards stale dispatcher results
    /// and scopes `out_epoch` connect failures to a single round.
    epoch: u64,
    dispatchers_alive: usize,
    shutdown: Option<ShutdownKind>,
}

struct Fleet {
    state: Mutex<FleetState>,
    cv: Condvar,
}

/// Execution backend over real worker processes at `host:port` addresses.
pub struct TcpBackend {
    profile: CapacityProfile,
    fleet: Arc<Fleet>,
}

impl TcpBackend {
    /// Uniform fleet: every part may be up to µ items (the paper's
    /// setting). Connections are established lazily and connect
    /// failures are retried on the next round, so workers may come up
    /// after the backend is constructed — or even mid-run.
    pub fn new(capacity: usize, workers: Vec<String>) -> Result<TcpBackend> {
        Self::with_profile(CapacityProfile::uniform(capacity), workers)
    }

    /// Heterogeneous fleet: the planner sizes part `j` for virtual
    /// capacity `µ_{j mod L}`, and dispatch places each part only on
    /// workers whose advertised capacity can hold it.
    pub fn with_profile(profile: CapacityProfile, workers: Vec<String>) -> Result<TcpBackend> {
        if workers.is_empty() {
            return Err(Error::invalid(
                "tcp backend needs at least one worker address (--workers host:port[,host:port…])",
            ));
        }
        // Dedupe: a worker serves one coordinator connection at a time,
        // so a second connection to the same address would park in its
        // accept backlog holding a part in flight.
        let mut seen = std::collections::HashSet::new();
        let slots: Vec<Slot> = workers
            .into_iter()
            .filter(|addr| seen.insert(addr.clone()))
            .map(|addr| Slot { addr, capacity: None, dead: false, out_epoch: 0 })
            .collect();
        let count = slots.len();
        let fleet = Arc::new(Fleet {
            state: Mutex::new(FleetState {
                slots,
                job: None,
                epoch: 0,
                dispatchers_alive: count,
                shutdown: None,
            }),
            cv: Condvar::new(),
        });
        for id in 0..count {
            let fleet = Arc::clone(&fleet);
            std::thread::Builder::new()
                .name(format!("hss-dispatch-{id}"))
                .spawn(move || dispatcher(fleet, id))
                .map_err(|e| Error::Worker(format!("spawn dispatcher: {e}")))?;
        }
        Ok(TcpBackend { profile, fleet })
    }

    /// Addresses this backend was configured with.
    pub fn worker_addrs(&self) -> Vec<String> {
        let st = self.fleet.state.lock().unwrap();
        st.slots.iter().map(|s| s.addr.clone()).collect()
    }

    /// Ask every reachable worker to shut down (best effort; used by
    /// orderly teardown paths and tests). Blocks until the dispatcher
    /// threads have exited.
    pub fn shutdown_workers(&self) {
        let mut st = self.fleet.state.lock().unwrap();
        st.shutdown = Some(ShutdownKind::Workers);
        self.fleet.cv.notify_all();
        while st.dispatchers_alive > 0 {
            st = self.fleet.cv.wait(st).unwrap();
        }
    }
}

impl Drop for TcpBackend {
    fn drop(&mut self) {
        // Wake parked dispatchers so they exit and close their worker
        // connections; don't block the dropping thread on it.
        let mut st = self.fleet.state.lock().unwrap();
        if st.shutdown.is_none() {
            st.shutdown = Some(ShutdownKind::Quiet);
        }
        self.fleet.cv.notify_all();
    }
}

impl Backend for TcpBackend {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn profile(&self) -> CapacityProfile {
        self.profile.clone()
    }

    fn submit_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundHandle> {
        enforce_profile(&self.profile, parts)?;
        let spec = ProblemSpec::from_problem(problem)?;
        let comp_name = compressor_wire_name(compressor)?;
        if parts.is_empty() {
            return Ok(RoundHandle::empty());
        }
        let seeds = machine_seeds(round_seed, parts.len());
        let caps: Vec<usize> = (0..parts.len())
            .map(|j| self.profile.virtual_capacity(j))
            .collect();

        let (tx, rx) = mpsc::channel();
        let expected = parts.len();
        let mut st = self.fleet.state.lock().unwrap();
        if st.shutdown.is_some() {
            return Err(Error::invalid("tcp backend is shut down"));
        }
        if st.job.is_some() {
            return Err(Error::invalid(
                "tcp backend already has a round in flight (one round at a time)",
            ));
        }
        st.epoch += 1;
        st.job = Some(Job {
            ctx: Arc::new(RoundCtx {
                spec,
                comp_name,
                parts: parts.to_vec(),
                seeds,
                caps,
                evals: problem.evals.clone(),
                tx,
            }),
            queue: (0..parts.len()).collect(),
            in_flight: 0,
            last_err: None,
        });
        // A fleet that is already known to be incapable (every slot dead
        // from earlier rounds) must fail the round now — no dispatcher
        // is left to notice.
        check_stall(&mut st);
        self.fleet.cv.notify_all();
        Ok(RoundHandle::new(rx, expected))
    }
}

/// Fail the in-flight round if some queued part can *never* complete:
/// every pending handshake has resolved and no live, in-round worker
/// advertises a capacity that holds it. Runs on state transitions
/// (submit, handshake failure, worker death, idle dispatcher about to
/// park) — the event-driven replacement for the old sleep-poll loop's
/// per-tick scan.
fn check_stall(st: &mut FleetState) {
    let epoch = st.epoch;
    let msg = {
        let Some(job) = &st.job else { return };
        // a slot that has never handshaken (and is not dead or sitting
        // this round out) may still reveal a fitting capacity
        if st
            .slots
            .iter()
            .any(|s| !s.dead && s.out_epoch != epoch && s.capacity.is_none())
        {
            return;
        }
        let avail: Vec<usize> = st
            .slots
            .iter()
            .filter(|s| !s.dead && s.out_epoch != epoch)
            .filter_map(|s| s.capacity)
            .collect();
        let orphan = job
            .queue
            .iter()
            .copied()
            .find(|&i| !avail.iter().any(|&c| job.ctx.parts[i].len() <= c));
        let Some(i) = orphan else { return };
        let detail = job
            .last_err
            .clone()
            .unwrap_or_else(|| "no fitting worker".into());
        if avail.is_empty() {
            format!(
                "part {i} of {} unprocessed — all workers lost ({detail})",
                job.ctx.parts.len()
            )
        } else {
            format!(
                "part {i} of {} ({} items) exceeds every live worker's capacity ({detail})",
                job.ctx.parts.len(),
                job.ctx.parts[i].len()
            )
        }
    };
    if let Some(job) = st.job.take() {
        let _ = job.ctx.tx.send(Err(Error::Transport(msg)));
    }
}

/// What a dispatcher decided to do with the lock held.
enum Step {
    /// Nothing to do until the fleet changes — park on the condvar.
    Park,
    /// No connection yet and a round wants workers: handshake.
    Connect(String),
    /// Claimed part `i` of the current round.
    Dispatch(usize, Arc<RoundCtx>, u64),
    /// Backend is shutting down; optionally tell the worker to exit.
    Exit(Option<String>),
}

/// Persistent per-worker dispatcher: parks on the fleet condvar, claims
/// capacity-fitting parts while a round is in flight, exits on shutdown
/// or when its worker dies mid-flight.
fn dispatcher(fleet: Arc<Fleet>, id: usize) {
    let mut conn: Option<WorkerConn> = None;
    let mut st = fleet.state.lock().unwrap();
    loop {
        // decide under the lock… (reborrow the guard once so the
        // decision can take disjoint field borrows of the state)
        let step = {
            let stx: &mut FleetState = &mut st;
            if let Some(kind) = stx.shutdown {
                let notify = kind == ShutdownKind::Workers && !stx.slots[id].dead;
                Step::Exit(if notify { Some(stx.slots[id].addr.clone()) } else { None })
            } else if stx.slots[id].dead {
                Step::Exit(None)
            } else {
                let epoch = stx.epoch;
                let out_this_round = stx.slots[id].out_epoch == epoch;
                let addr = stx.slots[id].addr.clone();
                match &mut stx.job {
                    None => Step::Park,
                    Some(_) if out_this_round => Step::Park,
                    Some(job) => {
                        if conn.is_none() {
                            Step::Connect(addr)
                        } else {
                            let my_cap = conn.as_ref().unwrap().capacity;
                            let pos = job
                                .queue
                                .iter()
                                .position(|&i| job.ctx.parts[i].len() <= my_cap);
                            match pos {
                                Some(pos) => {
                                    let i = job.queue.remove(pos).unwrap();
                                    job.in_flight += 1;
                                    Step::Dispatch(i, Arc::clone(&job.ctx), epoch)
                                }
                                None => Step::Park,
                            }
                        }
                    }
                }
            }
        };

        // …act without it.
        match step {
            Step::Park => {
                // Work may remain but none of it fits this worker, or
                // peers hold it in flight (if their machine is lost the
                // part comes back to the queue — stay parked to steal
                // it). Before parking, make sure a part that fits NO
                // live worker fails the round instead of hanging it.
                check_stall(&mut st);
                st = fleet.cv.wait(st).unwrap();
            }
            Step::Connect(addr) => {
                let epoch = st.epoch;
                drop(st);
                let attempt = WorkerConn::connect(&addr);
                st = fleet.state.lock().unwrap();
                match attempt {
                    Ok(c) => {
                        // register the capacity the moment the handshake
                        // resolves: peers' stall checks must see every
                        // successful worker before concluding "no fit"
                        st.slots[id].capacity = Some(c.capacity);
                        conn = Some(c);
                    }
                    Err(e) => {
                        // Never dispatched: not a requeue. The slot sits
                        // out the rest of this round only — workers are
                        // allowed to come up late, so the next round
                        // retries the connect. (`dead` is reserved for
                        // mid-flight failures.)
                        if st.epoch == epoch {
                            st.slots[id].out_epoch = epoch;
                            if let Some(job) = &mut st.job {
                                job.last_err = Some(e.to_string());
                            }
                            check_stall(&mut st);
                        }
                    }
                }
                fleet.cv.notify_all();
            }
            Step::Dispatch(i, ctx, epoch) => {
                drop(st);
                let request = Request::Compress {
                    problem: ctx.spec.clone(),
                    compressor: ctx.comp_name.clone(),
                    part: ctx.parts[i].clone(),
                    cap: ctx.caps[i],
                    seed: ctx.seeds[i],
                };
                let result = conn.as_mut().unwrap().roundtrip(&request);
                st = fleet.state.lock().unwrap();
                // The round could have been aborted (and even replaced)
                // while this reply was on the wire; only account against
                // the job if it is still the one we claimed from.
                let same_job = st.epoch == epoch && st.job.is_some();
                match result {
                    Ok(Response::Solution { items, value, evals, .. }) => {
                        // fold remote oracle work in BEFORE announcing
                        // completion, so a consumer reading the shared
                        // counter at the last event sees all of it
                        ctx.evals.fetch_add(evals, Ordering::Relaxed);
                        let _ = ctx.tx.send(Ok(PartEvent::Done {
                            part: i,
                            solution: Solution { items, value },
                        }));
                        if same_job {
                            let job = st.job.as_mut().unwrap();
                            job.in_flight -= 1;
                            if job.queue.is_empty() && job.in_flight == 0 {
                                st.job = None; // round complete
                            }
                        }
                    }
                    Ok(Response::Error { msg }) => {
                        // the worker is alive and rejected the job:
                        // retrying elsewhere cannot help
                        let addr = st.slots[id].addr.clone();
                        let _ = ctx
                            .tx
                            .send(Err(Error::Worker(format!("{addr}: {msg}"))));
                        if same_job {
                            st.job = None;
                        }
                    }
                    Ok(other) => {
                        let addr = st.slots[id].addr.clone();
                        let _ = ctx.tx.send(Err(Error::Protocol(format!(
                            "{addr}: unexpected reply {other:?}"
                        ))));
                        if same_job {
                            st.job = None;
                        }
                    }
                    Err(e) => {
                        // transport failure mid-flight: lose this
                        // machine for good, requeue the part for
                        // surviving workers that can hold it
                        let _ = ctx.tx.send(Ok(PartEvent::MachineLost {
                            machine: st.slots[id].addr.clone(),
                            detail: e.to_string(),
                        }));
                        let _ = ctx.tx.send(Ok(PartEvent::Requeued {
                            part: i,
                            reshipped_ids: ctx.parts[i].len(),
                        }));
                        st.slots[id].dead = true;
                        st.slots[id].capacity = None;
                        conn = None;
                        if same_job {
                            let job = st.job.as_mut().unwrap();
                            job.in_flight -= 1;
                            job.queue.push_back(i);
                            job.last_err = Some(e.to_string());
                            check_stall(&mut st);
                        }
                    }
                }
                fleet.cv.notify_all();
            }
            Step::Exit(notify_addr) => {
                if let Some(addr) = notify_addr {
                    drop(st);
                    let c = match conn.take() {
                        Some(c) => Some(c),
                        None => WorkerConn::connect(&addr).ok(),
                    };
                    if let Some(mut c) = c {
                        let _ = c.roundtrip(&Request::Shutdown);
                    }
                    st = fleet.state.lock().unwrap();
                    st.slots[id].dead = true;
                }
                break;
            }
        }
    }
    st.dispatchers_alive -= 1;
    fleet.cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use std::net::TcpListener;

    #[test]
    fn rejects_empty_worker_list() {
        assert!(TcpBackend::new(100, vec![]).is_err());
        let p = CapacityProfile::parse("100,50").unwrap();
        assert!(TcpBackend::with_profile(p, vec![]).is_err());
    }

    #[test]
    fn duplicate_worker_addresses_collapse_to_one_slot() {
        // two connections to one single-connection worker would deadlock
        let b = TcpBackend::new(
            100,
            vec!["127.0.0.1:7070".into(), "127.0.0.1:7070".into(), "127.0.0.1:7071".into()],
        )
        .unwrap();
        assert_eq!(b.worker_addrs(), vec!["127.0.0.1:7070", "127.0.0.1:7071"]);
    }

    #[test]
    fn profile_is_exposed_and_capacity_is_the_largest_class() {
        let p = CapacityProfile::parse("500,200,200").unwrap();
        let b = TcpBackend::with_profile(p.clone(), vec!["127.0.0.1:7070".into()]).unwrap();
        assert_eq!(b.profile(), p);
        assert_eq!(b.capacity(), 500);
    }

    #[test]
    fn unreachable_workers_fail_with_transport_error() {
        // 127.0.0.1:1 — connect is refused immediately on any sane host
        let backend = TcpBackend::new(50, vec!["127.0.0.1:1".into()]).unwrap();
        // from_problem runs before dispatch, so the problem must be
        // wire-representable for the failure to reach the transport layer
        let p = crate::objectives::Problem::exemplar(
            crate::data::registry::load("csn-2k", 1).unwrap(),
            5,
            1,
        );
        let parts = vec![(0..10).collect::<Vec<u32>>()];
        let err = backend
            .run_round(&p, &crate::algorithms::LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
    }

    #[test]
    fn non_wire_problem_fails_before_connecting() {
        let backend = TcpBackend::new(50, vec!["127.0.0.1:1".into()]).unwrap();
        let p = crate::objectives::Problem::modular(vec![1.0; 20], 3, 0);
        let parts = vec![(0..10).collect::<Vec<u32>>()];
        let err = backend
            .run_round(&p, &crate::algorithms::LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err}");
    }

    /// Hand-rolled worker impostor: handshakes with an arbitrary
    /// advertised capacity (after `hello_delay_ms`, to script handshake
    /// ordering), then serves `serve_parts` compress requests before
    /// dropping the connection mid-flight (0 = die on first request).
    /// Lets the dispatcher tests script exact failure points without
    /// real worker processes.
    fn spawn_impostor(capacity: usize, serve_parts: usize, hello_delay_ms: u64) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            // serve successive coordinator connections until the test ends
            for stream in listener.incoming() {
                let Ok(mut stream) = stream else { return };
                let mut served = 0usize;
                loop {
                    let Ok(msg) = recv_msg(&mut stream) else { break };
                    let Ok(req) = Request::from_json(&msg) else { break };
                    match req {
                        Request::Hello => {
                            if hello_delay_ms > 0 {
                                std::thread::sleep(std::time::Duration::from_millis(
                                    hello_delay_ms,
                                ));
                            }
                            if send_msg(&mut stream, &Response::Hello { capacity }.to_json())
                                .is_err()
                            {
                                break;
                            }
                        }
                        Request::Shutdown => {
                            let _ = send_msg(&mut stream, &Response::Bye.to_json());
                            return;
                        }
                        Request::Compress { problem, compressor, part, seed, .. } => {
                            if served >= serve_parts {
                                // die holding the part: drop the stream
                                // without replying
                                break;
                            }
                            served += 1;
                            // real compute so surviving-path tests stay
                            // bit-identical to local execution
                            let p = problem.materialize().unwrap();
                            let comp =
                                crate::dist::protocol::compressor_from_name(&compressor)
                                    .unwrap();
                            let sol = comp.compress(&p, &part, seed).unwrap();
                            let reply = Response::Solution {
                                items: sol.items,
                                value: sol.value,
                                evals: 0,
                                wall_ms: 0.0,
                            };
                            if send_msg(&mut stream, &reply.to_json()).is_err() {
                                break;
                            }
                        }
                    }
                }
            }
        });
        addr
    }

    fn wire_problem(k: usize) -> Problem {
        Problem::exemplar(crate::data::registry::load("csn-2k", 3).unwrap(), k, 3)
    }

    #[test]
    fn stall_detector_fails_round_when_no_live_worker_fits_a_part() {
        // worker advertises µ=10; the round's only part has 20 items and
        // passes coordinator-side enforcement (profile says 50) — the
        // capacity-fit dispatcher must fail the round, not hang.
        let addr = spawn_impostor(10, usize::MAX, 0);
        let backend = TcpBackend::new(50, vec![addr]).unwrap();
        let p = wire_problem(5);
        let parts = vec![(0..20).collect::<Vec<u32>>()];
        let err = backend.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(
            err.to_string().contains("exceeds every live worker's capacity"),
            "{err}"
        );
    }

    #[test]
    fn worker_death_holding_the_only_fitting_part_fails_with_requeue_accounting() {
        // big worker (µ=50) dies on its first request while holding the
        // 20-item part; the small survivor (µ=10) cannot hold it — the
        // requeue must surface, then the stall detector must fail the
        // round instead of hanging.
        let big = spawn_impostor(50, 0, 0);
        let small = spawn_impostor(10, usize::MAX, 0);
        let backend = TcpBackend::new(50, vec![big, small]).unwrap();
        let p = wire_problem(5);
        let parts = vec![(0..20).collect::<Vec<u32>>()];
        let mut handle =
            backend.submit_round(&p, &LazyGreedy::new(), &parts, 2).unwrap();
        let mut requeued_parts = 0usize;
        let mut requeued_ids = 0usize;
        let mut lost = 0usize;
        let mut fatal = None;
        while let Some(ev) = handle.next_event() {
            match ev {
                Ok(PartEvent::Requeued { part, reshipped_ids }) => {
                    assert_eq!(part, 0);
                    requeued_parts += 1;
                    requeued_ids += reshipped_ids;
                }
                Ok(PartEvent::MachineLost { .. }) => lost += 1,
                Ok(PartEvent::Done { .. }) => panic!("part cannot complete"),
                Ok(PartEvent::Delay { .. }) => {}
                Err(e) => {
                    fatal = Some(e);
                    break;
                }
            }
        }
        assert_eq!(requeued_parts, 1, "the death must requeue the in-flight part");
        assert_eq!(requeued_ids, 20, "requeue re-ships the part's ids");
        assert_eq!(lost, 1);
        let err = fatal.expect("round must fail — no surviving worker fits the part");
        assert!(
            err.to_string().contains("exceeds every live worker's capacity"),
            "{err}"
        );
    }

    #[test]
    fn requeued_part_completes_on_a_fitting_survivor() {
        // The dying worker serves one part then drops its connection
        // holding the second; the survivor (same capacity, handshake
        // delayed so the dying worker deterministically claims two
        // parts first) steals the requeued part and the round still
        // matches local execution bit-exactly.
        let dying = spawn_impostor(40, 1, 0);
        let survivor = spawn_impostor(40, usize::MAX, 300);
        let backend = TcpBackend::new(40, vec![dying, survivor]).unwrap();
        let p = wire_problem(4);
        let parts: Vec<Vec<u32>> =
            (0..4).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 7).unwrap();
        assert_eq!(out.solutions.len(), 4);
        assert_eq!(out.requeued_parts, 1, "exactly one part rode the dying worker twice");
        assert_eq!(out.requeued_ids, 30);
        let local = crate::dist::LocalBackend::new(40)
            .run_round(&p, &LazyGreedy::new(), &parts, 7)
            .unwrap();
        for (x, y) in out.solutions.iter().zip(&local.solutions) {
            assert_eq!(x.items, y.items, "requeue changed a solution");
            assert_eq!(x.value.to_bits(), y.value.to_bits());
        }
    }

    #[test]
    fn capacity_fit_dispatch_routes_each_part_to_a_worker_that_holds_it() {
        // heterogeneous impostors: parts sized 30 can only run on the
        // µ=40 worker, parts sized 10 run anywhere; everything completes
        let big = spawn_impostor(40, usize::MAX, 0);
        let small = spawn_impostor(12, usize::MAX, 0);
        let profile = CapacityProfile::parse("40,12").unwrap();
        let backend = TcpBackend::with_profile(profile, vec![big, small]).unwrap();
        let p = wire_problem(4);
        let parts: Vec<Vec<u32>> = vec![
            (0..30).collect(),
            (30..40).collect(),
            (40..70).collect(),
            (70..80).collect(),
        ];
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 3).unwrap();
        assert_eq!(out.solutions.len(), 4);
        assert_eq!(out.requeued_parts, 0);
    }
}

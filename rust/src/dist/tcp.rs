//! Coordinator-side TCP backend: shard rounds' parts over real
//! `hss worker` processes.
//!
//! Dispatch model (Backend v3): one **persistent dispatcher thread per
//! worker** lives for the backend's whole lifetime, parked on a condition
//! variable between rounds. [`Backend::open_round`] publishes a round as
//! a shared job (a part queue that grows as the session streams parts
//! in, plus the interned problem) and notifies the dispatchers; each one
//! pulls the first queued part its worker can hold, runs the
//! request/response roundtrip over its warm connection, and streams a
//! [`PartEvent`] the moment the reply lands. There is **no per-round
//! thread spawn/teardown and no sleep-polling**: every dispatcher
//! transition (handshake resolved, part submitted, part completed,
//! worker lost, round opened) is condvar-driven, so an idle worker
//! starts a freshly-submitted part the instant it is published.
//!
//! Rounds **overlap**: the backend keeps a FIFO of open jobs, so the
//! next round's session may open — and its straggler-independent parts
//! may start executing on idle workers — while the current round's
//! stragglers drain. Dispatchers always prefer the oldest job with a
//! fitting queued part, so overlap never starves an earlier round.
//!
//! Problems are **interned** (protocol v4): a coordinator-side
//! `SpecInterner` serializes each problem identity once (killing the
//! old per-round `ProblemSpec::from_problem` re-serialization), and the
//! spec crosses the wire once per (worker connection, problem identity)
//! via a `define-problem` request — every compress request thereafter
//! carries a short problem id. Fresh or reconnected workers are
//! re-interned transparently, and each shipment surfaces as a
//! [`PartEvent::SpecShipped`] so runs can report spec bytes per round.
//!
//! Workers advertise their fixed capacity µ in the protocol handshake,
//! and dispatch is **capacity-fitting**: a worker only claims parts it
//! can hold, so a heterogeneous fleet (capacities 500, 200, 200…)
//! serves a weighted partition with every part on a machine big enough
//! for it — work stealing still applies among the workers a part fits.
//! Transport failures mark the worker dead and **requeue** the part for
//! the surviving workers *that can hold it* (surfaced as
//! [`PartEvent::Requeued`] / [`PartEvent::MachineLost`]); once every
//! pending handshake has resolved, a queued part no surviving worker can
//! hold fails its round with a transport error (the stall detector —
//! evaluated on state transitions, never by polling). Application errors
//! reported by a worker (capacity violation, bad spec) abort the round —
//! retrying elsewhere cannot fix those.
//!
//! Determinism: per-machine seeds are positional (drawn by
//! [`RoundSession`] in submission order), so *which* worker executes a
//! part — and any requeueing along the way — never changes the result.
//! A `TcpBackend` run returns bit-identical solutions to
//! [`LocalBackend`] for the same `(problem, parts, round_seed)` —
//! including under hereditary constraints, which cross the wire as
//! construction recipes ([`crate::constraints::spec::ConstraintSpec`]),
//! and including heterogeneous capacity profiles.
//!
//! [`LocalBackend`]: crate::dist::LocalBackend

use std::collections::{BTreeMap, HashSet, VecDeque};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Condvar, Mutex};

use crate::algorithms::{Compressor, Solution};
use crate::coordinator::capacity::CapacityProfile;
use crate::dist::protocol::{
    compressor_wire_name, recv_response, send_request, PayloadMode, ProblemSpec, Request,
    Response, Telemetry,
};
use crate::dist::{Backend, PartEvent, RoundSession, RoundSink, SpecInterner, WorkerStats};
use crate::error::{Error, Result};
use crate::objectives::{EvalCounter, Problem};
use crate::runtime::EngineChoice;
use crate::trace;
use crate::util::log;

/// A persistent, handshaken connection to one worker process.
struct WorkerConn {
    addr: String,
    stream: TcpStream,
    /// Fixed capacity µ the worker advertised at handshake.
    capacity: usize,
    /// Problem ids already interned on THIS connection (protocol v4).
    /// Dies with the connection, so reconnects re-intern transparently.
    defined: HashSet<u64>,
    /// Negotiated payload encoding (protocol v6): the coordinator always
    /// advertises binary; the worker's hello reply decides. Fixed for
    /// the connection's lifetime.
    mode: PayloadMode,
    /// Payload bytes (sent + received) since the last drain, attributed
    /// by the connection's negotiated mode — drained into the per-worker
    /// [`WorkerStats`] split after every dispatched part.
    bytes_binary: u64,
    bytes_json: u64,
    /// Compute engine the worker granted at handshake (its pin wins
    /// over our request) — surfaced in [`WorkerStats`].
    engine: EngineChoice,
}

impl WorkerConn {
    fn connect(addr: &str, engine: EngineChoice) -> Result<WorkerConn> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::transport(addr, format!("connect failed: {e}")))?;
        stream.set_nodelay(true).ok();
        // Handshake-only timeout: a worker busy with another coordinator
        // parks this connection in its accept backlog; fail fast so the
        // slot goes dead and other workers absorb the queue instead of
        // the round hanging. Cleared after the handshake — compression
        // time is legitimately unbounded.
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(10)))
            .ok();
        let mut conn = WorkerConn {
            addr: addr.to_string(),
            stream,
            capacity: 0,
            defined: HashSet::new(),
            // handshake frames are exchanged pre-negotiation, in the
            // JSON shape any peer understands (protocol v6)
            mode: PayloadMode::Json,
            bytes_binary: 0,
            bytes_json: 0,
            engine: EngineChoice::Native,
        };
        let t0 = trace::now_us();
        let hello = Request::Hello {
            clock_ms: trace::clock_ms(),
            payload: PayloadMode::Binary,
            engine,
        };
        let reply = conn.roundtrip(&hello)?;
        conn.stream.set_read_timeout(None).ok();
        match reply {
            Response::Hello { capacity, clock_echo_ms, payload, engine } => {
                if trace::enabled() {
                    // the echo bounds coordinator↔worker clock alignment
                    // by this handshake's RTT (docs/OBSERVABILITY.md)
                    let rtt_ms = trace::now_us().saturating_sub(t0) as f64 / 1e3;
                    trace::instant(
                        addr,
                        "handshake",
                        vec![
                            ("capacity", trace::ArgValue::U64(capacity as u64)),
                            ("clock_echo_ms", trace::ArgValue::F64(clock_echo_ms)),
                            ("rtt_ms", trace::ArgValue::F64(rtt_ms)),
                        ],
                    );
                }
                conn.capacity = capacity;
                // the worker echoes binary only when it accepts it; a
                // JSON-only (or pinned) worker answers "json" — or, for
                // a silent pre-v6-shaped hello, defaults to it
                conn.mode = payload;
                // the engine the worker will actually serve with — its
                // own pin wins over our request
                conn.engine = engine;
                Ok(conn)
            }
            other => Err(Error::Protocol(format!(
                "{addr}: expected hello, got {other:?}"
            ))),
        }
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        let sent = send_request(&mut self.stream, req, self.mode)
            .map_err(|e| Error::transport(&self.addr, e))?;
        let (resp, received) = recv_response(&mut self.stream, self.mode)
            .map_err(|e| Error::transport(&self.addr, e))?;
        let bytes = (sent + received) as u64;
        match self.mode {
            PayloadMode::Binary => self.bytes_binary += bytes,
            PayloadMode::Json => self.bytes_json += bytes,
        }
        Ok(resp)
    }

    /// Drain the payload-byte counters accumulated since the last call.
    fn take_payload_bytes(&mut self) -> (u64, u64) {
        (
            std::mem::take(&mut self.bytes_binary),
            std::mem::take(&mut self.bytes_json),
        )
    }
}

/// Round context shared by every dispatcher serving it — owned data
/// only, since dispatchers outlive the submitter's borrows.
struct RoundCtx {
    /// The interned problem: spec + id + serialized size. The spec
    /// ships once per (worker connection, problem identity); every
    /// compress request carries only the id.
    spec: Arc<ProblemSpec>,
    spec_id: u64,
    spec_bytes: usize,
    comp_name: String,
    /// The submitting problem's shared oracle counter: remote evals fold
    /// in as each solution arrives, keeping Table-1 metrics comparable
    /// across backends.
    evals: EvalCounter,
    tx: mpsc::Sender<Result<PartEvent>>,
    /// Attribution scope this round was opened under
    /// ([`Backend::open_round_scoped`]): work is *additionally*
    /// accounted per `(scope, worker)`, so `hss serve` can report each
    /// job's own interval. `None` for unscoped rounds (`hss run`).
    scope: Option<u64>,
}

/// One queued (or requeued) part of an open round.
struct PartTask {
    idx: usize,
    part: Vec<u32>,
    /// Planned virtual machine capacity (protocol v3 `cap`).
    cap: usize,
    /// Positional per-machine seed (drawn by the session).
    seed: u64,
}

/// One in-flight round. Several may be open at once (streaming round
/// submission lets round `t+1` start while round `t` stragglers drain);
/// dispatchers serve them FIFO.
struct Job {
    /// Unique, monotonically increasing round identity.
    epoch: u64,
    ctx: Arc<RoundCtx>,
    queue: VecDeque<PartTask>,
    in_flight: usize,
    /// The session sealed the part list: the job is complete (and
    /// removed) when the queue is empty and nothing is in flight.
    closed: bool,
    /// Parts submitted so far (error-message context).
    submitted: usize,
    /// Most recent transport-level failure detail (connect refused,
    /// reset mid-flight) — context for stall-detector errors.
    last_err: Option<String>,
}

/// Dispatcher-visible state of one worker address.
struct Slot {
    addr: String,
    /// Advertised µ once a handshake has succeeded. `None` means the
    /// stall detector must wait for this slot's handshake to resolve
    /// before concluding that a part fits no one.
    capacity: Option<usize>,
    /// Permanent: the worker failed mid-flight. Connect *refusals* are
    /// not permanent — the slot merely sits out the epoch (`out_epoch`)
    /// and retries when the next round is opened.
    dead: bool,
    /// Epoch whose connect attempt failed; the slot is unavailable
    /// while that epoch is current (workers may come up late, even
    /// mid-run).
    out_epoch: u64,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum ShutdownKind {
    /// Exit dispatchers without touching workers (backend dropped).
    Quiet,
    /// Ask every reachable worker process to exit first.
    Workers,
}

struct FleetState {
    slots: Vec<Slot>,
    /// Open rounds, oldest first. Dispatchers claim from the first job
    /// with a fitting queued part, so a newer round only runs on
    /// workers the older rounds leave idle.
    jobs: VecDeque<Job>,
    /// Bumped once per opened round; identifies jobs and scopes
    /// `out_epoch` connect failures.
    epoch: u64,
    dispatchers_alive: usize,
    shutdown: Option<ShutdownKind>,
    /// Per-worker utilization/telemetry (protocol v5), keyed by address
    /// so [`Backend::worker_stats`] reports in a stable order.
    stats: BTreeMap<String, WorkerStats>,
    /// The same accounting keyed by `(scope, addr)` for rounds opened
    /// via [`Backend::open_round_scoped`] — each `hss serve` job reads
    /// (and then releases) only its own slice. BTreeMap keeps per-scope
    /// reports address-sorted like the global map.
    scope_stats: BTreeMap<(u64, String), WorkerStats>,
    /// Compute engine requested in every worker handshake (v6) — each
    /// worker's pin may override it per connection, so a mixed fleet is
    /// fine; the granted engine lands in [`WorkerStats::engine`].
    engine: EngineChoice,
}

struct Fleet {
    state: Mutex<FleetState>,
    cv: Condvar,
}

impl Fleet {
    /// Acquire the fleet state, surviving mutex poisoning. If a peer
    /// dispatcher panicked while holding the lock, cascading that panic
    /// here would kill the remaining dispatchers and strand every open
    /// round with no event sender — a silent coordinator hang. The
    /// state stays structurally sound under poison (a panicking holder
    /// can at worst leave one job's in_flight count high, which the
    /// stall detector eventually converts into a round error), so the
    /// surviving dispatchers keep draining work instead.
    fn lock(&self) -> std::sync::MutexGuard<'_, FleetState> {
        self.state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Condvar wait with the same poison policy as [`Fleet::lock`].
    fn wait<'a>(
        &'a self,
        guard: std::sync::MutexGuard<'a, FleetState>,
    ) -> std::sync::MutexGuard<'a, FleetState> {
        self.cv
            .wait(guard)
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

/// Execution backend over real worker processes at `host:port` addresses.
pub struct TcpBackend {
    profile: CapacityProfile,
    fleet: Arc<Fleet>,
    /// Coordinator-side problem interner: `ProblemSpec::from_problem`
    /// runs once per problem identity, not once per round.
    interner: SpecInterner,
}

impl TcpBackend {
    /// Uniform fleet: every part may be up to µ items (the paper's
    /// setting). Connections are established lazily and connect
    /// failures are retried on the next round, so workers may come up
    /// after the backend is constructed — or even mid-run.
    pub fn new(capacity: usize, workers: Vec<String>) -> Result<TcpBackend> {
        Self::with_profile(CapacityProfile::uniform(capacity), workers)
    }

    /// Heterogeneous fleet: the planner sizes part `j` for virtual
    /// capacity `µ_{j mod L}`, and dispatch places each part only on
    /// workers whose advertised capacity can hold it.
    pub fn with_profile(profile: CapacityProfile, workers: Vec<String>) -> Result<TcpBackend> {
        if workers.is_empty() {
            return Err(Error::invalid(
                "tcp backend needs at least one worker address (--workers host:port[,host:port…])",
            ));
        }
        // Dedupe: a worker serves one coordinator connection at a time,
        // so a second connection to the same address would park in its
        // accept backlog holding a part in flight.
        let mut seen = std::collections::HashSet::new();
        let slots: Vec<Slot> = workers
            .into_iter()
            .filter(|addr| seen.insert(addr.clone()))
            .map(|addr| Slot { addr, capacity: None, dead: false, out_epoch: 0 })
            .collect();
        let count = slots.len();
        let fleet = Arc::new(Fleet {
            state: Mutex::new(FleetState {
                slots,
                jobs: VecDeque::new(),
                epoch: 0,
                dispatchers_alive: count,
                shutdown: None,
                stats: BTreeMap::new(),
                scope_stats: BTreeMap::new(),
                engine: EngineChoice::Native,
            }),
            cv: Condvar::new(),
        });
        for id in 0..count {
            let fleet = Arc::clone(&fleet);
            std::thread::Builder::new()
                .name(format!("hss-dispatch-{id}"))
                .spawn(move || dispatcher(fleet, id))
                .map_err(|e| Error::Worker(format!("spawn dispatcher: {e}")))?;
        }
        Ok(TcpBackend { profile, fleet, interner: SpecInterner::new() })
    }

    /// Set the compute engine requested in every worker handshake
    /// (`hss run --engine`). Takes effect for connections established
    /// after the call — set it before the first round. Workers pinned
    /// with their own `--engine` override it per connection.
    pub fn with_engine_choice(self, engine: EngineChoice) -> TcpBackend {
        {
            let mut st = self.fleet.lock();
            st.engine = engine;
        }
        self
    }

    /// Addresses this backend was configured with.
    pub fn worker_addrs(&self) -> Vec<String> {
        let st = self.fleet.lock();
        st.slots.iter().map(|s| s.addr.clone()).collect()
    }

    /// Ask every reachable worker to shut down (best effort; used by
    /// orderly teardown paths and tests). Blocks until the dispatcher
    /// threads have exited.
    pub fn shutdown_workers(&self) {
        let mut st = self.fleet.lock();
        st.shutdown = Some(ShutdownKind::Workers);
        self.fleet.cv.notify_all();
        while st.dispatchers_alive > 0 {
            st = self.fleet.wait(st);
        }
    }

    /// Shared body of [`Backend::open_round`] and
    /// [`Backend::open_round_scoped`]: publish the round as a fleet job,
    /// tagged with its attribution scope (if any).
    fn open_round_inner(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        round_seed: u64,
        scope: Option<u64>,
    ) -> Result<RoundSession> {
        // interned once per problem identity — NOT once per round
        let interned = self.interner.intern(problem)?;
        let comp_name = compressor_wire_name(compressor)?;
        let (tx, rx) = mpsc::channel();
        let mut st = self.fleet.lock();
        if st.shutdown.is_some() {
            return Err(Error::invalid("tcp backend is shut down"));
        }
        st.epoch += 1;
        let epoch = st.epoch;
        st.jobs.push_back(Job {
            epoch,
            ctx: Arc::new(RoundCtx {
                spec: interned.spec,
                spec_id: interned.id,
                spec_bytes: interned.bytes,
                comp_name,
                evals: problem.evals.clone(),
                tx,
                scope,
            }),
            queue: VecDeque::new(),
            in_flight: 0,
            closed: false,
            submitted: 0,
            last_err: None,
        });
        drop(st);
        // wake dispatchers now: connects and handshakes resolve while
        // the caller is still partitioning its first parts
        self.fleet.cv.notify_all();
        Ok(RoundSession::new(
            Box::new(TcpRoundSink {
                fleet: Arc::clone(&self.fleet),
                epoch,
                profile: self.profile.clone(),
                open: true,
            }),
            rx,
            self.profile.clone(),
            round_seed,
        ))
    }
}

impl Drop for TcpBackend {
    fn drop(&mut self) {
        // Wake parked dispatchers so they exit and close their worker
        // connections; don't block the dropping thread on it.
        let mut st = self.fleet.lock();
        if st.shutdown.is_none() {
            st.shutdown = Some(ShutdownKind::Quiet);
        }
        self.fleet.cv.notify_all();
    }
}

/// The session's handle into the fleet: streams parts into the job it
/// opened and seals or cancels it.
struct TcpRoundSink {
    fleet: Arc<Fleet>,
    epoch: u64,
    profile: CapacityProfile,
    open: bool,
}

impl RoundSink for TcpRoundSink {
    fn submit(&mut self, idx: usize, part: Vec<u32>, seed: u64) -> Result<()> {
        let cap = self.profile.virtual_capacity(idx);
        let mut st = self.fleet.lock();
        match st.jobs.iter_mut().find(|j| j.epoch == self.epoch) {
            Some(job) => {
                job.queue.push_back(PartTask { idx, part, cap, seed });
                job.submitted += 1;
            }
            // The round already failed (stall detector): the fatal
            // error is on the event channel; accepting further parts
            // quietly keeps the submitter's control flow simple.
            None => return Ok(()),
        }
        // a part that fits no live worker must fail the round now, not
        // hang it — and a fleet already known dead must fail immediately
        check_stall(&mut st);
        self.fleet.cv.notify_all();
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        if !self.open {
            return Ok(());
        }
        self.open = false;
        let mut st = self.fleet.lock();
        if let Some(pos) = st.jobs.iter().position(|j| j.epoch == self.epoch) {
            let complete = {
                let job = &mut st.jobs[pos];
                job.closed = true;
                job.queue.is_empty() && job.in_flight == 0
            };
            if complete {
                let _ = st.jobs.remove(pos);
            }
        }
        Ok(())
    }

    fn abort(&mut self) {
        if !self.open {
            return;
        }
        self.open = false;
        let mut st = self.fleet.lock();
        if let Some(pos) = st.jobs.iter().position(|j| j.epoch == self.epoch) {
            // queued parts are discarded; in-flight replies find the
            // job gone (epoch lookup) and are dropped on arrival
            let _ = st.jobs.remove(pos);
        }
        self.fleet.cv.notify_all();
    }
}

impl Backend for TcpBackend {
    fn name(&self) -> &'static str {
        // lint:allow(protocol-doc): backend display name for CLI/bench output, not a wire or trace token
        "tcp"
    }

    fn profile(&self) -> CapacityProfile {
        self.profile.clone()
    }

    fn worker_stats(&self) -> Vec<WorkerStats> {
        let st = self.fleet.lock();
        // BTreeMap iteration → sorted by worker address
        st.stats.values().cloned().collect()
    }

    fn open_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        round_seed: u64,
    ) -> Result<RoundSession> {
        self.open_round_inner(problem, compressor, round_seed, None)
    }

    fn open_round_scoped(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        round_seed: u64,
        scope: u64,
    ) -> Result<RoundSession> {
        self.open_round_inner(problem, compressor, round_seed, Some(scope))
    }

    fn worker_stats_scoped(&self, scope: u64) -> Vec<WorkerStats> {
        let st = self.fleet.lock();
        // (scope, addr) key order → the scope's slice is address-sorted
        st.scope_stats
            .range((scope, String::new())..)
            .take_while(|((s, _), _)| *s == scope)
            .map(|(_, w)| w.clone())
            .collect()
    }

    fn release_scope(&self, scope: u64) {
        let mut st = self.fleet.lock();
        st.scope_stats.retain(|(s, _), _| *s != scope);
    }

    fn shutdown_fleet(&self) {
        self.shutdown_workers();
    }
}

/// Fail any open round holding a queued part that can *never* complete:
/// every pending handshake has resolved and no live, in-epoch worker
/// advertises a capacity that holds it. Runs on state transitions
/// (part submitted, handshake failure, worker death, idle dispatcher
/// about to park) — the event-driven replacement for the old
/// sleep-poll loop's per-tick scan.
fn check_stall(st: &mut FleetState) {
    let epoch = st.epoch;
    // a slot that has never handshaken (and is not dead or sitting the
    // current epoch out) may still reveal a fitting capacity
    if st
        .slots
        .iter()
        .any(|s| !s.dead && s.out_epoch != epoch && s.capacity.is_none())
    {
        return;
    }
    let avail: Vec<usize> = st
        .slots
        .iter()
        .filter(|s| !s.dead && s.out_epoch != epoch)
        .filter_map(|s| s.capacity)
        .collect();
    let mut pos = 0;
    while pos < st.jobs.len() {
        let msg = {
            let job = &st.jobs[pos];
            job.queue
                .iter()
                .find(|t| !avail.iter().any(|&c| t.part.len() <= c))
                .map(|t| {
                    let detail = job
                        .last_err
                        .clone()
                        .unwrap_or_else(|| "no fitting worker".into());
                    // a scoped round names its job, so a multi-tenant
                    // stall report says *whose* round died
                    let whose = match job.ctx.scope {
                        Some(s) => format!("job scope {s}: "),
                        None => String::new(),
                    };
                    if avail.is_empty() {
                        format!(
                            "{whose}part {} of {} unprocessed — all workers lost ({detail})",
                            t.idx, job.submitted
                        )
                    } else {
                        format!(
                            "{whose}part {} of {} ({} items) exceeds every live worker's \
                             capacity ({detail})",
                            t.idx,
                            job.submitted,
                            t.part.len()
                        )
                    }
                })
        };
        match msg {
            Some(m) => {
                log::error(&format!("round stalled: {m}"));
                if let Some(job) = st.jobs.remove(pos) {
                    let _ = job.ctx.tx.send(Err(Error::Transport(m)));
                }
                // the next job shifted into `pos`; re-examine it
            }
            None => pos += 1,
        }
    }
}

/// Fold one completed part's worker-reported numbers into a stats
/// entry — shared between the global per-worker map and the per-scope
/// slice so the two can never drift. Sums accumulate; worker-side
/// cumulative gauges are latest-wins (an engine-silent pre-v6 frame
/// parses as "" and must not wipe the handshake's answer).
fn fold_done(entry: &mut WorkerStats, evals: u64, wall_ms: f64, telemetry: &Telemetry) {
    entry.parts += 1;
    entry.oracle_evals += evals;
    entry.busy_ms += wall_ms;
    entry.queue_wait_ms += telemetry.queue_wait_ms;
    // per-request batched-eval sums (v6 engine telemetry)
    entry.bulk_gain_calls += telemetry.bulk_gain_calls;
    entry.bulk_gain_candidates += telemetry.bulk_gain_candidates;
    if !telemetry.engine.is_empty() {
        entry.engine = telemetry.engine.clone();
    }
    entry.dataset_hits = telemetry.dataset_hits;
    entry.dataset_misses = telemetry.dataset_misses;
    entry.problem_hits = telemetry.problem_hits;
    entry.problem_misses = telemetry.problem_misses;
    entry.problem_evictions = telemetry.problem_evictions;
}

/// What a dispatcher decided to do with the lock held.
enum Step {
    /// Nothing to do until the fleet changes — park on the condvar.
    Park,
    /// No connection yet and a round wants workers: handshake.
    Connect(String),
    /// Claimed a part of the identified job.
    Dispatch(PartTask, Arc<RoundCtx>, u64),
    /// Backend is shutting down; optionally tell the worker to exit.
    Exit(Option<String>),
}

/// Everything one part's wire conversation can come back with.
enum WireOutcome {
    Done {
        items: Vec<u32>,
        value: f64,
        evals: u64,
        /// Worker-reported execute wall time (protocol v5).
        wall_ms: f64,
        /// Worker-side telemetry the response carried (protocol v5).
        telemetry: Telemetry,
    },
    /// Worker alive but the request failed (or spoke nonsense):
    /// retrying elsewhere cannot help, the round dies.
    Fatal(Error),
    /// Transport failure: the worker is lost, the part requeues.
    Lost(String),
}

/// Run one part's full request/response conversation over a warm
/// connection — interning the problem first if this connection has not
/// seen it. Returns the outcome plus whether a full spec was shipped
/// (charged to the round's spec-byte telemetry even if the part itself
/// subsequently failed: the bytes did cross the wire).
///
/// At most two attempts: a worker's per-connection id table is bounded,
/// so a long-lived connection may have evicted our id — its
/// `unknown problem id` error (the normative token, `docs/PROTOCOL.md`
/// §4.3) triggers one transparent re-intern before anything is treated
/// as fatal.
fn dispatch_part(conn: &mut WorkerConn, ctx: &RoundCtx, task: &PartTask) -> (WireOutcome, bool) {
    let mut spec_shipped = false;
    for attempt in 0..2 {
        if !conn.defined.contains(&ctx.spec_id) {
            let define =
                Request::DefineProblem { id: ctx.spec_id, problem: (*ctx.spec).clone() };
            match conn.roundtrip(&define) {
                Ok(Response::Defined { id }) if id == ctx.spec_id => {
                    conn.defined.insert(ctx.spec_id);
                    spec_shipped = true;
                }
                Ok(Response::Error { msg }) => {
                    return (
                        WireOutcome::Fatal(Error::Worker(format!("{}: {msg}", conn.addr))),
                        spec_shipped,
                    )
                }
                Ok(other) => {
                    return (
                        WireOutcome::Fatal(Error::Protocol(format!(
                            "{}: unexpected reply to define-problem: {other:?}",
                            conn.addr
                        ))),
                        spec_shipped,
                    )
                }
                Err(e) => return (WireOutcome::Lost(e.to_string()), spec_shipped),
            }
        }
        let request = Request::Compress {
            problem_id: ctx.spec_id,
            compressor: ctx.comp_name.clone(),
            part: task.part.clone(),
            cap: task.cap,
            seed: task.seed,
        };
        match conn.roundtrip(&request) {
            Ok(Response::Solution { items, value, evals, wall_ms, telemetry }) => {
                return (
                    WireOutcome::Done { items, value, evals, wall_ms, telemetry },
                    spec_shipped,
                )
            }
            // the worker evicted our id from its bounded table:
            // re-intern once, transparently
            Ok(Response::Error { msg })
                if attempt == 0 && msg.contains("unknown problem id") =>
            {
                conn.defined.remove(&ctx.spec_id);
            }
            // the worker is alive and rejected the job: retrying
            // elsewhere cannot help
            Ok(Response::Error { msg }) => {
                return (
                    WireOutcome::Fatal(Error::Worker(format!("{}: {msg}", conn.addr))),
                    spec_shipped,
                )
            }
            Ok(other) => {
                return (
                    WireOutcome::Fatal(Error::Protocol(format!(
                        "{}: unexpected reply {other:?}",
                        conn.addr
                    ))),
                    spec_shipped,
                )
            }
            Err(e) => return (WireOutcome::Lost(e.to_string()), spec_shipped),
        }
    }
    // unreachable in practice: attempt 1's unknown-id falls into the
    // fatal arm above, and every other path returns
    (
        WireOutcome::Fatal(Error::Protocol(format!(
            "{}: problem id survived two intern attempts without resolving",
            conn.addr
        ))),
        spec_shipped,
    )
}

/// Persistent per-worker dispatcher: parks on the fleet condvar, claims
/// capacity-fitting parts (oldest open round first) while any round is
/// in flight, exits on shutdown or when its worker dies mid-flight.
fn dispatcher(fleet: Arc<Fleet>, id: usize) {
    let mut conn: Option<WorkerConn> = None;
    let mut st = fleet.lock();
    loop {
        // decide under the lock… (reborrow the guard once so the
        // decision can take disjoint field borrows of the state)
        let step = {
            let stx: &mut FleetState = &mut st;
            if let Some(kind) = stx.shutdown {
                let notify = kind == ShutdownKind::Workers && !stx.slots[id].dead;
                Step::Exit(if notify { Some(stx.slots[id].addr.clone()) } else { None })
            } else if stx.slots[id].dead {
                Step::Exit(None)
            } else if stx.jobs.is_empty() || stx.slots[id].out_epoch == stx.epoch {
                Step::Park
            } else if conn.is_none() {
                Step::Connect(stx.slots[id].addr.clone())
            } else if let Some(my) = conn.as_ref() {
                let my_cap = my.capacity;
                let mut claimed = None;
                for job in stx.jobs.iter_mut() {
                    if let Some(pos) =
                        job.queue.iter().position(|t| t.part.len() <= my_cap)
                    {
                        if let Some(task) = job.queue.remove(pos) {
                            job.in_flight += 1;
                            claimed =
                                Some(Step::Dispatch(task, Arc::clone(&job.ctx), job.epoch));
                        }
                        break;
                    }
                }
                claimed.unwrap_or(Step::Park)
            } else {
                // conn.is_none() is handled by the Connect arm above, so
                // this is unreachable — parking is the safe fallback
                Step::Park
            }
        };

        // …act without it.
        match step {
            Step::Park => {
                // Work may remain but none of it fits this worker, or
                // peers hold it in flight (if their machine is lost the
                // part comes back to the queue — stay parked to steal
                // it). Before parking, make sure a part that fits NO
                // live worker fails its round instead of hanging it.
                check_stall(&mut st);
                st = fleet.wait(st);
            }
            Step::Connect(addr) => {
                let epoch = st.epoch;
                let engine = st.engine;
                drop(st);
                let attempt = WorkerConn::connect(&addr, engine);
                st = fleet.lock();
                match attempt {
                    Ok(c) => {
                        // register the capacity the moment the handshake
                        // resolves: peers' stall checks must see every
                        // successful worker before concluding "no fit"
                        st.slots[id].capacity = Some(c.capacity);
                        // record the granted engine up front so stats
                        // name it even before the first part completes
                        let addr = st.slots[id].addr.clone();
                        let entry =
                            st.stats.entry(addr.clone()).or_insert_with(|| WorkerStats {
                                addr,
                                ..WorkerStats::default()
                            });
                        entry.engine = c.engine.wire_name().to_string();
                        conn = Some(c);
                    }
                    Err(e) => {
                        // Never dispatched: not a requeue. The slot sits
                        // out the current epoch only — workers are
                        // allowed to come up late, so the next round
                        // retries the connect. (`dead` is reserved for
                        // mid-flight failures.)
                        log::debug(&format!(
                            "connect to {addr} failed ({e}); retrying next round"
                        ));
                        if st.epoch == epoch {
                            st.slots[id].out_epoch = epoch;
                            if let Some(job) = st.jobs.front_mut() {
                                job.last_err = Some(e.to_string());
                            }
                            check_stall(&mut st);
                        }
                    }
                }
                fleet.cv.notify_all();
            }
            Step::Dispatch(task, ctx, epoch) => {
                drop(st);
                let t0 = trace::now_us();
                let (outcome, spec_shipped) = match conn.as_mut() {
                    Some(c) => dispatch_part(c, &ctx, &task),
                    // Dispatch is only decided while conn is Some; if
                    // that invariant ever breaks, degrade to the
                    // lost-worker path (the part requeues) instead of
                    // panicking a dispatcher mid-fleet.
                    None => (
                        WireOutcome::Lost("dispatcher lost its connection".into()),
                        false,
                    ),
                };
                // payload-byte split (protocol v6): charged per worker
                // whatever the outcome — the bytes did cross the wire
                let (bytes_binary, bytes_json) =
                    conn.as_mut().map(WorkerConn::take_payload_bytes).unwrap_or((0, 0));
                st = fleet.lock();
                if bytes_binary > 0 || bytes_json > 0 {
                    let addr = st.slots[id].addr.clone();
                    let entry = st.stats.entry(addr.clone()).or_insert_with(|| WorkerStats {
                        addr: addr.clone(),
                        ..WorkerStats::default()
                    });
                    entry.payload_bytes_binary += bytes_binary;
                    entry.payload_bytes_json += bytes_json;
                    // per-scope attribution: the bytes moved on behalf
                    // of this round's job, whatever the outcome
                    if let Some(scope) = ctx.scope {
                        let entry = st
                            .scope_stats
                            .entry((scope, addr.clone()))
                            .or_insert_with(|| WorkerStats {
                                addr,
                                ..WorkerStats::default()
                            });
                        entry.payload_bytes_binary += bytes_binary;
                        entry.payload_bytes_json += bytes_json;
                    }
                }
                if spec_shipped {
                    // spec-byte telemetry rides the round's event
                    // stream, ahead of the part's own event
                    let _ = ctx
                        .tx
                        .send(Ok(PartEvent::SpecShipped { bytes: ctx.spec_bytes }));
                }
                // The round could have been aborted (stall detector,
                // cancelled speculation) while this reply was on the
                // wire; only account against a job still in the deque.
                let job_pos = st.jobs.iter().position(|j| j.epoch == epoch);
                match outcome {
                    WireOutcome::Done { items, value, evals, wall_ms, telemetry } => {
                        let addr = st.slots[id].addr.clone();
                        if trace::enabled() {
                            // receipt-anchored: the rpc span covers the
                            // wire conversation; the execute span ends at
                            // receipt and extends the worker-reported
                            // wall time into the past, clamped into the
                            // rpc window so same-track spans stay
                            // well-nested regardless of clock skew
                            let end = trace::now_us();
                            let rpc_us = end.saturating_sub(t0);
                            let exec_us = ((wall_ms * 1e3) as u64).min(rpc_us);
                            trace::span_at(
                                &addr,
                                "rpc",
                                t0,
                                rpc_us,
                                vec![("part", trace::ArgValue::U64(task.idx as u64))],
                            );
                            trace::span_at(
                                &addr,
                                "execute",
                                end - exec_us,
                                exec_us,
                                vec![
                                    ("part", trace::ArgValue::U64(task.idx as u64)),
                                    ("oracle_evals", trace::ArgValue::U64(evals)),
                                    (
                                        "queue_wait_ms",
                                        trace::ArgValue::F64(telemetry.queue_wait_ms),
                                    ),
                                ],
                            );
                        }
                        let entry =
                            st.stats.entry(addr.clone()).or_insert_with(|| WorkerStats {
                                addr: addr.clone(),
                                ..WorkerStats::default()
                            });
                        fold_done(entry, evals, wall_ms, &telemetry);
                        // the same completion folded into the round's
                        // attribution scope, so a serve job's summary
                        // covers exactly its own parts
                        if let Some(scope) = ctx.scope {
                            let entry = st
                                .scope_stats
                                .entry((scope, addr.clone()))
                                .or_insert_with(|| WorkerStats {
                                    addr: addr.clone(),
                                    ..WorkerStats::default()
                                });
                            fold_done(entry, evals, wall_ms, &telemetry);
                        }
                        // fold remote oracle work in BEFORE announcing
                        // completion, so a consumer reading the shared
                        // counter at the last event sees all of it
                        // relaxed: the Done send below is the publishing
                        // edge — channel synchronization makes the fold
                        // visible to whoever receives the event
                        ctx.evals.fetch_add(evals, Ordering::Relaxed);
                        let _ = ctx.tx.send(Ok(PartEvent::Done {
                            part: task.idx,
                            solution: Solution { items, value },
                        }));
                        if let Some(pos) = job_pos {
                            let complete = {
                                let job = &mut st.jobs[pos];
                                job.in_flight -= 1;
                                job.closed && job.queue.is_empty() && job.in_flight == 0
                            };
                            if complete {
                                let _ = st.jobs.remove(pos); // round complete
                            }
                        }
                    }
                    WireOutcome::Fatal(e) => {
                        let _ = ctx.tx.send(Err(e));
                        if let Some(pos) = job_pos {
                            let _ = st.jobs.remove(pos);
                        }
                    }
                    WireOutcome::Lost(detail) => {
                        // transport failure mid-flight: lose this
                        // machine for good, requeue the part for
                        // surviving workers that can hold it
                        log::warn(&format!(
                            "worker {} lost mid-flight ({detail}); requeueing part {}",
                            st.slots[id].addr, task.idx
                        ));
                        let _ = ctx.tx.send(Ok(PartEvent::MachineLost {
                            machine: st.slots[id].addr.clone(),
                            detail: detail.clone(),
                        }));
                        let _ = ctx.tx.send(Ok(PartEvent::Requeued {
                            part: task.idx,
                            reshipped_ids: task.part.len(),
                        }));
                        st.slots[id].dead = true;
                        st.slots[id].capacity = None;
                        conn = None;
                        if let Some(pos) = job_pos {
                            {
                                let job = &mut st.jobs[pos];
                                job.in_flight -= 1;
                                job.queue.push_back(task);
                                job.last_err = Some(detail);
                            }
                            check_stall(&mut st);
                        }
                    }
                }
                fleet.cv.notify_all();
            }
            Step::Exit(notify_addr) => {
                if let Some(addr) = notify_addr {
                    let engine = st.engine;
                    drop(st);
                    let c = match conn.take() {
                        Some(c) => Some(c),
                        None => WorkerConn::connect(&addr, engine).ok(),
                    };
                    if let Some(mut c) = c {
                        let _ = c.roundtrip(&Request::Shutdown);
                    }
                    st = fleet.lock();
                    st.slots[id].dead = true;
                }
                break;
            }
        }
    }
    st.dispatchers_alive -= 1;
    fleet.cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::LazyGreedy;
    use crate::dist::protocol::{recv_msg, send_msg};
    use std::net::TcpListener;

    #[test]
    fn rejects_empty_worker_list() {
        assert!(TcpBackend::new(100, vec![]).is_err());
        let p = CapacityProfile::parse("100,50").unwrap();
        assert!(TcpBackend::with_profile(p, vec![]).is_err());
    }

    #[test]
    fn duplicate_worker_addresses_collapse_to_one_slot() {
        // two connections to one single-connection worker would deadlock
        let b = TcpBackend::new(
            100,
            vec!["127.0.0.1:7070".into(), "127.0.0.1:7070".into(), "127.0.0.1:7071".into()],
        )
        .unwrap();
        assert_eq!(b.worker_addrs(), vec!["127.0.0.1:7070", "127.0.0.1:7071"]);
    }

    #[test]
    fn profile_is_exposed_and_capacity_is_the_largest_class() {
        let p = CapacityProfile::parse("500,200,200").unwrap();
        let b = TcpBackend::with_profile(p.clone(), vec!["127.0.0.1:7070".into()]).unwrap();
        assert_eq!(b.profile(), p);
        assert_eq!(b.capacity(), 500);
    }

    #[test]
    fn unreachable_workers_fail_with_transport_error() {
        // 127.0.0.1:1 — connect is refused immediately on any sane host
        let backend = TcpBackend::new(50, vec!["127.0.0.1:1".into()]).unwrap();
        // interning runs before dispatch, so the problem must be
        // wire-representable for the failure to reach the transport layer
        let p = crate::objectives::Problem::exemplar(
            crate::data::registry::load("csn-2k", 1).unwrap(),
            5,
            1,
        );
        let parts = vec![(0..10).collect::<Vec<u32>>()];
        let err = backend
            .run_round(&p, &crate::algorithms::LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
    }

    #[test]
    fn non_wire_problem_fails_before_connecting() {
        let backend = TcpBackend::new(50, vec!["127.0.0.1:1".into()]).unwrap();
        let p = crate::objectives::Problem::modular(vec![1.0; 20], 3, 0);
        let parts = vec![(0..10).collect::<Vec<u32>>()];
        let err = backend
            .run_round(&p, &crate::algorithms::LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err}");
    }

    /// Hand-rolled worker impostor: handshakes with an arbitrary
    /// advertised capacity (after `hello_delay_ms`, to script handshake
    /// ordering), interns problems per connection (protocol v4), then
    /// serves `serve_parts` compress requests before dropping the
    /// connection mid-flight (0 = die on first compress). Lets the
    /// dispatcher tests script exact failure points without real worker
    /// processes.
    fn spawn_impostor(capacity: usize, serve_parts: usize, hello_delay_ms: u64) -> String {
        spawn_impostor_opts(capacity, serve_parts, hello_delay_ms, false)
    }

    /// `forget_after_each`: wipe the interned-problem table after every
    /// compress reply — the pathological limit of the worker's bounded
    /// id table, forcing a re-intern before every single part.
    fn spawn_impostor_opts(
        capacity: usize,
        serve_parts: usize,
        hello_delay_ms: u64,
        forget_after_each: bool,
    ) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            // serve successive coordinator connections until the test ends
            for stream in listener.incoming() {
                let Ok(mut stream) = stream else { return };
                let mut served = 0usize;
                let mut problems: std::collections::HashMap<u64, ProblemSpec> =
                    std::collections::HashMap::new();
                loop {
                    let Ok(msg) = recv_msg(&mut stream) else { break };
                    let Ok(req) = Request::from_json(&msg) else { break };
                    match req {
                        Request::Hello { clock_ms, .. } => {
                            if hello_delay_ms > 0 {
                                std::thread::sleep(std::time::Duration::from_millis(
                                    hello_delay_ms,
                                ));
                            }
                            // impostors are JSON-only peers: declining
                            // the binary advertisement keeps every frame
                            // they exchange a plain JSON document
                            let hello = Response::Hello {
                                capacity,
                                clock_echo_ms: clock_ms,
                                payload: PayloadMode::Json,
                                engine: EngineChoice::Native,
                            };
                            if send_msg(&mut stream, &hello.to_json()).is_err() {
                                break;
                            }
                        }
                        Request::Shutdown => {
                            let _ = send_msg(&mut stream, &Response::Bye.to_json());
                            return;
                        }
                        Request::DefineProblem { id, problem } => {
                            problems.insert(id, problem);
                            if send_msg(&mut stream, &Response::Defined { id }.to_json())
                                .is_err()
                            {
                                break;
                            }
                        }
                        Request::Compress { problem_id, compressor, part, seed, .. } => {
                            if served >= serve_parts {
                                // die holding the part: drop the stream
                                // without replying
                                break;
                            }
                            served += 1;
                            let reply = match problems.get(&problem_id) {
                                // real compute so surviving-path tests
                                // stay bit-identical to local execution
                                Some(spec) => {
                                    let p = spec.materialize().unwrap();
                                    let comp =
                                        crate::dist::protocol::compressor_from_name(
                                            &compressor,
                                        )
                                        .unwrap();
                                    let sol = comp.compress(&p, &part, seed).unwrap();
                                    Response::Solution {
                                        items: sol.items,
                                        value: sol.value,
                                        evals: 0,
                                        wall_ms: 0.0,
                                        telemetry: Telemetry::default(),
                                    }
                                }
                                None => Response::Error {
                                    msg: format!(
                                        "unknown problem id {problem_id} — re-intern"
                                    ),
                                },
                            };
                            if send_msg(&mut stream, &reply.to_json()).is_err() {
                                break;
                            }
                            if forget_after_each {
                                problems.clear();
                            }
                        }
                    }
                }
            }
        });
        addr
    }

    fn wire_problem(k: usize) -> Problem {
        Problem::exemplar(crate::data::registry::load("csn-2k", 3).unwrap(), k, 3)
    }

    fn assert_bit_identical(a: &[Solution], b: &[Solution]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.items, y.items, "solutions diverged");
            assert_eq!(x.value.to_bits(), y.value.to_bits());
        }
    }

    #[test]
    fn stall_detector_fails_round_when_no_live_worker_fits_a_part() {
        // worker advertises µ=10; the round's only part has 20 items and
        // passes coordinator-side enforcement (profile says 50) — the
        // capacity-fit dispatcher must fail the round, not hang.
        let addr = spawn_impostor(10, usize::MAX, 0);
        let backend = TcpBackend::new(50, vec![addr]).unwrap();
        let p = wire_problem(5);
        let parts = vec![(0..20).collect::<Vec<u32>>()];
        let err = backend.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
        assert!(
            err.to_string().contains("exceeds every live worker's capacity"),
            "{err}"
        );
    }

    #[test]
    fn worker_death_holding_the_only_fitting_part_fails_with_requeue_accounting() {
        // big worker (µ=50) dies on its first compress while holding the
        // 20-item part; the small survivor (µ=10) cannot hold it — the
        // requeue must surface, then the stall detector must fail the
        // round instead of hanging.
        let big = spawn_impostor(50, 0, 0);
        let small = spawn_impostor(10, usize::MAX, 0);
        let backend = TcpBackend::new(50, vec![big, small]).unwrap();
        let p = wire_problem(5);
        let parts = vec![(0..20).collect::<Vec<u32>>()];
        let mut handle =
            backend.submit_round(&p, &LazyGreedy::new(), &parts, 2).unwrap();
        let mut requeued_parts = 0usize;
        let mut requeued_ids = 0usize;
        let mut lost = 0usize;
        let mut fatal = None;
        while let Some(ev) = handle.next_event() {
            match ev {
                Ok(PartEvent::Requeued { part, reshipped_ids }) => {
                    assert_eq!(part, 0);
                    requeued_parts += 1;
                    requeued_ids += reshipped_ids;
                }
                Ok(PartEvent::MachineLost { .. }) => lost += 1,
                Ok(PartEvent::Done { .. }) => panic!("part cannot complete"),
                Ok(PartEvent::Delay { .. }) => {}
                Ok(PartEvent::SpecShipped { .. }) => {}
                Err(e) => {
                    fatal = Some(e);
                    break;
                }
            }
        }
        assert_eq!(requeued_parts, 1, "the death must requeue the in-flight part");
        assert_eq!(requeued_ids, 20, "requeue re-ships the part's ids");
        assert_eq!(lost, 1);
        let err = fatal.expect("round must fail — no surviving worker fits the part");
        assert!(
            err.to_string().contains("exceeds every live worker's capacity"),
            "{err}"
        );
    }

    #[test]
    fn requeued_part_completes_on_a_fitting_survivor() {
        // The dying worker serves one part then drops its connection
        // holding the second; the survivor (same capacity, handshake
        // delayed so the dying worker deterministically claims two
        // parts first) steals the requeued part and the round still
        // matches local execution bit-exactly.
        let dying = spawn_impostor(40, 1, 0);
        let survivor = spawn_impostor(40, usize::MAX, 300);
        let backend = TcpBackend::new(40, vec![dying, survivor]).unwrap();
        let p = wire_problem(4);
        let parts: Vec<Vec<u32>> =
            (0..4).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 7).unwrap();
        assert_eq!(out.solutions.len(), 4);
        assert_eq!(out.requeued_parts, 1, "exactly one part rode the dying worker twice");
        assert_eq!(out.requeued_ids, 30);
        let local = crate::dist::LocalBackend::new(40)
            .run_round(&p, &LazyGreedy::new(), &parts, 7)
            .unwrap();
        assert_bit_identical(&out.solutions, &local.solutions);
    }

    #[test]
    fn capacity_fit_dispatch_routes_each_part_to_a_worker_that_holds_it() {
        // heterogeneous impostors: parts sized 30 can only run on the
        // µ=40 worker, parts sized 10 run anywhere; everything completes
        let big = spawn_impostor(40, usize::MAX, 0);
        let small = spawn_impostor(12, usize::MAX, 0);
        let profile = CapacityProfile::parse("40,12").unwrap();
        let backend = TcpBackend::with_profile(profile, vec![big, small]).unwrap();
        let p = wire_problem(4);
        let parts: Vec<Vec<u32>> = vec![
            (0..30).collect(),
            (30..40).collect(),
            (40..70).collect(),
            (70..80).collect(),
        ];
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 3).unwrap();
        assert_eq!(out.solutions.len(), 4);
        assert_eq!(out.requeued_parts, 0);
    }

    #[test]
    fn spec_ships_once_per_worker_connection_then_o1_ids() {
        let addr = spawn_impostor(60, usize::MAX, 0);
        let backend = TcpBackend::new(60, vec![addr]).unwrap();
        let p = wire_problem(4);
        let parts: Vec<Vec<u32>> =
            (0..3).map(|i| (i * 20..(i + 1) * 20).collect()).collect();
        let out0 = backend.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        assert!(out0.spec_bytes > 0, "round 0 must ship the spec once");
        // same problem, next round: the id alone crosses the wire
        let out1 = backend.run_round(&p, &LazyGreedy::new(), &parts, 2).unwrap();
        assert_eq!(out1.spec_bytes, 0, "later rounds must reuse the interned id");
        // a different problem identity interns (and ships) separately
        let p2 = wire_problem(5);
        let out2 = backend.run_round(&p2, &LazyGreedy::new(), &parts, 3).unwrap();
        assert!(out2.spec_bytes > 0);
        // …and the answers stay bit-identical to local throughout
        let local = crate::dist::LocalBackend::new(60)
            .run_round(&p, &LazyGreedy::new(), &parts, 2)
            .unwrap();
        assert_bit_identical(&out1.solutions, &local.solutions);
    }

    #[test]
    fn next_round_session_opens_while_previous_round_drains() {
        // one worker serves both rounds FIFO: round B's session opens
        // and submits while round A's parts are still queued/in flight,
        // and both rounds come back bit-identical to local execution
        let addr = spawn_impostor(50, usize::MAX, 0);
        let backend = TcpBackend::new(50, vec![addr]).unwrap();
        let p = wire_problem(4);
        let parts_a: Vec<Vec<u32>> =
            (0..3).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let parts_b: Vec<Vec<u32>> = vec![(0..40).collect(), (40..80).collect()];
        let mut sess_a = backend.open_round(&p, &LazyGreedy::new(), 11).unwrap();
        sess_a.submit_parts(&parts_a).unwrap();
        let mut sess_b = backend.open_round(&p, &LazyGreedy::new(), 12).unwrap();
        sess_b.submit_parts(&parts_b).unwrap();
        let out_a = sess_a.close().unwrap().finish().unwrap();
        let out_b = sess_b.close().unwrap().finish().unwrap();
        let local = crate::dist::LocalBackend::new(50);
        let la = local.run_round(&p, &LazyGreedy::new(), &parts_a, 11).unwrap();
        let lb = local.run_round(&p, &LazyGreedy::new(), &parts_b, 12).unwrap();
        assert_bit_identical(&out_a.solutions, &la.solutions);
        assert_bit_identical(&out_b.solutions, &lb.solutions);
        // the spec crossed the wire once for the whole pair of rounds
        assert!(out_a.spec_bytes > 0);
        assert_eq!(out_b.spec_bytes, 0);
    }

    #[test]
    fn evicted_problem_ids_reintern_transparently() {
        // a worker whose bounded id table forgets everything after every
        // compress: each subsequent part triggers the unknown-id error
        // and one transparent re-intern — the round must complete,
        // match local execution bit-exactly, and never count a requeue
        let addr = spawn_impostor_opts(50, usize::MAX, 0, true);
        let backend = TcpBackend::new(50, vec![addr]).unwrap();
        let p = wire_problem(4);
        let parts: Vec<Vec<u32>> =
            (0..3).map(|i| (i * 30..(i + 1) * 30).collect()).collect();
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 9).unwrap();
        assert_eq!(out.solutions.len(), 3);
        assert_eq!(out.requeued_parts, 0, "re-interning is not a requeue");
        assert!(out.spec_bytes > 0, "the re-shipped specs must be accounted");
        let local = crate::dist::LocalBackend::new(50)
            .run_round(&p, &LazyGreedy::new(), &parts, 9)
            .unwrap();
        assert_bit_identical(&out.solutions, &local.solutions);
    }

    #[test]
    fn worker_stats_accumulate_completed_parts() {
        let addr = spawn_impostor(60, usize::MAX, 0);
        let backend = TcpBackend::new(60, vec![addr.clone()]).unwrap();
        assert!(backend.worker_stats().is_empty(), "no parts dispatched yet");
        let p = wire_problem(4);
        let parts: Vec<Vec<u32>> =
            (0..3).map(|i| (i * 20..(i + 1) * 20).collect()).collect();
        backend.run_round(&p, &LazyGreedy::new(), &parts, 1).unwrap();
        let stats = backend.worker_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].addr, addr);
        assert_eq!(stats[0].parts, 3);
        // the impostor reports zero evals/wall and default telemetry
        assert_eq!(stats[0].oracle_evals, 0);
        assert_eq!(stats[0].dataset_misses, 0);
        // it also declined the binary advertisement, so every payload
        // byte on this connection lands in the JSON bucket (v6 split)
        assert!(stats[0].payload_bytes_json > 0, "JSON payload bytes must be charged");
        assert_eq!(stats[0].payload_bytes_binary, 0);
    }

    #[test]
    fn aborted_session_discards_parts_and_the_backend_stays_healthy() {
        let addr = spawn_impostor(50, usize::MAX, 0);
        let backend = TcpBackend::new(50, vec![addr]).unwrap();
        let p = wire_problem(4);
        let mut sess = backend.open_round(&p, &LazyGreedy::new(), 5).unwrap();
        sess.submit_part((0..30).collect()).unwrap();
        sess.abort();
        // a fresh round on the same backend runs normally
        let parts: Vec<Vec<u32>> = vec![(0..30).collect(), (30..60).collect()];
        let out = backend.run_round(&p, &LazyGreedy::new(), &parts, 6).unwrap();
        assert_eq!(out.solutions.len(), 2);
        let local = crate::dist::LocalBackend::new(50)
            .run_round(&p, &LazyGreedy::new(), &parts, 6)
            .unwrap();
        assert_bit_identical(&out.solutions, &local.solutions);
    }
}

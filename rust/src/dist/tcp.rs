//! Coordinator-side TCP backend: shard a round's parts over real
//! `hss worker` processes.
//!
//! Dispatch model: one I/O thread per worker pulls part indices from a
//! shared queue, sends a `compress` request over its persistent
//! connection, and waits for the reply. Workers advertise their fixed
//! capacity µ in the protocol-v3 handshake, and dispatch is
//! **capacity-fitting**: a worker only claims parts it can hold, so a
//! heterogeneous fleet (capacities 500, 200, 200…) serves a weighted
//! partition with every part on a machine big enough for it — work
//! stealing still applies among the workers a part fits. Transport
//! failures mark the worker dead and **requeue** the part for the
//! surviving workers *that can hold it* (counted in
//! [`RoundOutcome::requeued_parts`]); a part no surviving worker can
//! hold fails the round with a transport error. Application errors
//! reported by a worker (capacity violation, bad spec) abort the round —
//! retrying elsewhere cannot fix those.
//!
//! Determinism: per-machine seeds are positional
//! (`machine_seeds` in [`crate::dist`]), so *which* worker executes a part —
//! and any requeueing along the way — never changes the result. A
//! `TcpBackend` run returns bit-identical solutions to [`LocalBackend`]
//! for the same `(problem, parts, round_seed)` — including under
//! hereditary constraints, which cross the wire as construction recipes
//! ([`crate::constraints::spec::ConstraintSpec`]), and including
//! heterogeneous capacity profiles.
//!
//! [`LocalBackend`]: crate::dist::LocalBackend

use std::collections::VecDeque;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::algorithms::{Compressor, Solution};
use crate::coordinator::capacity::CapacityProfile;
use crate::dist::protocol::{
    compressor_wire_name, recv_msg, send_msg, ProblemSpec, Request, Response,
};
use crate::dist::{enforce_profile, machine_seeds, Backend, RoundOutcome};
use crate::error::{Error, Result};
use crate::objectives::Problem;

/// A persistent, handshaken connection to one worker process.
struct WorkerConn {
    addr: String,
    stream: TcpStream,
    /// Fixed capacity µ the worker advertised at handshake.
    capacity: usize,
}

impl WorkerConn {
    fn connect(addr: &str) -> Result<WorkerConn> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::transport(addr, format!("connect failed: {e}")))?;
        stream.set_nodelay(true).ok();
        // Handshake-only timeout: a worker busy with another coordinator
        // parks this connection in its accept backlog; fail fast so the
        // slot goes dead and other workers absorb the queue instead of
        // the round hanging. Cleared after the handshake — compression
        // time is legitimately unbounded.
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(10)))
            .ok();
        let mut conn = WorkerConn { addr: addr.to_string(), stream, capacity: 0 };
        let reply = conn.roundtrip(&Request::Hello)?;
        conn.stream.set_read_timeout(None).ok();
        match reply {
            Response::Hello { capacity } => {
                conn.capacity = capacity;
                Ok(conn)
            }
            other => Err(Error::Protocol(format!(
                "{addr}: expected hello, got {other:?}"
            ))),
        }
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        send_msg(&mut self.stream, &req.to_json())
            .map_err(|e| Error::transport(&self.addr, e))?;
        let msg = recv_msg(&mut self.stream).map_err(|e| Error::transport(&self.addr, e))?;
        Response::from_json(&msg)
    }
}

/// Per-worker slot: address plus the live connection (lazily created,
/// reused across rounds, dropped on failure).
struct Slot {
    addr: String,
    conn: Option<WorkerConn>,
    dead: bool,
}

/// Execution backend over real worker processes at `host:port` addresses.
pub struct TcpBackend {
    profile: CapacityProfile,
    slots: Mutex<Vec<Slot>>,
}

impl TcpBackend {
    /// Uniform fleet: every part may be up to µ items (the paper's
    /// setting). Connections are established lazily and connect
    /// failures are retried on the next round, so workers may come up
    /// after the backend is constructed — or even mid-run.
    pub fn new(capacity: usize, workers: Vec<String>) -> Result<TcpBackend> {
        Self::with_profile(CapacityProfile::uniform(capacity), workers)
    }

    /// Heterogeneous fleet: the planner sizes part `j` for virtual
    /// capacity `µ_{j mod L}`, and dispatch places each part only on
    /// workers whose advertised capacity can hold it.
    pub fn with_profile(profile: CapacityProfile, workers: Vec<String>) -> Result<TcpBackend> {
        if workers.is_empty() {
            return Err(Error::invalid(
                "tcp backend needs at least one worker address (--workers host:port[,host:port…])",
            ));
        }
        // Dedupe: a worker serves one coordinator connection at a time,
        // so a second connection to the same address would park in its
        // accept backlog holding a part in flight.
        let mut seen = std::collections::HashSet::new();
        let slots = workers
            .into_iter()
            .filter(|addr| seen.insert(addr.clone()))
            .map(|addr| Slot { addr, conn: None, dead: false })
            .collect();
        Ok(TcpBackend { profile, slots: Mutex::new(slots) })
    }

    /// Addresses this backend was configured with.
    pub fn worker_addrs(&self) -> Vec<String> {
        self.slots.lock().unwrap().iter().map(|s| s.addr.clone()).collect()
    }

    /// Ask every reachable worker to shut down (best effort; used by
    /// orderly teardown paths and tests).
    pub fn shutdown_workers(&self) {
        let mut slots = self.slots.lock().unwrap();
        for slot in slots.iter_mut() {
            let conn = match slot.conn.take() {
                Some(c) => Some(c),
                None if !slot.dead => WorkerConn::connect(&slot.addr).ok(),
                None => None,
            };
            if let Some(mut c) = conn {
                let _ = c.roundtrip(&Request::Shutdown);
            }
            slot.dead = true;
        }
    }
}

impl Backend for TcpBackend {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn profile(&self) -> CapacityProfile {
        self.profile.clone()
    }

    fn run_round(
        &self,
        problem: &Problem,
        compressor: &dyn Compressor,
        parts: &[Vec<u32>],
        round_seed: u64,
    ) -> Result<RoundOutcome> {
        enforce_profile(&self.profile, parts)?;
        let spec = ProblemSpec::from_problem(problem)?;
        let comp_name = compressor_wire_name(compressor)?;
        let seeds = machine_seeds(round_seed, parts.len());
        let caps: Vec<usize> = (0..parts.len())
            .map(|j| self.profile.virtual_capacity(j))
            .collect();

        let queue: Mutex<VecDeque<usize>> = Mutex::new((0..parts.len()).collect());
        let results: Mutex<Vec<Option<(Solution, u64)>>> =
            Mutex::new((0..parts.len()).map(|_| None).collect());
        let completed = AtomicUsize::new(0);
        let requeued = AtomicUsize::new(0);
        let requeued_ids = AtomicUsize::new(0);
        let fatal: Mutex<Option<Error>> = Mutex::new(None);
        let abort = AtomicBool::new(false);
        let last_transport_err: Mutex<Option<String>> = Mutex::new(None);
        // Advertised capacities of workers currently able to take work
        // (slot index → µ), maintained so idle workers can tell a part
        // that is merely *in flight elsewhere* from one that fits no
        // surviving worker. `connecting` counts threads whose first
        // handshake has not resolved yet: the no-fit check is only
        // meaningful once every capacity is known.
        let live_caps: Mutex<Vec<(usize, usize)>> = Mutex::new(Vec::new());
        let connecting = AtomicUsize::new(0);

        let mut slots = self.slots.lock().unwrap();
        // Pre-register capacities of connections kept warm from earlier
        // rounds; count the rest as still-connecting.
        for (id, slot) in slots.iter().enumerate() {
            if slot.dead {
                continue;
            }
            match &slot.conn {
                Some(c) => live_caps.lock().unwrap().push((id, c.capacity)),
                None => {
                    connecting.fetch_add(1, Ordering::SeqCst);
                }
            }
        }
        std::thread::scope(|scope| {
            for (id, slot) in slots.iter_mut().enumerate() {
                if slot.dead {
                    continue;
                }
                let queue = &queue;
                let results = &results;
                let completed = &completed;
                let requeued = &requeued;
                let requeued_ids = &requeued_ids;
                let fatal = &fatal;
                let abort = &abort;
                let last_transport_err = &last_transport_err;
                let live_caps = &live_caps;
                let connecting = &connecting;
                let spec = &spec;
                let comp_name = &comp_name;
                let seeds = &seeds;
                let caps = &caps;
                scope.spawn(move || {
                    loop {
                        if abort.load(Ordering::Relaxed) {
                            break;
                        }
                        // (re)connect lazily; the handshake reveals µ
                        if slot.conn.is_none() {
                            match WorkerConn::connect(&slot.addr) {
                                Ok(c) => {
                                    // register the capacity BEFORE counting
                                    // this handshake as resolved: a peer that
                                    // observes `connecting == 0` must see
                                    // every successful worker in `live_caps`,
                                    // or its no-fit check could spuriously
                                    // fail the round
                                    live_caps.lock().unwrap().push((id, c.capacity));
                                    slot.conn = Some(c);
                                    connecting.fetch_sub(1, Ordering::SeqCst);
                                }
                                Err(e) => {
                                    connecting.fetch_sub(1, Ordering::SeqCst);
                                    // Never dispatched: not a requeue. The
                                    // slot sits out the rest of this round
                                    // only — workers are allowed to come up
                                    // late, so the next round retries the
                                    // connect. (`dead` is reserved for
                                    // mid-flight failures.)
                                    *last_transport_err.lock().unwrap() = Some(e.to_string());
                                    break;
                                }
                            }
                        }
                        let my_cap = slot.conn.as_ref().unwrap().capacity;
                        // claim the first queued part this worker can hold
                        let job = {
                            let mut q = queue.lock().unwrap();
                            let pos = q.iter().position(|&i| parts[i].len() <= my_cap);
                            pos.and_then(|pos| q.remove(pos))
                        };
                        let Some(i) = job else {
                            if completed.load(Ordering::Relaxed) >= parts.len() {
                                break;
                            }
                            // Work remains but none of it fits this
                            // worker, or peers hold it in flight (if their
                            // machine is lost, the part comes back to the
                            // queue — stay alive to steal it). Once every
                            // handshake has resolved, a queued part that
                            // fits NO live worker can never complete: fail
                            // the round instead of spinning forever.
                            if connecting.load(Ordering::SeqCst) == 0 {
                                let q = queue.lock().unwrap();
                                let live = live_caps.lock().unwrap();
                                let orphan = q.iter().find(|&&j| {
                                    !live.iter().any(|&(_, cap)| parts[j].len() <= cap)
                                });
                                if let Some(&j) = orphan {
                                    let detail = last_transport_err
                                        .lock()
                                        .unwrap()
                                        .clone()
                                        .unwrap_or_else(|| "no fitting worker".into());
                                    *fatal.lock().unwrap() = Some(Error::Transport(format!(
                                        "part {j} of {} ({} items) exceeds every live \
                                         worker's capacity ({detail})",
                                        parts.len(),
                                        parts[j].len()
                                    )));
                                    abort.store(true, Ordering::Relaxed);
                                    break;
                                }
                            }
                            std::thread::sleep(std::time::Duration::from_millis(1));
                            continue;
                        };
                        let conn = slot.conn.as_mut().unwrap();
                        let request = Request::Compress {
                            problem: spec.clone(),
                            compressor: comp_name.clone(),
                            part: parts[i].clone(),
                            cap: caps[i],
                            seed: seeds[i],
                        };
                        match conn.roundtrip(&request) {
                            Ok(Response::Solution { items, value, evals, .. }) => {
                                results.lock().unwrap()[i] =
                                    Some((Solution { items, value }, evals));
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            Ok(Response::Error { msg }) => {
                                // the worker is alive and rejected the job:
                                // retrying elsewhere cannot help
                                *fatal.lock().unwrap() =
                                    Some(Error::Worker(format!("{}: {msg}", slot.addr)));
                                abort.store(true, Ordering::Relaxed);
                                break;
                            }
                            Ok(other) => {
                                *fatal.lock().unwrap() = Some(Error::Protocol(format!(
                                    "{}: unexpected reply {other:?}",
                                    slot.addr
                                )));
                                abort.store(true, Ordering::Relaxed);
                                break;
                            }
                            Err(e) => {
                                // transport failure mid-flight: lose the
                                // machine, requeue the part for surviving
                                // workers that can hold it
                                requeued.fetch_add(1, Ordering::Relaxed);
                                requeued_ids.fetch_add(parts[i].len(), Ordering::Relaxed);
                                queue.lock().unwrap().push_back(i);
                                *last_transport_err.lock().unwrap() = Some(e.to_string());
                                live_caps.lock().unwrap().retain(|&(sid, _)| sid != id);
                                slot.conn = None;
                                slot.dead = true;
                                break;
                            }
                        }
                    }
                });
            }
        });
        drop(slots);

        if let Some(e) = fatal.into_inner().unwrap() {
            return Err(e);
        }
        let results = results.into_inner().unwrap();
        let last_err = last_transport_err.into_inner().unwrap();
        let mut solutions = Vec::with_capacity(parts.len());
        let mut total_evals = 0u64;
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Some((sol, evals)) => {
                    solutions.push(sol);
                    total_evals += evals;
                }
                None => {
                    let detail =
                        last_err.unwrap_or_else(|| "no worker reachable".into());
                    return Err(Error::Transport(format!(
                        "part {i} of {} unprocessed — all workers lost ({detail})",
                        parts.len()
                    )));
                }
            }
        }
        // fold remote oracle work into the problem's shared counter so
        // the Table-1 evals metric stays comparable across backends
        problem
            .evals
            .fetch_add(total_evals, std::sync::atomic::Ordering::Relaxed);
        Ok(RoundOutcome {
            solutions,
            requeued_parts: requeued.into_inner(),
            requeued_ids: requeued_ids.into_inner(),
            sim_delay_ms: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_empty_worker_list() {
        assert!(TcpBackend::new(100, vec![]).is_err());
        let p = CapacityProfile::parse("100,50").unwrap();
        assert!(TcpBackend::with_profile(p, vec![]).is_err());
    }

    #[test]
    fn duplicate_worker_addresses_collapse_to_one_slot() {
        // two connections to one single-connection worker would deadlock
        let b = TcpBackend::new(
            100,
            vec!["127.0.0.1:7070".into(), "127.0.0.1:7070".into(), "127.0.0.1:7071".into()],
        )
        .unwrap();
        assert_eq!(b.worker_addrs(), vec!["127.0.0.1:7070", "127.0.0.1:7071"]);
    }

    #[test]
    fn profile_is_exposed_and_capacity_is_the_largest_class() {
        let p = CapacityProfile::parse("500,200,200").unwrap();
        let b = TcpBackend::with_profile(p.clone(), vec!["127.0.0.1:7070".into()]).unwrap();
        assert_eq!(b.profile(), p);
        assert_eq!(b.capacity(), 500);
    }

    #[test]
    fn unreachable_workers_fail_with_transport_error() {
        // 127.0.0.1:1 — connect is refused immediately on any sane host
        let backend = TcpBackend::new(50, vec!["127.0.0.1:1".into()]).unwrap();
        // from_problem runs before dispatch, so the problem must be
        // wire-representable for the failure to reach the transport layer
        let p = crate::objectives::Problem::exemplar(
            crate::data::registry::load("csn-2k", 1).unwrap(),
            5,
            1,
        );
        let parts = vec![(0..10).collect::<Vec<u32>>()];
        let err = backend
            .run_round(&p, &crate::algorithms::LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        assert!(matches!(err, Error::Transport(_)), "{err}");
    }

    #[test]
    fn non_wire_problem_fails_before_connecting() {
        let backend = TcpBackend::new(50, vec!["127.0.0.1:1".into()]).unwrap();
        let p = crate::objectives::Problem::modular(vec![1.0; 20], 3, 0);
        let parts = vec![(0..10).collect::<Vec<u32>>()];
        let err = backend
            .run_round(&p, &crate::algorithms::LazyGreedy::new(), &parts, 0)
            .unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err}");
    }
}

//! The `hss worker` runtime: one fixed-capacity machine as a process.
//!
//! A worker binds a TCP listener, prints `hss-worker listening on
//! <addr>` on stdout (so launchers binding port 0 can discover the real
//! port), then serves coordinator connections one at a time: handshake,
//! a stream of compress requests, and an optional orderly shutdown.
//!
//! The worker is **stateless across connections** except for caches: it
//! reconstructs problems from [`ProblemSpec`]s (deterministic dataset
//! generation — the coordinator ships ids, never rows) and memoizes
//! loaded datasets per `(name, seed)` so a multi-round run pays dataset
//! generation once. Capacity is enforced per request: a part larger
//! than the worker's own µ *or* the planned virtual machine capacity
//! shipped with the request (protocol v3) is answered with an error
//! response, never silently spilled. The worker advertises its µ in the
//! handshake so heterogeneous coordinators dispatch by capacity fit.

use std::collections::HashMap;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::constraints::Constraint;

use crate::algorithms::Compressor as _;
use crate::data::DatasetRef;
use crate::dist::protocol::{recv_msg, send_msg, ProblemSpec, Request, Response};
use crate::error::{Error, Result};
use crate::objectives::Problem;

/// Worker process configuration.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Bind address, e.g. `127.0.0.1:7070`; port 0 picks a free port.
    pub listen: String,
    /// Fixed machine capacity µ.
    pub capacity: usize,
    /// Artificial per-request latency in milliseconds (`--straggle-ms`)
    /// — the straggler-injection knob for dispatch benches and
    /// robustness experiments over *real* workers. 0 (the default)
    /// means an honest worker.
    pub straggle_ms: u64,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig { listen: "127.0.0.1:7070".into(), capacity: 200, straggle_ms: 0 }
    }
}

/// Run the worker loop. Blocks serving coordinators until a `shutdown`
/// request arrives (then returns `Ok`) or the listener dies.
pub fn serve(cfg: &WorkerConfig) -> Result<()> {
    let listener = bind(cfg)?;
    let local = listener.local_addr()?;
    // Discovery line for launchers/tests; flush because stdout is
    // block-buffered when piped.
    println!("hss-worker listening on {local} (capacity {})", cfg.capacity);
    std::io::stdout().flush().ok();
    serve_on(listener, cfg)
}

/// Host a worker on a background thread over an ephemeral (or explicit)
/// port — the in-process variant of `hss worker` used by benches and
/// tests that need a real protocol-speaking peer without a process
/// boundary. Returns the bound address; the thread serves until a
/// `shutdown` request arrives (e.g. [`crate::dist::TcpBackend::shutdown_workers`]).
pub fn spawn_in_process(cfg: WorkerConfig) -> Result<String> {
    let listener = bind(&cfg)?;
    let addr = listener.local_addr()?.to_string();
    std::thread::Builder::new()
        .name(format!("hss-worker-{addr}"))
        .spawn(move || {
            if let Err(e) = serve_on(listener, &cfg) {
                eprintln!("hss-worker({addr}): {e}");
            }
        })
        .map_err(|e| Error::Worker(format!("spawn in-process worker: {e}")))?;
    Ok(addr)
}

fn bind(cfg: &WorkerConfig) -> Result<TcpListener> {
    if cfg.capacity == 0 {
        return Err(Error::invalid("worker capacity must be positive"));
    }
    TcpListener::bind(&cfg.listen)
        .map_err(|e| Error::transport(&cfg.listen, format!("bind failed: {e}")))
}

fn serve_on(listener: TcpListener, cfg: &WorkerConfig) -> Result<()> {
    let mut cache = DatasetCache::default();
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("hss-worker: accept failed: {e}");
                continue;
            }
        };
        match serve_connection(stream, cfg, &mut cache) {
            Ok(ConnectionEnd::Shutdown) => return Ok(()),
            Ok(ConnectionEnd::Disconnected) => {}
            Err(e) => eprintln!("hss-worker: connection error: {e}"),
        }
    }
    Ok(())
}

/// Why a coordinator connection ended.
enum ConnectionEnd {
    /// Peer closed the stream (normal between runs).
    Disconnected,
    /// Peer requested process shutdown.
    Shutdown,
}

/// Loaded datasets memoized per dataset-spec cache key — the expensive
/// part of materializing a spec. Problems themselves are rebuilt per
/// request (cheap: a subsample draw + constraint build), so a sweep
/// over k / eval_m / constraints shares one matrix Arc instead of
/// duplicating n·d floats per distinct spec. A small bound keeps a
/// long-lived worker from pinning matrices for every dataset it has
/// ever seen.
#[derive(Default)]
struct DatasetCache {
    datasets: HashMap<(String, u64), DatasetRef>,
    /// Built constraints memoized per `(dataset key, constraint spec)` —
    /// constraint tables (row-norm weights, group maps) are O(n·d) to
    /// materialize and identical for every part of a round.
    constraints: HashMap<(String, u64, String), Arc<dyn Constraint>>,
}

impl DatasetCache {
    const MAX_DATASETS: usize = 8;
    const MAX_CONSTRAINTS: usize = 32;

    fn problem(&mut self, spec: &ProblemSpec) -> Result<Problem> {
        let key = spec.dataset.cache_key();
        if !self.datasets.contains_key(&key) {
            if self.datasets.len() >= Self::MAX_DATASETS {
                self.datasets.clear();
                self.constraints.clear();
            }
            let ds = spec.dataset.load()?;
            self.datasets.insert(key.clone(), ds);
        }
        let ds = self.datasets.get(&key).unwrap().clone();
        // Memoize only generator-spec'd constraints: their JSON key is a
        // few bytes and their build is the O(n·d) cost worth saving. For
        // explicit tables the key itself would be O(n) per request and
        // the build is a validate+clone — cheaper to just rebuild.
        let constraint = if spec.constraint.has_explicit_table() {
            spec.constraint.build(&ds)?
        } else {
            let ckey = (key.0, key.1, spec.constraint.to_json().to_string());
            match self.constraints.get(&ckey) {
                Some(c) => c.clone(),
                None => {
                    if self.constraints.len() >= Self::MAX_CONSTRAINTS {
                        self.constraints.clear();
                    }
                    let c = spec.constraint.build(&ds)?;
                    self.constraints.insert(ckey, c.clone());
                    c
                }
            }
        };
        spec.materialize_with(ds, constraint)
    }
}

fn serve_connection(
    mut stream: TcpStream,
    cfg: &WorkerConfig,
    cache: &mut DatasetCache,
) -> Result<ConnectionEnd> {
    stream.set_nodelay(true).ok();
    loop {
        let msg = match recv_msg(&mut stream) {
            Ok(m) => m,
            // EOF / reset: coordinator went away, wait for the next one
            Err(Error::Io(_)) => return Ok(ConnectionEnd::Disconnected),
            Err(e) => return Err(e),
        };
        let request = match Request::from_json(&msg) {
            Ok(r) => r,
            Err(e) => {
                // protocol violation: tell the peer, drop the connection
                send_msg(&mut stream, &Response::Error { msg: e.to_string() }.to_json()).ok();
                return Err(e);
            }
        };
        let reply = match request {
            Request::Hello => Response::Hello { capacity: cfg.capacity },
            Request::Shutdown => {
                send_msg(&mut stream, &Response::Bye.to_json()).ok();
                return Ok(ConnectionEnd::Shutdown);
            }
            Request::Compress { problem, compressor, part, cap, seed } => {
                // injected straggler latency: charged per request, before
                // the compute, like a slow or overloaded machine
                if cfg.straggle_ms > 0 {
                    std::thread::sleep(std::time::Duration::from_millis(cfg.straggle_ms));
                }
                handle_compress(cfg.capacity, cache, &problem, &compressor, &part, cap, seed)
                    .unwrap_or_else(|e| Response::Error { msg: e.to_string() })
            }
        };
        send_msg(&mut stream, &reply.to_json())?;
    }
}

fn handle_compress(
    capacity: usize,
    cache: &mut DatasetCache,
    spec: &ProblemSpec,
    compressor_name: &str,
    part: &[u32],
    cap: usize,
    seed: u64,
) -> Result<Response> {
    if part.len() > capacity {
        return Err(Error::CapacityExceeded {
            capacity,
            got: part.len(),
            ctx: " (worker-side enforcement)".into(),
        });
    }
    // the coordinator sized this part for a virtual machine of capacity
    // `cap` (protocol v3); a part above it means the partitioner
    // overfilled a machine class — reject rather than mask the bug
    if part.len() > cap {
        return Err(Error::CapacityExceeded {
            capacity: cap,
            got: part.len(),
            ctx: " (worker-side enforcement of the planned virtual machine capacity)".into(),
        });
    }
    let compressor = crate::dist::protocol::compressor_from_name(compressor_name)?;
    let problem = cache.problem(spec)?;
    problem.check_ids(part)?;
    let evals_before = problem.eval_count();
    let t0 = std::time::Instant::now();
    let solution = compressor.compress(&problem, part, seed)?;
    Ok(Response::Solution {
        items: solution.items,
        value: solution.value,
        evals: problem.eval_count() - evals_before,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::spec::ConstraintSpec;
    use crate::data::spec::DatasetSpec;
    use crate::dist::protocol;
    use std::net::TcpStream;

    /// In-process worker on an ephemeral port (the *process*-boundary
    /// version lives in rust/tests/dist_integration.rs).
    fn spawn_worker(capacity: usize) -> (std::thread::JoinHandle<Result<()>>, String) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let mut cache = DatasetCache::default();
            let cfg = WorkerConfig { capacity, ..WorkerConfig::default() };
            let (stream, _) = listener.accept().map_err(Error::Io)?;
            match serve_connection(stream, &cfg, &mut cache)? {
                ConnectionEnd::Shutdown | ConnectionEnd::Disconnected => Ok(()),
            }
        });
        (handle, addr)
    }

    #[test]
    fn worker_compresses_and_shuts_down() {
        let (handle, addr) = spawn_worker(64);
        let mut stream = TcpStream::connect(&addr).unwrap();

        protocol::send_msg(&mut stream, &Request::Hello.to_json()).unwrap();
        let hello = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        assert_eq!(hello, Response::Hello { capacity: 64 });

        let spec = ProblemSpec {
            dataset: DatasetSpec::Registry { name: "csn-2k".into(), seed: 42 },
            objective: "exemplar".into(),
            k: 5,
            seed: 42,
            eval_m: 2000,
            h2: 0.0,
            sigma2: 0.0,
            constraint: ConstraintSpec::Cardinality { k: 5 },
        };
        let req = Request::Compress {
            problem: spec.clone(),
            compressor: "greedy".into(),
            part: (0..50).collect(),
            cap: 64,
            seed: 1,
        };
        protocol::send_msg(&mut stream, &req.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        match resp {
            Response::Solution { items, value, evals, .. } => {
                assert_eq!(items.len(), 5);
                assert!(items.iter().all(|&i| i < 50), "leaked items: {items:?}");
                assert!(value > 0.0);
                assert!(evals > 0, "worker must report oracle evals");
                // bit-identical to compressing locally
                let local = crate::algorithms::LazyGreedy::new();
                let p = spec.materialize().unwrap();
                let want = crate::algorithms::Compressor::compress(
                    &local,
                    &p,
                    &(0..50).collect::<Vec<u32>>(),
                    1,
                )
                .unwrap();
                assert_eq!(items, want.items);
                assert_eq!(value.to_bits(), want.value.to_bits());
            }
            other => panic!("expected solution, got {other:?}"),
        }

        // a hereditary constraint rebuilt from its wire recipe: the
        // worker's answer matches local compression bit-exactly
        let knap_spec = ProblemSpec {
            constraint: ConstraintSpec::Knapsack {
                budget: 250.0,
                k: 5,
                weights: crate::constraints::spec::WeightSpec::RowNorm2,
            },
            ..spec.clone()
        };
        let req = Request::Compress {
            problem: knap_spec.clone(),
            compressor: "greedy".into(),
            part: (0..50).collect(),
            cap: 64,
            seed: 3,
        };
        protocol::send_msg(&mut stream, &req.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        match resp {
            Response::Solution { items, value, .. } => {
                let p = knap_spec.materialize().unwrap();
                assert!(p.constraint.is_feasible(&items, &p.dataset));
                let want = crate::algorithms::Compressor::compress(
                    &crate::algorithms::LazyGreedy::new(),
                    &p,
                    &(0..50).collect::<Vec<u32>>(),
                    3,
                )
                .unwrap();
                assert_eq!(items, want.items);
                assert_eq!(value.to_bits(), want.value.to_bits());
            }
            other => panic!("expected solution, got {other:?}"),
        }

        // capacity enforcement on the worker side
        let too_big = Request::Compress {
            problem: spec.clone(),
            compressor: "greedy".into(),
            part: (0..65).collect(),
            cap: 64,
            seed: 2,
        };
        protocol::send_msg(&mut stream, &too_big.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        match resp {
            Response::Error { msg } => {
                assert!(msg.contains("capacity"), "unexpected msg: {msg}")
            }
            other => panic!("expected error, got {other:?}"),
        }

        // v3: the planned virtual machine capacity is enforced too — a
        // part that fits the worker's physical µ but overflows the
        // machine class it was sized for is a partitioner bug
        let over_virtual = Request::Compress {
            problem: spec,
            compressor: "greedy".into(),
            part: (0..30).collect(),
            cap: 20,
            seed: 2,
        };
        protocol::send_msg(&mut stream, &over_virtual.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        match resp {
            Response::Error { msg } => {
                assert!(msg.contains("virtual machine capacity"), "unexpected msg: {msg}")
            }
            other => panic!("expected error, got {other:?}"),
        }

        protocol::send_msg(&mut stream, &Request::Shutdown.to_json()).unwrap();
        let bye = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        assert_eq!(bye, Response::Bye);
        handle.join().unwrap().unwrap();
    }
}

//! The `hss worker` runtime: one fixed-capacity machine as a process.
//!
//! A worker binds a TCP listener, prints `hss-worker listening on
//! <addr>` on stdout (so launchers binding port 0 can discover the real
//! port), then serves coordinator connections one at a time: handshake,
//! a stream of compress requests, and an optional orderly shutdown.
//!
//! The worker is **stateless across connections** except for caches: it
//! reconstructs problems from [`ProblemSpec`]s (deterministic dataset
//! generation — the coordinator ships ids, never rows) and memoizes
//! loaded datasets per `(name, seed)` so a multi-round run pays dataset
//! generation once. Problems arrive **interned** (protocol v4): a
//! `define-problem` request registers a spec under a short id on the
//! current connection, and every `compress` request names that id —
//! the spec crosses the wire once per connection, not once per part.
//! The id table dies with the connection, so a reconnecting
//! coordinator simply re-interns; a `compress` naming an unknown id is
//! answered with an error telling the coordinator to do exactly that.
//! Capacity is enforced per request: a part larger than the worker's
//! own µ *or* the planned virtual machine capacity shipped with the
//! request (protocol v3) is answered with an error response, never
//! silently spilled. The worker advertises its µ in the handshake so
//! heterogeneous coordinators dispatch by capacity fit.
//!
//! Payload encoding (protocol v6) is negotiated per connection in the
//! same handshake: when the coordinator advertises `payload: "binary"`
//! and the worker was not pinned to `--payload json`, the hello reply
//! echoes `binary` and every later frame on the connection may carry
//! blob sections; otherwise the connection stays pure JSON. Mixed
//! fleets are therefore fine — each connection negotiates
//! independently.
//!
//! The compute engine serving a connection is negotiated in the same
//! handshake (v6, additive token): a worker pinned with `--engine`
//! answers with its own choice regardless of the request; an unpinned
//! worker follows the coordinator's `engine` token, defaulting to the
//! native batched kernel backend for engine-silent peers. Each
//! solution's telemetry names the engine that served it.

use std::collections::HashMap;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::constraints::Constraint;

use crate::algorithms::Compressor as _;
use crate::data::DatasetRef;
use crate::dist::protocol::{
    read_frame, send_response, PayloadMode, ProblemSpec, Request, Response, Telemetry,
};
use crate::error::{Error, Result};
use crate::objectives::Problem;
use crate::runtime::{Engine, EngineChoice};
use crate::util::log;

/// Worker process configuration.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Bind address, e.g. `127.0.0.1:7070`; port 0 picks a free port.
    pub listen: String,
    /// Fixed machine capacity µ.
    pub capacity: usize,
    /// Artificial per-request latency in milliseconds (`--straggle-ms`)
    /// — the straggler-injection knob for dispatch benches and
    /// robustness experiments over *real* workers. 0 (the default)
    /// means an honest worker.
    pub straggle_ms: u64,
    /// The richest payload encoding this worker will negotiate
    /// (`--payload`). [`PayloadMode::Binary`] (the default) lets
    /// binary-advertising coordinators ship blob payloads;
    /// [`PayloadMode::Json`] pins every connection to pure JSON — the
    /// knob behind mixed-fleet tests and wire debugging.
    pub payload: PayloadMode,
    /// Compute engine pin (`--engine`). `Some(choice)` serves every
    /// connection with that engine regardless of what the coordinator
    /// requests; `None` (the default) follows the coordinator's hello
    /// token, falling back to [`EngineChoice::Native`] for
    /// engine-silent peers. The hello reply always states the engine
    /// actually in effect.
    pub engine: Option<EngineChoice>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            listen: "127.0.0.1:7070".into(),
            capacity: 200,
            straggle_ms: 0,
            payload: PayloadMode::Binary,
            engine: None,
        }
    }
}

/// Run the worker loop. Blocks serving coordinators until a `shutdown`
/// request arrives (then returns `Ok`) or the listener dies.
pub fn serve(cfg: &WorkerConfig) -> Result<()> {
    let listener = bind(cfg)?;
    let local = listener.local_addr()?;
    // Discovery line for launchers/tests; flush because stdout is
    // block-buffered when piped.
    // lint:allow(logging): the listen line is the worker CLI's machine-readable contract — launchers and tests parse it off stdout, so it must not go through the leveled logger
    println!("hss-worker listening on {local} (capacity {})", cfg.capacity);
    std::io::stdout().flush().ok();
    serve_on(listener, cfg)
}

/// Host a worker on a background thread over an ephemeral (or explicit)
/// port — the in-process variant of `hss worker` used by benches and
/// tests that need a real protocol-speaking peer without a process
/// boundary. Returns the bound address; the thread serves until a
/// `shutdown` request arrives (e.g. [`crate::dist::TcpBackend::shutdown_workers`]).
pub fn spawn_in_process(cfg: WorkerConfig) -> Result<String> {
    let listener = bind(&cfg)?;
    let addr = listener.local_addr()?.to_string();
    std::thread::Builder::new()
        .name(format!("hss-worker-{addr}"))
        .spawn(move || {
            if let Err(e) = serve_on(listener, &cfg) {
                log::error(&format!("hss-worker({addr}): {e}"));
            }
        })
        .map_err(|e| Error::Worker(format!("spawn in-process worker: {e}")))?;
    Ok(addr)
}

fn bind(cfg: &WorkerConfig) -> Result<TcpListener> {
    if cfg.capacity == 0 {
        return Err(Error::invalid("worker capacity must be positive"));
    }
    TcpListener::bind(&cfg.listen)
        .map_err(|e| Error::transport(&cfg.listen, format!("bind failed: {e}")))
}

fn serve_on(listener: TcpListener, cfg: &WorkerConfig) -> Result<()> {
    let mut cache = DatasetCache::default();
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log::warn(&format!("hss-worker: accept failed: {e}"));
                continue;
            }
        };
        match serve_connection(stream, cfg, &mut cache) {
            Ok(ConnectionEnd::Shutdown) => return Ok(()),
            Ok(ConnectionEnd::Disconnected) => {
                log::debug("hss-worker: coordinator disconnected");
            }
            Err(e) => log::warn(&format!("hss-worker: connection error: {e}")),
        }
    }
    Ok(())
}

/// Why a coordinator connection ended.
enum ConnectionEnd {
    /// Peer closed the stream (normal between runs).
    Disconnected,
    /// Peer requested process shutdown.
    Shutdown,
}

/// Loaded datasets memoized per dataset-spec cache key — the expensive
/// part of materializing a spec. Problems themselves are rebuilt per
/// request (cheap: a subsample draw + constraint build), so a sweep
/// over k / eval_m / constraints shares one matrix Arc instead of
/// duplicating n·d floats per distinct spec. A small bound keeps a
/// long-lived worker from pinning matrices for every dataset it has
/// ever seen.
///
/// Eviction is **single-victim**: when the cache is full, only the most
/// recently *admitted* dataset is dropped (and only *its* memoized
/// constraints — survivors keep theirs). Keeping the long-resident
/// working set stable means a cyclic sweep over `MAX_DATASETS + 1`
/// datasets keeps hitting on all but one slot, where evict-newest
/// thrashes exactly one slot and LRU (or the old wipe-everything) would
/// miss on every single request.
#[derive(Default)]
struct DatasetCache {
    datasets: HashMap<(String, u64), DatasetRef>,
    /// Admission order of the resident datasets (newest last) — the
    /// eviction policy's bookkeeping.
    admitted: Vec<(String, u64)>,
    /// Built constraints memoized per `(dataset key, constraint spec)` —
    /// constraint tables (row-norm weights, group maps) are O(n·d) to
    /// materialize and identical for every part of a round.
    constraints: HashMap<(String, u64, String), Arc<dyn Constraint>>,
    /// Cache telemetry (also what the eviction regression test asserts).
    dataset_hits: u64,
    dataset_misses: u64,
}

impl DatasetCache {
    const MAX_DATASETS: usize = 8;
    const MAX_CONSTRAINTS: usize = 32;

    fn problem(&mut self, spec: &ProblemSpec) -> Result<Problem> {
        let key = spec.dataset.cache_key();
        // hit/miss branches each produce the Arc directly — no
        // post-insert re-lookup (and no unwrap on it) needed
        let ds = match self.datasets.get(&key) {
            Some(ds) => {
                self.dataset_hits += 1;
                ds.clone()
            }
            None => {
                self.dataset_misses += 1;
                if self.datasets.len() >= Self::MAX_DATASETS {
                    if let Some(victim) = self.admitted.pop() {
                        self.datasets.remove(&victim);
                        // drop only the victim's constraints; survivors keep
                        // their O(n·d) tables
                        self.constraints
                            .retain(|k, _| !(k.0 == victim.0 && k.1 == victim.1));
                    }
                }
                let ds = spec.dataset.load()?;
                self.datasets.insert(key.clone(), ds.clone());
                self.admitted.push(key.clone());
                ds
            }
        };
        // Memoize only generator-spec'd constraints: their JSON key is a
        // few bytes and their build is the O(n·d) cost worth saving. For
        // explicit tables the key itself would be O(n) per request and
        // the build is a validate+clone — cheaper to just rebuild.
        let constraint = if spec.constraint.has_explicit_table() {
            spec.constraint.build(&ds)?
        } else {
            let ckey = (key.0, key.1, spec.constraint.to_json().to_string());
            match self.constraints.get(&ckey) {
                Some(c) => c.clone(),
                None => {
                    if self.constraints.len() >= Self::MAX_CONSTRAINTS {
                        // single-victim here too: one arbitrary entry
                        // goes, the rest of the working set survives
                        if let Some(victim) = self.constraints.keys().next().cloned() {
                            self.constraints.remove(&victim);
                        }
                    }
                    let c = spec.constraint.build(&ds)?;
                    self.constraints.insert(ckey, c.clone());
                    c
                }
            }
        };
        spec.materialize_with(ds, constraint)
    }
}

/// Bound on the per-connection interned-problem table: like the
/// [`DatasetCache`] caps, this keeps a long-lived warm connection from
/// pinning every spec it has ever seen (`Explicit` constraint tables
/// make a spec O(n)). Eviction is safe because the coordinator
/// re-interns transparently when a `compress` names an evicted id.
const MAX_PROBLEMS: usize = 64;

fn serve_connection(
    mut stream: TcpStream,
    cfg: &WorkerConfig,
    cache: &mut DatasetCache,
) -> Result<ConnectionEnd> {
    stream.set_nodelay(true).ok();
    // Interned problems (protocol v4), scoped to THIS connection: the
    // table dying with the stream is what makes re-interning after a
    // reconnect automatic instead of a coordination problem.
    let mut problems: HashMap<u64, ProblemSpec> = HashMap::new();
    // Problem-id-table telemetry (protocol v5), connection-scoped like
    // the table itself.
    let mut problem_hits = 0u64;
    let mut problem_misses = 0u64;
    let mut problem_evictions = 0u64;
    // Payload mode for THIS connection (protocol v6): JSON until the
    // handshake negotiates otherwise, so pre-negotiation frames are
    // decoded exactly as a v5-shaped peer would send them.
    let mut mode = PayloadMode::Json;
    // Compute engine for THIS connection: a pinned worker serves its
    // own choice, otherwise the coordinator's hello token decides
    // (absent → native). Built lazily on the first compress so hellos
    // stay cheap and a connection that never compresses never pays
    // engine startup.
    let mut engine_choice = cfg.engine.unwrap_or_default();
    let mut engine: Option<Arc<dyn Engine>> = None;
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            // EOF / reset: coordinator went away, wait for the next one
            Err(Error::Io(_)) => return Ok(ConnectionEnd::Disconnected),
            Err(e) => return Err(e),
        };
        // queue-wait anchor: everything between reading the frame and
        // starting the compute (including injected straggle sleep) is
        // worker-side queueing, reported in the v5 telemetry block
        let t_recv = std::time::Instant::now();
        let request = match Request::decode(&frame, mode) {
            Ok(r) => r,
            Err(e) => {
                // protocol violation: tell the peer, drop the connection
                send_response(&mut stream, &Response::Error { msg: e.to_string() }, mode).ok();
                return Err(e);
            }
        };
        let reply = match request {
            Request::Hello { clock_ms, payload, engine: requested } => {
                // negotiate the payload encoding: binary only when the
                // coordinator advertised it AND this worker allows it —
                // then echo the coordinator's trace clock so its spans
                // and ours share a timeline (skew bounded by RTT)
                mode = if cfg.payload == PayloadMode::Binary && payload == PayloadMode::Binary
                {
                    PayloadMode::Binary
                } else {
                    PayloadMode::Json
                };
                // engine negotiation: a pinned worker overrides the
                // request; the reply states the engine actually in
                // effect so the coordinator's telemetry is truthful
                engine_choice = cfg.engine.unwrap_or(requested);
                engine = None;
                Response::Hello {
                    capacity: cfg.capacity,
                    clock_echo_ms: clock_ms,
                    payload: mode,
                    engine: engine_choice,
                }
            }
            Request::Shutdown => {
                send_response(&mut stream, &Response::Bye, mode).ok();
                return Ok(ConnectionEnd::Shutdown);
            }
            Request::DefineProblem { id, problem } => {
                // bounded table: evict an arbitrary victim when full —
                // the coordinator re-interns on the unknown-id error if
                // it ever names an evicted id again
                if problems.len() >= MAX_PROBLEMS && !problems.contains_key(&id) {
                    if let Some(victim) = problems.keys().next().copied() {
                        problems.remove(&victim);
                        problem_evictions += 1;
                    }
                }
                // re-defining an id overwrites it — the coordinator owns
                // the id space and a re-intern must win
                problems.insert(id, problem);
                Response::Defined { id }
            }
            Request::Compress { problem_id, compressor, part, cap, seed } => {
                // injected straggler latency: charged per request, before
                // the compute, like a slow or overloaded machine
                if cfg.straggle_ms > 0 {
                    std::thread::sleep(std::time::Duration::from_millis(cfg.straggle_ms));
                }
                match problems.get(&problem_id) {
                    Some(spec) => {
                        problem_hits += 1;
                        let telemetry = Telemetry {
                            queue_wait_ms: t_recv.elapsed().as_secs_f64() * 1e3,
                            problem_hits,
                            problem_misses,
                            problem_evictions,
                            // dataset counters filled after the cache
                            // lookup inside handle_compress
                            ..Telemetry::default()
                        };
                        // per-connection engine, built once on first use
                        let eng = engine
                            .get_or_insert_with(|| engine_choice.build())
                            .clone();
                        handle_compress(
                            cfg.capacity,
                            cache,
                            spec,
                            &compressor,
                            &part,
                            cap,
                            seed,
                            eng,
                            telemetry,
                        )
                        .unwrap_or_else(|e| Response::Error { msg: e.to_string() })
                    }
                    None => {
                        problem_misses += 1;
                        Response::Error {
                            msg: format!(
                                "unknown problem id {problem_id} on this connection — \
                                 re-intern it with define-problem"
                            ),
                        }
                    }
                }
            }
        };
        send_response(&mut stream, &reply, mode)?;
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_compress(
    capacity: usize,
    cache: &mut DatasetCache,
    spec: &ProblemSpec,
    compressor_name: &str,
    part: &[u32],
    cap: usize,
    seed: u64,
    eng: Arc<dyn Engine>,
    mut telemetry: Telemetry,
) -> Result<Response> {
    if part.len() > capacity {
        return Err(Error::CapacityExceeded {
            capacity,
            got: part.len(),
            ctx: " (worker-side enforcement)".into(),
        });
    }
    // the coordinator sized this part for a virtual machine of capacity
    // `cap` (protocol v3); a part above it means the partitioner
    // overfilled a machine class — reject rather than mask the bug
    if part.len() > cap {
        return Err(Error::CapacityExceeded {
            capacity: cap,
            got: part.len(),
            ctx: " (worker-side enforcement of the planned virtual machine capacity)".into(),
        });
    }
    let compressor = crate::dist::protocol::compressor_from_name(compressor_name)?;
    // the problem is rebuilt per request, so its bulk counter starts at
    // zero and the post-compress snapshot is this request's own sums
    telemetry.engine = eng.name().to_string();
    let problem = cache.problem(spec)?.with_compute(eng);
    // cumulative gauges, read after this request's lookup so the
    // coordinator's latest-value bookkeeping includes it
    telemetry.dataset_hits = cache.dataset_hits;
    telemetry.dataset_misses = cache.dataset_misses;
    problem.check_ids(part)?;
    let evals_before = problem.eval_count();
    let t0 = std::time::Instant::now();
    let solution = compressor.compress(&problem, part, seed)?;
    let (bulk_gain_calls, bulk_gain_candidates) = problem.bulk.snapshot();
    telemetry.bulk_gain_calls = bulk_gain_calls;
    telemetry.bulk_gain_candidates = bulk_gain_candidates;
    Ok(Response::Solution {
        items: solution.items,
        value: solution.value,
        evals: problem.eval_count() - evals_before,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        telemetry,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::spec::ConstraintSpec;
    use crate::data::spec::DatasetSpec;
    use crate::dist::protocol;
    use std::net::TcpStream;

    /// In-process worker on an ephemeral port (the *process*-boundary
    /// version lives in rust/tests/dist_integration.rs).
    fn spawn_worker(capacity: usize) -> (std::thread::JoinHandle<Result<()>>, String) {
        spawn_worker_cfg(WorkerConfig { capacity, ..WorkerConfig::default() })
    }

    /// Same, but with the full config exposed — the payload-negotiation
    /// tests need to pin `payload` on the worker side.
    fn spawn_worker_cfg(cfg: WorkerConfig) -> (std::thread::JoinHandle<Result<()>>, String) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let mut cache = DatasetCache::default();
            let (stream, _) = listener.accept().map_err(Error::Io)?;
            match serve_connection(stream, &cfg, &mut cache)? {
                ConnectionEnd::Shutdown | ConnectionEnd::Disconnected => Ok(()),
            }
        });
        (handle, addr)
    }

    #[test]
    fn worker_compresses_and_shuts_down() {
        let (handle, addr) = spawn_worker(64);
        let mut stream = TcpStream::connect(&addr).unwrap();

        // v5 handshake: the worker echoes the coordinator's clock; a
        // JSON-only coordinator keeps the connection in JSON mode
        let hi = Request::Hello {
            clock_ms: 41.5,
            payload: PayloadMode::Json,
            engine: EngineChoice::Native,
        };
        protocol::send_msg(&mut stream, &hi.to_json()).unwrap();
        let hello = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        assert_eq!(
            hello,
            Response::Hello {
                capacity: 64,
                clock_echo_ms: 41.5,
                payload: PayloadMode::Json,
                engine: EngineChoice::Native,
            }
        );

        let spec = ProblemSpec {
            dataset: DatasetSpec::Registry { name: "csn-2k".into(), seed: 42 },
            objective: "exemplar".into(),
            k: 5,
            seed: 42,
            eval_m: 2000,
            h2: 0.0,
            sigma2: 0.0,
            constraint: ConstraintSpec::Cardinality { k: 5 },
        };

        // v4: compressing against an id that was never interned on this
        // connection is answered with a re-intern hint, not a crash
        let orphan = Request::Compress {
            problem_id: 9,
            compressor: "greedy".into(),
            part: (0..10).collect(),
            cap: 64,
            seed: 1,
        };
        protocol::send_msg(&mut stream, &orphan.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        match resp {
            Response::Error { msg } => {
                assert!(msg.contains("unknown problem id"), "{msg}");
                assert!(msg.contains("define-problem"), "{msg}");
            }
            other => panic!("expected error, got {other:?}"),
        }

        // intern the problem once; every later compress ships only its id
        let define = Request::DefineProblem { id: 0, problem: spec.clone() };
        protocol::send_msg(&mut stream, &define.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        assert_eq!(resp, Response::Defined { id: 0 });

        let req = Request::Compress {
            problem_id: 0,
            compressor: "greedy".into(),
            part: (0..50).collect(),
            cap: 64,
            seed: 1,
        };
        protocol::send_msg(&mut stream, &req.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        match resp {
            Response::Solution { items, value, evals, telemetry, .. } => {
                assert_eq!(items.len(), 5);
                assert!(items.iter().all(|&i| i < 50), "leaked items: {items:?}");
                assert!(value > 0.0);
                assert!(evals > 0, "worker must report oracle evals");
                // v5 telemetry: first compress on this connection after
                // one unknown-id miss; the dataset was a cold miss
                assert!(telemetry.queue_wait_ms >= 0.0);
                assert_eq!(telemetry.problem_hits, 1);
                assert_eq!(telemetry.problem_misses, 1);
                assert_eq!(telemetry.problem_evictions, 0);
                assert_eq!(telemetry.dataset_misses, 1);
                assert_eq!(telemetry.dataset_hits, 0);
                // engine telemetry: the default fleet serves native and
                // lazy greedy's heap build is at least one batched call
                assert_eq!(telemetry.engine, "native");
                assert!(telemetry.bulk_gain_calls >= 1, "{}", telemetry.bulk_gain_calls);
                assert!(
                    telemetry.bulk_gain_candidates >= 50,
                    "{}",
                    telemetry.bulk_gain_candidates
                );
                // bit-identical to compressing locally
                let local = crate::algorithms::LazyGreedy::new();
                let p = spec.materialize().unwrap();
                let want = crate::algorithms::Compressor::compress(
                    &local,
                    &p,
                    &(0..50).collect::<Vec<u32>>(),
                    1,
                )
                .unwrap();
                assert_eq!(items, want.items);
                assert_eq!(value.to_bits(), want.value.to_bits());
            }
            other => panic!("expected solution, got {other:?}"),
        }

        // a hereditary constraint rebuilt from its wire recipe: the
        // worker's answer matches local compression bit-exactly
        let knap_spec = ProblemSpec {
            constraint: ConstraintSpec::Knapsack {
                budget: 250.0,
                k: 5,
                weights: crate::constraints::spec::WeightSpec::RowNorm2,
            },
            ..spec.clone()
        };
        let define = Request::DefineProblem { id: 1, problem: knap_spec.clone() };
        protocol::send_msg(&mut stream, &define.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        assert_eq!(resp, Response::Defined { id: 1 });
        let req = Request::Compress {
            problem_id: 1,
            compressor: "greedy".into(),
            part: (0..50).collect(),
            cap: 64,
            seed: 3,
        };
        protocol::send_msg(&mut stream, &req.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        match resp {
            Response::Solution { items, value, .. } => {
                let p = knap_spec.materialize().unwrap();
                assert!(p.constraint.is_feasible(&items, &p.dataset));
                let want = crate::algorithms::Compressor::compress(
                    &crate::algorithms::LazyGreedy::new(),
                    &p,
                    &(0..50).collect::<Vec<u32>>(),
                    3,
                )
                .unwrap();
                assert_eq!(items, want.items);
                assert_eq!(value.to_bits(), want.value.to_bits());
            }
            other => panic!("expected solution, got {other:?}"),
        }

        // capacity enforcement on the worker side
        let too_big = Request::Compress {
            problem_id: 0,
            compressor: "greedy".into(),
            part: (0..65).collect(),
            cap: 64,
            seed: 2,
        };
        protocol::send_msg(&mut stream, &too_big.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        match resp {
            Response::Error { msg } => {
                assert!(msg.contains("capacity"), "unexpected msg: {msg}")
            }
            other => panic!("expected error, got {other:?}"),
        }

        // v3: the planned virtual machine capacity is enforced too — a
        // part that fits the worker's physical µ but overflows the
        // machine class it was sized for is a partitioner bug
        let over_virtual = Request::Compress {
            problem_id: 0,
            compressor: "greedy".into(),
            part: (0..30).collect(),
            cap: 20,
            seed: 2,
        };
        protocol::send_msg(&mut stream, &over_virtual.to_json()).unwrap();
        let resp = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        match resp {
            Response::Error { msg } => {
                assert!(msg.contains("virtual machine capacity"), "unexpected msg: {msg}")
            }
            other => panic!("expected error, got {other:?}"),
        }

        protocol::send_msg(&mut stream, &Request::Shutdown.to_json()).unwrap();
        let bye = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        assert_eq!(bye, Response::Bye);
        handle.join().unwrap().unwrap();
    }

    /// v6: one connection negotiates binary, one stays JSON; the same
    /// define + compress sequence must yield bit-identical solutions.
    #[test]
    fn binary_negotiation_switches_the_connection_and_matches_json() {
        use crate::dist::protocol::{recv_response, send_request};

        let spec = ProblemSpec {
            dataset: DatasetSpec::Registry { name: "csn-2k".into(), seed: 7 },
            objective: "exemplar".into(),
            k: 4,
            seed: 7,
            eval_m: 500,
            h2: 0.0,
            sigma2: 0.0,
            constraint: ConstraintSpec::Cardinality { k: 4 },
        };
        let define = Request::DefineProblem { id: 1, problem: spec };
        let compress = Request::Compress {
            problem_id: 1,
            compressor: "greedy".into(),
            part: (0..12).collect(),
            cap: 64,
            seed: 3,
        };

        let run = |advertise: PayloadMode| -> (PayloadMode, Response) {
            let (handle, addr) = spawn_worker(64);
            let mut stream = TcpStream::connect(&addr).unwrap();
            // hello frames are mode-invariant: sent pre-negotiation
            let hi = Request::Hello {
                clock_ms: 7.0,
                payload: advertise,
                engine: EngineChoice::Native,
            };
            send_request(&mut stream, &hi, PayloadMode::Json).unwrap();
            let (resp, _) = recv_response(&mut stream, PayloadMode::Json).unwrap();
            let mode = match resp {
                Response::Hello { payload, .. } => payload,
                other => panic!("expected hello, got {other:?}"),
            };
            send_request(&mut stream, &define, mode).unwrap();
            let (defined, _) = recv_response(&mut stream, mode).unwrap();
            assert_eq!(defined, Response::Defined { id: 1 });
            send_request(&mut stream, &compress, mode).unwrap();
            let (solution, _) = recv_response(&mut stream, mode).unwrap();
            send_request(&mut stream, &Request::Shutdown, mode).unwrap();
            let (bye, _) = recv_response(&mut stream, mode).unwrap();
            assert_eq!(bye, Response::Bye);
            handle.join().unwrap().unwrap();
            (mode, solution)
        };

        let (bin_mode, bin) = run(PayloadMode::Binary);
        let (json_mode, json) = run(PayloadMode::Json);
        assert_eq!(bin_mode, PayloadMode::Binary, "default worker must accept binary");
        assert_eq!(json_mode, PayloadMode::Json);
        match (&bin, &json) {
            (
                Response::Solution { items: a, value: va, evals: ea, .. },
                Response::Solution { items: b, value: vb, evals: eb, .. },
            ) => {
                assert_eq!(a, b, "items must be bit-identical across encodings");
                assert_eq!(va.to_bits(), vb.to_bits(), "values must be bit-identical");
                assert_eq!(ea, eb);
            }
            other => panic!("expected two solutions, got {other:?}"),
        }
    }

    /// v6: a worker pinned to `--payload json` declines a binary
    /// advertisement, and the connection stays JSON end-to-end.
    #[test]
    fn json_pinned_worker_declines_binary_advertisement() {
        use crate::dist::protocol::{recv_response, send_request};

        let cfg =
            WorkerConfig { capacity: 64, payload: PayloadMode::Json, ..WorkerConfig::default() };
        let (handle, addr) = spawn_worker_cfg(cfg);
        let mut stream = TcpStream::connect(&addr).unwrap();
        let hi = Request::Hello {
            clock_ms: 0.25,
            payload: PayloadMode::Binary,
            engine: EngineChoice::Native,
        };
        send_request(&mut stream, &hi, PayloadMode::Json).unwrap();
        let (resp, _) = recv_response(&mut stream, PayloadMode::Json).unwrap();
        assert_eq!(
            resp,
            Response::Hello {
                capacity: 64,
                clock_echo_ms: 0.25,
                payload: PayloadMode::Json,
                engine: EngineChoice::Native,
            }
        );
        send_request(&mut stream, &Request::Shutdown, PayloadMode::Json).unwrap();
        let (bye, _) = recv_response(&mut stream, PayloadMode::Json).unwrap();
        assert_eq!(bye, Response::Bye);
        handle.join().unwrap().unwrap();
    }

    /// v6 engine negotiation: an unpinned worker follows the
    /// coordinator's request; a pinned worker answers with its own
    /// engine regardless of what was asked for.
    #[test]
    fn engine_negotiation_follows_request_unless_pinned() {
        use crate::dist::protocol::{recv_response, send_request};

        let handshake = |cfg: WorkerConfig, ask: EngineChoice| -> EngineChoice {
            let (handle, addr) = spawn_worker_cfg(cfg);
            let mut stream = TcpStream::connect(&addr).unwrap();
            let hi = Request::Hello {
                clock_ms: 0.0,
                payload: PayloadMode::Json,
                engine: ask,
            };
            send_request(&mut stream, &hi, PayloadMode::Json).unwrap();
            let (resp, _) = recv_response(&mut stream, PayloadMode::Json).unwrap();
            let granted = match resp {
                Response::Hello { engine, .. } => engine,
                other => panic!("expected hello, got {other:?}"),
            };
            send_request(&mut stream, &Request::Shutdown, PayloadMode::Json).unwrap();
            let (bye, _) = recv_response(&mut stream, PayloadMode::Json).unwrap();
            assert_eq!(bye, Response::Bye);
            handle.join().unwrap().unwrap();
            granted
        };

        let unpinned = |cap| WorkerConfig { capacity: cap, ..WorkerConfig::default() };
        assert_eq!(handshake(unpinned(64), EngineChoice::Native), EngineChoice::Native);
        assert_eq!(handshake(unpinned(64), EngineChoice::Xla), EngineChoice::Xla);
        let pinned = WorkerConfig {
            capacity: 64,
            engine: Some(EngineChoice::Native),
            ..WorkerConfig::default()
        };
        assert_eq!(
            handshake(pinned, EngineChoice::Xla),
            EngineChoice::Native,
            "a pinned worker must win the negotiation"
        );
    }

    #[test]
    fn bounded_problem_table_evicts_one_victim_and_hints_reintern() {
        let (handle, addr) = spawn_worker(64);
        let mut stream = TcpStream::connect(&addr).unwrap();
        let hi = Request::Hello {
            clock_ms: 0.0,
            payload: PayloadMode::Json,
            engine: EngineChoice::Native,
        };
        protocol::send_msg(&mut stream, &hi.to_json()).unwrap();
        let hello = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        assert_eq!(
            hello,
            Response::Hello {
                capacity: 64,
                clock_echo_ms: 0.0,
                payload: PayloadMode::Json,
                engine: EngineChoice::Native,
            }
        );
        let base = ProblemSpec {
            dataset: DatasetSpec::Registry { name: "csn-2k".into(), seed: 42 },
            objective: "exemplar".into(),
            k: 3,
            seed: 0,
            eval_m: 50,
            h2: 0.0,
            sigma2: 0.0,
            constraint: ConstraintSpec::Cardinality { k: 3 },
        };
        // define MAX_PROBLEMS + 1 distinct problems on one connection:
        // exactly one victim must be evicted, never the whole table
        for id in 0..=(MAX_PROBLEMS as u64) {
            let spec = ProblemSpec { seed: id, ..base.clone() };
            protocol::send_msg(
                &mut stream,
                &Request::DefineProblem { id, problem: spec }.to_json(),
            )
            .unwrap();
            let resp =
                Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
            assert_eq!(resp, Response::Defined { id });
        }
        let mut unknown = 0usize;
        for id in 0..=(MAX_PROBLEMS as u64) {
            let req = Request::Compress {
                problem_id: id,
                compressor: "greedy".into(),
                part: (0..10).collect(),
                cap: 64,
                seed: 1,
            };
            protocol::send_msg(&mut stream, &req.to_json()).unwrap();
            match Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap() {
                Response::Solution { items, telemetry, .. } => {
                    assert_eq!(items.len(), 3);
                    assert_eq!(
                        telemetry.problem_evictions, 1,
                        "v5 telemetry must surface the eviction"
                    );
                }
                Response::Error { msg } => {
                    assert!(msg.contains("unknown problem id"), "{msg}");
                    assert!(msg.contains("define-problem"), "{msg}");
                    unknown += 1;
                }
                other => panic!("unexpected reply {other:?}"),
            }
        }
        assert_eq!(unknown, 1, "exactly one victim must have been evicted");
        protocol::send_msg(&mut stream, &Request::Shutdown.to_json()).unwrap();
        let bye = Response::from_json(&protocol::recv_msg(&mut stream).unwrap()).unwrap();
        assert_eq!(bye, Response::Bye);
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn dataset_cache_evicts_one_victim_and_keeps_surviving_constraints() {
        use crate::data::synthetic;

        // a recorded-provenance synthetic dataset per seed, under a
        // generator-spec'd (memoizable) knapsack
        let spec_for = |seed: u64| -> ProblemSpec {
            let ds: crate::data::DatasetRef = Arc::new(synthetic::csn_like(40, seed));
            ProblemSpec {
                dataset: DatasetSpec::from_dataset(&ds).unwrap(),
                objective: "exemplar".into(),
                k: 3,
                seed,
                eval_m: 10,
                h2: 0.0,
                sigma2: 0.0,
                constraint: ConstraintSpec::Knapsack {
                    budget: 1e9,
                    k: 3,
                    weights: crate::constraints::spec::WeightSpec::RowNorm2,
                },
            }
        };
        let mut cache = DatasetCache::default();
        // warm-up cycle over MAX_DATASETS + 1 datasets: all misses
        for s in 0..9u64 {
            cache.problem(&spec_for(s)).unwrap();
        }
        assert_eq!(cache.dataset_misses, 9);
        assert_eq!(cache.dataset_hits, 0);
        assert!(cache.datasets.len() <= DatasetCache::MAX_DATASETS, "cap violated");
        // two more round-robin cycles: the stable working set keeps
        // hitting — the old wipe-everything eviction missed on EVERY
        // request once the cap was reached
        for _ in 0..2 {
            for s in 0..9u64 {
                cache.problem(&spec_for(s)).unwrap();
            }
        }
        assert_eq!(cache.dataset_hits, 14, "expected 7 hits per post-warm-up cycle");
        assert_eq!(cache.dataset_misses, 13, "expected 2 misses per post-warm-up cycle");
        assert!(cache.datasets.len() <= DatasetCache::MAX_DATASETS, "cap violated");
        // survivors kept their memoized constraint tables: one entry per
        // resident dataset (victims' entries were dropped with them)
        assert!(
            cache.constraints.len() >= 7,
            "surviving constraints were wiped: {} entries",
            cache.constraints.len()
        );
        assert!(cache.constraints.len() <= DatasetCache::MAX_DATASETS);
    }
}

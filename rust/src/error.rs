//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no proc-macro dependency: the
//! offline build vendors no `thiserror`).

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors surfaced by the coordinator, runtime and substrates.
#[derive(Debug)]
pub enum Error {
    /// A machine was asked to hold more items than its fixed capacity µ.
    /// This is the failure mode the paper's framework exists to avoid —
    /// we *hard-fail* instead of silently spilling, so benches can prove
    /// the two-round baselines break down where Table 1 says they do.
    CapacityExceeded {
        capacity: usize,
        got: usize,
        ctx: String,
    },

    InvalidArgument(String),

    NoArtifact(String),

    Manifest(String),

    Xla(String),

    EngineUnavailable(String),

    Json { offset: usize, msg: String },

    Config(String),

    Io(std::io::Error),

    DataFormat(String),

    Worker(String),

    /// The wire to a distributed worker failed (connect/read/write/EOF).
    /// Distinct from [`Error::Worker`]: transport failures are retryable
    /// by requeueing the part on another machine; worker errors are not.
    Transport(String),

    /// A peer spoke the `dist` protocol incorrectly (bad frame, bad
    /// message shape, version mismatch).
    Protocol(String),

    /// A job was cancelled before it finished (`hss serve` cancel API
    /// or service drain). Distinct from a failure: cancellation is a
    /// caller decision, so nothing about the fleet is suspect.
    Cancelled(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::CapacityExceeded { capacity, got, ctx } => write!(
                f,
                "capacity exceeded: machine of capacity {capacity} received {got} items{ctx}"
            ),
            Error::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
            Error::NoArtifact(m) => write!(f, "no artifact matches request: {m}"),
            Error::Manifest(m) => write!(f, "artifact manifest error: {m}"),
            Error::Xla(m) => write!(f, "XLA/PJRT runtime error: {m}"),
            Error::EngineUnavailable(m) => write!(f, "engine unavailable: {m}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::DataFormat(m) => write!(f, "data format error: {m}"),
            Error::Worker(m) => write!(f, "worker panicked or disconnected: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Cancelled(m) => write!(f, "cancelled: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Helper for invalid-argument errors.
    pub fn invalid<S: Into<String>>(msg: S) -> Self {
        Error::InvalidArgument(msg.into())
    }

    /// Helper for transport errors tagged with the peer address.
    pub fn transport<S: fmt::Display>(addr: &str, msg: S) -> Self {
        Error::Transport(format!("{addr}: {msg}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_previous_derive_output() {
        let e = Error::CapacityExceeded { capacity: 10, got: 11, ctx: " (machine 0 of 2)".into() };
        assert_eq!(
            e.to_string(),
            "capacity exceeded: machine of capacity 10 received 11 items (machine 0 of 2)"
        );
        assert_eq!(Error::invalid("x").to_string(), "invalid argument: x");
        assert_eq!(
            Error::transport("127.0.0.1:7070", "connection refused").to_string(),
            "transport error: 127.0.0.1:7070: connection refused"
        );
    }

    #[test]
    fn io_source_is_preserved() {
        use std::error::Error as _;
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(e.source().is_some());
        assert!(e.to_string().contains("boom"));
    }
}

//! Crate-wide error type.

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors surfaced by the coordinator, runtime and substrates.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// A machine was asked to hold more items than its fixed capacity µ.
    /// This is the failure mode the paper's framework exists to avoid —
    /// we *hard-fail* instead of silently spilling, so benches can prove
    /// the two-round baselines break down where Table 1 says they do.
    #[error("capacity exceeded: machine of capacity {capacity} received {got} items{ctx}")]
    CapacityExceeded {
        capacity: usize,
        got: usize,
        ctx: String,
    },

    #[error("invalid argument: {0}")]
    InvalidArgument(String),

    #[error("no artifact matches request: {0}")]
    NoArtifact(String),

    #[error("artifact manifest error: {0}")]
    Manifest(String),

    #[error("XLA/PJRT runtime error: {0}")]
    Xla(String),

    #[error("engine unavailable: {0}")]
    EngineUnavailable(String),

    #[error("json parse error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    #[error("config error: {0}")]
    Config(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("data format error: {0}")]
    DataFormat(String),

    #[error("worker panicked or disconnected: {0}")]
    Worker(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl Error {
    /// Helper for invalid-argument errors.
    pub fn invalid<S: Into<String>>(msg: S) -> Self {
        Error::InvalidArgument(msg.into())
    }
}

//! # hss — Horizontally Scalable Submodular Maximization
//!
//! A full-system reproduction of *Horizontally Scalable Submodular
//! Maximization* (Lucic, Bachem, Zadimoghaddam, Krause — ICML 2016).
//!
//! The paper's contribution is a **multi-round, tree-based compression
//! framework** ([`coordinator::tree`]) that performs constrained
//! submodular maximization on a cluster of machines with **fixed
//! capacity** µ: each round randomly partitions the surviving items
//! across `⌈|A_t|/µ⌉` machines, each machine compresses its partition to
//! at most `k` items with a β-nice algorithm ([`algorithms`]), and the
//! union survives to the next round. The returned solution is the best
//! partial solution observed anywhere in the tree.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: round planner, balanced random
//!   partitioner, pluggable execution backends ([`dist`]: in-process
//!   thread pool, real TCP worker processes, deterministic fault
//!   simulator), β-nice compressors, objectives, hereditary constraints,
//!   baselines and the bench harness.
//! * **L2/L1 (python/compile, build-time only)** — JAX graphs + Pallas
//!   kernels for the oracle-evaluation hot spot, AOT-lowered to
//!   `artifacts/*.hlo.txt`, executed from rust through PJRT
//!   ([`runtime`]). Python never runs on the request path.
//!
//! ## Module map (crate ↔ paper)
//!
//! | module | paper section | contents |
//! |---|---|---|
//! | [`coordinator::tree`] | §3 Algorithm 1 | tree-based compression runner |
//! | [`coordinator::partitioner`] | §3 "virtual free locations" | balanced random partition + capacity-weighted generalization |
//! | [`coordinator::planner`] | Prop 3.1 | round bounds, worst-case machine counts |
//! | [`coordinator::capacity`] | — (extension) | per-worker capacity profiles, weighted sharding |
//! | [`coordinator::baselines`] | §2, §4.3 | centralized GREEDY, GREEDI, RANDGREEDI, RANDOM |
//! | [`algorithms`] | §3.1 β-nice | lazy/stochastic/threshold greedy compressors |
//! | [`objectives`] | §4.1 | exemplar clustering, log-det; oracle counters |
//! | [`constraints`] | §3.2 hereditary | cardinality, knapsack, partition matroid, intersections |
//! | [`analysis`] | Thm 3.3/3.5 | approximation-bound formulas |
//! | [`dist`] | — (systems) | execution backends, wire protocol (`docs/PROTOCOL.md`) |
//! | [`trace`] | — (systems) | span/event recorder, Chrome-trace export (`docs/OBSERVABILITY.md`) |
//! | [`data`] | §4.1 Table 2 | dataset registry, synthetic generators, wire specs |
//! | [`bench`] | §4 | table/figure report generators |
//! | [`lint`] | — (systems) | repo static analysis, `hss lint` (`docs/STATIC_ANALYSIS.md`) |
//! | [`coordinator::job`] | — (systems) | a run as a first-class value: `JobSpec` → `JobRunner` → `JobOutput` |
//! | [`serve`] | — (systems) | `hss serve` multi-tenant job service over a shared fleet (`docs/SERVE.md`) |
//!
//! ## Distributed execution
//!
//! Rounds dispatch through the [`dist::Backend`] trait. The default is
//! the in-process [`dist::LocalBackend`]; `hss worker --listen
//! host:port` starts a real fixed-capacity worker process and `hss run
//! --backend tcp --workers host:port,…` shards every round over those
//! workers via a length-prefixed binary protocol ([`dist::protocol`],
//! normative spec in `docs/PROTOCOL.md`). [`dist::SimBackend`] replays
//! scripted machine losses, stragglers and shrinking fleets for
//! robustness experiments. All backends return bit-identical solutions
//! for the same seed — the substrate changes cost and availability,
//! never the answer. Problems cross the wire by specification: datasets
//! as registry names or recorded synthetic-generator calls
//! ([`data::spec::DatasetSpec`]) and hereditary constraints as
//! construction recipes ([`constraints::spec::ConstraintSpec`] —
//! cardinality, knapsack, partition matroid, intersections).
//!
//! Fleets need not be uniform: a [`coordinator::capacity::CapacityProfile`]
//! gives every machine class its own µ_p (protocol v3 workers advertise
//! theirs at handshake), parts are sized to classes by weighted
//! sharding, and the TCP coordinator dispatches each part only to a
//! worker that can hold it.
//!
//! ## Quick start
//!
//! ```no_run
//! use hss::prelude::*;
//! use std::sync::Arc;
//!
//! let dataset = Arc::new(hss::data::synthetic::csn_like(2_000, 7));
//! let problem = Problem::exemplar(dataset, /*k=*/ 20, /*seed=*/ 7);
//! let tree = TreeBuilder::new(/*capacity=*/ 200).build();
//! let result = tree.run(&problem, 7).unwrap();
//! println!("f(S) = {:.4} in {} rounds", result.best.value, result.rounds);
//! ```
//!
//! The grammars shared by the CLI, config files and the wire are
//! executable documentation — these examples run as doctests:
//!
//! ```
//! use hss::constraints::spec::ConstraintSpec;
//! use hss::coordinator::capacity::CapacityProfile;
//! use hss::coordinator::RoundPlan;
//!
//! // `--constraint` grammar: '+' intersects hereditary constraints
//! let spec = ConstraintSpec::parse("knapsack:b=30,w=rownorm2+pmatroid:groups=5,cap=2", 10);
//! assert!(spec.is_ok());
//!
//! // `--capacity` grammar: scalar µ, explicit classes, or repeats
//! let fleet = CapacityProfile::parse("500,200x2").unwrap();
//! assert_eq!(fleet.caps(), &[500, 200, 200]);
//!
//! // Prop 3.1 round planning against that fleet
//! let plan = RoundPlan::for_profile(10_000, 50, &fleet).unwrap();
//! assert!(plan.rounds() <= plan.round_bound + 2);
//! ```

pub mod algorithms;
pub mod analysis;
pub mod bench;
pub mod config;
pub mod constraints;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod error;
pub mod linalg;
pub mod lint;
pub mod objectives;
pub mod runtime;
pub mod serve;
pub mod trace;
pub mod util;

pub use error::{Error, Result};

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::algorithms::{
        Compressor, LazyGreedy, RandomCompressor, Solution, StochasticGreedy,
        ThresholdGreedy,
    };
    pub use crate::analysis::bounds;
    pub use crate::constraints::spec::ConstraintSpec;
    pub use crate::constraints::{Cardinality, Constraint, Knapsack, PartitionMatroid};
    pub use crate::coordinator::{
        baselines, CapacityProfile, TreeBuilder, TreeResult, TreeRunner,
    };
    pub use crate::data::Dataset;
    pub use crate::dist::{
        Backend, BackendChoice, FaultPlan, LocalBackend, PartEvent, RoundHandle,
        SimBackend, TcpBackend, WorkerStats,
    };
    pub use crate::error::{Error, Result};
    pub use crate::objectives::{Objective, Oracle, Problem};
    pub use crate::runtime::{Engine, EngineChoice, NativeEngine, XlaEngine, XlaRuntime};
    pub use crate::util::rng::Rng;
}

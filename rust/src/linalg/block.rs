//! Blocked (cache-tiled) batch kernels behind [`crate::runtime::Engine`].
//!
//! These are the worker hot path: `lazy_greedy_over` refreshes stale heap
//! entries in blocks, and each refresh lands here as one batched call
//! instead of µ independent `gain` invocations. The win is memory reuse —
//! a tile of resident rows (or prior Cholesky rows) is streamed once and
//! applied to every candidate in the batch — plus the amortized per-call
//! overhead (virtual dispatch, eval-counter atomics) measured by
//! `benches/oracle.rs`.
//!
//! **Bit-identity contract:** every kernel performs, per output element,
//! exactly the f64 operations of the scalar oracle loop in the same order.
//! Blocking only re-tiles the *iteration space*; it never reassociates a
//! floating-point reduction (e.g. no ‖a‖² + ‖b‖² − 2a·b rewrite of
//! `sq_dist`). Batched engine-backed runs must produce byte-identical
//! `Solution`s to the scalar path, and the differential tests in
//! `objectives/` hold each kernel to that.

use crate::linalg::sq_dist;

/// Rows per tile. Sized so a tile of f32 rows (up to ~d=1536) plus the
/// curmin/colnorm2 slices stay L1/L2-resident while every candidate in
/// the batch re-reads them.
pub const BLOCK: usize = 64;

/// Batched exemplar marginal gains: for each candidate row `c`,
/// `1/m · Σ_i max(0, curmin[i] − ‖w_i − c‖²)` over the gathered
/// evaluation rows (`eval_rows` is row-major `[m, d]`).
///
/// Tiled i-outer / candidate-mid / i-in-tile-inner: each tile of
/// evaluation rows is loaded once and scored against the whole batch.
/// Per candidate the accumulator still sees i = 0..m in increasing
/// order, so the sum is bit-identical to the scalar `gain` loop.
pub fn exemplar_gains(
    eval_rows: &[f32],
    d: usize,
    curmin: &[f64],
    cands: &[&[f32]],
) -> Vec<f64> {
    let m = curmin.len();
    debug_assert_eq!(eval_rows.len(), m * d);
    let mut acc = vec![0.0f64; cands.len()];
    let mut lo = 0;
    while lo < m {
        let hi = (lo + BLOCK).min(m);
        for (a, cand) in acc.iter_mut().zip(cands.iter()) {
            for i in lo..hi {
                let d2 = sq_dist(&eval_rows[i * d..(i + 1) * d], cand);
                let diff = curmin[i] - d2;
                if diff > 0.0 {
                    *a += diff;
                }
            }
        }
        lo = hi;
    }
    acc.iter().map(|&a| a / m as f64).collect()
}

/// Exemplar commit: fold one selected candidate's distances into the
/// `curmin` row vector, returning the realized gain `1/m · Σ max(0, ·)`.
/// Single streaming pass over the resident rows (one candidate — there
/// is nothing to tile), identical to the scalar commit loop.
pub fn exemplar_commit(
    eval_rows: &[f32],
    d: usize,
    curmin: &mut [f64],
    cand: &[f32],
) -> f64 {
    let m = curmin.len();
    debug_assert_eq!(eval_rows.len(), m * d);
    let mut acc = 0.0f64;
    for (i, cur) in curmin.iter_mut().enumerate() {
        let d2 = sq_dist(&eval_rows[i * d..(i + 1) * d], cand);
        if d2 < *cur {
            acc += *cur - d2;
            *cur = d2;
        }
    }
    acc / m as f64
}

/// Rank-1 blocked Cholesky row update (the log-det commit): given the
/// new pivot `λ`, the σ⁻²-scaled kernel column `kcol` of the committed
/// item, its z-column `zj` over the prior rows, and the prior z-rows,
/// produce the new z-row `z[i] = (kcol[i] − Σ_u zj[u]·zrows[u][i]) / λ`
/// and fold `z²` into `colnorm2`.
///
/// i-chunked / u-inner-contiguous: each prior row's chunk `zrows[u][lo..hi]`
/// streams once per tile instead of being gathered column-wise per i.
/// Per output element the subtraction order is u = 0..t increasing,
/// exactly the scalar commit loop.
pub fn cholesky_rank1_row(
    kcol: &[f64],
    zj: &[f64],
    zrows: &[Vec<f64>],
    lambda: f64,
    colnorm2: &mut [f64],
) -> Vec<f64> {
    let n = kcol.len();
    debug_assert_eq!(colnorm2.len(), n);
    debug_assert_eq!(zj.len(), zrows.len());
    let mut row = vec![0.0f64; n];
    let mut lo = 0;
    while lo < n {
        let hi = (lo + BLOCK).min(n);
        row[lo..hi].copy_from_slice(&kcol[lo..hi]);
        for (zju, zrow) in zj.iter().zip(zrows.iter()) {
            for (r, &z) in row[lo..hi].iter_mut().zip(&zrow[lo..hi]) {
                *r -= zju * z;
            }
        }
        for (r, c2) in row[lo..hi].iter_mut().zip(&mut colnorm2[lo..hi]) {
            let z = *r / lambda;
            *r = z;
            *c2 += z * z;
        }
        lo = hi;
    }
    row
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sq_norm;

    fn rows(m: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::seed_from(seed);
        (0..m * d).map(|_| rng.f32() * 4.0 - 2.0).collect()
    }

    #[test]
    fn exemplar_gains_bit_match_scalar_across_tile_boundaries() {
        // m > BLOCK so the tiling actually splits the reduction
        let (m, d) = (BLOCK * 2 + 17, 5);
        let eval = rows(m, d, 1);
        let curmin: Vec<f64> =
            (0..m).map(|i| sq_norm(&eval[i * d..(i + 1) * d])).collect();
        let cand_rows = rows(6, d, 2);
        let cands: Vec<&[f32]> =
            (0..6).map(|c| &cand_rows[c * d..(c + 1) * d]).collect();
        let batched = exemplar_gains(&eval, d, &curmin, &cands);
        for (c, cand) in cands.iter().enumerate() {
            let mut acc = 0.0f64;
            for i in 0..m {
                let diff = curmin[i] - sq_dist(&eval[i * d..(i + 1) * d], cand);
                if diff > 0.0 {
                    acc += diff;
                }
            }
            assert_eq!(batched[c].to_bits(), (acc / m as f64).to_bits());
        }
    }

    #[test]
    fn exemplar_commit_updates_curmin_exactly() {
        let (m, d) = (40, 3);
        let eval = rows(m, d, 3);
        let mut curmin: Vec<f64> =
            (0..m).map(|i| sq_norm(&eval[i * d..(i + 1) * d])).collect();
        let mut expect = curmin.clone();
        let cand_row = rows(1, d, 4);
        let mut acc = 0.0f64;
        for (i, cur) in expect.iter_mut().enumerate() {
            let d2 = sq_dist(&eval[i * d..(i + 1) * d], &cand_row);
            if d2 < *cur {
                acc += *cur - d2;
                *cur = d2;
            }
        }
        let g = exemplar_commit(&eval, d, &mut curmin, &cand_row);
        assert_eq!(g.to_bits(), (acc / m as f64).to_bits());
        for (a, b) in curmin.iter().zip(&expect) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn cholesky_rank1_row_bit_matches_scalar() {
        let n = BLOCK + 9;
        let mut rng = crate::util::rng::Rng::seed_from(7);
        let mut f = || rng.f64() - 0.5;
        let kcol: Vec<f64> = (0..n).map(|_| f()).collect();
        let zrows: Vec<Vec<f64>> =
            (0..3).map(|_| (0..n).map(|_| f()).collect()).collect();
        let zj: Vec<f64> = (0..3).map(|_| f()).collect();
        let lambda = 1.3;
        let mut colnorm2: Vec<f64> = (0..n).map(|_| f().abs()).collect();
        let mut expect_c2 = colnorm2.clone();
        let mut expect_row = vec![0.0f64; n];
        for i in 0..n {
            let mut acc = kcol[i];
            for (u, zju) in zj.iter().enumerate() {
                acc -= zju * zrows[u][i];
            }
            let z = acc / lambda;
            expect_row[i] = z;
            expect_c2[i] += z * z;
        }
        let row = cholesky_rank1_row(&kcol, &zj, &zrows, lambda, &mut colnorm2);
        for i in 0..n {
            assert_eq!(row[i].to_bits(), expect_row[i].to_bits());
            assert_eq!(colnorm2[i].to_bits(), expect_c2[i].to_bits());
        }
    }

    #[test]
    fn empty_batch_and_empty_rows_are_safe() {
        assert!(exemplar_gains(&[], 3, &[], &[]).is_empty());
        let row = cholesky_rank1_row(&[], &[], &[], 1.0, &mut []);
        assert!(row.is_empty());
    }
}

//! Incrementally-grown Cholesky factorization.
//!
//! The log-det objective `f(S) = 1/2 · logdet(I + σ⁻² K_SS)` is maximized
//! greedily by growing `M = I + σ⁻² K_SS` one row/column at a time. We
//! maintain the lower-triangular factor `L` (so `M = L Lᵀ`) and expose:
//!
//! * `extend(v, diag)` — append item with cross-kernel column `v = K(S, x)`
//!   and diagonal `diag = 1 + σ⁻² k(x,x)`;
//! * `solve_lower(v)` — `z = L⁻¹ v` (the quantity behind the marginal
//!   gain `1/2·ln(diag − σ⁻⁴‖z‖²)`);
//! * `logdet()` — `Σ ln L_tt = 1/2 logdet(M) = f(S)`.

/// Lower-triangular factor of a symmetric positive-definite matrix grown
/// one row at a time. Row-major packed storage: row t occupies
/// `t(t+1)/2 .. t(t+1)/2 + t + 1`.
#[derive(Debug, Clone, Default)]
pub struct IncrementalCholesky {
    rows: Vec<f64>,
    n: usize,
    log_det_half: f64,
}

impl IncrementalCholesky {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn size(&self) -> usize {
        self.n
    }

    /// `1/2 · logdet(M) = Σ_t ln(L_tt)`.
    pub fn logdet_half(&self) -> f64 {
        self.log_det_half
    }

    fn row(&self, t: usize) -> &[f64] {
        let start = t * (t + 1) / 2;
        &self.rows[start..start + t + 1]
    }

    /// Solve `L z = v` by forward substitution; `v.len() == self.n`.
    pub fn solve_lower(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n);
        let mut z = vec![0.0; self.n];
        for t in 0..self.n {
            let row = self.row(t);
            let mut acc = v[t];
            for (i, zi) in z[..t].iter().enumerate() {
                acc -= row[i] * zi;
            }
            z[t] = acc / row[t];
        }
        z
    }

    /// Append a new item. `cross` is the new off-diagonal column of M
    /// restricted to the existing items (`M[new, i]` for i < n), `diag`
    /// is `M[new, new]`. Returns the appended pivot `λ = L_nn`, or `None`
    /// if the Schur complement is numerically non-positive (item is
    /// linearly dependent — adding it would gain nothing).
    pub fn extend(&mut self, cross: &[f64], diag: f64) -> Option<f64> {
        assert_eq!(cross.len(), self.n);
        let z = self.solve_lower(cross);
        let schur = diag - z.iter().map(|x| x * x).sum::<f64>();
        if schur <= 1e-12 {
            return None;
        }
        let lambda = schur.sqrt();
        self.rows.extend_from_slice(&z);
        self.rows.push(lambda);
        self.n += 1;
        self.log_det_half += lambda.ln();
        Some(lambda)
    }

    /// Schur complement of a *hypothetical* extension — the quantity whose
    /// log is the marginal gain — without mutating the factor.
    pub fn schur(&self, cross: &[f64], diag: f64) -> f64 {
        let z = self.solve_lower(cross);
        diag - z.iter().map(|x| x * x).sum::<f64>()
    }

    /// Reconstruct the dense M = L Lᵀ (test helper).
    pub fn reconstruct(&self) -> Vec<f64> {
        let n = self.n;
        let mut m = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut acc = 0.0;
                let ri = self.row(i);
                let rj = self.row(j);
                for t in 0..=j {
                    acc += ri[t] * rj[t];
                }
                m[i * n + j] = acc;
                m[j * n + i] = acc;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Vec<f64> {
        // A = B Bᵀ + n·I is SPD
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = if i == j { n as f64 } else { 0.0 };
                for t in 0..n {
                    acc += b[i * n + t] * b[j * n + t];
                }
                a[i * n + j] = acc;
            }
        }
        a
    }

    fn build_from_dense(a: &[f64], n: usize) -> IncrementalCholesky {
        let mut c = IncrementalCholesky::new();
        for t in 0..n {
            let cross: Vec<f64> = (0..t).map(|j| a[t * n + j]).collect();
            c.extend(&cross, a[t * n + t]).expect("SPD extend");
        }
        c
    }

    #[test]
    fn factor_reconstructs_matrix() {
        let mut rng = Rng::seed_from(11);
        for n in [1usize, 2, 5, 12] {
            let a = random_spd(&mut rng, n);
            let c = build_from_dense(&a, n);
            let m = c.reconstruct();
            for (x, y) in a.iter().zip(m.iter()) {
                assert!((x - y).abs() < 1e-8, "n={n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn logdet_matches_known_diagonal() {
        // M = diag(4, 9) -> 1/2 logdet = 1/2 (ln4 + ln9) = ln2 + ln3
        let mut c = IncrementalCholesky::new();
        c.extend(&[], 4.0).unwrap();
        c.extend(&[0.0], 9.0).unwrap();
        let want = 2.0f64.ln() + 3.0f64.ln();
        assert!((c.logdet_half() - want).abs() < 1e-12);
    }

    #[test]
    fn solve_lower_inverts() {
        let mut rng = Rng::seed_from(13);
        let n = 8;
        let a = random_spd(&mut rng, n);
        let c = build_from_dense(&a, n);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let z = c.solve_lower(&v);
        // check L z == v
        for t in 0..n {
            let row = c.row(t);
            let acc: f64 = row.iter().zip(z.iter()).map(|(l, zz)| l * zz).sum();
            assert!((acc - v[t]).abs() < 1e-9);
        }
    }

    #[test]
    fn schur_predicts_extension() {
        let mut rng = Rng::seed_from(17);
        let n = 6;
        let a = random_spd(&mut rng, n);
        let mut c = IncrementalCholesky::new();
        for t in 0..n {
            let cross: Vec<f64> = (0..t).map(|j| a[t * n + j]).collect();
            let s = c.schur(&cross, a[t * n + t]);
            let lam = c.extend(&cross, a[t * n + t]).unwrap();
            assert!((s.sqrt() - lam).abs() < 1e-10);
        }
    }

    #[test]
    fn dependent_item_rejected() {
        let mut c = IncrementalCholesky::new();
        c.extend(&[], 1.0).unwrap();
        // M would be [[1,1],[1,1]] — singular
        assert!(c.extend(&[1.0], 1.0).is_none());
        assert_eq!(c.size(), 1);
    }
}

//! Dense linear algebra primitives for the oracle paths.
//!
//! [`block`] holds the cache-tiled batch kernels that back the default
//! [`crate::runtime::NativeEngine`] (the worker hot path); the scalar
//! routines here back single-candidate lazy-greedy re-evaluations
//! (O(m·d)), the reference oracles used for validation, and the
//! incremental Cholesky machinery of the log-det objective.

pub mod block;
pub mod cholesky;

pub use cholesky::IncrementalCholesky;

/// Squared euclidean distance between two f32 rows, accumulated in f64.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc
}

/// Squared L2 norm of an f32 row, accumulated in f64.
#[inline]
pub fn sq_norm(a: &[f32]) -> f64 {
    a.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// Dot product of two f32 rows in f64.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// RBF (squared-exponential) kernel `exp(-||a-b||^2 / h2)`.
#[inline]
pub fn rbf(a: &[f32], b: &[f32], h2: f64) -> f64 {
    (-sq_dist(a, b) / h2).exp()
}

/// Dense matrix-vector product `y = A x` with A row-major `[rows, cols]`.
pub fn matvec(a: &[f64], rows: usize, cols: usize, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(x.len(), cols);
    assert_eq!(y.len(), rows);
    for r in 0..rows {
        let row = &a[r * cols..(r + 1) * cols];
        y[r] = row.iter().zip(x.iter()).map(|(&m, &v)| m * v).sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_dist_matches_manual() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [0.0f32, 2.0, 5.0];
        assert_eq!(sq_dist(&a, &b), 1.0 + 0.0 + 4.0);
        assert_eq!(sq_dist(&a, &a), 0.0);
    }

    #[test]
    fn norm_and_dot() {
        let a = [3.0f32, 4.0];
        assert_eq!(sq_norm(&a), 25.0);
        assert_eq!(dot(&a, &[1.0, 1.0]), 7.0);
    }

    #[test]
    fn rbf_unit_diag_and_decay() {
        let a = [0.5f32, -1.0];
        assert!((rbf(&a, &a, 0.25) - 1.0).abs() < 1e-12);
        let far = [100.0f32, 100.0];
        assert!(rbf(&a, &far, 0.25) < 1e-30);
    }

    #[test]
    fn matvec_small() {
        let a = [1.0, 2.0, 3.0, 4.0]; // [[1,2],[3,4]]
        let x = [1.0, -1.0];
        let mut y = [0.0; 2];
        matvec(&a, 2, 2, &x, &mut y);
        assert_eq!(y, [-1.0, -1.0]);
    }
}

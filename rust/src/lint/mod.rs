//! `hss lint` — a dependency-free static-analysis pass over the repo's
//! own sources (`rust/src/**` and `benches/**`).
//!
//! The repo hand-rolls concurrent machinery (condvar dispatcher threads,
//! a relaxed-atomic trace recorder, speculative dispatch that must stay
//! bit-identical to serial) — exactly the code where NaN-ordering bugs,
//! unjustified relaxed atomics, lock-order inversions, stray panics and
//! protocol-doc rot hide. The lint pass machine-checks those invariants
//! in CI; `docs/STATIC_ANALYSIS.md` is the user-facing spec.
//!
//! Rules (named in findings and in suppression markers):
//!
//! | rule | invariant |
//! |---|---|
//! | `nan-ordering` | float comparisons go through `total_cmp` |
//! | `relaxed-atomics` | `Ordering::Relaxed` carries a `// relaxed:` reason |
//! | `lock-order` | the dispatcher's lock acquisition graph is acyclic |
//! | `panic-freedom` | dist/coordinator/util-json/runtime/linalg/serve panics carry an `// invariant:` reason |
//! | `logging` | print macros only in `util/log.rs` and `main.rs` |
//! | `protocol-doc` | wire literals and docs/PROTOCOL.md agree both ways |
//! | `suppression` | every `lint:allow` names a real rule and a reason |
//!
//! Any finding can be suppressed where it fires with a justified marker
//! on the line or in the comment block directly above, e.g.
//! `// lint:allow(logging): stdout is this path's artifact` — the rule
//! name must be real and the reason must be non-empty, otherwise the
//! marker itself becomes a `suppression` finding.
//!
//! The analyzer is deliberately line/token-level, not a Rust parser:
//! [`source`] blanks string contents and strips comments so token
//! matches are trustworthy, and that is all the precision these rules
//! need. No dependencies, no syn, no rustc plumbing — the same ADR-002
//! trade the rest of the repo makes.

pub mod rules;
pub mod source;

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::Path;

use crate::error::Result;
use source::Line;

pub const NAN_ORDERING: &str = "nan-ordering";
pub const RELAXED_ATOMICS: &str = "relaxed-atomics";
pub const LOCK_ORDER: &str = "lock-order";
pub const PANIC_FREEDOM: &str = "panic-freedom";
pub const LOGGING: &str = "logging";
pub const PROTOCOL_DOC: &str = "protocol-doc";
pub const SUPPRESSION: &str = "suppression";

/// Every rule name a `lint:allow` marker may reference (`suppression`
/// is listed for completeness but cannot itself be suppressed).
pub const RULES: [&str; 7] = [
    NAN_ORDERING,
    RELAXED_ATOMICS,
    LOCK_ORDER,
    PANIC_FREEDOM,
    LOGGING,
    PROTOCOL_DOC,
    SUPPRESSION,
];

/// One finding. Ordering (derived, field order matters) groups output
/// by file, then line, then rule, then message.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Violation {
    /// Repo-relative path with forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl Violation {
    pub(crate) fn new(
        file: &str,
        line: usize,
        rule: &'static str,
        msg: impl Into<String>,
    ) -> Violation {
        Violation { file: file.to_string(), line, rule, msg: msg.into() }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Run every rule over the tree rooted at `root` (the repo checkout:
/// `rust/src/**` and `benches/**` are scanned; either may be absent).
/// Returns the findings sorted for stable output; empty means clean.
pub fn run(root: &Path) -> Result<Vec<Violation>> {
    let files = collect_files(root)?;
    let mut out = Vec::new();
    for (rel, lines) in &files {
        rules::check_suppressions(rel, lines, &mut out);
        rules::nan_ordering(rel, lines, &mut out);
        rules::relaxed_atomics(rel, lines, &mut out);
        rules::panic_freedom(rel, lines, &mut out);
        rules::logging(rel, lines, &mut out);
    }
    rules::lock_order(&files, &mut out);
    rules::protocol_doc(&files, root, &mut out);
    out.sort();
    Ok(out)
}

/// Recursively gather `.rs` files under `<root>/rust/src` and
/// `<root>/benches`, keyed by repo-relative forward-slash path.
fn collect_files(root: &Path) -> Result<BTreeMap<String, Vec<Line>>> {
    let mut files = BTreeMap::new();
    for base in ["rust/src", "benches"] {
        let dir = root.join(base);
        if !dir.is_dir() {
            continue;
        }
        let mut pending = vec![dir];
        while let Some(d) = pending.pop() {
            for entry in fs::read_dir(&d)? {
                let path = entry?.path();
                if path.is_dir() {
                    pending.push(path);
                } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
                    let text = fs::read_to_string(&path)?;
                    let rel = match path.strip_prefix(root) {
                        Ok(r) => r.to_string_lossy().replace('\\', "/"),
                        Err(_) => path.to_string_lossy().replace('\\', "/"),
                    };
                    files.insert(rel, source::preprocess(&text));
                }
            }
        }
    }
    Ok(files)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn violations_render_and_sort_stably() {
        let mut v = vec![
            Violation::new("b.rs", 2, LOGGING, "later file"),
            Violation::new("a.rs", 9, LOGGING, "later line"),
            Violation::new("a.rs", 1, NAN_ORDERING, "first"),
        ];
        v.sort();
        assert_eq!(v[0].file, "a.rs");
        assert_eq!(v[0].line, 1);
        assert_eq!(v[2].file, "b.rs");
        assert_eq!(v[0].to_string(), "a.rs:1: [nan-ordering] first");
    }

    #[test]
    fn a_missing_root_scans_nothing_but_still_checks_docs() {
        let root = std::env::temp_dir().join(format!("hss-lint-empty-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).unwrap();
        let got = run(&root).unwrap();
        let _ = std::fs::remove_dir_all(&root);
        // no sources → the only possible finding is the missing doc
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].rule, PROTOCOL_DOC);
    }
}

//! The six rules behind `hss lint`, plus the suppression-grammar check.
//!
//! Every rule works on the preprocessed [`Line`] view from
//! [`super::source`]: tokens are matched against `Line::code` (string
//! contents blanked, comments stripped), so mentioning a forbidden
//! token in a comment or a string literal never trips a rule. Findings
//! are suppressible per line with a justified `lint:allow` marker —
//! see [`source::suppressed`] for the grammar.
//!
//! Scopes differ per rule and are part of the contract:
//!
//! * `nan-ordering` — every scanned file, tests included (a NaN-ordering
//!   bug in a test comparator hides real failures just as well).
//! * `relaxed-atomics`, `logging` — non-test code under `rust/src/`.
//! * `panic-freedom` — non-test code under `rust/src/dist/`,
//!   `rust/src/coordinator/` (the always-on concurrent core) and
//!   `rust/src/util/json/` (v6: it parses attacker-shaped frame bytes).
//! * `lock-order` — the dispatcher files listed in [`LOCK_ORDER_FILES`].
//! * `protocol-doc` — wire literals in [`PROTOCOL_FILES`] against
//!   `docs/PROTOCOL.md` (both directions, plus version consistency).

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use super::source::{self, Line};
use super::{
    Violation, LOCK_ORDER, LOGGING, NAN_ORDERING, PANIC_FREEDOM, PROTOCOL_DOC, RELAXED_ATOMICS,
    RULES, SUPPRESSION,
};

/// Files whose per-function lock-acquisition order is checked for
/// cross-function cycles (the condvar dispatcher and its neighbors).
pub const LOCK_ORDER_FILES: [&str; 4] = [
    "rust/src/dist/tcp.rs",
    "rust/src/dist/local.rs",
    "rust/src/dist/sim.rs",
    "rust/src/trace/mod.rs",
];

/// Files whose string literals are treated as candidate wire tokens.
pub const PROTOCOL_FILES: [&str; 3] = [
    "rust/src/dist/protocol.rs",
    "rust/src/dist/worker.rs",
    "rust/src/dist/tcp.rs",
];

/// Files allowed to use raw print macros: the leveled logger itself and
/// the CLI entry point (stdout *is* the CLI's artifact).
pub const LOGGING_ALLOWED: [&str; 2] = ["rust/src/util/log.rs", "rust/src/main.rs"];

/// Validate every `lint:allow` marker in the file: the named rule must
/// exist (and not be `suppression` itself) and a written reason must
/// follow the closing paren. Malformed markers are findings of their
/// own — a suppression that silently fails to parse would otherwise
/// read as "allowed".
pub fn check_suppressions(relpath: &str, lines: &[Line], out: &mut Vec<Violation>) {
    for (i, ln) in lines.iter().enumerate() {
        match source::parse_allow(&ln.comment) {
            None => {}
            Some(Err(msg)) => out.push(Violation::new(relpath, i + 1, SUPPRESSION, msg)),
            Some(Ok(allow)) => {
                if !RULES.contains(&allow.rule) || allow.rule == SUPPRESSION {
                    out.push(Violation::new(
                        relpath,
                        i + 1,
                        SUPPRESSION,
                        format!("lint:allow names unknown rule '{}'", allow.rule),
                    ));
                } else if !source::allow_has_reason(allow.tail) {
                    out.push(Violation::new(
                        relpath,
                        i + 1,
                        SUPPRESSION,
                        "lint:allow without a written reason",
                    ));
                }
            }
        }
    }
}

fn ident_tail_is_clear(code: &str, pos: usize) -> bool {
    match code[pos..].chars().next() {
        None => true,
        Some(c) => !(c.is_alphanumeric() || c == '_'),
    }
}

/// Rule `nan-ordering`: the bug class re-fixed in PRs 2, 4 and 5.
/// Comparator tokens that absorb or mis-order NaN are forbidden in
/// favor of `total_cmp`; applies everywhere, tests included.
pub fn nan_ordering(relpath: &str, lines: &[Line], out: &mut Vec<Violation>) {
    for (i, ln) in lines.iter().enumerate() {
        let code = &ln.code;
        if code.contains(".partial_cmp(")
            && !code.contains("total_cmp")
            && !source::suppressed(lines, i, NAN_ORDERING)
        {
            out.push(Violation::new(
                relpath,
                i + 1,
                NAN_ORDERING,
                ".partial_cmp( on floats — use total_cmp",
            ));
        }
        for tok in ["f64::max", "f64::min"] {
            if let Some(p) = code.find(tok) {
                if ident_tail_is_clear(code, p + tok.len())
                    && !source::suppressed(lines, i, NAN_ORDERING)
                {
                    out.push(Violation::new(
                        relpath,
                        i + 1,
                        NAN_ORDERING,
                        format!("{tok} is NaN-absorbing — use total_cmp"),
                    ));
                }
            }
        }
        if code.contains(".sort_by(") {
            // the comparator often sits on the following lines; give it
            // a 4-line window to mention total_cmp
            let window: String = lines[i..lines.len().min(i + 4)]
                .iter()
                .map(|l| l.code.as_str())
                .collect();
            if !window.contains("total_cmp") && !source::suppressed(lines, i, NAN_ORDERING) {
                out.push(Violation::new(
                    relpath,
                    i + 1,
                    NAN_ORDERING,
                    ".sort_by( without total_cmp in the comparator",
                ));
            }
        }
    }
}

/// Rule `relaxed-atomics`: every `Ordering::Relaxed` in non-test
/// `rust/src/` code needs an adjacent `// relaxed: <why it is sound>`.
pub fn relaxed_atomics(relpath: &str, lines: &[Line], out: &mut Vec<Violation>) {
    if !relpath.starts_with("rust/src/") {
        return;
    }
    for (i, ln) in lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        if ln.code.contains("Ordering::Relaxed")
            && !source::comment_has(lines, i, "relaxed:")
            && !source::suppressed(lines, i, RELAXED_ATOMICS)
        {
            out.push(Violation::new(
                relpath,
                i + 1,
                RELAXED_ATOMICS,
                "Ordering::Relaxed without an adjacent `// relaxed:` justification",
            ));
        }
    }
}

/// Rule `panic-freedom`: no unwrap/expect/panic in the non-test
/// dist/coordinator core — or the JSON codec the wire decoders are
/// built on (v6: `util/json` parses attacker-shaped frame bytes, so
/// its panic-freedom is part of the decode contract fuzzed by
/// `rust/tests/protocol_fuzz.rs`), or the compute substrate
/// (`runtime/` engine dispatch and `linalg/` kernels now sit under
/// every oracle call a worker serves, so a panic there is a fleet
/// outage, not a local bug), or the job service (`serve/` threads
/// multiplex every tenant over one fleet — a panic there takes the
/// daemon down for all of them) — without an
/// `// invariant: <why it holds>`.
pub fn panic_freedom(relpath: &str, lines: &[Line], out: &mut Vec<Violation>) {
    if !(relpath.starts_with("rust/src/dist/")
        || relpath.starts_with("rust/src/coordinator/")
        || relpath.starts_with("rust/src/util/json/")
        || relpath.starts_with("rust/src/runtime/")
        || relpath.starts_with("rust/src/linalg/")
        || relpath.starts_with("rust/src/serve/"))
    {
        return;
    }
    for (i, ln) in lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        let code = &ln.code;
        let mut hits: Vec<&str> = Vec::new();
        if code.contains(".unwrap()") {
            hits.push(".unwrap()");
        }
        if code.contains(".expect(") {
            hits.push(".expect(");
        }
        if code.contains("panic!") {
            hits.push("panic!");
        }
        for tok in hits {
            if source::comment_has(lines, i, "invariant:") {
                continue;
            }
            if !source::suppressed(lines, i, PANIC_FREEDOM) {
                out.push(Violation::new(
                    relpath,
                    i + 1,
                    PANIC_FREEDOM,
                    format!(
                        "{tok} in dist/coordinator/util-json/runtime/linalg/serve \
                         without `// invariant:` justification"
                    ),
                ));
            }
        }
    }
}

/// Rule `logging`: raw print macros bypass the leveled logger; only the
/// logger itself and the CLI entry point may use them.
pub fn logging(relpath: &str, lines: &[Line], out: &mut Vec<Violation>) {
    if !relpath.starts_with("rust/src/") || LOGGING_ALLOWED.contains(&relpath) {
        return;
    }
    for (i, ln) in lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        for tok in ["println!", "eprintln!", "print!(", "eprint!("] {
            if ln.code.contains(tok) {
                if !source::suppressed(lines, i, LOGGING) {
                    out.push(Violation::new(
                        relpath,
                        i + 1,
                        LOGGING,
                        format!(
                            "raw {} outside util/log.rs — use util::log",
                            tok.trim_end_matches('(')
                        ),
                    ));
                }
                break;
            }
        }
    }
}

/// The identifier path segment immediately before a `.lock()` call at
/// byte `pos` — the "lock class" used as a graph node. A chained-call
/// receiver (`recorder().lock()`) has no identifier segment and yields
/// an empty class, which the caller skips.
fn lock_class(code: &str, pos: usize) -> String {
    let bytes = code.as_bytes();
    let mut j = pos;
    while j > 0 {
        let b = bytes[j - 1];
        if b.is_ascii_alphanumeric() || b == b'_' || b == b'.' || b == b':' {
            j -= 1;
        } else {
            break;
        }
    }
    code[j..pos]
        .replace("::", ".")
        .split('.')
        .filter(|s| !s.is_empty())
        .last()
        .map(str::to_string)
        .unwrap_or_default()
}

/// Rule `lock-order`: build the per-function lock acquisition graph
/// over [`LOCK_ORDER_FILES`] (edge a→b when a function acquires lock
/// class `b` while holding `a`) and report a cycle if one exists —
/// static deadlock detection for the dispatcher. At most one cycle is
/// reported per run; fixing it re-exposes any next one.
pub fn lock_order(files: &BTreeMap<String, Vec<Line>>, out: &mut Vec<Violation>) {
    // (a, b) -> the "file::fn" witnesses that acquire b while holding a
    let mut edges: BTreeMap<(String, String), Vec<String>> = BTreeMap::new();
    for (relpath, lines) in files {
        if !LOCK_ORDER_FILES.contains(&relpath.as_str()) {
            continue;
        }
        let mut cur_fn = String::from("?");
        let mut acquired: Vec<String> = Vec::new();
        for ln in lines {
            if ln.in_test {
                continue;
            }
            let code = &ln.code;
            if let Some(p) = code.find("fn ") {
                let boundary = p == 0 || {
                    let b = code.as_bytes()[p - 1];
                    !(b.is_ascii_alphanumeric() || b == b'_')
                };
                if boundary {
                    let name: String = code[p + 3..]
                        .chars()
                        .take_while(|c| c.is_alphanumeric() || *c == '_')
                        .collect();
                    if !name.is_empty() {
                        cur_fn = name;
                        acquired.clear();
                    }
                }
            }
            let mut start = 0usize;
            while let Some(off) = code[start..].find(".lock()") {
                let p = start + off;
                let cls = lock_class(code, p);
                if !cls.is_empty() {
                    for prev in &acquired {
                        if prev != &cls {
                            edges
                                .entry((prev.clone(), cls.clone()))
                                .or_default()
                                .push(format!("{relpath}::{cur_fn}"));
                        }
                    }
                    acquired.push(cls);
                }
                start = p + ".lock()".len();
            }
        }
    }

    let mut graph: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    let mut nodes: BTreeSet<&str> = BTreeSet::new();
    for (a, b) in edges.keys() {
        graph.entry(a.as_str()).or_default().insert(b.as_str());
        nodes.insert(a.as_str());
        nodes.insert(b.as_str());
    }
    let mut color: BTreeMap<&str, u8> = nodes.iter().map(|n| (*n, 0u8)).collect();
    let mut stack: Vec<&str> = Vec::new();
    for &n in &nodes {
        let white = color.get(n).copied().unwrap_or(0) == 0;
        if white && dfs_cycle(n, &graph, &mut color, &mut stack, &edges, out) {
            break;
        }
    }
}

const GRAY: u8 = 1;
const BLACK: u8 = 2;

fn dfs_cycle<'a>(
    n: &'a str,
    graph: &BTreeMap<&'a str, BTreeSet<&'a str>>,
    color: &mut BTreeMap<&'a str, u8>,
    stack: &mut Vec<&'a str>,
    edges: &BTreeMap<(String, String), Vec<String>>,
    out: &mut Vec<Violation>,
) -> bool {
    color.insert(n, GRAY);
    stack.push(n);
    let succs: Vec<&str> = graph.get(n).map(|s| s.iter().copied().collect()).unwrap_or_default();
    for m in succs {
        let cm = color.get(m).copied().unwrap_or(0);
        if cm == GRAY {
            let k = stack.iter().position(|x| *x == m).unwrap_or(0);
            let mut cyc: Vec<&str> = stack[k..].to_vec();
            cyc.push(m);
            let mut fns: BTreeSet<&str> = BTreeSet::new();
            for w in cyc.windows(2) {
                if let Some(v) = edges.get(&(w[0].to_string(), w[1].to_string())) {
                    for f in v {
                        fns.insert(f.as_str());
                    }
                }
            }
            let via: Vec<&str> = fns.into_iter().collect();
            out.push(Violation::new(
                "rust/src/dist/tcp.rs",
                1,
                LOCK_ORDER,
                format!(
                    "lock acquisition cycle: {} (via {})",
                    cyc.join(" -> "),
                    via.join(", ")
                ),
            ));
            return true;
        }
        if cm == 0 && dfs_cycle(m, graph, color, stack, edges, out) {
            return true;
        }
    }
    stack.pop();
    color.insert(n, BLACK);
    false
}

/// A string literal that plausibly names a wire field: starts with an
/// ASCII lowercase letter, continues with lowercase/digit/`_`/`-`.
fn is_wire_token(s: &str) -> bool {
    match s.chars().next() {
        Some(c) if c.is_ascii_lowercase() => s
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '-'),
        _ => false,
    }
}

/// Does the doc mention the token as `` `tok` `` or `"tok"`?
fn doc_mentions(doc: &str, tok: &str) -> bool {
    doc.contains(&format!("`{tok}`")) || doc.contains(&format!("\"{tok}\""))
}

/// Rule `protocol-doc`: keep docs/PROTOCOL.md and the wire code from
/// drifting apart. Forward: every wire-token string literal in
/// [`PROTOCOL_FILES`] must be documented (tcp.rs trace literals may
/// alternatively live in docs/OBSERVABILITY.md). Reverse: every row of
/// the doc's wire field registry must still appear in the code. Plus:
/// `PROTOCOL_VERSION` must match the version stated in the doc title.
pub fn protocol_doc(files: &BTreeMap<String, Vec<Line>>, root: &Path, out: &mut Vec<Violation>) {
    let proto = match std::fs::read_to_string(root.join("docs/PROTOCOL.md")) {
        Ok(s) => s,
        Err(_) => {
            out.push(Violation::new(
                "docs/PROTOCOL.md",
                1,
                PROTOCOL_DOC,
                "docs/PROTOCOL.md missing",
            ));
            return;
        }
    };
    let obs = std::fs::read_to_string(root.join("docs/OBSERVABILITY.md")).unwrap_or_default();

    let mut code_lits: BTreeSet<&str> = BTreeSet::new();
    for relpath in PROTOCOL_FILES {
        let Some(lines) = files.get(relpath) else { continue };
        for (i, ln) in lines.iter().enumerate() {
            if ln.in_test {
                continue;
            }
            for s in &ln.strings {
                if !is_wire_token(s) {
                    continue;
                }
                code_lits.insert(s.as_str());
                let ok = doc_mentions(&proto, s)
                    || (relpath == "rust/src/dist/tcp.rs" && doc_mentions(&obs, s));
                if !ok && !source::suppressed(lines, i, PROTOCOL_DOC) {
                    out.push(Violation::new(
                        relpath,
                        i + 1,
                        PROTOCOL_DOC,
                        format!("wire token \"{s}\" is not documented in docs/PROTOCOL.md"),
                    ));
                }
            }
        }
    }

    let mut in_registry = false;
    for (i, line) in proto.split('\n').enumerate() {
        if line.starts_with("## ") && line.to_lowercase().contains("field registry") {
            in_registry = true;
            continue;
        }
        if in_registry && line.starts_with("## ") {
            in_registry = false;
        }
        if in_registry && line.starts_with("| `") {
            if let Some(e) = line[3..].find('`') {
                let tok = &line[3..3 + e];
                if !tok.is_empty() && !code_lits.contains(tok) {
                    out.push(Violation::new(
                        "docs/PROTOCOL.md",
                        i + 1,
                        PROTOCOL_DOC,
                        format!("registry field `{tok}` no longer appears in the wire code"),
                    ));
                }
            }
        }
    }

    let mut code_ver: Option<u64> = None;
    if let Some(lines) = files.get("rust/src/dist/protocol.rs") {
        let tag = "PROTOCOL_VERSION: usize = ";
        for ln in lines {
            if let Some(p) = ln.code.find(tag) {
                let num: String = ln.code[p + tag.len()..]
                    .chars()
                    .take_while(char::is_ascii_digit)
                    .collect();
                if !num.is_empty() {
                    code_ver = num.parse().ok();
                }
            }
        }
    }
    let mut doc_ver: Option<u64> = None;
    let first = proto.split('\n').next().unwrap_or("");
    if let Some(p) = first.find("version ") {
        let num: String = first[p + "version ".len()..]
            .chars()
            .take_while(char::is_ascii_digit)
            .collect();
        if !num.is_empty() {
            doc_ver = num.parse().ok();
        }
    }
    if let (Some(cv), Some(dv)) = (code_ver, doc_ver) {
        if cv != dv {
            out.push(Violation::new(
                "docs/PROTOCOL.md",
                1,
                PROTOCOL_DOC,
                format!("PROTOCOL_VERSION is {cv} but the doc title says version {dv}"),
            ));
        }
    }
    if doc_ver.is_none() {
        out.push(Violation::new(
            "docs/PROTOCOL.md",
            1,
            PROTOCOL_DOC,
            "doc title does not state a protocol version",
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pp(src: &str) -> Vec<Line> {
        source::preprocess(src)
    }

    /// Run every per-file rule over one fixture.
    fn lint_one(relpath: &str, src: &str) -> Vec<Violation> {
        let lines = pp(src);
        let mut out = Vec::new();
        check_suppressions(relpath, &lines, &mut out);
        nan_ordering(relpath, &lines, &mut out);
        relaxed_atomics(relpath, &lines, &mut out);
        panic_freedom(relpath, &lines, &mut out);
        logging(relpath, &lines, &mut out);
        out
    }

    fn rules_of(v: &[Violation]) -> Vec<&'static str> {
        v.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn nan_ordering_catches_the_thrice_fixed_bug_class() {
        let bad = "let c = a.partial_cmp(&b);";
        assert_eq!(rules_of(&lint_one("rust/src/a.rs", bad)), vec![NAN_ORDERING]);
        let good = "let c = a.total_cmp(&b);";
        assert!(lint_one("rust/src/a.rs", good).is_empty());
        let absorb = "let m = xs.iter().cloned().fold(0.0, f64::max);";
        assert_eq!(rules_of(&lint_one("benches/b.rs", absorb)), vec![NAN_ORDERING]);
        // identifier tails are not the token
        assert!(lint_one("rust/src/a.rs", "let m = f64::max_value();").is_empty());
        // sort_by is fine when the comparator uses total_cmp nearby
        let sorted_ok = "xs.sort_by(|a, b| {\n    a.total_cmp(b)\n});";
        assert!(lint_one("rust/src/a.rs", sorted_ok).is_empty());
        let sorted_bad = "xs.sort_by(|a, b| a.cmp(b));";
        assert_eq!(rules_of(&lint_one("rust/src/a.rs", sorted_bad)), vec![NAN_ORDERING]);
    }

    #[test]
    fn nan_ordering_applies_to_test_code_too() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t(a: f64, b: f64) { a.partial_cmp(&b); }\n}";
        assert_eq!(rules_of(&lint_one("rust/src/a.rs", src)), vec![NAN_ORDERING]);
    }

    #[test]
    fn nan_ordering_in_strings_and_comments_is_harmless() {
        let src = "// mentions partial_cmp and f64::max freely\nlet s = \"uses .partial_cmp( and .sort_by(\";";
        assert!(lint_one("rust/src/a.rs", src).is_empty());
    }

    #[test]
    fn relaxed_atomics_needs_an_adjacent_justification() {
        let bad = "c.fetch_add(1, Ordering::Relaxed);";
        assert_eq!(rules_of(&lint_one("rust/src/c.rs", bad)), vec![RELAXED_ATOMICS]);
        let good = "// relaxed: monotone counter, read only for reporting\nc.fetch_add(1, Ordering::Relaxed);";
        assert!(lint_one("rust/src/c.rs", good).is_empty());
        // multi-line comment blocks above the site count
        let block = "// relaxed: monotone counter,\n// read only for reporting\nc.fetch_add(1, Ordering::Relaxed);";
        assert!(lint_one("rust/src/c.rs", block).is_empty());
        // out of scope: benches and test regions
        assert!(lint_one("benches/c.rs", bad).is_empty());
        let test_code = "#[cfg(test)]\nmod tests {\n    fn t() { c.load(Ordering::Relaxed); }\n}";
        assert!(lint_one("rust/src/c.rs", test_code).is_empty());
    }

    #[test]
    fn panic_freedom_guards_dist_coordinator_and_util_json_only() {
        let bad = "let v = maybe.unwrap();";
        assert_eq!(rules_of(&lint_one("rust/src/dist/d.rs", bad)), vec![PANIC_FREEDOM]);
        assert_eq!(
            rules_of(&lint_one("rust/src/coordinator/d.rs", bad)),
            vec![PANIC_FREEDOM]
        );
        assert_eq!(
            rules_of(&lint_one("rust/src/util/json/lazy.rs", bad)),
            vec![PANIC_FREEDOM]
        );
        // the compute substrate joined the scope with the engine refactor:
        // these paths run under every worker-served oracle call
        assert_eq!(
            rules_of(&lint_one("rust/src/runtime/engine.rs", bad)),
            vec![PANIC_FREEDOM]
        );
        assert_eq!(
            rules_of(&lint_one("rust/src/linalg/block.rs", bad)),
            vec![PANIC_FREEDOM]
        );
        // the job service joined with `hss serve`: a panic there takes
        // every tenant down at once
        assert_eq!(
            rules_of(&lint_one("rust/src/serve/http.rs", bad)),
            vec![PANIC_FREEDOM]
        );
        assert!(lint_one("rust/src/algorithms/d.rs", bad).is_empty());
        assert!(lint_one("rust/src/util/rng.rs", bad).is_empty());
        let justified = "// invariant: key inserted two lines up\nlet v = maybe.unwrap();";
        assert!(lint_one("rust/src/dist/d.rs", justified).is_empty());
        let expects = "let v = maybe.expect(\"always set\");\nworkers.iter().for_each(|w| panic!());";
        let got = lint_one("rust/src/dist/d.rs", expects);
        assert_eq!(rules_of(&got), vec![PANIC_FREEDOM, PANIC_FREEDOM]);
    }

    #[test]
    fn logging_is_confined_to_the_logger_and_the_cli() {
        let bad = "println!(\"done\");";
        assert_eq!(rules_of(&lint_one("rust/src/foo.rs", bad)), vec![LOGGING]);
        assert!(lint_one("rust/src/util/log.rs", bad).is_empty());
        assert!(lint_one("rust/src/main.rs", bad).is_empty());
        let e = "eprintln!(\"{x}\");";
        let got = lint_one("rust/src/foo.rs", e);
        assert_eq!(rules_of(&got), vec![LOGGING]);
        // one finding per line even when several macros appear
        let two = "print!(\"a\"); println!(\"b\");";
        assert_eq!(rules_of(&lint_one("rust/src/foo.rs", two)), vec![LOGGING]);
    }

    #[test]
    fn suppression_grammar_is_validated() {
        let unknown = "// lint:allow(bogus-rule): hmm\nlet x = 1;";
        let got = lint_one("rust/src/s.rs", unknown);
        assert_eq!(rules_of(&got), vec![SUPPRESSION]);
        assert!(got[0].msg.contains("bogus-rule"));
        let reasonless = "// lint:allow(logging):\nprintln!(\"x\");";
        let got = lint_one("rust/src/s.rs", reasonless);
        // the reasonless marker is a finding AND does not suppress
        assert!(rules_of(&got).contains(&SUPPRESSION));
        assert!(rules_of(&got).contains(&LOGGING));
        let justified = "// lint:allow(logging): progress output is this path's artifact\nprintln!(\"x\");";
        assert!(lint_one("rust/src/s.rs", justified).is_empty());
    }

    #[test]
    fn lock_class_takes_the_last_path_segment() {
        let code = "let st = fleet.state.lock();";
        let p = code.find(".lock()").unwrap();
        assert_eq!(lock_class(code, p), "state");
        let chained = "let r = recorder().lock();";
        let p = chained.find(".lock()").unwrap();
        assert_eq!(lock_class(chained, p), "");
    }

    fn lock_files(src: &str) -> BTreeMap<String, Vec<Line>> {
        let mut m = BTreeMap::new();
        m.insert("rust/src/dist/tcp.rs".to_string(), pp(src));
        m
    }

    #[test]
    fn opposite_acquisition_orders_are_a_cycle() {
        let src = "fn ab(s: &S) {\n    let a = s.alpha.lock();\n    let b = s.beta.lock();\n}\nfn ba(s: &S) {\n    let b = s.beta.lock();\n    let a = s.alpha.lock();\n}\n";
        let mut out = Vec::new();
        lock_order(&lock_files(src), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, LOCK_ORDER);
        assert!(out[0].msg.contains("alpha -> beta -> alpha"), "{}", out[0].msg);
        assert!(out[0].msg.contains("::ab") && out[0].msg.contains("::ba"), "{}", out[0].msg);
    }

    #[test]
    fn consistent_acquisition_orders_are_clean() {
        let src = "fn ab(s: &S) {\n    let a = s.alpha.lock();\n    let b = s.beta.lock();\n}\nfn also_ab(s: &S) {\n    let a = s.alpha.lock();\n    let b = s.beta.lock();\n}\n";
        let mut out = Vec::new();
        lock_order(&lock_files(src), &mut out);
        assert!(out.is_empty(), "{out:?}");
        // files outside the lock-order scope are ignored entirely
        let mut m = BTreeMap::new();
        m.insert(
            "rust/src/other.rs".to_string(),
            pp("fn ab(s: &S) { s.alpha.lock(); s.beta.lock(); }\nfn ba(s: &S) { s.beta.lock(); s.alpha.lock(); }"),
        );
        let mut out = Vec::new();
        lock_order(&m, &mut out);
        assert!(out.is_empty());
    }

    fn fake_root(name: &str, protocol_md: &str) -> std::path::PathBuf {
        let root = std::env::temp_dir().join(format!("hss-lint-unit-{name}-{}", std::process::id()));
        std::fs::create_dir_all(root.join("docs")).unwrap();
        std::fs::write(root.join("docs/PROTOCOL.md"), protocol_md).unwrap();
        root
    }

    #[test]
    fn protocol_doc_checks_both_directions_and_the_version() {
        let root = fake_root(
            "proto",
            "# fake wire protocol — version 3\n\nThe `documented` field.\n\n## Appendix A — wire field registry\n\n| `documented` | field | ok |\n| `ghost` | field | removed from code |\n",
        );
        let mut files = BTreeMap::new();
        files.insert(
            "rust/src/dist/protocol.rs".to_string(),
            pp("pub const PROTOCOL_VERSION: usize = 4;\npub const A: &str = \"documented\";\npub const B: &str = \"undocumented_knob\";\n"),
        );
        let mut out = Vec::new();
        protocol_doc(&files, &root, &mut out);
        let _ = std::fs::remove_dir_all(&root);
        assert!(out.iter().any(|v| v.msg.contains("undocumented_knob")), "{out:?}");
        assert!(out.iter().any(|v| v.msg.contains("`ghost`")), "{out:?}");
        assert!(
            out.iter().any(|v| v.msg.contains("PROTOCOL_VERSION is 4")),
            "{out:?}"
        );
        assert!(!out.iter().any(|v| v.msg.contains("\"documented\"")), "{out:?}");
    }

    #[test]
    fn protocol_doc_reports_a_missing_doc() {
        let root = std::env::temp_dir().join(format!("hss-lint-unit-nodoc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).unwrap();
        let files = BTreeMap::new();
        let mut out = Vec::new();
        protocol_doc(&files, &root, &mut out);
        let _ = std::fs::remove_dir_all(&root);
        assert_eq!(rules_of(&out), vec![PROTOCOL_DOC]);
        assert!(out[0].msg.contains("missing"));
    }

    #[test]
    fn wire_tokens_are_lowercase_snake_or_kebab() {
        assert!(is_wire_token("dataset_hits"));
        assert!(is_wire_token("round-trip2"));
        assert!(!is_wire_token("CamelCase"));
        assert!(!is_wire_token("9starts_with_digit"));
        assert!(!is_wire_token(""));
        assert!(!is_wire_token("has space"));
    }
}

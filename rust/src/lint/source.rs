//! Line/token-level source model for `hss lint`.
//!
//! The analyzer never parses Rust properly — it preprocesses each file
//! into a per-line view that is just structured enough for the rules in
//! [`super::rules`] to be reliable:
//!
//! * **`code`** — the line with comments stripped and string-literal
//!   *contents* blanked to spaces (delimiters kept, lengths preserved,
//!   so byte offsets into `code` line up with the original line). Rules
//!   match tokens here, which is what makes a mention of a forbidden
//!   token inside a string or a comment harmless.
//! * **`comment`** — the text after a `//` (line, doc or inner-doc)
//!   comment, where justification tags (`relaxed:`, `invariant:`) and
//!   suppressions live.
//! * **`strings`** — the recorded contents of string literals opened on
//!   the line (the protocol-doc rule reads wire field names from these).
//! * **`in_test`** — whether the line sits inside a `#[cfg(test)]`
//!   brace region; most rules skip test code.
//!
//! The scanner is a small state machine carried across lines: block
//! comments nest, normal strings and raw strings (`r"…"`, `r#"…"#`,
//! `br"…"`) may span lines, and char literals (`'"'`, `'\''`) are
//! skipped wholesale so a quote inside one can never open a string.

/// One preprocessed source line. See the module docs for field
/// semantics.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Code text: comments stripped, string contents blanked to spaces.
    pub code: String,
    /// Text after `//` on this line (empty when there is no comment).
    pub comment: String,
    /// True inside a `#[cfg(test)]` brace region.
    pub in_test: bool,
    /// Contents of string literals opened on this line (per-line
    /// fragments for literals that span lines).
    pub strings: Vec<String>,
}

/// Preprocess a whole file into per-line [`Line`] records.
pub fn preprocess(text: &str) -> Vec<Line> {
    let mut lines: Vec<Line> = Vec::new();
    let mut in_block: usize = 0; // block-comment nesting depth
    let mut in_str = false; // inside a normal "…" string
    let mut in_raw: i32 = -1; // >= 0: inside a raw string, value = hash count

    for raw_line in text.split('\n') {
        let raw: Vec<char> = raw_line.chars().collect();
        let n = raw.len();
        let mut code = String::new();
        let mut comment = String::new();
        let mut strings: Vec<String> = Vec::new();
        let mut cur = String::new();
        let mut i = 0usize;
        while i < n {
            let c = raw[i];
            if in_block > 0 {
                if c == '*' && i + 1 < n && raw[i + 1] == '/' {
                    in_block -= 1;
                    i += 2;
                    code.push_str("  ");
                } else if c == '/' && i + 1 < n && raw[i + 1] == '*' {
                    in_block += 1;
                    i += 2;
                    code.push_str("  ");
                } else {
                    code.push(' ');
                    i += 1;
                }
                continue;
            }
            if in_raw >= 0 {
                let h = in_raw as usize;
                if c == '"' && i + 1 + h <= n && (0..h).all(|t| raw[i + 1 + t] == '#') {
                    strings.push(std::mem::take(&mut cur));
                    code.push('"');
                    for _ in 0..h {
                        code.push('#');
                    }
                    i += 1 + h;
                    in_raw = -1;
                } else {
                    cur.push(c);
                    code.push(' ');
                    i += 1;
                }
                continue;
            }
            if in_str {
                if c == '\\' && i + 1 < n {
                    cur.push(c);
                    cur.push(raw[i + 1]);
                    code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    strings.push(std::mem::take(&mut cur));
                    in_str = false;
                    code.push('"');
                    i += 1;
                } else {
                    cur.push(c);
                    code.push(' ');
                    i += 1;
                }
                continue;
            }
            if c == '/' && i + 1 < n && raw[i + 1] == '/' {
                comment = raw[i + 2..].iter().collect();
                break;
            }
            if c == '/' && i + 1 < n && raw[i + 1] == '*' {
                in_block += 1;
                code.push_str("  ");
                i += 2;
                continue;
            }
            if c == '"' {
                in_str = true;
                code.push('"');
                i += 1;
                continue;
            }
            // raw string openers: r"…", r#"…"#, br"…" (the prefix must
            // not be the tail of an identifier)
            if (c == 'r' || c == 'b') && (i == 0 || !(raw[i - 1].is_alphanumeric() || raw[i - 1] == '_'))
            {
                let mut j = i + 1;
                if c == 'b' && j < n && raw[j] == 'r' {
                    j += 1;
                }
                if c == 'r' || j > i + 1 {
                    let mut h = 0usize;
                    while j < n && raw[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && raw[j] == '"' {
                        in_raw = h as i32;
                        for t in i..=j {
                            code.push(raw[t]);
                        }
                        i = j + 1;
                        continue;
                    }
                }
            }
            // char literals 'x' / '\x': skip wholesale so '"' cannot
            // open a string (lifetimes like <'a> fall through harmlessly)
            if c == '\'' {
                if i + 3 < n && raw[i + 1] == '\\' && raw[i + 3] == '\'' {
                    code.push_str("    ");
                    i += 4;
                    continue;
                }
                if i + 2 < n && raw[i + 1] != '\\' && raw[i + 1] != '\'' && raw[i + 2] == '\'' {
                    code.push_str("   ");
                    i += 3;
                    continue;
                }
            }
            code.push(c);
            i += 1;
        }
        if (in_str || in_raw >= 0) && !cur.is_empty() {
            // a string literal continues onto the next line: record the
            // fragment opened on this one
            strings.push(cur);
        }
        lines.push(Line { code, comment, in_test: false, strings });
    }

    mark_test_regions(&mut lines);
    lines
}

/// Mark `#[cfg(test)]` brace regions. The attribute arms a pending
/// flag; the next `{` opens a test region at that depth, and the region
/// closes when the depth unwinds back past it.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut test_stack: Vec<i64> = Vec::new();
    for ln in lines.iter_mut() {
        let started_in = !test_stack.is_empty();
        if ln.code.contains("#[cfg(test)]") {
            pending = true;
        }
        for c in ln.code.chars() {
            if c == '{' {
                if pending {
                    test_stack.push(depth);
                    pending = false;
                }
                depth += 1;
            } else if c == '}' {
                depth -= 1;
                if let Some(&top) = test_stack.last() {
                    if depth == top {
                        test_stack.pop();
                    }
                }
            }
        }
        ln.in_test = started_in || !test_stack.is_empty();
    }
}

/// Line `idx` itself plus the contiguous block of comment-only lines
/// immediately above it — the region where a justification or
/// suppression for a finding on `idx` may live.
pub fn adjacent_comment_lines(lines: &[Line], idx: usize) -> Vec<usize> {
    let mut v = vec![idx];
    let mut j = idx;
    while j > 0 {
        j -= 1;
        if lines[j].code.trim().is_empty() && !lines[j].comment.is_empty() {
            v.push(j);
        } else {
            break;
        }
    }
    v
}

/// Is a justification tag (`relaxed:` / `invariant:`) with nonempty
/// trailing text present on line `idx` or in the comment block above?
pub fn comment_has(lines: &[Line], idx: usize, tag: &str) -> bool {
    adjacent_comment_lines(lines, idx).into_iter().any(|j| {
        let c = &lines[j].comment;
        match c.find(tag) {
            Some(p) => !c[p + tag.len()..].trim().is_empty(),
            None => false,
        }
    })
}

const ALLOW_OPEN: &str = "lint:allow(";

/// Parsed pieces of a `lint:allow` marker found in a comment: the rule
/// name between the parens and the tail after the closing paren.
pub struct Allow<'a> {
    pub rule: &'a str,
    pub tail: &'a str,
}

/// Find a `lint:allow` marker in a comment, if any. Returns `None`
/// when the comment has no marker at all, `Some(Err(line_msg))` when the
/// marker is malformed (no closing paren), and `Some(Ok(allow))` with
/// the rule/tail split otherwise. Validation of the rule name and
/// reason is the caller's job.
pub fn parse_allow(comment: &str) -> Option<std::result::Result<Allow<'_>, &'static str>> {
    let p = comment.find(ALLOW_OPEN)?;
    let rest = &comment[p + ALLOW_OPEN.len()..];
    let q = match rest.find(')') {
        Some(q) => q,
        None => return Some(Err("malformed lint:allow (no closing paren)")),
    };
    Some(Ok(Allow { rule: rest[..q].trim(), tail: rest[q + 1..].trim() }))
}

/// Does `tail` (the text after the closing paren) carry a written
/// reason, i.e. `: <nonempty>`?
pub fn allow_has_reason(tail: &str) -> bool {
    match tail.strip_prefix(':') {
        Some(reason) => !reason.trim().is_empty(),
        None => false,
    }
}

/// Is a finding of `rule` on line `idx` suppressed by a well-formed,
/// reason-carrying `lint:allow` on the line or in the comment block
/// above? Malformed or reason-less markers never suppress — they are
/// themselves reported by the `suppression` rule.
pub fn suppressed(lines: &[Line], idx: usize, rule: &str) -> bool {
    for j in adjacent_comment_lines(lines, idx) {
        if let Some(Ok(allow)) = parse_allow(&lines[j].comment) {
            if allow.rule == rule && allow_has_reason(allow.tail) {
                return true;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn string_contents_are_blanked_but_delimiters_kept() {
        let lines = preprocess("let x = \"panic! inside\"; call();");
        assert_eq!(lines.len(), 1);
        assert!(!lines[0].code.contains("panic!"));
        assert!(lines[0].code.contains("call()"));
        assert_eq!(lines[0].code.len(), "let x = \"panic! inside\"; call();".len());
        assert_eq!(lines[0].strings, vec!["panic! inside".to_string()]);
    }

    #[test]
    fn line_comments_are_captured_and_stripped() {
        let lines = preprocess("foo(); // trailing note");
        assert!(lines[0].code.contains("foo()"));
        assert!(!lines[0].code.contains("trailing"));
        assert_eq!(lines[0].comment, " trailing note");
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let lines = preprocess("a(); /* one /* two */ still */ b();\n/* open\nclose */ c();");
        assert!(lines[0].code.contains("a()"));
        assert!(lines[0].code.contains("b()"));
        assert!(!lines[0].code.contains("two"));
        assert!(!lines[1].code.contains("open"));
        assert!(!lines[2].code.contains("close"));
        assert!(lines[2].code.contains("c()"));
    }

    #[test]
    fn raw_strings_with_hashes_are_blanked() {
        let lines = preprocess("let r = r#\"quote \" inside\"#; after();");
        assert!(lines[0].code.contains("after()"));
        assert!(!lines[0].code.contains("inside"));
        assert_eq!(lines[0].strings, vec!["quote \" inside".to_string()]);
    }

    #[test]
    fn identifier_tails_do_not_open_raw_strings() {
        // `var` ends in r but the following string is a normal one
        let lines = preprocess("var(\"content\");");
        assert_eq!(lines[0].strings, vec!["content".to_string()]);
    }

    #[test]
    fn char_literal_quote_cannot_open_a_string() {
        let lines = preprocess("if c == '\"' { panic!(\"q\") }");
        // the " inside the char literal must not flip string state:
        // panic! is real code here and survives into `code`
        assert!(lines[0].code.contains("panic!"));
        assert_eq!(lines[0].strings, vec!["q".to_string()]);
    }

    #[test]
    fn multi_line_strings_record_per_line_fragments() {
        let lines = preprocess("let s = \"first\nsecond\";\ntail();");
        assert_eq!(lines[0].strings, vec!["first".to_string()]);
        assert_eq!(lines[1].strings, vec!["second".to_string()]);
        assert!(lines[2].code.contains("tail()"));
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}";
        let lines = preprocess(src);
        assert!(!lines[0].in_test);
        assert!(lines[2].in_test);
        assert!(lines[3].in_test);
        assert!(lines[4].in_test);
        assert!(!lines[5].in_test);
    }

    #[test]
    fn adjacency_walks_contiguous_comment_blocks_only() {
        let src = "code();\n// a\n// b\ntarget();";
        let lines = preprocess(src);
        let adj = adjacent_comment_lines(&lines, 3);
        assert_eq!(adj, vec![3, 2, 1]);
        // a code line breaks the block
        let src2 = "// a\ncode();\ntarget();";
        let lines2 = preprocess(src2);
        assert_eq!(adjacent_comment_lines(&lines2, 2), vec![2]);
    }

    #[test]
    fn comment_has_requires_a_nonempty_reason() {
        let ok = preprocess("// relaxed: monotone counter\nx.load(o);");
        assert!(comment_has(&ok, 1, "relaxed:"));
        let empty = preprocess("// relaxed:\nx.load(o);");
        assert!(!comment_has(&empty, 1, "relaxed:"));
        let absent = preprocess("x.load(o);");
        assert!(!comment_has(&absent, 0, "relaxed:"));
    }

    #[test]
    fn suppression_requires_matching_rule_and_written_reason() {
        let good = preprocess("// lint:allow(logging): stdout is this tool's artifact\nx();");
        assert!(suppressed(&good, 1, "logging"));
        assert!(!suppressed(&good, 1, "nan-ordering"));
        let reasonless = preprocess("// lint:allow(logging):\nx();");
        assert!(!suppressed(&reasonless, 1, "logging"));
        let unclosed = preprocess("// lint:allow(logging without a paren\nx();");
        assert!(!suppressed(&unclosed, 1, "logging"));
    }
}
